"""Typed metrics instruments and a thread-safe registry.

Instruments are Counter (monotone), Gauge (settable, series removable)
and Histogram (fixed buckets, cumulative exposition).  Every instrument
lives in a :class:`MetricsRegistry`; components create their own
registry (so tests see isolated counters) while worker-level state (the
stage-latency histogram fed by tracing, the shared codec-bank cache)
lands in the process-wide :func:`default_registry`.

Naming convention -- enforced at registration time:

    repro_<subsystem>_<name>_<unit>

lowercase ``[a-z0-9_]`` tokens; the last token must be a recognized
unit (``total`` for counters, ``seconds``/``bytes``/... otherwise) so
names stay scrape-stable across PRs (see tests/test_obs_naming.py).
"""

from __future__ import annotations

import bisect
import math
import re
import threading

__all__ = [
    "ALLOWED_UNITS",
    "BPE_BUCKETS",
    "LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "default_registry",
    "render_registries",
    "validate_name",
]

# log-spaced 100us .. 10s: covers a no-op span through a full serve run
LATENCY_BUCKETS = (0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005,
                   0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)
# bits/element of the coded split stream: 0.25 .. 16 (bf16 passthrough)
BPE_BUCKETS = (0.25, 0.5, 0.75, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, 8.0,
               12.0, 16.0)

# last name token must be one of these (counters additionally must end
# in _total, the Prometheus convention for monotone series)
ALLOWED_UNITS = frozenset({
    "total", "seconds", "bytes", "bits", "elements", "chunks", "count",
    "bpe", "ratio", "info",
})

_NAME_RE = re.compile(r"^repro(_[a-z][a-z0-9]*)+$")
_LABEL_RE = re.compile(r"^[a-z][a-z0-9_]*$")


def validate_name(name: str, kind: str) -> None:
    """Raise ValueError unless ``name`` follows the naming convention."""
    if not _NAME_RE.match(name):
        raise ValueError(
            f"instrument name {name!r} violates repro_<subsystem>_<name>_"
            f"<unit> (lowercase, underscore-separated, 'repro_' prefix)")
    tokens = name.split("_")
    if len(tokens) < 3:
        raise ValueError(f"instrument name {name!r} needs at least "
                         "repro_<subsystem>_<unit>")
    unit = tokens[-1]
    if unit not in ALLOWED_UNITS:
        raise ValueError(f"instrument name {name!r} ends in unknown unit "
                         f"{unit!r}; allowed: {sorted(ALLOWED_UNITS)}")
    if kind == "counter" and unit != "total":
        raise ValueError(f"counter {name!r} must end in _total")
    if kind != "counter" and unit == "total":
        raise ValueError(f"{kind} {name!r} must not end in _total "
                         "(reserved for counters)")


def _escape_label(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r'\"')
            .replace("\n", r"\n"))


def _escape_help(text: str) -> str:
    return text.replace("\\", r"\\").replace("\n", r"\n")


def _fmt(value: float) -> str:
    if value == math.inf:
        return "+Inf"
    if value == -math.inf:
        return "-Inf"
    f = float(value)
    return repr(int(f)) if f == int(f) and abs(f) < 1e15 else repr(f)


class _Instrument:
    """Base: a named family of label series sharing one lock."""

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple[str, ...] = ()):
        validate_name(name, self.kind)
        for ln in labelnames:
            if not _LABEL_RE.match(ln):
                raise ValueError(f"bad label name {ln!r} on {name}")
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = threading.Lock()
        self._series: dict[tuple[str, ...], object] = {}

    def _key(self, labels: dict[str, object]) -> tuple[str, ...]:
        if set(labels) != set(self.labelnames):
            raise ValueError(
                f"{self.name}: got labels {sorted(labels)}, "
                f"declared {sorted(self.labelnames)}")
        return tuple(str(labels[ln]) for ln in self.labelnames)

    def remove(self, **labels) -> bool:
        """Drop one label series (e.g. on session eviction)."""
        with self._lock:
            return self._series.pop(self._key(labels), None) is not None

    def clear(self) -> None:
        with self._lock:
            self._series.clear()

    def series(self) -> dict[tuple[str, ...], object]:
        with self._lock:
            return dict(self._series)

    # exposition -------------------------------------------------------
    def _render_series(self, out: list[str]) -> None:
        raise NotImplementedError

    def render(self) -> str:
        out = [f"# HELP {self.name} {_escape_help(self.help)}",
               f"# TYPE {self.name} {self.kind}"]
        self._render_series(out)
        return "\n".join(out)

    def _labelstr(self, key: tuple[str, ...],
                  extra: tuple[tuple[str, str], ...] = ()) -> str:
        pairs = [f'{ln}="{_escape_label(lv)}"'
                 for ln, lv in zip(self.labelnames, key)]
        pairs += [f'{ln}="{_escape_label(lv)}"' for ln, lv in extra]
        return "{" + ",".join(pairs) + "}" if pairs else ""


class Counter(_Instrument):
    kind = "counter"

    def inc(self, amount: float = 1, **labels) -> None:
        if amount < 0:
            raise ValueError(f"{self.name}: counters only go up")
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), 0.0))

    def total(self) -> float:
        with self._lock:
            return float(sum(self._series.values()))

    def _render_series(self, out: list[str]) -> None:
        for key, val in sorted(self.series().items()):
            out.append(f"{self.name}{self._labelstr(key)} {_fmt(val)}")

    def snapshot(self) -> list[dict]:
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self.series().items())]


class Gauge(_Instrument):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[self._key(labels)] = float(value)

    def inc(self, amount: float = 1, **labels) -> None:
        key = self._key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(self._key(labels), 0.0))

    def _render_series(self, out: list[str]) -> None:
        for key, val in sorted(self.series().items()):
            out.append(f"{self.name}{self._labelstr(key)} {_fmt(val)}")

    def snapshot(self) -> list[dict]:
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self.series().items())]


class Histogram(_Instrument):
    """Fixed-bucket histogram; exposition uses cumulative ``le`` buckets."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "",
                 labelnames: tuple[str, ...] = (),
                 buckets: tuple[float, ...] = LATENCY_BUCKETS):
        super().__init__(name, help, labelnames)
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise ValueError(f"{name}: needs at least one bucket bound")
        self.buckets = bounds

    def observe(self, value: float, **labels) -> None:
        key = self._key(labels)
        idx = bisect.bisect_left(self.buckets, float(value))
        with self._lock:
            state = self._series.get(key)
            if state is None:
                state = [[0] * (len(self.buckets) + 1), 0.0, 0]
                self._series[key] = state
            state[0][idx] += 1
            state[1] += float(value)
            state[2] += 1

    def count(self, **labels) -> int:
        with self._lock:
            state = self._series.get(self._key(labels))
            return int(state[2]) if state else 0

    def sum(self, **labels) -> float:
        with self._lock:
            state = self._series.get(self._key(labels))
            return float(state[1]) if state else 0.0

    def quantile(self, q: float, **labels) -> float:
        """Bucket-midpoint estimate of the q-quantile (0 <= q <= 1)."""
        with self._lock:
            state = self._series.get(self._key(labels))
            if not state or state[2] == 0:
                return 0.0
            counts, _, n = state
            rank = q * n
            seen = 0
            for i, c in enumerate(counts):
                seen += c
                if seen >= rank and c:
                    lo = self.buckets[i - 1] if i else 0.0
                    hi = (self.buckets[i] if i < len(self.buckets)
                          else self.buckets[-1])
                    return 0.5 * (lo + hi)
            return self.buckets[-1]

    def _render_series(self, out: list[str]) -> None:
        for key, state in sorted(self.series().items()):
            counts, total, n = state
            cum = 0
            for bound, c in zip(self.buckets, counts):
                cum += c
                ls = self._labelstr(key, (("le", _fmt(bound)),))
                out.append(f"{self.name}_bucket{ls} {cum}")
            cum += counts[-1]
            ls = self._labelstr(key, (("le", "+Inf"),))
            out.append(f"{self.name}_bucket{ls} {cum}")
            out.append(f"{self.name}_sum{self._labelstr(key)} {_fmt(total)}")
            out.append(f"{self.name}_count{self._labelstr(key)} {n}")

    def snapshot(self) -> list[dict]:
        return [{"labels": dict(zip(self.labelnames, k)),
                 "count": s[2], "sum": s[1],
                 "buckets": dict(zip(map(_fmt, self.buckets), s[0]))}
                for k, s in sorted(self.series().items())]


class MetricsRegistry:
    """Get-or-create instrument store; thread-safe; renders Prometheus text."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _get_or_create(self, cls, name, help, labelnames, **kw):
        labelnames = tuple(labelnames)
        with self._lock:
            inst = self._instruments.get(name)
            if inst is not None:
                if not isinstance(inst, cls):
                    raise ValueError(f"{name} already registered as "
                                     f"{inst.kind}, not {cls.kind}")
                if inst.labelnames != labelnames:
                    raise ValueError(f"{name} already registered with labels "
                                     f"{inst.labelnames}, not {labelnames}")
                return inst
            inst = cls(name, help, labelnames, **kw)
            self._instruments[name] = inst
            return inst

    def counter(self, name: str, help: str = "",
                labelnames: tuple[str, ...] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: tuple[str, ...] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: tuple[str, ...] = (),
                  buckets: tuple[float, ...] = LATENCY_BUCKETS) -> Histogram:
        return self._get_or_create(Histogram, name, help, labelnames,
                                   buckets=buckets)

    def get(self, name: str) -> _Instrument | None:
        with self._lock:
            return self._instruments.get(name)

    def instruments(self) -> list[_Instrument]:
        with self._lock:
            return sorted(self._instruments.values(), key=lambda i: i.name)

    def clear_values(self) -> None:
        """Reset every series (tests / clear_bank_cache); names stay."""
        for inst in self.instruments():
            inst.clear()

    def render(self) -> str:
        parts = [inst.render() for inst in self.instruments()]
        return "\n".join(parts) + ("\n" if parts else "")

    def snapshot(self) -> dict:
        return {inst.name: {"type": inst.kind, "help": inst.help,
                            "series": inst.snapshot()}
                for inst in self.instruments()}


def render_registries(registries) -> str:
    """Concatenate several registries, skipping duplicate family names."""
    seen: set[str] = set()
    parts = []
    for reg in registries:
        for inst in reg.instruments():
            if inst.name in seen:
                continue
            seen.add(inst.name)
            parts.append(inst.render())
    return "\n".join(parts) + ("\n" if parts else "")


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    """Process-wide registry for worker-level instruments."""
    return _DEFAULT
