"""Span-based stage tracing for the split-inference tick pipeline.

A :class:`Tracer` hands out context-manager spans named after pipeline
stages (``calibrate``, ``fused_launch``, ``device_to_host``,
``host_unpack``, ``entropy_encode``, ``entropy_decode``, ``dequantize``,
``framing``, ``socket_write``, ``tick_drain``, ``tail``, ...).  Parent
links propagate through :mod:`contextvars`, so spans nest correctly
across the async server and worker threads.

Tracing is **off by default**: ``span()`` then returns a shared no-op
context manager, so instrumented hot paths pay only an attribute check
(the disabled-overhead benchmark gate in bench_transport.py holds this
to ~0%).  When enabled, each closed span

- appends a structured event ``{span_id, parent_id, stage, t_start,
  dur_s, **attrs}`` to a bounded in-memory deque (optionally mirrored to
  a JSONL file), and
- feeds ``repro_pipeline_stage_latency_seconds{stage=...}`` in the
  default metrics registry.

``REPRO_OBS_TRACE=1`` enables tracing at import; ``REPRO_OBS_JAX_TRACE=1``
additionally wraps the fused-encode megakernel dispatch in
``jax.profiler.TraceAnnotation`` so spans line up with XLA traces.
"""

from __future__ import annotations

import contextlib
import contextvars
import itertools
import json
import os
import threading
import time
from collections import deque

from .metrics import default_registry

__all__ = ["Span", "Tracer", "configure_tracing", "span", "tracer"]

_STAGE_HIST = "repro_pipeline_stage_latency_seconds"

_current_span: contextvars.ContextVar = contextvars.ContextVar(
    "repro_obs_current_span", default=None)


class _NullSpan:
    """Shared no-op span: the disabled-path cost is one enabled check."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NULL_SPAN = _NullSpan()


class Span:
    __slots__ = ("stage", "attrs", "span_id", "parent_id", "t_start",
                 "dur_s", "_tracer", "_token", "_t0")

    def __init__(self, tracer: "Tracer", stage: str, attrs: dict):
        self.stage = stage
        self.attrs = attrs
        self.span_id = next(tracer._ids)
        self.parent_id = None
        self.t_start = 0.0
        self.dur_s = 0.0
        self._tracer = tracer
        self._token = None
        self._t0 = 0.0

    def set(self, **attrs):
        self.attrs.update(attrs)
        return self

    def __enter__(self):
        parent = _current_span.get()
        self.parent_id = parent.span_id if parent is not None else None
        self._token = _current_span.set(self)
        self.t_start = time.time()
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb):
        self.dur_s = time.perf_counter() - self._t0
        if self._token is not None:
            _current_span.reset(self._token)
        if exc_type is not None:
            self.attrs["error"] = exc_type.__name__
        self._tracer._finish(self)
        return False


class Tracer:
    """Per-process tracer; use the module-level :func:`tracer` singleton."""

    def __init__(self, registry=None, max_events: int = 65536):
        self.enabled = False
        self.jax_trace = False
        self.events: deque[dict] = deque(maxlen=max_events)
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._event_path: str | None = None
        self._registry = registry or default_registry()
        self._hist = None

    # configuration ----------------------------------------------------
    def configure(self, enabled: bool | None = None,
                  event_log_path: str | None | type(...) = ...,
                  jax_trace: bool | None = None) -> "Tracer":
        if enabled is not None:
            self.enabled = bool(enabled)
        if event_log_path is not ...:
            self._event_path = event_log_path
        if jax_trace is not None:
            self.jax_trace = bool(jax_trace)
        if self.enabled and self._hist is None:
            self._hist = self._registry.histogram(
                _STAGE_HIST, "wall time per pipeline stage span",
                labelnames=("stage",))
        return self

    def reset(self) -> None:
        with self._lock:
            self.events.clear()

    # span API ---------------------------------------------------------
    def span(self, stage: str, **attrs):
        if not self.enabled:
            return _NULL_SPAN
        return Span(self, stage, attrs)

    def _finish(self, sp: Span) -> None:
        event = {"span_id": sp.span_id, "parent_id": sp.parent_id,
                 "stage": sp.stage, "t_start": sp.t_start,
                 "dur_s": sp.dur_s}
        if sp.attrs:
            event.update(sp.attrs)
        with self._lock:
            self.events.append(event)
            if self._event_path:
                try:
                    with open(self._event_path, "a") as fh:
                        fh.write(json.dumps(event) + "\n")
                except OSError:
                    self._event_path = None  # stop retrying a dead path
        if self._hist is not None:
            self._hist.observe(sp.dur_s, stage=sp.stage)

    # jax.profiler hook ------------------------------------------------
    def annotate(self, name: str):
        """TraceAnnotation ctx for the megakernel dispatch (opt-in)."""
        if not (self.enabled and self.jax_trace):
            return contextlib.nullcontext()
        try:
            from jax.profiler import TraceAnnotation
        except Exception:
            return contextlib.nullcontext()
        return TraceAnnotation(name)

    # analysis helpers -------------------------------------------------
    def snapshot_events(self) -> list[dict]:
        with self._lock:
            return list(self.events)

    def stage_totals(self, stages=None) -> dict[str, float]:
        """Summed duration per stage (optionally restricted to `stages`)."""
        totals: dict[str, float] = {}
        for ev in self.snapshot_events():
            st = ev["stage"]
            if stages is not None and st not in stages:
                continue
            totals[st] = totals.get(st, 0.0) + ev["dur_s"]
        return totals

    def dump_events(self, path: str) -> int:
        events = self.snapshot_events()
        with open(path, "w") as fh:
            json.dump({"events": events}, fh, indent=1)
        return len(events)


_TRACER = Tracer()
if os.environ.get("REPRO_OBS_TRACE", "") not in ("", "0"):
    _TRACER.configure(enabled=True)
if os.environ.get("REPRO_OBS_JAX_TRACE", "") not in ("", "0"):
    _TRACER.configure(enabled=True, jax_trace=True)


def tracer() -> Tracer:
    return _TRACER


def span(stage: str, **attrs):
    """Module-level convenience: ``with span("entropy_encode"): ...``."""
    return _TRACER.span(stage, **attrs)


def configure_tracing(enabled: bool | None = None,
                      event_log_path: str | None | type(...) = ...,
                      jax_trace: bool | None = None) -> Tracer:
    return _TRACER.configure(enabled, event_log_path, jax_trace)
