"""Prometheus text-format exposition over a minimal asyncio HTTP server.

No web framework: the scrape protocol is one GET and one response.
Routes:

- ``GET /metrics``  -- Prometheus text format 0.0.4 rendering every
  registry handed to the exposition (duplicate families skipped).
- ``GET /events``   -- the tracer's structured JSON span log.
- ``GET /healthz``  -- liveness probe.

Also provides :func:`parse_prometheus_text`, a small parser used by the
CI smoke job and tests to assert the scrape is well-formed.
"""

from __future__ import annotations

import asyncio
import json
import re

from .metrics import render_registries
from .tracing import tracer as _default_tracer

__all__ = ["MetricsExposition", "parse_prometheus_text"]

_SAMPLE_RE = re.compile(
    r'^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)'
    r'(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)\s*$')
_LABEL_RE = re.compile(r'([a-zA-Z_][a-zA-Z0-9_]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus_text(text: str) -> dict:
    """Parse exposition text into ``{family: {"type", "samples"}}``.

    Samples map ``(sample_name, frozenset(label items)) -> float``.
    Raises ValueError on a malformed line, so tests can assert the
    endpoint output is parseable.
    """
    families: dict[str, dict] = {}
    current = None
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            name = line.split(None, 3)[2]
            current = families.setdefault(
                name, {"type": "untyped", "samples": {}})
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            families.setdefault(
                parts[2], {"type": "untyped", "samples": {}})
            families[parts[2]]["type"] = parts[3]
            current = families[parts[2]]
            continue
        if line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if not m:
            raise ValueError(f"malformed exposition line: {line!r}")
        labels = frozenset(_LABEL_RE.findall(m.group("labels") or ""))
        value = float(m.group("value").replace("+Inf", "inf")
                      .replace("-Inf", "-inf"))
        sample_name = m.group("name")
        family = sample_name
        for suffix in ("_bucket", "_sum", "_count"):
            base = sample_name[:-len(suffix)] \
                if sample_name.endswith(suffix) else None
            if base and base in families:
                family = base
                break
        fam = families.setdefault(
            family, {"type": "untyped", "samples": {}})
        fam["samples"][(sample_name, labels)] = value
        current = fam
    return families


class MetricsExposition:
    """Serve ``/metrics`` + ``/events`` for a set of registries."""

    def __init__(self, registries, tracer=None,
                 host: str = "127.0.0.1", port: int = 0,
                 collectors=()):
        self.registries = list(registries)
        self.tracer = tracer if tracer is not None else _default_tracer()
        self.host = host
        self.port = port
        # zero-arg callables run before each render: pull-style sources
        # (cache stats, queue depths) sync their gauges at scrape time
        self.collectors = list(collectors)
        self._server: asyncio.AbstractServer | None = None

    def render(self) -> str:
        for collect in self.collectors:
            collect()
        return render_registries(self.registries)

    async def start(self) -> "MetricsExposition":
        self._server = await asyncio.start_server(
            self._handle, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        return self

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        try:
            request = await asyncio.wait_for(reader.readline(), timeout=5.0)
            parts = request.decode("latin-1", "replace").split()
            path = parts[1] if len(parts) >= 2 else "/"
            # drain (and ignore) the request headers
            while True:
                line = await asyncio.wait_for(reader.readline(), timeout=5.0)
                if line in (b"\r\n", b"\n", b""):
                    break
            if path.startswith("/metrics"):
                body = self.render().encode()
                ctype = "text/plain; version=0.0.4; charset=utf-8"
                status = "200 OK"
            elif path.startswith("/events"):
                body = json.dumps(
                    {"events": self.tracer.snapshot_events()}).encode()
                ctype = "application/json"
                status = "200 OK"
            elif path.startswith("/healthz"):
                body, ctype, status = b"ok\n", "text/plain", "200 OK"
            else:
                body, ctype, status = b"not found\n", "text/plain", \
                    "404 Not Found"
            writer.write((f"HTTP/1.1 {status}\r\n"
                          f"Content-Type: {ctype}\r\n"
                          f"Content-Length: {len(body)}\r\n"
                          "Connection: close\r\n\r\n").encode())
            writer.write(body)
            await writer.drain()
        except (asyncio.TimeoutError, ConnectionError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, OSError):
                pass
