"""Unified observability layer: metrics, stage tracing, exposition.

Dependency-free (stdlib only).  Three pieces:

- :mod:`repro.obs.metrics` -- a thread-safe :class:`MetricsRegistry` of
  typed Counter/Gauge/Histogram instruments with label support and
  Prometheus text-format rendering.  Instrument names follow the
  ``repro_<subsystem>_<name>_<unit>`` convention (enforced at
  registration; see ``validate_name``).
- :mod:`repro.obs.tracing` -- span-based stage tracing through the tick
  pipeline (calibrate, fused launch, device->host, entropy, framing,
  socket write, tick drain, tail inference).  Disabled by default; when
  enabled it emits a structured JSON event log and feeds the
  ``repro_pipeline_stage_latency_seconds`` histogram.
- :mod:`repro.obs.exposition` -- a minimal asyncio HTTP endpoint serving
  ``GET /metrics`` (Prometheus text 0.0.4) and ``GET /events`` (the JSON
  span log), plus a text-format parser for tests/CI.
"""

from .exposition import MetricsExposition, parse_prometheus_text
from .metrics import (
    BPE_BUCKETS,
    LATENCY_BUCKETS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    render_registries,
    validate_name,
)
from .tracing import Tracer, configure_tracing, span, tracer

__all__ = [
    "BPE_BUCKETS",
    "LATENCY_BUCKETS",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsExposition",
    "MetricsRegistry",
    "Tracer",
    "configure_tracing",
    "default_registry",
    "parse_prometheus_text",
    "render_registries",
    "span",
    "tracer",
    "validate_name",
]
