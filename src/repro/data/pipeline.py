"""Deterministic synthetic token pipeline with device-sharded delivery.

Production shape: host-side generation (here a seeded Zipf-ish sampler
standing in for tokenized shards), double-buffered prefetch onto devices
with the batch sharding, and exact resumability: the stream is a pure
function of (seed, step), so restoring a checkpoint at step k replays the
identical data order with no state files.
"""

from __future__ import annotations

import dataclasses
import threading
import queue
from typing import Iterator

import jax
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 17
    embed_dim: int = 0   # > 0: also emit frontend-stub embeddings


def _batch_at(cfg: DataConfig, step: int) -> dict[str, np.ndarray]:
    rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
    # Zipf-ish marginal so entropy-coding benchmarks see realistic skew
    z = rng.zipf(1.3, size=(cfg.batch, cfg.seq_len + 1))
    tokens = (z % cfg.vocab_size).astype(np.int32)
    out = {"tokens": tokens[:, : cfg.seq_len]}
    if cfg.embed_dim:
        out["inputs"] = rng.standard_normal(
            (cfg.batch, cfg.seq_len, cfg.embed_dim)).astype(np.float32)
    return out


def stream(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield _batch_at(cfg, step)
        step += 1


class PrefetchingLoader:
    """Background-thread prefetch + device_put with a target sharding."""

    def __init__(self, cfg: DataConfig, shardings=None, start_step: int = 0,
                 depth: int = 2):
        self.cfg = cfg
        self.shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._worker, args=(start_step,), daemon=True)
        self._thread.start()

    def _worker(self, start_step: int):
        for batch in stream(self.cfg, start_step):
            if self._stop.is_set():
                return
            if self.shardings is not None:
                batch = {k: jax.device_put(v, self.shardings[k])
                         for k, v in batch.items()}
            self._q.put(batch)

    def __iter__(self):
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            self._q.get_nowait()
        except queue.Empty:
            pass
