from .pipeline import DataConfig, PrefetchingLoader, stream

__all__ = ["DataConfig", "PrefetchingLoader", "stream"]
