"""Accuracy scenario-matrix CLI: the paper's <1% claim, end to end.

Runs real split inference (``models.forward_head`` -> FeatureCodec
round trip, optionally through the loopback socket transport ->
``models.forward_from_boundary``) over a declarative scenario matrix and
reports task-metric degradation against the measured wire rate.

Usage:
    PYTHONPATH=src python -m repro.launch.eval_accuracy \
        [--matrix default|all|name,name,...|file.json] \
        [--backend jnp|kernel|kernel_interpret] \
        [--select [--budget 0.01]] [--out report.json]

``--matrix`` accepts the pinned default mini-matrix, every registered
scenario, a comma-separated list of registry names, or a JSON file of
scenario dicts (see ``repro.eval.scenarios.Scenario``).  ``--select``
runs the auto split-point selector instead of a plain sweep: for each
scenario it sweeps every legal boundary tap and reports the cheapest
(HLO-measured head FLOPs) tap whose worst-case degradation stays within
``--budget``.
"""

from __future__ import annotations

import argparse
import json
import sys

from ..eval import load_matrix, run_scenario, select_split_point


def _print_report(rep) -> None:
    print(f"scenario={rep.scenario.name} split_after={rep.split_after} "
          f"n_tokens={rep.n_tokens} "
          f"n_decisive={rep.cases[0].n_decisive} "
          f"elapsed_s={rep.elapsed_s:.1f}")
    for c in rep.cases:
        print(f"  {c.clip_mode:10s} N={c.rung:5d} "
              f"bpe={c.bits_per_elem:7.3f} deg={c.degradation:.4f} "
              f"raw_deg={c.raw_degradation:.4f} "
              f"logit_rmse={c.logit_rmse:.4f} bytes={c.coded_bytes}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="accuracy scenario matrix over real split inference")
    ap.add_argument("--matrix", default="default",
                    help="'default', 'all', comma-separated scenario "
                         "names, or a .json scenario file")
    ap.add_argument("--backend", default=None,
                    choices=("jnp", "kernel", "kernel_interpret"),
                    help="pin the quantizer backend (default: codec "
                         "auto-detect)")
    ap.add_argument("--split-after", type=int, default=None,
                    help="override every scenario's boundary tap")
    ap.add_argument("--select", action="store_true",
                    help="run the auto split-point selector per scenario "
                         "instead of a plain sweep")
    ap.add_argument("--budget", type=float, default=0.01,
                    help="degradation budget for --select (default: the "
                         "paper's 1%%)")
    ap.add_argument("--out", default=None,
                    help="write the full JSON report here")
    args = ap.parse_args(argv)

    scenarios = load_matrix(args.matrix)
    out: dict = {"matrix": [sc.name for sc in scenarios]}
    if args.select:
        out["budget"] = args.budget
        out["selections"] = {}
        for sc in scenarios:
            sel = select_split_point(sc, budget=args.budget,
                                     backend=args.backend)
            out["selections"][sc.name] = sel.to_dict()
            chosen = (f"split_after={sel.chosen.split_after} "
                      f"(head_flops={sel.chosen.head_flops:.3g}, "
                      f"worst_deg={sel.chosen.worst_degradation:.4f})"
                      if sel.chosen is not None
                      else "NONE (no tap meets the budget)")
            print(f"scenario={sc.name} budget={args.budget}: {chosen}")
            for c in sel.candidates:
                print(f"  sa={c.split_after} flops={c.head_flops:.3g} "
                      f"worst_deg={c.worst_degradation:.4f} "
                      f"meets={c.meets_budget}")
    else:
        out["reports"] = {}
        for sc in scenarios:
            rep = run_scenario(sc, split_after=args.split_after,
                               backend=args.backend)
            out["reports"][sc.name] = rep.to_dict()
            _print_report(rep)
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, sort_keys=True)
        print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
