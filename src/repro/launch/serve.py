"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Runs the continuous-batching engine on a (reduced by default) config, with
the paper's codec optionally applied at the split boundary, and prints
tokens/s plus the measured split-link rate.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--codec-levels", type=int, default=0,
                    help="0 = no split codec; else N quantizer levels")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    import jax

    from ..configs import get_config, reduced
    from ..core import CodecConfig, calibrate
    from ..models import init_params
    from ..serving import Request, ServeEngine

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))

    codec = None
    if args.codec_levels:
        codec = calibrate(CodecConfig(n_levels=args.codec_levels,
                                      clip_mode="manual", manual_cmin=-8.0,
                                      manual_cmax=8.0))

    eng = ServeEngine(cfg, params, slots=4,
                      max_seq=args.prompt_len + args.new_tokens + 8,
                      codec=codec)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"{total} tokens in {dt:.2f}s = {total / dt:.1f} tok/s "
          f"({args.requests} requests)")
    if eng.rate_log:
        print(f"split-link rate: {np.mean(eng.rate_log):.3f} bits/element "
              f"({16 / max(np.mean(eng.rate_log), 1e-9):.1f}x vs bf16)")


if __name__ == "__main__":
    main()
