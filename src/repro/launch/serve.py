"""Serving launcher: `python -m repro.launch.serve --arch <id> [...]`.

Runs the continuous-batching engine on a (reduced by default) config, with
the paper's codec applied at the split boundary, and prints tokens/s, the
measured split-link rate, and per-request latency.

The codec is calibrated from a *warm-up batch of real split-layer
activations* (``--clip-mode model|empirical|minmax|aciq``, the paper's
calibration modes) instead of a hardcoded manual range; ``--clip-mode
manual`` keeps the old [-8, 8] behavior.  ``--granularity channel``
(with ``--channel-group``) calibrates a TilePlan codec -- one clipping
range per group of d_model channels, shipped in the v3 self-describing
stream header.

``--transport loopback`` wires the split boundary through a real socket
pair: a CloudServer thread on localhost receives the streamed, framed
bitstream and echoes the reconstruction, and the engine round-trips
every boundary tensor through it *between* its two jitted halves
(``ServeEngine(codec_host_fn=...)``) -- the transport stack under a live
serving load, safe on single-CPU hosts.
"""

from __future__ import annotations

import argparse
import time

import numpy as np


def _calibrate_warmup(cfg, params, args):
    """Calibrate the codec on a warm-up batch of split-layer activations.

    Tiled granularities keep the d_model channel axis in the calibration
    samples (reshaped to (tokens, d_model)), so per-channel-group /
    per-tile ranges come from real per-feature statistics.
    """
    import jax

    from ..core import CodecConfig
    from ..data import DataConfig, stream
    from ..models import forward
    from ..transport import shared_bank

    # "tile" (fixed spatial extent -- 1-D spatial_block_size or the 2-D
    # spatial_block_hw row x column split) is not offered here: serving
    # tensors change spatial size between prefill and decode steps, so
    # only the extent-free granularities calibrate from a warm-up pass
    # (fixed-shape deployments get 2-D tiles via CodecConfig directly;
    # see examples/edge_cloud_demo.py --granularity tile2d)
    ccfg = CodecConfig(n_levels=args.codec_levels, clip_mode=args.clip_mode,
                       constrain_cmin_zero=False,
                       granularity=args.granularity, channel_axis=-1,
                       channel_group_size=args.channel_group)
    if args.clip_mode == "manual":
        if args.granularity != "tensor":
            raise SystemExit("--clip-mode manual implies per-tensor "
                             "granularity")
        # manual ranges ignore samples; dummy samples let the bank cache
        # still dedupe repeated workers
        bank = shared_bank(
            CodecConfig(n_levels=args.codec_levels, clip_mode="manual",
                        manual_cmin=-8.0, manual_cmax=8.0),
            np.zeros(1, np.float32), ladder=(args.codec_levels,))
        return bank.get(args.codec_levels)
    probe = {}

    def probe_fn(x):
        probe["x"] = x
        return x, 0.0

    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=4,
                      seq_len=min(64, args.prompt_len + args.new_tokens))
    chunks = []
    for _, batch in zip(range(args.warmup_batches), stream(dcfg)):
        forward(cfg, params, jax.numpy.asarray(batch["tokens"]),
                codec_fn=probe_fn)
        chunks.append(np.asarray(probe["x"], np.float32)
                      .reshape(-1, cfg.d_model))
    samples = np.concatenate(chunks, axis=0)
    if args.granularity == "tensor":
        samples = samples.reshape(-1)
    # rung tables are immutable -- one worker-level bank serves every
    # session with this (config, warm-up samples) pair
    codec = shared_bank(ccfg, samples,
                        ladder=(args.codec_levels,)).get(args.codec_levels)
    grain = args.granularity if args.granularity == "tensor" else \
        f"{args.granularity}(g={args.channel_group})"
    print(f"calibrated codec on {samples.size} warm-up activations: "
          f"clip_mode={args.clip_mode} granularity={grain} "
          f"range=[{float(np.min(codec.cmin)):.3f},"
          f" {float(np.max(codec.cmax)):.3f}]")
    return codec


def _loopback_codec_fn(codec, chunk_elems: int, tick_ms: float = 0.0,
                       metrics_port: int | None = None,
                       workers: int = 1, max_queue: int | None = None,
                       tls_cert: str | None = None,
                       tls_key: str | None = None,
                       secret: str | None = None):
    """Split-boundary host hook that streams every tensor over localhost.

    Starts a CloudServer (echoing reconstructions) on a daemon thread and
    returns a *host* round-trip ``x -> (recon, bits_per_elem)`` for
    ``ServeEngine(codec_host_fn=...)``: the engine runs each stage as two
    jitted halves split at the boundary and calls this eagerly in
    between, so the client's own jax encode never executes beneath an
    in-flight jitted program.  (The old ``io_callback`` hookup deadlocked
    on single-CPU hosts: the callback held XLA's only dispatch thread
    while the nested encode waited for it.  Running the round-trip
    *between* programs removes that cycle structurally --
    tests/test_serve_loopback.py pins it on 1 CPU.)  The reported rate is
    the true wire bits/element (frames, headers and all).

    The server always runs the cross-session tick drain (one batched
    entropy call per tick); ``tick_ms`` sets the tick window.  The
    engine keeps one tensor in flight per boundary crossing, so the
    default window is 0 (drain as soon as the loop is idle) and client-
    side encode coalescing only engages for ``tick_ms > 0``.

    Hardened-serving knobs (DESIGN.md, "Hardened scale-out serving"):
    ``workers > 1`` puts a session-affine :class:`Dispatcher` over a
    pool of in-process CloudServers (worker kill/restart tolerant; the
    client gets a retry policy so restarts replay transparently);
    ``max_queue`` bounds in-flight sessions (BUSY shedding);
    ``tls_cert``/``tls_key`` wrap the edge-facing socket in TLS; and
    ``secret`` requires the authenticated HELLO handshake.
    """
    import asyncio
    import ssl as ssl_mod
    import threading

    from ..serving import TickConfig
    from ..transport import (CloudServer, Dispatcher, RetryPolicy,
                             SyncEdgeClient)

    loop = asyncio.new_event_loop()
    threading.Thread(target=loop.run_forever, name="cloud-server",
                     daemon=True).start()
    tick = TickConfig(max_wait_s=tick_ms / 1e3)

    server_ssl = client_ssl = None
    if tls_cert is not None:
        server_ssl = ssl_mod.SSLContext(ssl_mod.PROTOCOL_TLS_SERVER)
        server_ssl.load_cert_chain(tls_cert, tls_key or tls_cert)
        # self-signed deployments pin the cert itself as the CA; the
        # hostname check is skipped (loopback certs rarely carry SANs)
        client_ssl = ssl_mod.create_default_context(cafile=tls_cert)
        client_ssl.check_hostname = False

    retry = None
    if workers > 1:
        server = Dispatcher(
            workers=workers,
            worker_factory=lambda i: CloudServer(echo_features=True,
                                                 tick=tick),
            max_queue=max_queue, ssl=server_ssl, secret=secret)
        retry = RetryPolicy()      # worker restarts replay transparently
    else:
        server = CloudServer(echo_features=True, tick=tick,
                             metrics_port=metrics_port,
                             max_queue=max_queue, ssl=server_ssl,
                             secret=secret)
    asyncio.run_coroutine_threadsafe(server.start(), loop).result()
    client = SyncEdgeClient("127.0.0.1", server.port, codec=codec,
                            chunk_elems=chunk_elems,
                            tick=tick if tick_ms > 0 else None,
                            ssl=client_ssl, secret=secret, retry=retry)
    kind = (f"dispatcher x{workers} workers" if workers > 1
            else "cloud server")
    print(f"loopback transport: streaming split tensors via {kind} on "
          f"127.0.0.1:{server.port} (tick window {tick_ms:.1f}ms"
          f"{', TLS' if server_ssl is not None else ''}"
          f"{', authenticated' if secret is not None else ''})")
    if getattr(server, "metrics_port", None) is not None:
        print(f"metrics: http://127.0.0.1:{server.metrics_port}/metrics")

    def host_roundtrip(x):
        res = client.submit(np.asarray(x, np.float32))
        recon = np.asarray(res.arrays[0], np.float32).reshape(x.shape)
        return recon, float(res.bits_per_elem)

    def cleanup():
        if workers > 1:
            snap = server.metrics.snapshot()

            def val(name):
                s = snap.get(name, {}).get("series", [])
                return s[0]["value"] if s else 0

            client.close()
            asyncio.run_coroutine_threadsafe(server.close(), loop).result()
            loop.call_soon_threadsafe(loop.stop)
            print(f"dispatcher: "
                  f"{val('repro_dispatcher_routed_sessions_total'):.0f} "
                  f"sessions routed, "
                  f"{val('repro_dispatcher_worker_restarts_total'):.0f} "
                  f"worker restarts, "
                  f"{val('repro_dispatcher_shed_sessions_total'):.0f} shed")
            return
        counters = server.counters
        client.close()
        asyncio.run_coroutine_threadsafe(server.close(), loop).result()
        loop.call_soon_threadsafe(loop.stop)
        print(f"cloud ticks: {counters.get('ticks', 0)} "
              f"(occupancy {counters.get('batch_occupancy_avg', 0.0):.2f}, "
              f"entropy calls {counters.get('entropy_calls', 0)}, "
              f"bpe {counters.get('bpe_avg', 0.0):.3f}, header cache "
              f"{counters.get('header_cache', {})})")

    return host_roundtrip, cleanup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--codec-levels", type=int, default=0,
                    help="0 = no split codec; else N quantizer levels")
    ap.add_argument("--clip-mode", default="model",
                    choices=["model", "empirical", "minmax", "aciq",
                             "manual"],
                    help="codec calibration mode (warm-up activations; "
                         "'manual' keeps the legacy [-8, 8] range)")
    ap.add_argument("--warmup-batches", type=int, default=4)
    ap.add_argument("--granularity", default="tensor",
                    choices=["tensor", "channel"],
                    help="codec granularity at the split boundary: "
                         "'channel' calibrates one range per d_model "
                         "channel group (TilePlan, v3 streams).  Spatial "
                         "('tile') granularities -- incl. the 2-D "
                         "spatial_block_hw split, v4 streams -- pin the "
                         "spatial extent at calibration and are for "
                         "fixed-shape boundaries, not the varying "
                         "prefill/decode shapes served here")
    ap.add_argument("--channel-group", type=int, default=1,
                    help="channels per range group for "
                         "--granularity channel")
    ap.add_argument("--transport", default="none",
                    choices=["none", "loopback"],
                    help="'loopback' streams every split tensor through "
                         "the framed transport over a localhost socket")
    ap.add_argument("--chunk-elems", type=int, default=1 << 16)
    ap.add_argument("--tick-ms", type=float, default=0.0,
                    help="cross-session batching tick window for the "
                         "loopback transport (0 = drain immediately; the "
                         "engine keeps one tensor in flight per boundary "
                         "crossing, so >0 only helps with several "
                         "engines sharing the worker)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve Prometheus-text telemetry on this port "
                         "alongside the loopback CloudServer (0 = pick a "
                         "free one); needs --transport loopback")
    ap.add_argument("--workers", type=int, default=1,
                    help=">1 puts a session-affine Dispatcher over a "
                         "pool of in-process cloud workers (heartbeats, "
                         "crash restart, client-side retry); needs "
                         "--transport loopback")
    ap.add_argument("--max-queue", type=int, default=None,
                    help="admission-control bound on concurrently open "
                         "sessions; saturated servers answer new streams "
                         "with a retryable BUSY error")
    ap.add_argument("--tls-cert", default=None, metavar="PEM",
                    help="serve the loopback transport over TLS with "
                         "this certificate (also pinned as the client "
                         "CA -- self-signed certs work)")
    ap.add_argument("--tls-key", default=None, metavar="PEM",
                    help="private key for --tls-cert (default: key is "
                         "in the cert PEM)")
    ap.add_argument("--secret", default=None,
                    help="require the authenticated HELLO handshake "
                         "with this shared secret")
    ap.add_argument("--trace", metavar="PATH", default=None,
                    help="enable pipeline stage tracing and mirror the "
                         "JSON span log to PATH")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    if args.metrics_port is not None and args.transport != "loopback":
        ap.error("--metrics-port needs --transport loopback")
    if args.transport != "loopback":
        for flag, val in (("--workers", args.workers != 1),
                          ("--max-queue", args.max_queue is not None),
                          ("--tls-cert", args.tls_cert is not None),
                          ("--secret", args.secret is not None)):
            if val:
                ap.error(f"{flag} needs --transport loopback")
    if args.workers < 1:
        ap.error("--workers must be >= 1")
    if args.tls_key is not None and args.tls_cert is None:
        ap.error("--tls-key needs --tls-cert")
    if args.workers > 1 and args.metrics_port is not None:
        ap.error("--metrics-port is per-worker; not supported with "
                 "--workers > 1 (scrape the dispatcher registry instead)")
    if args.trace is not None:
        from ..obs import configure_tracing
        configure_tracing(enabled=True, event_log_path=args.trace)
        print(f"stage tracing on: span log -> {args.trace}")

    import jax

    from ..configs import get_config, reduced
    from ..models import init_params
    from ..serving import Request, ServeEngine

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    params = init_params(cfg, jax.random.PRNGKey(0))

    codec = None
    codec_host_fn = None
    cleanup = None
    if args.codec_levels:
        codec = _calibrate_warmup(cfg, params, args)
        if args.transport == "loopback":
            codec_host_fn, cleanup = _loopback_codec_fn(
                codec, args.chunk_elems, args.tick_ms,
                metrics_port=args.metrics_port,
                workers=args.workers, max_queue=args.max_queue,
                tls_cert=args.tls_cert, tls_key=args.tls_key,
                secret=args.secret)
            codec = None
    elif args.transport == "loopback":
        ap.error("--transport loopback needs --codec-levels")

    eng = ServeEngine(cfg, params, slots=4,
                      max_seq=args.prompt_len + args.new_tokens + 8,
                      codec=codec, codec_host_fn=codec_host_fn)
    rng = np.random.default_rng(0)
    reqs = [Request(prompt=rng.integers(0, cfg.vocab_size,
                                        size=args.prompt_len).astype(np.int32),
                    max_new_tokens=args.new_tokens)
            for _ in range(args.requests)]
    t0 = time.time()
    eng.generate(reqs)
    dt = time.time() - t0
    total = sum(len(r.out_tokens) for r in reqs)
    print(f"{total} tokens in {dt:.2f}s = {total / dt:.1f} tok/s "
          f"({args.requests} requests)")
    if eng.rate_log:
        print(f"split-link rate: {np.mean(eng.rate_log):.3f} bits/element "
              f"({16 / max(np.mean(eng.rate_log), 1e-9):.1f}x vs bf16)")
    if eng.latency_log:
        lat = [d["latency_s"] for d in eng.latency_log]
        print(f"request latency: mean={np.mean(lat):.3f}s "
              f"p50={np.percentile(lat, 50):.3f}s "
              f"max={np.max(lat):.3f}s")
    ec = eng.counters
    print(f"engine: {ec['steps']} steps, occupancy "
          f"{ec['batch_occupancy_avg']:.2f}, {ec['refills']} refills, "
          f"{ec['epochs']} epochs")
    if args.codec_levels:
        from ..transport import bank_cache_stats
        print(f"codec bank cache: {bank_cache_stats()}")
    if cleanup is not None:
        cleanup()


if __name__ == "__main__":
    main()
