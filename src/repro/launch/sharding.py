"""Sharding rules: parameter, optimizer, cache, and input PartitionSpecs.

Strategy (baseline; hillclimbs revisit per-cell):
  * 2-D parameter sharding: tensor-parallel ('model') on one contraction
    dimension, FSDP (('pod','data')) on another -- ZeRO-3 style.  XLA
    inserts the per-layer all-gathers inside the layer scan.
  * attention heads shard over 'model' when divisible, else head_dim
    (GQA archs with few KV heads), else replicated -- decided per tensor.
  * MoE experts shard over 'model' (expert parallelism).
  * KV caches: batch over dp axes; heads over 'model' when divisible,
    else the sequence dimension (sequence-parallel KV); batch=1 long-context
    shards the sequence over ('data','model').

Everything is divisibility-checked against the actual mesh, so the same
rules serve the (16,16) pod mesh, the (2,16,16) multi-pod mesh, and tiny
test meshes.
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig


def _size(mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh, axes) -> bool:
    return axes is not None and dim % _size(mesh, axes) == 0


def _path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


def param_spec(path_s: str, shape: tuple[int, ...], mesh,
               fsdp: Any = ("pod", "data"), tp: str = "model") -> P:
    """Rule table keyed by the trailing parameter name."""
    fsdp = tuple(a for a in (fsdp if isinstance(fsdp, tuple) else (fsdp,))
                 if a in mesh.axis_names) or None
    if tp not in mesh.axis_names:
        tp = None
    name = path_s.rsplit("/", 2)
    leaf = name[-1]
    parent = name[-2] if len(name) > 1 else ""

    def ax(dim, axes):
        return axes if _fits(dim, mesh, axes) else None

    # ---- top level ----
    if path_s.endswith("embed/table"):        # (V, d)
        return P(ax(shape[0], tp), ax(shape[1], fsdp))
    if path_s.endswith("head/w"):             # (d, V)
        return P(ax(shape[0], fsdp), ax(shape[1], tp))
    if "final_norm" in path_s or parent in ("norm1", "norm2"):
        return P(*([None] * len(shape)))

    # ---- stacked layer params: shape[0] = n_periods ----
    if parent == "attn":
        # Head-divisible archs shard heads over tp (classic TP attention).
        # Head-indivisible archs (gemma3: 4H, qwen2-vl: 12H, ...) REPLICATE
        # the (small) attention weights; attention compute is distributed
        # by sequence-sharding K/V instead (see models/transformer.py) --
        # hd-sharding would force a partial-sum all-reduce of the f32
        # logits every chunk, measured 16x worse in the dry-run.
        if leaf == "wq":                      # (L, d, H, hd)
            if _fits(shape[2], mesh, tp):
                return P(None, ax(shape[1], fsdp), tp, None)
            return P(None, ax(shape[1], fsdp), None, None)
        if leaf in ("wk", "wv"):              # (L, d, K, hd)
            if _fits(shape[2], mesh, tp):
                return P(None, ax(shape[1], fsdp), tp, None)
            return P(None, ax(shape[1], fsdp), None, None)
        if leaf == "wo":                      # (L, H, hd, d)
            if _fits(shape[1], mesh, tp):
                return P(None, tp, None, ax(shape[3], fsdp))
            return P(None, None, None, ax(shape[3], fsdp))
        return P(*([None] * len(shape)))      # q_norm/k_norm
    if parent == "mlp":
        if leaf in ("w1", "w3"):              # (L, d, f)
            return P(None, ax(shape[1], fsdp), ax(shape[2], tp))
        return P(None, ax(shape[1], tp), ax(shape[2], fsdp))  # w2 (L, f, d)
    if parent == "moe":
        if leaf == "router":                  # (L, d, E)
            return P(None, ax(shape[1], fsdp), None)
        if leaf in ("w1", "w3"):              # (L, E, d, ef)
            return P(None, ax(shape[1], tp), ax(shape[2], fsdp), None)
        return P(None, ax(shape[1], tp), None, ax(shape[3], fsdp))  # w2
    if parent == "rec":
        r_rules = {
            "w_in": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "w_gate": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "conv_w": lambda s: P(None, None, ax(s[2], tp)),
            "wa": lambda s: P(None, ax(s[1], tp), None),
            "wx": lambda s: P(None, ax(s[1], tp), None),
            "w_out": lambda s: P(None, ax(s[1], tp), ax(s[2], fsdp)),
        }
        if leaf in r_rules:
            return r_rules[leaf](shape)
        if len(shape) == 2:                   # conv_b, ba, bx, lam (L, r)
            return P(None, ax(shape[1], tp))
        return P(*([None] * len(shape)))
    if parent == "tmix":
        # tp-sharded on m: the (B,S,m)->(B,S,H,n) reshape costs per-layer
        # gathers (m=H*hd doesn't factor onto tp for 40 heads), but the
        # tested alternative -- replicating time-mix over tp -- regressed
        # train 5.3x (16x redundant recurrence backward); see §Perf.
        t_rules = {
            "wr": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "wk": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "wv": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "wg": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "wo": lambda s: P(None, ax(s[1], tp), ax(s[2], fsdp)),
            "wa": lambda s: P(None, ax(s[1], fsdp), None),
            "wb": lambda s: P(None, None, ax(s[2], tp)),
        }
        if leaf in t_rules:
            return t_rules[leaf](shape)
        if leaf in ("w0", "ln"):              # (L, m)
            return P(None, ax(shape[1], tp))
        return P(*([None] * len(shape)))      # mu, u
    if parent == "cmix":
        c_rules = {
            "wk": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
            "wv": lambda s: P(None, ax(s[1], tp), ax(s[2], fsdp)),
            "wr": lambda s: P(None, ax(s[1], fsdp), ax(s[2], tp)),
        }
        if leaf in c_rules:
            return c_rules[leaf](shape)
        return P(*([None] * len(shape)))
    return P(*([None] * len(shape)))


def param_shardings(cfg: ModelConfig, mesh, params_tree, fsdp=("pod", "data")):
    """Tree of NamedShardings matching a params (shape) tree."""
    def rule(path, leaf):
        spec = param_spec(_path_str(path), leaf.shape, mesh, fsdp=fsdp)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(rule, params_tree)


def opt_shardings(cfg: ModelConfig, mesh, opt_tree, fsdp=("pod", "data")):
    """mu/nu mirror params; step replicated."""
    def rule(path, leaf):
        ps = _path_str(path)
        if ps.endswith("step"):
            return NamedSharding(mesh, P())
        stripped = ps.split("/", 1)[1] if "/" in ps else ps  # drop mu|nu
        return NamedSharding(mesh, param_spec(stripped, leaf.shape, mesh,
                                              fsdp=fsdp))
    return jax.tree_util.tree_map_with_path(rule, opt_tree)


# -- caches & inputs -----------------------------------------------------------

def _dp_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def cache_spec(path_s: str, shape: tuple[int, ...], mesh,
               tp: str = "model") -> P:
    dp = _dp_axes(mesh)
    if tp not in mesh.axis_names:
        tp = None
    leaf = path_s.rsplit("/", 1)[-1]
    batch_ok = len(shape) >= 2 and _fits(shape[1], mesh, dp)
    b_ax = dp if batch_ok else None
    if leaf in ("k", "v"):                   # (L, B, S, K, hd)
        if _fits(shape[3], mesh, tp):
            return P(None, b_ax, None, tp, None)
        if not batch_ok:
            # batch=1 long context: spread sequence over everything usable
            seq_axes = tuple(a for a in ("data", tp) if a in mesh.axis_names)
            if _fits(shape[2], mesh, seq_axes):
                return P(None, None, seq_axes, None, None)
        if _fits(shape[2], mesh, tp):
            return P(None, b_ax, tp, None, None)
        return P(None, b_ax, None, None, ax_last(shape, mesh, tp))
    if leaf == "state":                      # rwkv (L, B, H, n, n)
        return P(None, b_ax, None, None,
                 tp if _fits(shape[4], mesh, tp) else None)
    if leaf == "shift":                      # (L, B, d)
        return P(None, b_ax, tp if _fits(shape[2], mesh, tp) else None)
    if leaf == "h":                          # rglru (L, B, r)
        return P(None, b_ax, tp if _fits(shape[2], mesh, tp) else None)
    if leaf == "conv":                       # (L, B, cw-1, r)
        return P(None, b_ax, None, tp if _fits(shape[3], mesh, tp) else None)
    return P(*([None] * len(shape)))


def ax_last(shape, mesh, tp):
    return tp if _fits(shape[-1], mesh, tp) else None


def cache_shardings(cfg: ModelConfig, mesh, cache_tree):
    def rule(path, leaf):
        return NamedSharding(mesh, cache_spec(_path_str(path), leaf.shape, mesh))
    return jax.tree_util.tree_map_with_path(rule, cache_tree)


def batch_sharding(mesh, shape: tuple[int, ...]):
    """Tokens (B,S) / embeddings (B,S,d) / decode tokens (B,)."""
    dp = _dp_axes(mesh)
    b_ax = dp if _fits(shape[0], mesh, dp) else None
    return NamedSharding(mesh, P(b_ax, *([None] * (len(shape) - 1))))


def replicated(mesh):
    return NamedSharding(mesh, P())
