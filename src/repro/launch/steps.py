"""Jittable train / prefill / decode step builders shared by the dry-run,
the trainer, and the serving engine."""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..models import decode_step as _decode
from ..models import loss_fn, prefill as _prefill
from ..models.context import DistContext
from ..optim import AdamWConfig, adamw_update


def make_train_step(cfg: ModelConfig, ctx: DistContext | None = None,
                    opt_cfg: AdamWConfig | None = None, codec_fn=None,
                    remat: bool = True, microbatches: int = 1):
    """Train step with optional gradient accumulation over microbatches.

    Microbatching divides activation memory by ``microbatches`` at the cost
    of re-running the FSDP weight all-gathers per microbatch; the gradient
    all-reduce/reduce-scatter still happens once per step.
    """
    opt_cfg = opt_cfg or AdamWConfig()

    def grads_of(p, batch):
        def lf(pp):
            return loss_fn(cfg, pp, batch["tokens"], ctx=ctx,
                           inputs=batch.get("inputs"), codec_fn=codec_fn,
                           remat=remat)
        return jax.value_and_grad(lf, has_aux=True)(p)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            (loss, aux), grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(microbatches, x.shape[0] // microbatches,
                                    *x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def body(gsum, mbatch):
                (l, _), g = grads_of(params, mbatch)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return gsum, l

            gsum, losses = jax.lax.scan(body, g0, mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss, aux = jnp.mean(losses), {}
        new_params, new_opt, metrics = adamw_update(opt_cfg, params, grads,
                                                    opt_state)
        out = {"loss": loss, **metrics}
        if "codec_rate_bits" in aux:
            out["codec_rate_bits"] = aux["codec_rate_bits"]
        return new_params, new_opt, out

    return train_step


def make_prefill_step(cfg: ModelConfig, ctx: DistContext | None = None,
                      codec_fn=None):
    def prefill_step(params, batch, cache):
        inp = batch.get("inputs", batch["tokens"])
        logits, new_cache = _prefill(cfg, params, inp, cache, ctx=ctx,
                                     codec_fn=codec_fn)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return prefill_step


def make_decode_step(cfg: ModelConfig, ctx: DistContext | None = None,
                     codec_fn=None):
    def serve_step(params, token, cache, pos):
        logits, new_cache, aux = _decode(cfg, params, token, cache, pos,
                                         ctx=ctx, codec_fn=codec_fn)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_cache

    return serve_step
