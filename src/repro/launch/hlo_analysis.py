"""Loop-aware HLO cost analysis for the dry-run roofline.

XLA's ``compiled.cost_analysis()`` visits each while-loop body ONCE, which
undercounts scanned-layer models by ~num_layers x.  This module parses the
post-optimization HLO text, multiplies while bodies by their
``known_trip_count`` backend config, and produces:

  * flops           -- dot/convolution FLOPs per device per step
  * traffic_bytes   -- approximate HBM traffic (fusion-boundary operands +
                       results; GTE/bitcast/tuple/constant excluded)
  * collectives     -- per-op-type wire bytes per device, using ring-model
                       factors: all-reduce 2(n-1)/n, all-gather/reduce-
                       scatter/all-to-all (n-1)/n, collective-permute 1

All numbers are per-device (the HLO module is the SPMD per-device program).
"""

from __future__ import annotations

import dataclasses
import json
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
# the result type may be a large tuple containing /*index=N*/ comments, so
# match the opcode as the first bare `word(` token after the `=`.
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*?)\s+([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_GROUPS_NEW_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_OLD_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")

_NO_TRAFFIC = {"get-tuple-element", "tuple", "bitcast", "parameter",
               "constant", "after-all", "partition-id", "replica-id",
               "opt-barrier", "copy-start", "copy-done"}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "ragged-all-to-all")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _shape_dims(type_str: str) -> tuple[list[int], str]:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",")] if m.group(2) else []
    return dims, m.group(1)


@dataclasses.dataclass
class Instr:
    name: str
    type_str: str
    opcode: str
    rest: str


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list[Instr]
    shapes: dict[str, str]  # instr/param name -> type string


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_RE.match(line)
            # computation defs are `[ENTRY] %name (sig) -> type {`; instruction
            # lines have `=` before the first paren (signatures may contain
            # `/*index=N*/` comments, so only inspect the head)
            if m and "=" not in line.split("(", 1)[0]:
                cur = Computation(m.group(2), [], {})
                if m.group(1):
                    entry_name = m.group(2)
                # parameters in the signature carry shapes
                sig = line[line.find("(") + 1: line.rfind("->")]
                for pm in re.finditer(r"([\w\.\-]+):\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\](?:\{[^}]*\})?))", sig):
                    cur.shapes[pm.group(1)] = pm.group(2)
            continue
        if line.startswith("}"):
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            ins = Instr(m.group(1), m.group(2).strip(), m.group(3), m.group(4))
            cur.instrs.append(ins)
            cur.shapes[ins.name] = ins.type_str
    if cur is not None:
        comps[cur.name] = cur
    comps["__entry__"] = comps[entry_name] if entry_name else None
    return comps


def _operand_names(rest: str) -> list[str]:
    # operands are %name references inside the first balanced paren group
    depth = 1
    out = []
    buf = []
    for ch in rest:
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                break
        buf.append(ch)
    args = "".join(buf)
    for m in re.finditer(r"%([\w\.\-]+)", args):
        out.append(m.group(1))
    return out


def _dot_flops(ins: Instr, shapes: dict[str, str]) -> float:
    dims, _ = _shape_dims(ins.type_str)
    out_elems = 1
    for d in dims:
        out_elems *= d
    ops = _operand_names(ins.rest)
    k = 1
    if ops:
        lhs_dims, _ = _shape_dims(shapes.get(ops[0], ""))
        m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
        if m and m.group(1):
            for ci in m.group(1).split(","):
                i = int(ci)
                if i < len(lhs_dims):
                    k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _group_size(rest: str, default: int) -> int:
    m = _GROUPS_NEW_RE.search(rest)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_OLD_RE.search(rest)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def _collective_wire_bytes(ins: Instr, shapes: dict[str, str],
                           n_devices: int) -> tuple[str, float]:
    op = next(c for c in COLLECTIVE_OPS if ins.opcode.startswith(c))
    n = _group_size(ins.rest, n_devices)
    out_b = _shape_bytes(ins.type_str)
    in_b = sum(_shape_bytes(shapes.get(o, "")) for o in _operand_names(ins.rest))
    frac = (n - 1) / n if n > 1 else 0.0
    if op == "all-reduce":
        return op, 2.0 * out_b * frac
    if op == "all-gather":
        return op, out_b * frac
    if op == "reduce-scatter":
        return op, in_b * frac
    if op in ("all-to-all", "ragged-all-to-all"):
        return op, out_b * frac
    return op, out_b  # collective-permute


_PARAM_IDX_RE = re.compile(r"^(\d+)\)?")


def _fusion_traffic(ins: Instr, caller_shapes: dict[str, str],
                    comps: dict[str, "Computation"]) -> float:
    """HBM traffic of a fusion: output + operands, but operands that are
    only dynamic-sliced (or in-place dynamic-update-sliced) inside the
    fusion count at the slice size, not the full array -- otherwise
    scan-sliced stacked parameters/xs are overcounted by the trip count."""
    out_b = _shape_bytes(ins.type_str)
    ops = _operand_names(ins.rest)
    cm = _CALLS_RE.search(ins.rest)
    fc = comps.get(cm.group(1)) if cm else None
    if fc is None:
        return out_b + sum(_shape_bytes(caller_shapes.get(o, "")) for o in ops)

    param_arg: dict[str, int] = {}
    for fi in fc.instrs:
        if fi.opcode == "parameter":
            m = _PARAM_IDX_RE.match(fi.rest)
            if m:
                param_arg[fi.name] = int(m.group(1))
    sliced_bytes: dict[int, float] = {}
    full_use: set[int] = set()
    root_name = fc.instrs[-1].name if fc.instrs else None
    root_dus_update = None
    for fi in fc.instrs:
        if fi.opcode == "parameter":
            continue
        f_ops = _operand_names(fi.rest)
        for pos, on in enumerate(f_ops):
            if on not in param_arg:
                continue
            ai = param_arg[on]
            if fi.opcode in ("dynamic-slice", "gather") and pos == 0:
                sliced_bytes[ai] = sliced_bytes.get(ai, 0.0) \
                    + _shape_bytes(fi.type_str)
            elif fi.opcode == "dynamic-update-slice" and pos == 0:
                upd = _shape_bytes(fc.shapes.get(f_ops[1], "")) \
                    if len(f_ops) > 1 else 0.0
                sliced_bytes[ai] = sliced_bytes.get(ai, 0.0) + upd
            elif fi.opcode == "dynamic-update-slice" and pos > 1:
                pass  # indices
            else:
                full_use.add(ai)
        if fi.opcode == "dynamic-update-slice" and fi.name == root_name:
            root_dus_update = _shape_bytes(fc.shapes.get(f_ops[1], "")) \
                if len(f_ops) > 1 else None
    total = 0.0
    for ai, on in enumerate(ops):
        full = _shape_bytes(caller_shapes.get(on, ""))
        if ai in sliced_bytes and ai not in full_use:
            total += min(full, sliced_bytes[ai])
        else:
            total += full
    if root_dus_update is not None:
        out_b = root_dus_update  # in-place update: only the window is written
    return out_b + total


@dataclasses.dataclass
class HloStats:
    flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))
    op_counts: dict[str, float] = dataclasses.field(
        default_factory=lambda: defaultdict(float))

    def add(self, other: "HloStats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic_bytes += other.traffic_bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] += v * mult
        for k, v in other.op_counts.items():
            self.op_counts[k] += v * mult

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def to_json(self) -> dict:
        return {"flops": self.flops, "traffic_bytes": self.traffic_bytes,
                "collective_bytes": dict(self.collective_bytes),
                "total_collective_bytes": self.total_collective_bytes,
                "op_counts": dict(self.op_counts)}


def analyze(text: str, n_devices: int) -> HloStats:
    comps = parse_hlo(text)
    entry = comps.pop("__entry__")
    memo: dict[str, HloStats] = {}

    def comp_stats(name: str) -> HloStats:
        if name in memo:
            return memo[name]
        memo[name] = HloStats()  # cycle guard
        c = comps.get(name)
        if c is None:
            return memo[name]
        st = HloStats()
        for ins in c.instrs:
            if ins.opcode == "dot" or ins.opcode.startswith("convolution"):
                st.flops += _dot_flops(ins, c.shapes)
                st.op_counts["dot"] += 1
                st.traffic_bytes += _shape_bytes(ins.type_str) + sum(
                    _shape_bytes(c.shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
            elif any(ins.opcode.startswith(co) for co in COLLECTIVE_OPS):
                if ins.opcode.endswith("-done"):
                    continue
                op, wb = _collective_wire_bytes(ins, c.shapes, n_devices)
                st.collective_bytes[op] += wb
                st.op_counts[op] += 1
            elif ins.opcode == "while":
                body = _BODY_RE.search(ins.rest)
                cond = _COND_RE.search(ins.rest)
                trip = 1
                tm = _TRIP_RE.search(ins.rest)
                if tm:
                    trip = int(tm.group(1))
                st.op_counts["while"] += 1
                if body:
                    st.add(comp_stats(body.group(1)), trip)
                if cond:
                    st.add(comp_stats(cond.group(1)), trip)
                continue
            elif ins.opcode in ("call", "conditional", "async-start"):
                for cm in _CALLS_RE.finditer(ins.rest):
                    st.add(comp_stats(cm.group(1)))
            elif ins.opcode == "fusion":
                cm = _CALLS_RE.search(ins.rest)
                if cm:
                    inner = comp_stats(cm.group(1))
                    st.flops += inner.flops  # dots inside fusions
                st.op_counts["fusion"] += 1
                st.traffic_bytes += _fusion_traffic(ins, c.shapes, comps)
            elif ins.opcode in ("dynamic-slice", "gather"):
                st.op_counts[ins.opcode] += 1
                st.traffic_bytes += 2.0 * _shape_bytes(ins.type_str)
            elif ins.opcode == "dynamic-update-slice":
                ops_n = _operand_names(ins.rest)
                upd = _shape_bytes(c.shapes.get(ops_n[1], "")) \
                    if len(ops_n) > 1 else _shape_bytes(ins.type_str)
                st.op_counts[ins.opcode] += 1
                st.traffic_bytes += 2.0 * upd
            elif ins.opcode not in _NO_TRAFFIC:
                st.op_counts[ins.opcode] += 1
                st.traffic_bytes += _shape_bytes(ins.type_str) + sum(
                    _shape_bytes(c.shapes.get(o, ""))
                    for o in _operand_names(ins.rest))
        memo[name] = st
        return st

    # fusions' inner computations would double-count traffic if walked from
    # the entry; comp_stats only walks them for flops via the fusion branch.
    return comp_stats(entry.name)
