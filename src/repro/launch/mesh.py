"""Production mesh construction.

Defined as functions (never module-level constants) so importing this
module never touches JAX device state.  The production target is TPU v5e:
one pod = 16 x 16 = 256 chips; the multi-pod config stacks 2 pods (512
chips) along a leading 'pod' axis used for data parallelism and for the
collaborative-intelligence edge/cloud split (see split_runtime).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(devices: int | None = None, model_axis: int | None = None):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = devices or len(jax.devices())
    m = model_axis or (2 if n % 2 == 0 and n > 1 else 1)
    return jax.make_mesh((n // m, m), ("data", "model"))


def dp_axes_of(mesh) -> tuple[str, ...]:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
