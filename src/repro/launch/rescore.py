"""Re-run the HLO analysis over saved dry-run artifacts (no recompilation).

Used whenever the cost model in hlo_analysis.py improves: re-reads each
cell's .hlo.gz, recomputes the roofline record, and rewrites the JSON.

    PYTHONPATH=src python -m repro.launch.rescore
"""

from __future__ import annotations

import glob
import gzip
import json
import os
import sys

from ..configs import SHAPES, get_config
from .hlo_analysis import analyze

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def rescore_record(rec: dict, hlo_text: str) -> dict:
    n_dev = 512 if rec["mesh"] == "pod2x16x16" else 256
    st = analyze(hlo_text, n_dev)
    rec["hlo"] = st.to_json()
    cfg = get_config(rec["arch"])
    shape = SHAPES[rec["shape"]]
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = (6 if shape.kind == "train" else 2) * cfg.active_param_count() * tokens
    rec["model_flops_global"] = float(mf)
    flops_t = st.flops / PEAK_FLOPS
    mem_t = st.traffic_bytes / HBM_BW
    coll_t = st.total_collective_bytes / ICI_BW
    dom = max((flops_t, "compute"), (mem_t, "memory"), (coll_t, "collective"))
    lb = max(flops_t, mem_t, coll_t)
    rec["roofline"] = {
        "compute_s": flops_t, "memory_s": mem_t, "collective_s": coll_t,
        "bound": dom[1], "step_time_lower_bound_s": lb,
        "model_flops_ratio": mf / (st.flops * n_dev) if st.flops else 0.0,
        "mfu_bound": (mf / n_dev / PEAK_FLOPS) / lb if lb else 0.0,
    }
    return rec


def main(pattern: str = "*.json"):
    base = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                        "experiments", "dryrun")
    for jpath in sorted(glob.glob(os.path.join(base, pattern))):
        rec = json.load(open(jpath))
        if rec.get("status") != "ok" or "hlo_path" not in rec:
            continue
        hpath = rec["hlo_path"]
        if not os.path.exists(hpath):
            hpath = os.path.join(base, os.path.basename(hpath))
        if not os.path.exists(hpath):
            print(f"[rescore] missing HLO for {jpath}")
            continue
        rec = rescore_record(rec, gzip.open(hpath, "rt").read())
        json.dump(rec, open(jpath, "w"), indent=1)
        rl = rec["roofline"]
        print(f"[rescore] {rec['arch']:24s} {rec['shape']:12s} {rec['mesh']:11s}"
              f" bound={rl['bound']:10s} lb={rl['step_time_lower_bound_s']:.3f}s"
              f" mfu_bound={rl['mfu_bound']:.4f}")


if __name__ == "__main__":
    main(*(sys.argv[1:] or []))
