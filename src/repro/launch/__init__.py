# NOTE: dryrun is intentionally NOT imported here -- it sets XLA_FLAGS for
# 512 placeholder devices and must only run as __main__.
from .mesh import dp_axes_of, make_production_mesh, make_smoke_mesh

__all__ = ["dp_axes_of", "make_production_mesh", "make_smoke_mesh"]
