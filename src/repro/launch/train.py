"""Training launcher: `python -m repro.launch.train --arch <id> [...]`.

Single-host entry; on a real cluster each host calls
``jax.distributed.initialize()`` first (flag below) and the same code
runs over the global device set.  For CPU-container experimentation the
default runs a reduced config; ``--full`` uses the real architecture (only
feasible on real accelerators).
"""

from __future__ import annotations

import argparse


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train")
    ap.add_argument("--full", action="store_true",
                    help="use the full-size config (accelerators only)")
    ap.add_argument("--distributed", action="store_true",
                    help="call jax.distributed.initialize() (multi-host)")
    ap.add_argument("--grad-compress-bits", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.distributed:
        import jax
        jax.distributed.initialize()

    from ..compression import GradCompressionConfig
    from ..configs import get_config, reduced
    from ..data import DataConfig
    from ..train import Trainer, TrainerConfig

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced(cfg)
    gc = None
    if args.grad_compress_bits:
        gc = GradCompressionConfig(n_levels=1 << args.grad_compress_bits)
    trainer = Trainer(
        cfg,
        TrainerConfig(steps=args.steps, ckpt_every=max(args.steps // 5, 1),
                      ckpt_dir=args.ckpt_dir, warmup_steps=args.steps // 10,
                      grad_compression=gc),
        DataConfig(vocab_size=cfg.vocab_size, batch=args.batch,
                   seq_len=args.seq_len,
                   embed_dim=cfg.d_model if cfg.input_mode == "embeddings" else 0),
    )
    trainer.run(resume=args.resume)
    for m in trainer.metrics_log[:: max(len(trainer.metrics_log) // 10, 1)]:
        print(f"step {m['step']:5d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.3f}")
    print(f"final loss: {trainer.metrics_log[-1]['loss']:.4f}; "
          f"straggler steps: {trainer.straggler_steps}")


if __name__ == "__main__":
    main()
