import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay first: JAX locks the device count at first
initialization, and the production meshes need 512 placeholder host
devices.  Tests and benchmarks never import this module; they see 1 device.

Usage:
    python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
    python -m repro.launch.dryrun --arch all --shape all [--multipod]
Outputs one JSON per cell under experiments/dryrun/ plus the gzipped HLO
for the roofline/perf analysis.
"""

import argparse
import functools
import gzip
import json
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs import ARCHS, SHAPES, get_config
from ..configs.base import InputShape, ModelConfig
from ..launch import sharding as SH
from ..launch.hlo_analysis import analyze
from ..launch.mesh import dp_axes_of, make_production_mesh
from ..launch.steps import make_decode_step, make_prefill_step, make_train_step
from ..models import init_cache, init_params
from ..models.context import DistContext
from ..optim import init_opt_state

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

# TPU v5e hardware model (per chip)
PEAK_FLOPS = 197e12     # bf16
HBM_BW = 819e9          # bytes/s
ICI_BW = 50e9           # bytes/s per link


def input_specs(cfg: ModelConfig, shape: InputShape, mesh=None):
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: {'tokens': (B,S) i32[, 'inputs': (B,S,d) bf16]}
    decode:        {'token': (B,) i32, 'pos': scalar i32}
    """
    b, s = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    specs = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    if cfg.input_mode == "embeddings":
        specs["inputs"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), dt)
    return specs


def _tree_sds(f, *args):
    return jax.eval_shape(f, *args)


def cell_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    if shape.name == "long_500k" and not cfg.long_context_ok:
        return False, ("pure full-attention arch: 500k KV cache/quadratic "
                       "prefill out of scope (see DESIGN.md)")
    return True, ""


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             save_hlo: bool = True, overrides: dict | None = None,
             tag: str = "", microbatches: int = 4,
             kv_bits: int = 0) -> dict:
    import dataclasses
    cfg = get_config(arch)
    if kv_bits:
        cfg = dataclasses.replace(cfg, kv_quant_bits=kv_bits)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                 "tag": tag or "baseline", "microbatches": microbatches}
    ok, why = cell_applicable(cfg, shape)
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=multi_pod)
        n_dev = mesh.size
        ctx = DistContext(mesh, dp_axes_of(mesh))
        key = jax.random.PRNGKey(0)
        params_sds = _tree_sds(functools.partial(init_params, cfg), key)
        p_sh = SH.param_shardings(cfg, mesh, params_sds)
        batch_sds = input_specs(cfg, shape, mesh)

        if shape.kind == "train":
            opt_sds = _tree_sds(init_opt_state, params_sds)
            o_sh = SH.opt_shardings(cfg, mesh, opt_sds)
            b_sh = {k: SH.batch_sharding(mesh, v.shape)
                    for k, v in batch_sds.items()}
            step = make_train_step(cfg, ctx, microbatches=microbatches)
            jf = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
            lowered = jf.lower(params_sds, opt_sds, batch_sds)
        elif shape.kind == "prefill":
            cache_sds = _tree_sds(functools.partial(
                init_cache, cfg, shape.global_batch, shape.seq_len))
            c_sh = SH.cache_shardings(cfg, mesh, cache_sds)
            b_sh = {k: SH.batch_sharding(mesh, v.shape)
                    for k, v in batch_sds.items()}
            step = make_prefill_step(cfg, ctx)
            jf = jax.jit(step, in_shardings=(p_sh, b_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
            lowered = jf.lower(params_sds, batch_sds, cache_sds)
        else:  # decode
            cache_sds = _tree_sds(functools.partial(
                init_cache, cfg, shape.global_batch, shape.seq_len))
            c_sh = SH.cache_shardings(cfg, mesh, cache_sds)
            tok_sh = SH.batch_sharding(mesh, (shape.global_batch,))
            step = make_decode_step(cfg, ctx)
            jf = jax.jit(step, in_shardings=(p_sh, tok_sh, c_sh, None),
                         out_shardings=(tok_sh, c_sh), donate_argnums=(2,))
            lowered = jf.lower(params_sds, batch_sds["token"], cache_sds,
                               batch_sds["pos"])
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        # ---- memory ----
        try:
            ma = compiled.memory_analysis()
            rec["memory"] = {
                "argument_bytes": int(ma.argument_size_in_bytes),
                "output_bytes": int(ma.output_size_in_bytes),
                "temp_bytes": int(ma.temp_size_in_bytes),
                "alias_bytes": int(ma.alias_size_in_bytes),
                "peak_bytes_est": int(ma.argument_size_in_bytes
                                      + ma.output_size_in_bytes
                                      + ma.temp_size_in_bytes
                                      - ma.alias_size_in_bytes),
            }
        except Exception as e:  # pragma: no cover
            rec["memory"] = {"error": str(e)}

        # ---- XLA's own (loop-unaware) cost analysis, for cross-checking ----
        try:
            ca = compiled.cost_analysis()
            rec["xla_cost"] = {"flops": float(ca.get("flops", -1)),
                               "bytes_accessed": float(ca.get("bytes accessed", -1))}
        except Exception as e:  # pragma: no cover
            rec["xla_cost"] = {"error": str(e)}

        # ---- loop-aware HLO analysis ----
        hlo = compiled.as_text()
        if save_hlo:
            os.makedirs(OUT_DIR, exist_ok=True)
            hpath = os.path.join(
                OUT_DIR, f"{arch}_{shape_name}_{mesh_name}{tag and '_' + tag}.hlo.gz")
            with gzip.open(hpath, "wt") as f:
                f.write(hlo)
            rec["hlo_path"] = hpath
        st = analyze(hlo, n_dev)
        rec["hlo"] = st.to_json()

        # ---- roofline terms (per device; global numerators / chips) ----
        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        n_active = cfg.active_param_count()
        mf = (6 if shape.kind == "train" else 2) * n_active * tokens
        rec["model_flops_global"] = float(mf)
        flops_t = st.flops / PEAK_FLOPS
        mem_t = st.traffic_bytes / HBM_BW
        coll_t = st.total_collective_bytes / ICI_BW
        dom = max((flops_t, "compute"), (mem_t, "memory"), (coll_t, "collective"))
        rec["roofline"] = {
            "compute_s": flops_t, "memory_s": mem_t, "collective_s": coll_t,
            "bound": dom[1],
            "step_time_lower_bound_s": max(flops_t, mem_t, coll_t),
            "model_flops_ratio": mf / (st.flops * n_dev) if st.flops else 0.0,
            "mfu_bound": (mf / n_dev / PEAK_FLOPS)
            / max(flops_t, mem_t, coll_t) if max(flops_t, mem_t, coll_t) else 0.0,
        }
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--no-hlo", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--kv-bits", type=int, default=0)
    args = ap.parse_args()
    archs = sorted(ARCHS) if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    os.makedirs(OUT_DIR, exist_ok=True)
    for a in archs:
        for s in shapes:
            mesh_name = "pod2x16x16" if args.multipod else "pod16x16"
            fname = f"{a}_{s}_{mesh_name}{args.tag and '_' + args.tag}.json"
            fpath = os.path.join(OUT_DIR, fname)
            if os.path.exists(fpath):
                print(f"[skip existing] {fname}", flush=True)
                continue
            print(f"[dryrun] {a} x {s} on {mesh_name} ...", flush=True)
            rec = run_cell(a, s, multi_pod=args.multipod,
                           save_hlo=not args.no_hlo, tag=args.tag,
                           microbatches=args.microbatches,
                           kv_bits=args.kv_bits)
            with open(fpath, "w") as f:
                json.dump(rec, f, indent=1)
            status = rec["status"]
            extra = rec.get("reason", rec.get("error", ""))[:120]
            rl = rec.get("roofline", {})
            print(f"  -> {status} ({rec.get('total_s', 0)}s) "
                  f"bound={rl.get('bound', '-')} {extra}", flush=True)


if __name__ == "__main__":
    main()
