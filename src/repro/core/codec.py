"""FeatureCodec: the paper's lightweight compression pipeline as a
first-class framework feature.

    clip -> coarse scalar quantize (uniform eq.1 or modified ECSQ Alg.1)
         -> truncated-unary binarization -> CABAC

Deployment modes:
  * in-graph fake-quant (quantize+dequantize) at a split layer, with an
    in-graph entropy rate estimate -- used inside jitted train/serve steps;
  * host bitstream encode/decode (exact CABAC round trip) -- used by the
    split-inference example and codec benchmarks;
  * packed integer transport -- indices packed to uint8 (2x4bit / 8x1bit)
    for real inter-pod bandwidth reduction in the split runtime.

Side information (header): c_min, c_max, N, element count -- 12 bytes for
classification-style payloads, matching the paper's accounting; object
detection adds tensor dims (24 bytes total).
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Literal

import jax.numpy as jnp
import numpy as np

from . import aciq, cabac, clipping, uniform
from .distributions import FeatureModel
from .ecsq import ECSQQuantizer, design_ecsq
from .rate_model import estimated_bits_per_element
from .stats import RunningStats

ClipMode = Literal["model", "empirical", "aciq", "manual"]

_HEADER_FMT = "<ffHHI"  # cmin, cmax, n_levels, flags, n_elems  (16 bytes)


@dataclasses.dataclass
class CodecConfig:
    n_levels: int = 4
    clip_mode: ClipMode = "model"
    kappa: float = 0.5
    leaky_slope: float = 0.1
    constrain_cmin_zero: bool = True
    use_ecsq: bool = False
    ecsq_lagrangian: float = 0.05
    ecsq_pin_boundaries: bool = True
    manual_cmin: float = 0.0
    manual_cmax: float = 1.0


@dataclasses.dataclass
class FeatureCodec:
    """Calibrated codec instance.  Build with :func:`calibrate`."""

    config: CodecConfig
    cmin: float
    cmax: float
    model: FeatureModel | None = None
    ecsq: ECSQQuantizer | None = None

    # -- in-graph ops ---------------------------------------------------------

    def quantize(self, x):
        """x -> int32 indices (jnp). ECSQ uses designed thresholds."""
        if self.ecsq is not None:
            t = jnp.asarray(self.ecsq.thresholds, dtype=jnp.float32)
            xc = jnp.clip(x.astype(jnp.float32), self.cmin, self.cmax)
            return jnp.searchsorted(t, xc, side="right").astype(jnp.int32)
        return uniform.quantize(x, self.cmin, self.cmax, self.config.n_levels)

    def dequantize(self, idx, dtype=jnp.float32):
        if self.ecsq is not None:
            levels = jnp.asarray(self.ecsq.levels, dtype=jnp.float32)
            return levels[idx].astype(dtype)
        return uniform.dequantize(idx, self.cmin, self.cmax,
                                  self.config.n_levels, dtype=dtype)

    def apply(self, x):
        """Fake-quant pass-through preserving dtype (the split-layer op)."""
        return self.dequantize(self.quantize(x), dtype=x.dtype)

    def estimate_rate(self, x):
        """Bits/element the CABAC stage would need (in-graph, entropy bound)."""
        return estimated_bits_per_element(self.quantize(x), self.config.n_levels)

    # -- packed transport (inter-pod) ------------------------------------------

    def bits_per_index(self) -> int:
        n = self.config.n_levels
        return max(1, int(np.ceil(np.log2(n))))

    def pack(self, idx):
        """Pack int32 indices into uint8 lanes (2x4b or 8x1b per byte)."""
        bits = self.bits_per_index()
        per = 8 // bits if bits in (1, 2, 4) else 1
        if per == 1:
            return idx.astype(jnp.uint8)
        flat = idx.reshape(-1, per).astype(jnp.uint8)
        shifts = jnp.arange(per, dtype=jnp.uint8) * bits
        return jnp.sum(flat << shifts, axis=-1).astype(jnp.uint8)

    def unpack(self, packed, n_elems: int):
        bits = self.bits_per_index()
        per = 8 // bits if bits in (1, 2, 4) else 1
        if per == 1:
            return packed.astype(jnp.int32)
        shifts = jnp.arange(per, dtype=jnp.uint8) * bits
        mask = jnp.uint8((1 << bits) - 1)
        vals = (packed[..., None] >> shifts) & mask
        return vals.reshape(-1)[:n_elems].astype(jnp.int32)

    # -- host bitstream ---------------------------------------------------------

    def encode(self, x: np.ndarray) -> bytes:
        """Full host encode: clip+quantize+TU+CABAC with header."""
        idx = np.asarray(self.quantize(jnp.asarray(np.asarray(x, np.float32))))
        payload = cabac.encode_indices(idx.ravel(), self.config.n_levels)
        flags = 1 if self.ecsq is not None else 0
        header = struct.pack(_HEADER_FMT, self.cmin, self.cmax,
                             self.config.n_levels, flags, idx.size)
        return header + payload

    def decode(self, data: bytes, shape=None) -> np.ndarray:
        cmin, cmax, n_levels, flags, n_elems = struct.unpack_from(_HEADER_FMT, data)
        idx = cabac.decode_indices(data[struct.calcsize(_HEADER_FMT):],
                                   n_elems, n_levels)
        out = np.asarray(self.dequantize(jnp.asarray(idx)))
        return out.reshape(shape) if shape is not None else out

    def compressed_bits_per_element(self, x: np.ndarray) -> float:
        data = self.encode(x)
        return 8.0 * len(data) / np.asarray(x).size


def calibrate(config: CodecConfig,
              samples: np.ndarray | None = None,
              stats: RunningStats | None = None,
              sample_mean: float | None = None,
              sample_var: float | None = None) -> FeatureCodec:
    """Build a codec from calibration data or pre-computed stats.

    ``model`` / ``aciq`` modes need only (mean, var) / samples respectively;
    ``empirical`` grid-searches measured MSRE like the paper's empirical
    columns; ECSQ additionally runs Algorithm 1 on the samples.
    """
    cfg = config
    model = None
    if cfg.clip_mode == "manual":
        cmin, cmax = cfg.manual_cmin, cfg.manual_cmax
    elif cfg.clip_mode == "model":
        if sample_mean is None:
            if stats is None:
                if samples is None:
                    raise ValueError("model mode needs samples or stats")
                stats = RunningStats().update(np.asarray(samples))
            sample_mean, sample_var = stats.mean, stats.var
        model = FeatureModel.fit(sample_mean, sample_var, cfg.kappa, cfg.leaky_slope)
        if cfg.constrain_cmin_zero:
            cmin, cmax = 0.0, clipping.optimal_cmax(model, cfg.n_levels)
        else:
            cmin, cmax = clipping.optimal_range(model, cfg.n_levels)
    elif cfg.clip_mode == "aciq":
        if samples is None:
            raise ValueError("aciq mode needs samples")
        cmin = 0.0
        cmax = aciq.aciq_cmax_from_samples(np.asarray(samples), cfg.n_levels)
    elif cfg.clip_mode == "empirical":
        if samples is None:
            raise ValueError("empirical mode needs samples")
        cmin = 0.0
        cmax = clipping.empirical_optimal_cmax(np.asarray(samples), cfg.n_levels)
    else:
        raise ValueError(f"unknown clip mode {cfg.clip_mode}")

    ecsq_q = None
    if cfg.use_ecsq:
        if samples is None:
            raise ValueError("ECSQ design needs calibration samples")
        ecsq_q = design_ecsq(np.asarray(samples), cfg.n_levels,
                             cfg.ecsq_lagrangian, cmin, cmax,
                             pin_boundaries=cfg.ecsq_pin_boundaries)
    return FeatureCodec(config=cfg, cmin=float(cmin), cmax=float(cmax),
                        model=model, ecsq=ecsq_q)
