"""FeatureCodec: the paper's lightweight compression pipeline as a
first-class framework feature.

    clip -> coarse scalar quantize (uniform eq.1 or modified ECSQ Alg.1)
         -> truncated-unary binarization -> entropy coding

Deployment modes:
  * in-graph fake-quant (quantize+dequantize) at a split layer, with an
    in-graph entropy rate estimate -- used inside jitted train/serve steps;
  * host bitstream encode/decode (exact entropy-coder round trip) -- used
    by the split-inference example and codec benchmarks;
  * packed integer transport -- indices packed to uint8 (2x4bit / 8x1bit)
    for real inter-pod bandwidth reduction in the split runtime.

All quantization primitives route through a :mod:`repro.core.backend`
``QuantBackend``: the fused Pallas kernels on TPU, the jnp reference path
on CPU -- one code path for in-graph, host, and kernel execution.

Granularity is a :class:`~repro.core.tiling.TilePlan` (companion-paper
channel mosaic, arXiv 2105.06002, plus the spatial structure of
arXiv 1804.09963): per-tensor mode uses one (c_min, c_max); "channel" and
"tile" granularities calibrate a range -- and optionally an ECSQ table --
per (channel-group x spatial-block) tile and record the tile geometry +
tables in a self-describing header (v3 for 1-D flat spatial runs, v4 for
the 2-D ``spatial_block_hw`` row x column split of conv feature maps),
so heterogeneous channels and spatially drifting feature maps neither
waste levels nor blow up the coded rate.  Tiled streams serialize
indices in tile-major (channel-major) order -- 2-D plans additionally
permute each channel row so every row x column tile is one contiguous
run -- so consecutive coded symbols share a tile and streaming chunk
boundaries align to tiles.

Side information (header): c_min, c_max, N, flags, element count --
16 bytes for classification-style payloads, matching the paper's
accounting.  Flags extend the header with the ECSQ reconstruction table
and/or the tile extension (geometry + per-tile range/level tables) so a
receiver decodes with *no* shared calibration state; see DESIGN.md for
the layout.  Legacy v2 per-channel and v1 seed streams still decode.
"""

from __future__ import annotations

import dataclasses
import os
import struct
from typing import Literal

import jax.numpy as jnp
import numpy as np

from ..obs.tracing import span
from . import aciq, cabac, clipping
from .backend import QuantSpec, get_backend, spec_from_numpy
from .distributions import FeatureModel
from .ecsq import ECSQQuantizer, design_ecsq
from .rate_model import estimated_bits_from_hist, estimated_bits_from_tile_hists
from .stats import RunningStats
from .tiling import TileECSQ, TilePlan, plan_from_config

ClipMode = Literal["model", "empirical", "aciq", "manual", "minmax"]
Granularity = Literal["tensor", "channel", "tile"]

_HEADER_FMT = "<ffHHI"  # cmin, cmax, n_levels, flags, n_elems  (16 bytes)
_CHANNEL_EXT_FMT = "<BBHH"  # ndim, channel_axis, group_size, n_groups
# v3 tile ext: ndim, channel_axis, tile flags, pad, channel_group_size,
# n_cgroups, spatial_block_size, n_sblocks (then dims + range tables)
_TILE_EXT_FMT = "<BBBBHHII"
# v4 2-D tile ext: ndim, channel_axis, tile flags, pad,
# channel_group_size, n_cgroups, block_rows (bh), block_cols (bw),
# spatial_rows (H), spatial_cols (W)  (then dims + range tables, exactly
# like v3 -- n_sblocks = ceil(H/bh) * ceil(W/bw) is derived)
_TILE2D_EXT_FMT = "<BBBBHHHHII"
_STREAM_META_FMT = "<IIB"  # chunk_elems, n_chunks, ndim (then ndim u32 dims)

FLAG_ECSQ = 1      # per-tensor ECSQ; v2 streams append the level table
FLAG_CHANNEL = 2   # legacy v2 per-channel granularity (decode-only)
FLAG_V2 = 4        # payload starts with a coder-id byte (serial | rans)
FLAG_TILE = 8      # v3 tile extension (geometry + per-tile tables)
FLAG_TILE2D = 16   # v4 2-D (row x column) tile extension

TFLAG_ECSQ = 1     # tile ext carries per-tile ECSQ level tables

# chunk payloads of one streamed tensor are entropy-coded in batches of
# this many: big enough to amortize the per-chunk python dispatch through
# the batched rANS loop, small enough to keep the encode->wire pipeline
# fine-grained (first frame on the socket after one batch, not the tensor)
STREAM_CHUNK_BATCH = 8


@dataclasses.dataclass
class CodecConfig:
    n_levels: int = 4
    clip_mode: ClipMode = "model"
    kappa: float = 0.5
    leaky_slope: float = 0.1
    constrain_cmin_zero: bool = True
    use_ecsq: bool = False
    ecsq_lagrangian: float = 0.05
    ecsq_pin_boundaries: bool = True
    manual_cmin: float = 0.0
    manual_cmax: float = 1.0
    granularity: Granularity = "tensor"
    channel_axis: int = -1
    channel_group_size: int = 1
    # 'tile' granularity: elements per spatial block of the channel-major
    # (C, M) view; 0 = one block spanning M (pure per-channel tiling)
    spatial_block_size: int = 0
    # 'tile' granularity, 2-D mode: (bh, bw) row x column blocks over the
    # (H, W) spatial grid of a conv feature map (W = innermost non-channel
    # dim).  Mutually exclusive with spatial_block_size; streams carry the
    # v4 header.
    spatial_block_hw: tuple[int, int] | None = None
    backend: str | None = None   # None = auto (kernel on TPU, jnp on CPU)
    # calibration-sample budget per clip-range fit (0 = use everything).
    # Scenario sweeps calibrate hundreds of (rung x clip-mode x tile)
    # combinations from the same activation batch; an evenly-strided,
    # deterministic subsample keeps the empirical grid searches O(cap)
    # without a randomness source that would make sweeps unrepeatable.
    calib_sample_cap: int = 0


@dataclasses.dataclass
class ParsedHeader:
    """Decoded self-describing bitstream header (see DESIGN.md layout)."""

    cmin: float
    cmax: float
    n_levels: int
    flags: int
    n_elems: int
    levels: np.ndarray | None = None   # ECSQ reconstruction table (v2)
    dims: tuple[int, ...] | None = None
    spec: QuantSpec | None = None      # per-channel / per-tile dequant spec
    plan: TilePlan | None = None       # v3 tile geometry
    tile_levels: np.ndarray | None = None  # (n_tiles, N) per-tile ECSQ
    payload_off: int = 0               # byte offset of the entropy payload


def parse_header(data: bytes) -> ParsedHeader:
    """Parse the self-describing header shared by one-shot and streamed
    bitstreams.  ``payload_off`` points at the entropy-coder payload."""
    cmin, cmax, n_levels, flags, n_elems = struct.unpack_from(
        _HEADER_FMT, data)
    off = struct.calcsize(_HEADER_FMT)
    levels = None
    if flags & FLAG_ECSQ and flags & FLAG_V2:
        levels = np.frombuffer(data, "<f4", n_levels, off)
        off += 4 * n_levels
    dims = None
    spec = None
    plan = None
    tile_levels = None
    if flags & (FLAG_TILE | FLAG_TILE2D):
        if flags & FLAG_TILE2D:
            ndim, axis, tflags, _, gsize, ngroups, bh, bw, sh, sw = \
                struct.unpack_from(_TILE2D_EXT_FMT, data, off)
            off += struct.calcsize(_TILE2D_EXT_FMT)
        else:
            ndim, axis, tflags, _, gsize, ngroups, sblock, nsblocks = \
                struct.unpack_from(_TILE_EXT_FMT, data, off)
            off += struct.calcsize(_TILE_EXT_FMT)
        dims = tuple(int(d) for d in np.frombuffer(data, "<u4", ndim, off))
        off += 4 * ndim
        c = dims[axis]
        m = int(np.prod(dims)) // max(c, 1)
        if flags & FLAG_TILE2D:
            if sh * sw != m:
                raise ValueError("2-D tile header spatial grid does not "
                                 "match the tensor dims")
            plan = TilePlan(channel_axis=axis, channel_group_size=gsize,
                            spatial_block_size=0, n_channels=c,
                            spatial_extent=m, spatial_hw=(sh, sw),
                            spatial_block_hw=(bh, bw))
            if plan.n_cgroups != ngroups:
                raise ValueError("tile header geometry is inconsistent")
        else:
            plan = TilePlan(channel_axis=axis, channel_group_size=gsize,
                            spatial_block_size=sblock, n_channels=c,
                            spatial_extent=m if sblock else None)
            if (plan.n_cgroups, plan.n_sblocks) != (ngroups, nsblocks):
                raise ValueError("tile header geometry is inconsistent")
        n_tiles = plan.n_tiles
        table = np.frombuffer(data, "<f4", 2 * n_tiles, off) \
            .reshape(plan.n_cgroups, plan.n_sblocks, 2)
        off += 8 * n_tiles
        ecsq = None
        if tflags & TFLAG_ECSQ:
            tile_levels = np.frombuffer(
                data, "<f4", n_tiles * n_levels, off) \
                .reshape(n_tiles, n_levels)
            off += 4 * n_tiles * n_levels
        spec = QuantSpec(np.ascontiguousarray(table[..., 0]),
                         np.ascontiguousarray(table[..., 1]),
                         int(n_levels), int(axis), ecsq, plan)
    elif flags & FLAG_CHANNEL:  # legacy v2 per-channel stream
        ndim, axis, gsize, ngroups = struct.unpack_from(
            _CHANNEL_EXT_FMT, data, off)
        off += struct.calcsize(_CHANNEL_EXT_FMT)
        dims = tuple(int(d) for d in np.frombuffer(data, "<u4", ndim, off))
        off += 4 * ndim
        table = np.frombuffer(data, "<f4", 2 * ngroups, off) \
            .reshape(ngroups, 2)
        off += 8 * ngroups
        lo = np.repeat(table[:, 0], gsize)[:dims[axis]]
        hi = np.repeat(table[:, 1], gsize)[:dims[axis]]
        spec = spec_from_numpy(lo, hi, n_levels, axis)
    return ParsedHeader(cmin=float(cmin), cmax=float(cmax),
                        n_levels=int(n_levels), flags=int(flags),
                        n_elems=int(n_elems), levels=levels, dims=dims,
                        spec=spec, plan=plan, tile_levels=tile_levels,
                        payload_off=off)


def reconstruct_indices(idx: np.ndarray, hdr: ParsedHeader, *,
                        backend=None, ecsq: ECSQQuantizer | None = None,
                        shape=None) -> np.ndarray:
    """Dequantize decoded indices per the stream header.

    The single reconstruction path shared by :meth:`FeatureCodec.decode`
    and the chunked/stream decoders, so both are bit-exact by
    construction.  ``backend``/``ecsq`` default to the auto backend and no
    legacy-ECSQ fallback (a self-describing v2/v3 stream needs neither).
    v3 tiled payloads arrive in tile-major coded order and are restored to
    the tensor layout here.
    """
    backend = backend if backend is not None else get_backend(None)
    if hdr.plan is not None:
        idx_full = hdr.plan.from_coded_order(idx.reshape(-1), hdr.dims)
        if hdr.tile_levels is not None:
            tid = hdr.plan.tile_ids(hdr.dims)
            out = hdr.tile_levels.astype(np.float32)[tid, idx_full]
        else:
            out = np.asarray(backend.dequantize(
                jnp.asarray(idx_full), hdr.spec))
    elif hdr.levels is not None:
        out = hdr.levels[idx].astype(np.float32)
    elif hdr.flags & FLAG_ECSQ:  # legacy ECSQ stream without a level table
        if ecsq is None:
            raise ValueError("legacy ECSQ stream needs a calibrated codec")
        out = np.asarray(ecsq.levels, np.float32)[idx]
    elif hdr.spec is not None:
        out = np.asarray(backend.dequantize(
            jnp.asarray(idx.reshape(hdr.dims)), hdr.spec))
    else:
        out = np.asarray(backend.dequantize(
            jnp.asarray(idx), QuantSpec(hdr.cmin, hdr.cmax, hdr.n_levels)))
    if shape is not None:
        return out.reshape(shape)
    return out.reshape(hdr.dims) if hdr.dims is not None else out


class HeaderCache:
    """Worker-level cache of parsed stream headers, keyed by the exact
    header bytes.

    Concurrent sessions of one serving worker overwhelmingly share a few
    (shape, rung) combinations, and same-rung same-shape tensors produce
    byte-identical headers -- so the parse (including the QuantSpec /
    TilePlan construction and the per-tile table views inside it) runs
    once per distinct header instead of once per session.  Sharing is
    safe because every consumer treats :class:`ParsedHeader` as
    immutable (``reconstruct_indices`` only reads it) and the numpy views
    reference the immutable key bytes.  ``hits``/``misses`` feed the
    server's counters dict.
    """

    def __init__(self, maxsize: int = 256) -> None:
        from collections import OrderedDict
        self._entries: "OrderedDict[bytes, ParsedHeader]" = OrderedDict()
        self.maxsize = max(1, maxsize)
        self.hits = 0
        self.misses = 0

    def parse(self, data: bytes) -> ParsedHeader:
        hdr = self._entries.get(data)
        if hdr is not None:
            self.hits += 1
            self._entries.move_to_end(data)
            return hdr
        self.misses += 1
        hdr = parse_header(data)
        self._entries[data] = hdr
        if len(self._entries) > self.maxsize:
            self._entries.popitem(last=False)
        return hdr

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "entries": len(self._entries)}


class ChunkStreamDecoder:
    """Incremental decoder for :meth:`FeatureCodec.encode_stream` payloads.

    Chunks are entropy-decoded in *batches* of ``chunk_batch`` as they
    arrive (one batched rANS step loop per batch -- the receive-side
    mirror of the batched chunk encoder; that is the expensive stage, and
    what streaming overlaps with the transfer); any remainder decodes in
    :meth:`finish` together with the one-off dequantize.  Results are
    bit-exact with per-chunk decoding (``decode_indices_batch`` is
    result-identical to per-payload ``decode_indices``).  Chunks may
    arrive in any order -- each payload carries its chunk id --
    and ``chunk_batch=1`` restores strict decode-on-arrival.

    ``chunk_batch=0`` defers entropy decode entirely: chunks only
    accumulate, and either :meth:`finish` or a cross-session
    :func:`flush_decoders` pass drains them -- the mode the serving
    tick loop uses to collapse many sessions' chunks into one batched
    entropy call.  ``header_cache`` shares parsed headers across the
    sessions of a worker (see :class:`HeaderCache`).
    """

    def __init__(self, header_payload: bytes, *, backend=None,
                 ecsq: ECSQQuantizer | None = None,
                 chunk_batch: int = STREAM_CHUNK_BATCH,
                 header_cache: HeaderCache | None = None) -> None:
        self.chunk_elems, self.n_chunks, ndim = struct.unpack_from(
            _STREAM_META_FMT, header_payload)
        meta = struct.calcsize(_STREAM_META_FMT)
        self.shape = tuple(
            int(d) for d in np.frombuffer(header_payload, "<u4", ndim, meta))
        meta += 4 * ndim
        hdr_bytes = header_payload[meta:]
        self.header = header_cache.parse(hdr_bytes) if header_cache \
            is not None else parse_header(hdr_bytes)
        if self.header.payload_off != len(header_payload) - meta:
            raise ValueError("trailing bytes after stream header")
        self._backend = backend
        self._ecsq = ecsq
        self._idx = np.zeros(self.header.n_elems, dtype=np.int32)
        self._seen = np.zeros(self.n_chunks, dtype=bool)
        self._batch = max(0, chunk_batch)
        self._pending: list[tuple[int, bytes]] = []

    def _bounds(self, cid: int) -> tuple[int, int]:
        start = cid * self.chunk_elems
        return start, min(start + self.chunk_elems, self.header.n_elems)

    def _flush(self) -> None:
        if not self._pending:
            return
        pending, self._pending = self._pending, []
        bounds = [self._bounds(cid) for cid, _ in pending]
        try:
            with span("entropy_decode", chunks=len(pending)):
                decoded = cabac.decode_indices_batch(
                    [blob for _, blob in pending],
                    [b - a for a, b in bounds], self.header.n_levels)
        except Exception:
            # un-see the whole batch so the caller can re-request the
            # bad chunk(s) -- a corrupt payload must not poison the
            # stream (re-feeding a corrected copy is not a duplicate)
            for cid, _ in pending:
                self._seen[cid] = False
            raise
        for (a, b), arr in zip(bounds, decoded):
            self._idx[a:b] = arr

    def add_chunk(self, payload: bytes) -> int:
        """Accept one chunk payload (entropy-decoded with its batch);
        returns its chunk id."""
        (cid,) = struct.unpack_from("<I", payload)
        if cid >= self.n_chunks:
            raise ValueError(f"chunk id {cid} out of range")
        if self._seen[cid]:
            raise ValueError(f"duplicate chunk {cid}")
        self._seen[cid] = True
        self._pending.append((cid, payload[4:]))
        if self._batch and len(self._pending) >= self._batch:
            self._flush()
        return cid

    @property
    def pending_chunks(self) -> int:
        """Chunks accumulated but not yet entropy-decoded."""
        return len(self._pending)

    @property
    def complete(self) -> bool:
        return bool(self._seen.all())

    def finish(self, shape=None) -> np.ndarray:
        if not self.complete:
            missing = int((~self._seen).sum())
            raise ValueError(f"stream incomplete: {missing} chunks missing")
        self._flush()
        with span("dequantize", n_elems=self.header.n_elems):
            return reconstruct_indices(self._idx, self.header,
                                       backend=self._backend,
                                       ecsq=self._ecsq,
                                       shape=self.shape if shape is None
                                       else shape)


def flush_decoders(decoders) -> tuple[int, int, list]:
    """Entropy-decode the pending chunks of *many* stream decoders in one
    batched call -- the cross-session drain of the serving tick loop.

    Where per-session decoding runs one ``decode_indices_batch`` per
    stream, this gathers every decoder's pending payloads (each knows its
    own element counts and quantizer level count -- mixed shapes and
    rungs coexist in one call) into a single
    :func:`cabac.decode_indices_batch` pass, so all sessions of a tick
    share one python dispatch and one batched rANS step loop per TU
    plane round.  Results are scattered back into each decoder's index
    buffer, bit-exact with per-decoder :meth:`ChunkStreamDecoder._flush`.

    Isolation: when the combined batch fails (one corrupt session must
    not poison a tick), every decoder falls back to its own per-decoder
    flush; failing decoders un-see their chunks (re-feeding a corrected
    copy is not a duplicate) and are reported rather than raised, so the
    caller can error out only the affected sessions.

    Returns ``(n_chunks_decoded, n_elems_decoded, failures)`` with
    ``failures`` a list of ``(decoder, exception)`` pairs.
    """
    work = []
    for dec in decoders:
        if dec._pending:
            pend, dec._pending = dec._pending, []
            work.append((dec, pend))
    if not work:
        return 0, 0, []
    payloads, counts, levels, owners = [], [], [], []
    for dec, pend in work:
        for cid, blob in pend:
            a, b = dec._bounds(cid)
            payloads.append(blob)
            counts.append(b - a)
            levels.append(dec.header.n_levels)
            owners.append((dec, a, b))
    try:
        with span("entropy_decode", chunks=len(payloads),
                  sessions=len(work)):
            decoded = cabac.decode_indices_batch(payloads, counts, levels)
    except Exception:
        failures = []
        n_chunks = n_elems = 0
        for dec, pend in work:
            dec._pending = pend
            try:
                dec._flush()
            except Exception as e:     # noqa: BLE001 -- reported, not raised
                failures.append((dec, e))
            else:
                n_chunks += len(pend)
                n_elems += sum(b - a for a, b in
                               (dec._bounds(cid) for cid, _ in pend))
        return n_chunks, n_elems, failures
    for (dec, a, b), arr in zip(owners, decoded):
        dec._idx[a:b] = arr
    return len(payloads), sum(counts), []


@dataclasses.dataclass
class FeatureCodec:
    """Calibrated codec instance.  Build with :func:`calibrate`.

    Per-tensor mode: ``cmin``/``cmax`` are floats.  Tiled modes carry a
    :class:`TilePlan` in ``plan`` and per-tile range tables in
    ``cmin``/``cmax``: a (n_cgroups,) float32 vector for "channel"
    granularity (one spatial block) or a (n_cgroups, n_sblocks) table for
    "tile"; ``n_channels`` records the calibrated channel count and
    ``tile_ecsq`` the optional per-tile quantizer tables.
    """

    config: CodecConfig
    cmin: float | np.ndarray
    cmax: float | np.ndarray
    model: FeatureModel | None = None
    ecsq: ECSQQuantizer | None = None
    n_channels: int | None = None
    plan: TilePlan | None = None
    tile_ecsq: TileECSQ | None = None

    # -- backend routing --------------------------------------------------------

    @property
    def backend(self):
        return get_backend(self.config.backend)

    @property
    def per_channel(self) -> bool:
        return self.n_channels is not None

    def tile_tables(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-tile (lo, hi) range tables, (n_cgroups, n_sblocks)."""
        if self.plan is None:
            raise ValueError("per-tensor codec has no tile tables")
        shape = (self.plan.n_cgroups, self.plan.n_sblocks)
        return (np.asarray(self.cmin, np.float32).reshape(shape),
                np.asarray(self.cmax, np.float32).reshape(shape))

    def channel_ranges(self) -> tuple[np.ndarray, np.ndarray]:
        """Per-channel (cmin, cmax) vectors, group table expanded
        ("channel" granularity -- one spatial block -- only)."""
        if self.plan is None or self.plan.n_sblocks != 1:
            raise ValueError("codec has no per-channel range vectors")
        gs = max(1, self.config.channel_group_size)
        lo = np.repeat(np.asarray(self.cmin, np.float32).ravel(),
                       gs)[:self.n_channels]
        hi = np.repeat(np.asarray(self.cmax, np.float32).ravel(),
                       gs)[:self.n_channels]
        return lo, hi

    def spec(self) -> QuantSpec:
        """The backend-facing view of this codec's quantizer."""
        if self.plan is None:
            return spec_from_numpy(self.cmin, self.cmax,
                                   self.config.n_levels, None, self.ecsq)
        lo, hi = self.tile_tables()
        return QuantSpec(lo, hi, self.config.n_levels,
                         self.config.channel_axis, self.tile_ecsq,
                         self.plan)

    # -- in-graph ops ---------------------------------------------------------

    def quantize(self, x):
        """x -> int32 indices (backend-dispatched: Pallas on TPU, jnp on CPU)."""
        return self.backend.quantize(x, self.spec())

    def dequantize(self, idx, dtype=jnp.float32):
        return self.backend.dequantize(idx, self.spec(), dtype=dtype)

    def apply(self, x):
        """Fake-quant pass-through preserving dtype (the split-layer op).

        Uses the fused quantize+dequantize primitive: a single kernel pass
        on the TPU path.
        """
        return self.backend.quantize_dequantize(x, self.spec())[1]

    def estimate_rate(self, x):
        """Bits/element the entropy stage would need (in-graph bound)."""
        idx = self.quantize(x)
        return self.rate_from_indices(idx, np.shape(x))

    def rate_from_indices(self, idx, shape):
        """Bits/element estimate from indices (in-graph).

        Tiled codecs estimate per tile and sum: the chunked entropy stage
        codes tile-aligned runs with tile-local statistics, so the sum of
        per-tile entropies (never above the global-histogram bound, by
        conditioning) is the tighter model of what it actually spends.
        """
        n = max(int(np.prod(shape)), 1)
        if self.plan is not None:
            hists = self.backend.tile_histogram(idx, self.spec())
            return estimated_bits_from_tile_hists(
                hists, self.config.n_levels) / n
        hist = self.backend.histogram(idx, self.config.n_levels)
        return estimated_bits_from_hist(hist, self.config.n_levels) / n

    def tile_rate_bits(self, x):
        """(n_cgroups, n_sblocks) per-tile entropy-bits estimates from
        one quantization pass.  The per-tile view of the same in-graph
        signal :meth:`estimate_rate` sums (and the controller seeding in
        ``CodecBank.prime_controller`` consumes); exposed for callers
        that weigh individual tiles -- e.g. spatially selective rungs or
        per-tile drop decisions -- without a host round trip."""
        if self.plan is None:
            raise ValueError("per-tensor codec has no tile rates")
        idx = self.quantize(x)
        hists = self.backend.tile_histogram(idx, self.spec())
        return estimated_bits_from_tile_hists(
            hists, self.config.n_levels, per_tile=True)

    def apply_with_rate(self, x):
        """(fake-quant x, rate bits/element) from one quantization pass.

        The split-layer serving hook: quantizes once (one fused kernel on
        the TPU path) and derives both the pass-through activations and
        the rate estimate from it.
        """
        idx, deq = self.backend.quantize_dequantize(x, self.spec())
        return deq, self.rate_from_indices(idx, np.shape(x))

    # -- packed transport (inter-pod) ------------------------------------------

    def bits_per_index(self) -> int:
        n = self.config.n_levels
        return max(1, int(np.ceil(np.log2(n))))

    def pack(self, idx):
        """Pack int32 indices into uint8 lanes (4x2b / 2x4b / 8x1b per
        byte), backend-dispatched: the in-graph Pallas pack kernel on the
        kernel backend (fuses with clip+quant, only wire-width bytes leave
        the device), the jnp host fallback elsewhere -- both share one bit
        layout (little-end-first lanes), so packed streams are
        backend-portable.  Sizes that do not fill the last byte are
        zero-padded; ``unpack`` truncates back to the element count.
        """
        return self.backend.pack_indices(idx, self.bits_per_index())

    def unpack(self, packed, n_elems: int):
        bits = self.bits_per_index()
        per = 8 // bits if bits in (1, 2, 4) else 1
        if per == 1:
            return packed.astype(jnp.int32)
        shifts = jnp.arange(per, dtype=jnp.uint8) * bits
        mask = jnp.uint8((1 << bits) - 1)
        vals = (packed[..., None] >> shifts) & mask
        return vals.reshape(-1)[:n_elems].astype(jnp.int32)

    # -- host bitstream ---------------------------------------------------------

    def _header(self, x: np.ndarray) -> tuple[bytes, int]:
        """Self-describing header for ``x``; returns (bytes, flags).

        Tiled codecs write the v3 tile extension (geometry, per-tile
        ranges, optional per-tile ECSQ level tables); per-tensor codecs
        keep the seed's 16-byte accounting (plus the v2 ECSQ table).
        """
        flags = FLAG_V2
        ext = b""
        if self.plan is not None:
            axis, _, _ = self.plan.resolve(x.shape)
            lo, hi = self.tile_tables()
            tflags = TFLAG_ECSQ if self.tile_ecsq is not None else 0
            if self.plan.is_2d:
                flags |= FLAG_TILE2D
                ext += struct.pack(_TILE2D_EXT_FMT, x.ndim, axis, tflags, 0,
                                   self.plan.channel_group_size,
                                   self.plan.n_cgroups,
                                   self.plan.spatial_block_hw[0],
                                   self.plan.spatial_block_hw[1],
                                   self.plan.spatial_hw[0],
                                   self.plan.spatial_hw[1])
            else:
                flags |= FLAG_TILE
                ext += struct.pack(_TILE_EXT_FMT, x.ndim, axis, tflags, 0,
                                   self.plan.channel_group_size,
                                   self.plan.n_cgroups,
                                   self.plan.spatial_block_size,
                                   self.plan.n_sblocks)
            ext += np.asarray(x.shape, "<u4").tobytes()
            ext += np.stack([lo, hi], axis=-1).astype("<f4").tobytes()
            if self.tile_ecsq is not None:
                ext += np.asarray(self.tile_ecsq.levels, "<f4").tobytes()
            head_lo, head_hi = float(lo.min()), float(hi.max())
        elif self.ecsq is not None:
            flags |= FLAG_ECSQ
            ext += np.asarray(self.ecsq.levels, "<f4").tobytes()
            head_lo, head_hi = float(self.cmin), float(self.cmax)
        else:
            head_lo, head_hi = float(self.cmin), float(self.cmax)
        base = struct.pack(_HEADER_FMT, head_lo, head_hi,
                           self.config.n_levels, flags, int(np.prod(x.shape)))
        return base + ext, flags

    def _coded_indices(self, x: np.ndarray) -> np.ndarray:
        """Quantize ``x`` and ravel the indices in coded order (tile-major
        for tiled codecs -- consecutive coded symbols share a tile).

        The *unfused reference path*: a full int32 index tensor crosses
        from the device.  :meth:`_fused_indices` is the hot path;
        ``benchmarks/bench_codec.py`` asserts the two bit-identical.
        """
        idx = np.asarray(self.quantize(jnp.asarray(x)))
        if self.plan is not None:
            return self.plan.to_coded_order(idx)
        return idx.ravel()

    def _fused_indices(self, x: np.ndarray,
                       want_hist: bool = False):
        """Coded-order indices (and optionally per-tile histograms) via
        the backend's single-pass fused encode: on the kernel backend one
        megakernel pass whose packed bytes + tile histograms are the only
        device->host transfer."""
        return self.backend.encode_fused(jnp.asarray(x), self.spec(),
                                         self.bits_per_index(),
                                         want_hist=want_hist)

    def _device_entropy(self, device_entropy, coder_mode: str) -> bool:
        """Resolve the device-resident entropy opt-in: an explicit
        argument wins; otherwise ``REPRO_ENTROPY_DEVICE=1`` turns it on
        whenever the coder choice is ours (``coder_mode == "auto"``) --
        pinned coder modes keep their exact wire bytes."""
        if device_entropy is not None:
            return bool(device_entropy)
        return coder_mode == "auto" \
            and os.environ.get("REPRO_ENTROPY_DEVICE") == "1"

    def encode(self, x: np.ndarray, coder_mode: str = "auto",
               fused: bool = True, device_entropy: bool | None = None
               ) -> bytes:
        """Full host encode: clip+quantize+TU+entropy coding with header.

        ``fused=True`` (default) runs the single-pass fused device encode;
        ``fused=False`` forces the unfused reference path.  Both produce
        byte-identical streams -- the entropy payload is a pure function
        of the coded-order indices, which the two paths share bit-exactly.

        ``device_entropy=True`` (default: the ``REPRO_ENTROPY_DEVICE``
        env opt-in, only with ``coder_mode="auto"``) keeps the entropy
        stage on device too (``encode_fused(emit_wire=True)``): the
        payload is a coder-id-4 stream and only wire bytes cross to the
        host.
        """
        x = np.asarray(x, np.float32)
        header, _ = self._header(x)
        if fused and self._device_entropy(device_entropy, coder_mode):
            payload, _ = self.backend.encode_fused(
                jnp.asarray(x), self.spec(), self.bits_per_index(),
                emit_wire=True)
            return header + payload
        coded = self._fused_indices(x)[0] if fused \
            else self._coded_indices(x)
        with span("entropy_encode", n_elems=int(coded.size)):
            payload = cabac.encode_indices(coded, self.config.n_levels,
                                           mode=coder_mode)
        return header + payload

    def decode(self, data: bytes, shape=None) -> np.ndarray:
        """Decode a bitstream using *its own header* for dequantization.

        A receiver-side codec needs no matching calibration state: the
        clipping range(s), level count, ECSQ table, and channel layout all
        come from the stream.  (Exception: legacy seed streams with the
        ECSQ flag predate the level table and fall back to this instance's
        designed quantizer.)
        """
        hdr = parse_header(data)
        if hdr.flags & FLAG_V2:
            idx = cabac.decode_indices(data[hdr.payload_off:],
                                       hdr.n_elems, hdr.n_levels)
        else:  # seed stream: bare serial-CABAC payload
            idx = cabac.decode_indices_serial(data[hdr.payload_off:],
                                              hdr.n_elems, hdr.n_levels)
        return reconstruct_indices(idx, hdr, backend=self.backend,
                                   ecsq=self.ecsq, shape=shape)

    def compressed_bits_per_element(self, x: np.ndarray) -> float:
        data = self.encode(x)
        return 8.0 * len(data) / np.asarray(x).size

    # -- chunked (streaming) bitstream ------------------------------------------

    def encode_stream(self, x: np.ndarray, chunk_elems: int = 1 << 18,
                      coder_mode: str = "auto",
                      chunk_batch: int = STREAM_CHUNK_BATCH,
                      device_entropy: bool | None = None):
        """Chunked encode: yields the header payload, then chunk payloads.

        The first payload is the stream header: ``<II>`` (chunk_elems,
        n_chunks) followed by the same self-describing tensor header
        :meth:`encode` writes.  Every following payload is ``<I>``
        (chunk id) + an independently flushed :func:`cabac.encode_indices`
        stream over that chunk's coded-order indices, so a receiver
        entropy-decodes each chunk the moment it arrives and only the
        final dequantize waits for the last chunk.  Reconstruction is
        bit-exact with the one-shot path (same quantize, same coded order,
        same dequantize -- asserted in tests/test_transport.py).

        Tiled codecs round ``chunk_elems`` up so chunk boundaries align to
        tile runs in coded order (:meth:`TilePlan.align_chunk_elems`) --
        no chunk splits a tile's contiguous segment, and each chunk's
        chunk-static entropy probabilities see tile-homogeneous index
        statistics.  Chunks are entropy-coded ``chunk_batch`` at a time
        through the batched rANS loop (one python step loop per batch, not
        per chunk); framing for the wire (session ids, CRC, end-of-tensor)
        lives in :mod:`repro.transport.framing`.

        ``device_entropy`` (see :meth:`encode`) swaps the host entropy
        batches for one device emit_wire pass producing every chunk's
        coder-id-4 payload -- same chunk boundaries, and each payload's
        rANS blob is byte-identical to the host coder id 2 single-shard
        stream past the id byte.
        """
        if chunk_elems <= 0:
            raise ValueError("chunk_elems must be positive")
        x = np.asarray(x, np.float32)
        if self.plan is not None:
            chunk_elems = self.plan.align_chunk_elems(chunk_elems, x.shape)
        if self._device_entropy(device_entropy, coder_mode):
            # device-resident entropy: one emit_wire pass yields every
            # chunk's coder-id-4 payload; no index tensor ever crosses
            n = int(x.size)
            n_chunks = max(1, -(-n // chunk_elems))
            header, _ = self._header(x)
            meta = struct.pack(_STREAM_META_FMT, chunk_elems, n_chunks,
                               x.ndim)
            meta += np.asarray(x.shape, "<u4").tobytes()
            yield meta + header
            bounds = [(c * chunk_elems, min((c + 1) * chunk_elems, n))
                      for c in range(n_chunks)]
            blobs, _ = self.backend.encode_fused(
                jnp.asarray(x), self.spec(), self.bits_per_index(),
                emit_wire=True, chunk_bounds=bounds)
            for c, blob in enumerate(blobs):
                yield struct.pack("<I", c) + blob
            return
        idx = self._fused_indices(x)[0]
        header, _ = self._header(x)
        n_chunks = max(1, -(-idx.size // chunk_elems))
        # the stream meta carries the tensor shape (the one-shot header only
        # does for tiled streams): a cloud receiver reshapes before
        # running the tail network
        meta = struct.pack(_STREAM_META_FMT, chunk_elems, n_chunks, x.ndim)
        meta += np.asarray(x.shape, "<u4").tobytes()
        yield meta + header
        batch = max(1, chunk_batch)
        for c0 in range(0, n_chunks, batch):
            ids = range(c0, min(c0 + batch, n_chunks))
            with span("entropy_encode", chunks=len(ids)):
                blobs = cabac.encode_indices_batch(
                    [idx[c * chunk_elems:(c + 1) * chunk_elems]
                     for c in ids],
                    self.config.n_levels, mode=coder_mode)
            for c, blob in zip(ids, blobs):
                yield struct.pack("<I", c) + blob

    def decode_stream(self, payloads, shape=None) -> np.ndarray:
        """Inverse of :meth:`encode_stream` over an iterable of payloads."""
        dec = None
        for p in payloads:
            if dec is None:
                dec = ChunkStreamDecoder(p, backend=self.backend,
                                         ecsq=self.ecsq)
            else:
                dec.add_chunk(p)
        if dec is None:
            raise ValueError("empty payload stream")
        return dec.finish(shape)


def _calibrate_range(cfg: CodecConfig,
                     samples: np.ndarray | None = None,
                     stats: RunningStats | None = None,
                     sample_mean: float | None = None,
                     sample_var: float | None = None):
    """One (cmin, cmax, model) from calibration data -- the scalar core
    reused per channel group in per-channel mode."""
    if samples is not None:
        s = np.asarray(samples)
        if s.size == 0:
            raise ValueError(
                "calibration samples are empty (a tile plan that slices "
                "to zero elements, or an empty calibration batch)")
        if cfg.calib_sample_cap and s.size > cfg.calib_sample_cap:
            # deterministic even-stride subsample: repeatable sweeps, no
            # RNG, and the extremes of a sorted-ish activation layout
            # still land in the sample
            stride = -(-s.size // cfg.calib_sample_cap)
            samples = s.ravel()[::stride]
    model = None
    if cfg.clip_mode == "manual":
        cmin, cmax = cfg.manual_cmin, cfg.manual_cmax
    elif cfg.clip_mode == "model":
        if sample_mean is None:
            if stats is None:
                if samples is None:
                    raise ValueError("model mode needs samples or stats")
                stats = RunningStats().update(np.asarray(samples))
            sample_mean, sample_var = stats.mean, stats.var
        model = FeatureModel.fit(sample_mean, sample_var, cfg.kappa,
                                 cfg.leaky_slope)
        if cfg.constrain_cmin_zero:
            cmin, cmax = 0.0, clipping.optimal_cmax(model, cfg.n_levels)
        else:
            cmin, cmax = clipping.optimal_range(model, cfg.n_levels)
    elif cfg.clip_mode == "aciq":
        if samples is None:
            raise ValueError("aciq mode needs samples")
        cmin = 0.0
        cmax = aciq.aciq_cmax_from_samples(np.asarray(samples), cfg.n_levels)
    elif cfg.clip_mode == "empirical":
        if samples is None:
            raise ValueError("empirical mode needs samples")
        if cfg.constrain_cmin_zero:
            cmin = 0.0
            cmax = clipping.empirical_optimal_cmax(np.asarray(samples),
                                                   cfg.n_levels)
        else:
            cmin, cmax = clipping.empirical_optimal_range(np.asarray(samples),
                                                          cfg.n_levels)
    elif cfg.clip_mode == "minmax":
        if samples is None:
            raise ValueError("minmax mode needs samples")
        s = np.asarray(samples)
        cmax = float(s.max())
        # pin cmin to 0 only when the data actually lives above it; an
        # all-negative channel would otherwise degenerate to [0, ~0]
        cmin = 0.0 if cfg.constrain_cmin_zero and cmax > 0.0 \
            else float(s.min())
    else:
        raise ValueError(f"unknown clip mode {cfg.clip_mode}")
    # NaN compares False against everything, so it would sail through the
    # degenerate-range lift below and poison the step size -- fail loudly
    if not (np.isfinite(cmin) and np.isfinite(cmax)):
        raise ValueError(
            f"non-finite clip range ({cmin}, {cmax}) from "
            f"clip_mode={cfg.clip_mode!r}; calibration samples likely "
            "contain NaN/Inf")
    if cmax <= cmin:
        cmax = cmin + 1e-6
    return float(cmin), float(cmax), model


def calibrate(config: CodecConfig,
              samples: np.ndarray | None = None,
              stats: RunningStats | None = None,
              sample_mean: float | None = None,
              sample_var: float | None = None) -> FeatureCodec:
    """Build a codec from calibration data or pre-computed stats (see
    :func:`_calibrate_impl` for the modes); traced as one ``calibrate``
    pipeline span."""
    with span("calibrate", granularity=config.granularity,
              n_levels=config.n_levels, clip_mode=config.clip_mode):
        return _calibrate_impl(config, samples, stats, sample_mean,
                               sample_var)


def _calibrate_impl(config: CodecConfig,
                    samples: np.ndarray | None = None,
                    stats: RunningStats | None = None,
                    sample_mean: float | None = None,
                    sample_var: float | None = None) -> FeatureCodec:
    """Build a codec from calibration data or pre-computed stats.

    ``model`` / ``aciq`` modes need only (mean, var) / samples respectively;
    ``empirical`` grid-searches measured MSRE like the paper's empirical
    columns; ``minmax`` uses the sample extremes; ECSQ additionally runs
    Algorithm 1 on the samples.

    "channel" / "tile" granularities calibrate every tile of the
    :class:`TilePlan` independently (``samples`` must then carry the
    channel axis; "tile" additionally pins the spatial extent) and return
    per-tile range tables in ``cmin``/``cmax``.  ``use_ecsq`` with a
    tiled granularity designs one quantizer *per tile* (per-channel /
    per-group ECSQ is the one-spatial-block case).
    """
    cfg = config
    if cfg.spatial_block_hw is not None and cfg.granularity != "tile":
        raise ValueError(
            "spatial_block_hw is a 'tile'-granularity setting; "
            f"granularity={cfg.granularity!r} would silently ignore it")
    if cfg.granularity in ("channel", "tile"):
        if samples is None:
            raise ValueError(f"{cfg.granularity} granularity needs "
                             "calibration samples with the channel axis "
                             "present")
        arr = np.asarray(samples)
        plan = plan_from_config(cfg, arr.shape)
        axis = cfg.channel_axis % arr.ndim
        n_channels = arr.shape[axis]
        per_ch = np.moveaxis(arr, axis, 0).reshape(n_channels, -1)
        lo = np.empty((plan.n_cgroups, plan.n_sblocks), np.float32)
        hi = np.empty_like(lo)
        tile_q = None
        if cfg.use_ecsq:
            tile_q = (np.empty((plan.n_tiles, cfg.n_levels), np.float32),
                      np.empty((plan.n_tiles, cfg.n_levels - 1), np.float32))
        for t, cs, ss in plan.tile_slices(n_channels, per_ch.shape[1]):
            seg = per_ch[cs, ss].ravel()
            cmin_t, cmax_t, _ = _calibrate_range(cfg, seg)
            lo[t // plan.n_sblocks, t % plan.n_sblocks] = cmin_t
            hi[t // plan.n_sblocks, t % plan.n_sblocks] = cmax_t
            if tile_q is not None:
                q = design_ecsq(seg, cfg.n_levels, cfg.ecsq_lagrangian,
                                cmin_t, cmax_t,
                                pin_boundaries=cfg.ecsq_pin_boundaries)
                tile_q[0][t] = q.levels
                tile_q[1][t] = q.thresholds
        tile_ecsq = TileECSQ(*tile_q) if tile_q is not None else None
        # "channel" keeps the historical 1-D group-vector storage
        table_lo = lo.ravel() if plan.n_sblocks == 1 else lo
        table_hi = hi.ravel() if plan.n_sblocks == 1 else hi
        return FeatureCodec(config=cfg, cmin=table_lo, cmax=table_hi,
                            n_channels=n_channels, plan=plan,
                            tile_ecsq=tile_ecsq)

    cmin, cmax, model = _calibrate_range(cfg, samples, stats,
                                         sample_mean, sample_var)
    ecsq_q = None
    if cfg.use_ecsq:
        if samples is None:
            raise ValueError("ECSQ design needs calibration samples")
        ecsq_q = design_ecsq(np.asarray(samples), cfg.n_levels,
                             cfg.ecsq_lagrangian, cmin, cmax,
                             pin_boundaries=cfg.ecsq_pin_boundaries)
    return FeatureCodec(config=cfg, cmin=cmin, cmax=cmax,
                        model=model, ecsq=ecsq_q)
