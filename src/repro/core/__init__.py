"""Core library: the paper's lightweight feature-compression technique.

Modules:
  distributions -- asymmetric-Laplace + leaky-ReLU analytic feature model
  clipping      -- closed-form e_quant/e_clip and optimal clipping ranges
  aciq          -- ACIQ comparison baseline (eq. 13)
  uniform       -- pinned-boundary uniform quantizer (eq. 1)
  ecsq          -- modified entropy-constrained quantizer design (Alg. 1)
  binarization  -- truncated-unary bit planes
  cabac         -- adaptive binary arithmetic codec (host, exact round trip)
  rate_model    -- in-graph entropy rate estimation
  rans          -- vectorized (numpy-batched) rANS plane coder
  stats         -- streaming calibration statistics
  tiling        -- TilePlan geometry (channel-group x spatial-block tiles)
  backend       -- QuantBackend dispatch (Pallas kernels on TPU, jnp on CPU)
  codec         -- FeatureCodec facade tying it all together
"""

from .backend import QuantSpec, get_backend
from .codec import (ChunkStreamDecoder, CodecConfig, FeatureCodec,
                    ParsedHeader, calibrate, parse_header,
                    reconstruct_indices)
from .distributions import FeatureModel, resnet50_layer21_model, yolov3_layer12_model
from .tiling import TileECSQ, TilePlan

__all__ = [
    "CodecConfig", "FeatureCodec", "calibrate", "FeatureModel",
    "QuantSpec", "get_backend", "TilePlan", "TileECSQ",
    "ChunkStreamDecoder", "ParsedHeader", "parse_header",
    "reconstruct_indices",
    "resnet50_layer21_model", "yolov3_layer12_model",
]
