"""Core library: the paper's lightweight feature-compression technique.

Modules:
  distributions -- asymmetric-Laplace + leaky-ReLU analytic feature model
  clipping      -- closed-form e_quant/e_clip and optimal clipping ranges
  aciq          -- ACIQ comparison baseline (eq. 13)
  uniform       -- pinned-boundary uniform quantizer (eq. 1)
  ecsq          -- modified entropy-constrained quantizer design (Alg. 1)
  binarization  -- truncated-unary bit planes
  cabac         -- adaptive binary arithmetic codec (host, exact round trip)
  rate_model    -- in-graph entropy rate estimation
  stats         -- streaming calibration statistics
  codec         -- FeatureCodec facade tying it all together
"""

from .codec import CodecConfig, FeatureCodec, calibrate
from .distributions import FeatureModel, resnet50_layer21_model, yolov3_layer12_model

__all__ = [
    "CodecConfig", "FeatureCodec", "calibrate", "FeatureModel",
    "resnet50_layer21_model", "yolov3_layer12_model",
]
