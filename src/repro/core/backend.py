"""QuantBackend: one dispatch point for every quantization primitive.

The seed grew three parallel implementations of clip/quantize/histogram --
inline jnp in ``core``, Pallas kernels in ``repro.kernels`` that nothing
called, and numpy helpers on the host.  ``FeatureCodec`` (and everything
above it: split runtime, serving engine, examples) now routes through a
backend object so the hot path picks the fused Pallas kernels on TPU and
the plain-jnp reference everywhere else, from a single code path.

Backends implement seven primitives over a :class:`QuantSpec`:

    quantize(x, spec)             -> int32 indices
    dequantize(idx, spec, dtype)  -> reconstructed values
    quantize_dequantize(x, spec)  -> (indices, reconstruction)  [fused]
    histogram(idx, n_levels)      -> (n_levels,) int32 counts
    tile_histogram(idx, spec)     -> (n_cgroups, n_sblocks, N) counts
    pack_indices(idx, bits)       -> uint8 wire bytes (in-graph pack)
    encode_fused(x, spec, bits)   -> (coded-order indices, per-tile hists)

``encode_fused`` is the host encode path's single-pass contract: on the
kernel backend one fused megakernel pass (clip -> quantize -> bit-pack ->
per-tile histogram) produces wire-width packed bytes plus tile index
counts, so exactly one device->host transfer feeds the entropy stage --
no int32 index tensor ever crosses.  The jnp backend fulfils the same
contract with its vectorized formulas (on CPU there is no transfer to
save).  Both return bit-identical coded-order indices, which keeps the
entropy payload byte-identical to the unfused reference path.

``encode_fused(..., emit_wire=True)`` moves the *entropy stage itself*
onto the device: quantize, coded-order permute and the interleaved-rANS
bit-plane coder (``repro.kernels.rans_coder``) all run in-graph, and the
call returns ``(payload, None)`` where ``payload`` is a finished
coder-id-4 bitstream (or a list of per-chunk payloads when
``chunk_bounds`` is given).  Only the coded wire bytes plus the small
per-span probability side info cross device->host.  Payloads are
byte-identical to the host coder id 2 single-shard stream past the id
byte, and shapes the device coder cannot take (``n_levels`` above
:data:`~repro.kernels.rans_coder.MAX_DEVICE_LEVELS`, oversize tensors)
fall back to the host step loop inside the same container -- the wire
format never depends on where the blob was coded.  ``want_hist`` is
incompatible with ``emit_wire`` (histograms live on the index path).

Selection: ``get_backend()`` picks "kernel" when JAX's default backend is
TPU and "jnp" otherwise; override per-codec via ``CodecConfig.backend`` or
globally with the ``REPRO_QUANT_BACKEND`` environment variable
("jnp" | "kernel" | "kernel_interpret", the latter forcing the Pallas
bodies through the interpreter for CPU validation).

Granularity is a :class:`~repro.core.tiling.TilePlan`: ``spec.plan is
None`` with scalar cmin/cmax is the paper's per-tensor mode; a plan makes
cmin/cmax (n_cgroups, n_sblocks) per-tile tables over the channel-major
view.  The legacy per-channel spec form -- (C,) vectors plus
``channel_axis``, produced by v2 stream headers and direct QuantSpec
users -- is normalized into a one-spatial-block plan on entry, so there
is exactly one granularity code path per backend.  The two backends
produce bit-identical *indices* for every plan (so bitstreams and rate
accounting never depend on the backend); reconstructions agree to ~1 ulp
(fma/ordering differences in ``cmin + q*delta``).  Dequantize-only calls
(receiver side) always use the jnp formula -- there is no dedicated
kernel because on-device decode gets the reconstruction from the fused
quantize_dequantize pass.  Per-tile ECSQ (``spec.ecsq`` a
:class:`~repro.core.tiling.TileECSQ`) runs on the jnp formulas in both
backends: it is a host/receiver path, not the in-graph hot path.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..obs.tracing import span, tracer
from . import uniform
from .tiling import TileECSQ, TilePlan

_CHANNEL_EPS = 1e-12  # degenerate-range guard, shared with the tile kernel


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Everything a backend needs to quantize one tensor.

    ``cmin``/``cmax`` are floats (per-tensor), (C,) arrays broadcast
    along ``channel_axis`` (legacy per-channel form), or
    (n_cgroups, n_sblocks) per-tile tables when ``plan`` is set.
    ``ecsq`` optionally carries a designed non-uniform quantizer: an
    ``ECSQQuantizer`` (per-tensor) or a ``TileECSQ`` (per-tile, with
    ``plan``).
    """

    cmin: Any
    cmax: Any
    n_levels: int
    channel_axis: int | None = None
    ecsq: Any = None
    plan: TilePlan | None = None

    @property
    def per_channel(self) -> bool:
        return self.channel_axis is not None or self.plan is not None


def _normalize(spec: QuantSpec) -> QuantSpec:
    """Fold the legacy (C,)-vector per-channel form into a TilePlan, and
    reject spec combinations that would otherwise be silently ignored."""
    if spec.plan is not None or spec.channel_axis is not None:
        if spec.ecsq is not None and not isinstance(spec.ecsq, TileECSQ):
            raise ValueError(
                "a tiled QuantSpec needs per-tile TileECSQ tables; a "
                "per-tensor ECSQQuantizer cannot be combined with a "
                "plan or channel_axis")
    if spec.plan is not None:
        return spec
    if spec.channel_axis is None:
        return spec
    lo = np.asarray(spec.cmin, np.float32).reshape(-1, 1)
    hi = np.asarray(spec.cmax, np.float32).reshape(-1, 1)
    plan = TilePlan(channel_axis=spec.channel_axis, channel_group_size=1,
                    spatial_block_size=0, n_channels=lo.shape[0])
    return dataclasses.replace(spec, cmin=lo, cmax=hi, plan=plan)


def _tile_tables(x_ndim_shape, spec: QuantSpec):
    """Per-element (C, M) range views for a plan spec over ``shape``.

    Returns (axis, C, M, lo, hi) with lo/hi broadcastable against the
    channel-major (C, M) view: (C, 1) when one spatial block (no
    materialized (C, M) table), full (C, M) gathers otherwise.
    """
    plan = spec.plan
    axis, c, m = plan.resolve(x_ndim_shape)
    lo = jnp.asarray(spec.cmin, jnp.float32).reshape(
        plan.n_cgroups, plan.n_sblocks)
    hi = jnp.asarray(spec.cmax, jnp.float32).reshape(
        plan.n_cgroups, plan.n_sblocks)
    cg = plan.cgroup_ids()
    if plan.n_sblocks == 1:
        return axis, c, m, lo[cg], hi[cg]          # (C, 1) broadcast
    sb = plan.sblock_ids(m)
    return axis, c, m, lo[cg][:, sb], hi[cg][:, sb]


def _coded_order(idx: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Flat coded-order view of quantizer indices (tile-major for plans)."""
    if spec.plan is not None:
        return spec.plan.to_coded_order(idx)
    return np.asarray(idx).ravel()


def _coded_order_device(q, spec: QuantSpec):
    """In-graph mirror of :func:`_coded_order`: device coded-order indices
    with no host round-trip (the spatial permutation is a static gather)."""
    plan = spec.plan
    if plan is None:
        return q.reshape(-1)
    axis, c, m = plan.resolve(q.shape)
    rows = jnp.moveaxis(q, axis, 0).reshape(c, m)
    perm = plan.spatial_perm(m)
    if perm is not None:
        rows = jnp.take(rows, jnp.asarray(perm), axis=1)
    return rows.reshape(-1)


def _unpack_bytes_device(packed, bits: int):
    """In-graph mirror of ``ops.unpack_bytes`` (uint8 -> int32 indices)."""
    per = 8 // bits if bits in (1, 2, 4) else 1
    if per == 1:
        return packed.astype(jnp.int32)
    shifts = (jnp.arange(per, dtype=jnp.uint8) * bits)[None, :]
    mask = jnp.uint8((1 << bits) - 1)
    vals = (packed.reshape(-1, 1) >> shifts) & mask
    return vals.reshape(packed.shape[:-1] + (-1,)).astype(jnp.int32)


def _unpack_layout_device(idx2d, lay):
    """In-graph mirror of ``PaddedLayout.unpack_indices``: strip the
    megakernel's padded (rows, cols) view down to flat coded order using
    only static slices and gathers."""
    idx2d = idx2d.reshape(lay.rows, lay.cols)
    if lay.flat_n is not None:
        return idx2d.reshape(-1)[:lay.flat_n]
    if lay.band_valid is not None:
        return jnp.take(idx2d[:lay.ch], jnp.asarray(lay.coded_cols()),
                        axis=1).reshape(-1)
    a = idx2d[:lay.ch].reshape(lay.ch, lay.n_sblocks, lay.sb_cols)
    a = a[:, :, :lay.bs].reshape(lay.ch, -1)[:, :lay.m]
    return a.reshape(-1)


def _encode_wire(coded, spec: QuantSpec, chunk_bounds, *, use_kernel: bool,
                 interpret):
    """Device entropy stage: coded-order indices (on device) -> finished
    coder-id-4 payload bytes (one, or one per ``chunk_bounds`` range)."""
    from ..kernels import rans_coder
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    if chunk_bounds is None:
        return rans_coder.encode_indices_device(
            coded, spec.n_levels, use_kernel=use_kernel, interpret=interpret)
    return rans_coder.encode_index_chunks_device(
        coded, spec.n_levels, list(chunk_bounds),
        use_kernel=use_kernel, interpret=interpret)


def _tile_hists_np(coded: np.ndarray, spec: QuantSpec) -> np.ndarray:
    """Host per-tile histograms from coded-order indices:
    (n_cgroups, n_sblocks, N); (1, 1, N) for per-tensor specs.

    Works off the coded-order band bounds (every tile is a contiguous
    run of each coded channel row), so 1-D flat runs and 2-D row x column
    blocks are the same loop.
    """
    n = spec.n_levels
    if spec.plan is None:
        return np.bincount(coded, minlength=n).reshape(1, 1, n) \
            .astype(np.int32)
    plan = spec.plan
    c = plan.n_channels
    m = coded.size // max(c, 1)
    arr = coded.reshape(c, m)
    gc = plan.channel_group_size
    bounds = plan.coded_band_bounds(m)
    out = np.zeros((plan.n_cgroups, plan.n_sblocks, n), np.int32)
    for g in range(plan.n_cgroups):
        rows = arr[g * gc:min((g + 1) * gc, c)]
        for b in range(plan.n_sblocks):
            out[g, b] = np.bincount(
                rows[:, bounds[b]:bounds[b + 1]].ravel(), minlength=n)
    return out


class JnpBackend:
    """Pure-jnp reference path (CPU default; numerics identical to seed)."""

    name = "jnp"

    def _tiled_qdq(self, x, spec: QuantSpec, want_deq: bool):
        axis, c, m, lo, hi = _tile_tables(x.shape, spec)
        xm = jnp.moveaxis(x, axis, 0).reshape(c, m).astype(jnp.float32)
        if isinstance(spec.ecsq, TileECSQ):
            tid = spec.plan.tile_ids_2d(m)
            thr = np.asarray(spec.ecsq.thresholds, np.float32)
            xc = jnp.clip(xm, lo, hi)
            idx = jnp.zeros(xm.shape, jnp.int32)
            for k in range(spec.n_levels - 1):
                idx = idx + (xc >= jnp.asarray(thr[:, k])[tid]) \
                    .astype(jnp.int32)
            deq = None
            if want_deq:
                lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
                deq = lv[tid, idx]
        else:
            span = jnp.maximum(hi - lo, _CHANNEL_EPS)
            scale = (spec.n_levels - 1) / span
            xc = jnp.clip(xm, lo, hi)
            q = jnp.floor((xc - lo) * scale + 0.5)
            idx = q.astype(jnp.int32)
            deq = (lo + q * (span / (spec.n_levels - 1))) if want_deq \
                else None

        def restore(a, dtype):
            moved = (c,) + tuple(s for d, s in enumerate(x.shape)
                                 if d != axis)
            return jnp.moveaxis(a.reshape(moved), 0, axis).astype(dtype)
        idx = restore(idx, jnp.int32)
        return idx, (restore(deq, x.dtype) if want_deq else None)

    def quantize(self, x, spec: QuantSpec):
        # index-only path: eager host callers (encode/estimate_rate) would
        # otherwise materialize a discarded reconstruction tensor
        spec = _normalize(spec)
        if spec.plan is not None:
            return self._tiled_qdq(x, spec, want_deq=False)[0]
        if spec.ecsq is not None:
            t = jnp.asarray(spec.ecsq.thresholds, jnp.float32)
            xc = jnp.clip(x.astype(jnp.float32), spec.cmin, spec.cmax)
            return jnp.searchsorted(t, xc, side="right").astype(jnp.int32)
        return uniform.quantize(x, spec.cmin, spec.cmax, spec.n_levels)

    def quantize_dequantize(self, x, spec: QuantSpec):
        spec = _normalize(spec)
        if spec.plan is not None:
            return self._tiled_qdq(x, spec, want_deq=True)
        if spec.ecsq is not None:
            t = jnp.asarray(spec.ecsq.thresholds, jnp.float32)
            lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
            xc = jnp.clip(x.astype(jnp.float32), spec.cmin, spec.cmax)
            idx = jnp.searchsorted(t, xc, side="right").astype(jnp.int32)
            return idx, lv[idx].astype(x.dtype)
        idx = uniform.quantize(x, spec.cmin, spec.cmax, spec.n_levels)
        deq = uniform.dequantize(idx, spec.cmin, spec.cmax,
                                 spec.n_levels, dtype=x.dtype)
        return idx, deq

    def dequantize(self, idx, spec: QuantSpec, dtype=jnp.float32):
        spec = _normalize(spec)
        if spec.plan is not None:
            axis, c, m, lo, hi = _tile_tables(idx.shape, spec)
            im = jnp.moveaxis(idx, axis, 0).reshape(c, m)
            if isinstance(spec.ecsq, TileECSQ):
                lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
                out = lv[spec.plan.tile_ids_2d(m), im]
            else:
                delta = jnp.maximum(hi - lo, _CHANNEL_EPS) \
                    / (spec.n_levels - 1)
                out = lo + im.astype(jnp.float32) * delta
            moved = (c,) + tuple(s for d, s in enumerate(idx.shape)
                                 if d != axis)
            return jnp.moveaxis(out.reshape(moved), 0, axis).astype(dtype)
        if spec.ecsq is not None:
            lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
            return lv[idx].astype(dtype)
        return uniform.dequantize(idx, spec.cmin, spec.cmax,
                                  spec.n_levels, dtype=dtype)

    def histogram(self, idx, n_levels: int):
        from .rate_model import index_histogram
        return index_histogram(idx, n_levels)

    def tile_histogram(self, idx, spec: QuantSpec):
        """(n_cgroups, n_sblocks, N) in-graph per-tile index counts."""
        spec = _normalize(spec)
        if spec.plan is None:
            return self.histogram(idx, spec.n_levels).reshape(1, 1, -1)
        plan = spec.plan
        axis, c, m = plan.resolve(idx.shape)
        im = jnp.moveaxis(idx, axis, 0).reshape(c, m)
        tid = plan.tile_ids_2d(m)
        hist = jnp.zeros((plan.n_tiles, spec.n_levels), jnp.int32) \
            .at[tid, im].add(1)
        return hist.reshape(plan.n_cgroups, plan.n_sblocks, spec.n_levels)

    def coded_indices_device(self, x, spec: QuantSpec, bits: int):
        """Device coded-order indices, no host transfer: quantize +
        coded-order permute stay in-graph (the emit_wire intermediate,
        exposed for cross-session batching)."""
        spec = _normalize(spec)
        return _coded_order_device(self.quantize(x, spec), spec)

    def encode_fused(self, x, spec: QuantSpec, bits: int,
                     want_hist: bool = False, emit_wire: bool = False,
                     chunk_bounds=None):
        """Fused-encode contract on the reference path: coded-order
        indices plus (optionally) host per-tile histograms; with
        ``emit_wire`` the device entropy stage returns finished payload
        bytes instead (see the module docstring)."""
        spec = _normalize(spec)
        tr = tracer()
        if emit_wire:
            if want_hist:
                raise ValueError("emit_wire returns wire bytes; per-tile "
                                 "histograms need the index path")
            with tr.span("fused_launch", backend=self.name), \
                    tr.annotate("repro.encode_fused"):
                coded = self.coded_indices_device(x, spec, bits)
            return _encode_wire(coded, spec, chunk_bounds,
                                use_kernel=False, interpret=False), None
        with tr.span("fused_launch", backend=self.name), \
                tr.annotate("repro.encode_fused"):
            q = self.quantize(x, spec)
            if tr.enabled:
                # bound the launch span at the device sync, so the
                # device_to_host span measures only the transfer
                q = jax.block_until_ready(q)
        with tr.span("device_to_host"):
            q = np.asarray(q)
        with tr.span("host_unpack"):
            coded = _coded_order(q, spec)
        hists = _tile_hists_np(coded, spec) if want_hist else None
        return coded, hists

    def pack_indices(self, idx, bits: int):
        """Host/jnp bit-pack (the wire layout every backend shares)."""
        per = 8 // bits if bits in (1, 2, 4) else 1
        if per == 1:
            return idx.astype(jnp.uint8)
        flat = idx.reshape(-1)
        pad = (-flat.shape[0]) % per
        if pad:
            flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
        flat = flat.reshape(-1, per).astype(jnp.uint8)
        shifts = jnp.arange(per, dtype=jnp.uint8) * bits
        return jnp.sum(flat << shifts, axis=-1).astype(jnp.uint8)


class KernelBackend:
    """Pallas-kernel path (TPU hot path; interpretable on CPU).

    Quantization lowers through the fused clip+quant kernels in
    ``repro.kernels`` (scalar-range or blocked per-tile variant);
    histograms use the on-device reduction kernel and packing the
    on-device pack kernel.  Falls back to the jnp formulas only where no
    kernel exists (dequantize-only, per-tile ECSQ, N > 64).
    """

    name = "kernel"

    def __init__(self, interpret: bool | None = None) -> None:
        self.interpret = interpret
        self._jnp = JnpBackend()

    def quantize(self, x, spec: QuantSpec):
        return self.quantize_dequantize(x, spec)[0]

    def quantize_dequantize(self, x, spec: QuantSpec):
        from ..kernels import ops
        from ..kernels.ecsq_assign import MAX_LEVELS
        spec = _normalize(spec)
        if spec.plan is not None:
            if isinstance(spec.ecsq, TileECSQ):
                if spec.n_levels > MAX_LEVELS:
                    return self._jnp.quantize_dequantize(x, spec)
                plan = spec.plan
                plan.resolve(x.shape)
                lo = jnp.asarray(spec.cmin, jnp.float32).reshape(
                    plan.n_cgroups, plan.n_sblocks)
                hi = jnp.asarray(spec.cmax, jnp.float32).reshape(
                    plan.n_cgroups, plan.n_sblocks)
                return ops.ecsq_quantize_tiled(
                    x, lo, hi,
                    jnp.asarray(spec.ecsq.thresholds, jnp.float32),
                    jnp.asarray(spec.ecsq.levels, jnp.float32),
                    n_levels=spec.n_levels, plan=plan,
                    interpret=self.interpret)
            plan = spec.plan
            plan.resolve(x.shape)
            lo = jnp.asarray(spec.cmin, jnp.float32).reshape(
                plan.n_cgroups, plan.n_sblocks)
            hi = jnp.asarray(spec.cmax, jnp.float32).reshape(
                plan.n_cgroups, plan.n_sblocks)
            return ops.clip_quantize_tiled(
                x, lo, hi, n_levels=spec.n_levels, plan=plan,
                interpret=self.interpret)
        if spec.ecsq is not None:
            if spec.n_levels > MAX_LEVELS:
                return self._jnp.quantize_dequantize(x, spec)
            return ops.ecsq_quantize(
                x, jnp.asarray(spec.ecsq.thresholds, jnp.float32),
                jnp.asarray(spec.ecsq.levels, jnp.float32),
                cmin=float(spec.cmin), cmax=float(spec.cmax),
                interpret=self.interpret)
        return ops.clip_quantize(x, cmin=float(spec.cmin),
                                 cmax=float(spec.cmax),
                                 n_levels=spec.n_levels,
                                 interpret=self.interpret)

    def dequantize(self, idx, spec: QuantSpec, dtype=jnp.float32):
        return self._jnp.dequantize(idx, spec, dtype=dtype)

    def histogram(self, idx, n_levels: int):
        from ..kernels import ops
        from ..kernels.rate_hist import MAX_LEVELS
        if n_levels > MAX_LEVELS:
            return self._jnp.histogram(idx, n_levels)
        return ops.index_histogram(idx, n_levels=n_levels,
                                   interpret=self.interpret)

    def tile_histogram(self, idx, spec: QuantSpec):
        from ..kernels import ops
        from ..kernels.rate_hist import MAX_LEVELS
        spec = _normalize(spec)
        if spec.plan is None:
            return self.histogram(idx, spec.n_levels).reshape(1, 1, -1)
        if spec.n_levels > MAX_LEVELS:
            return self._jnp.tile_histogram(idx, spec)
        plan = spec.plan
        plan.resolve(idx.shape)
        return ops.index_histogram_tiled(
            idx, n_levels=spec.n_levels, plan=plan,
            interpret=self.interpret)

    def coded_indices_device(self, x, spec: QuantSpec, bits: int):
        """Device coded-order indices, no host transfer: the megakernel's
        packed output is unpacked and layout-stripped in-graph (the
        emit_wire intermediate, exposed for cross-session batching)."""
        from ..kernels import ops
        from ..kernels.fused_clip_quant import HIST_WIDTH
        spec = _normalize(spec)
        if spec.ecsq is not None or spec.n_levels > HIST_WIDTH:
            return _coded_order_device(self.quantize(x, spec), spec)
        if spec.plan is None:
            packed, _, lay = ops.encode_fused(
                x, float(spec.cmin), float(spec.cmax),
                n_levels=spec.n_levels, bits=bits, interpret=self.interpret)
        else:
            plan = spec.plan
            plan.resolve(x.shape)
            lo = np.asarray(spec.cmin, np.float32).reshape(
                plan.n_cgroups, plan.n_sblocks)
            hi = np.asarray(spec.cmax, np.float32).reshape(
                plan.n_cgroups, plan.n_sblocks)
            packed, _, lay = ops.encode_fused(
                x, lo, hi, n_levels=spec.n_levels, bits=bits,
                plan=plan, interpret=self.interpret)
        return _unpack_layout_device(_unpack_bytes_device(packed, bits), lay)

    def encode_fused(self, x, spec: QuantSpec, bits: int,
                     want_hist: bool = False, emit_wire: bool = False,
                     chunk_bounds=None):
        """One megakernel pass -> (packed bytes + tile hists) on device;
        the np.asarray fetches here are the path's single transfer, and
        the host only unpacks wire-width bytes back to indices.

        ``emit_wire=True`` keeps going on device: the packed megakernel
        output is unpacked and layout-stripped in-graph and fed straight
        into the device rANS stage, so the only device->host traffic is
        the finished coder-id-4 payload."""
        from ..kernels import ops
        from ..kernels.fused_clip_quant import HIST_WIDTH
        spec = _normalize(spec)
        tr = tracer()
        if emit_wire:
            if want_hist:
                raise ValueError("emit_wire returns wire bytes; per-tile "
                                 "histograms need the index path")
            with tr.span("fused_launch", backend=self.name), \
                    tr.annotate("repro.encode_fused"):
                coded = self.coded_indices_device(x, spec, bits)
            return _encode_wire(coded, spec, chunk_bounds,
                                use_kernel=True,
                                interpret=self.interpret), None
        if spec.ecsq is not None or spec.n_levels > HIST_WIDTH:
            # no fused kernel for designed quantizers / wide histograms:
            # kernel-quantize, then the host fallback of the contract
            with tr.span("fused_launch", backend=self.name), \
                    tr.annotate("repro.encode_fused"):
                q = self.quantize(x, spec)
                if tr.enabled:
                    q = jax.block_until_ready(q)
            with tr.span("device_to_host"):
                q = np.asarray(q)
            with tr.span("host_unpack"):
                coded = _coded_order(q, spec)
            return coded, (_tile_hists_np(coded, spec) if want_hist
                           else None)
        with tr.span("fused_launch", backend=self.name), \
                tr.annotate("repro.encode_fused"):
            if spec.plan is None:
                packed, hist, lay = ops.encode_fused(
                    x, float(spec.cmin), float(spec.cmax),
                    n_levels=spec.n_levels, bits=bits,
                    interpret=self.interpret)
            else:
                plan = spec.plan
                plan.resolve(x.shape)
                lo = np.asarray(spec.cmin, np.float32).reshape(
                    plan.n_cgroups, plan.n_sblocks)
                hi = np.asarray(spec.cmax, np.float32).reshape(
                    plan.n_cgroups, plan.n_sblocks)
                packed, hist, lay = ops.encode_fused(
                    x, lo, hi, n_levels=spec.n_levels, bits=bits,
                    plan=plan, interpret=self.interpret)
            if tr.enabled:
                # bound the launch at the device sync so the transfer
                # span below measures only the packed-bytes fetch (the
                # path's single device->host transfer)
                packed = jax.block_until_ready(packed)
        with tr.span("device_to_host"):
            packed = np.asarray(packed)
            hist = np.asarray(hist) if want_hist else hist
        with tr.span("host_unpack"):
            coded = lay.unpack_indices(ops.unpack_bytes(packed, bits))
        hists = lay.group_hists(hist, spec.n_levels,
                                HIST_WIDTH) if want_hist else None
        return coded, hists

    def pack_indices(self, idx, bits: int):
        from ..kernels import ops
        if bits not in (1, 2, 4):
            return self._jnp.pack_indices(idx, bits)
        return ops.pack_indices(idx, bits=bits, interpret=self.interpret)


_BACKENDS: dict[str, Any] = {}


def get_backend(name: str | None = None):
    """Resolve a backend by name, env override, or hardware default."""
    if name is None:
        name = os.environ.get("REPRO_QUANT_BACKEND")
    if name is None:
        name = "kernel" if jax.default_backend() == "tpu" else "jnp"
    if name not in _BACKENDS:
        if name == "jnp":
            _BACKENDS[name] = JnpBackend()
        elif name == "kernel":
            _BACKENDS[name] = KernelBackend()
        elif name == "kernel_interpret":
            _BACKENDS[name] = KernelBackend(interpret=True)
        else:
            raise ValueError(f"unknown quant backend {name!r}")
    return _BACKENDS[name]


def spec_from_numpy(cmin, cmax, n_levels: int, channel_axis: int | None,
                    ecsq=None) -> QuantSpec:
    """Build a QuantSpec from host (numpy/float) calibration state."""
    if channel_axis is None:
        return QuantSpec(float(cmin), float(cmax), n_levels, None, ecsq)
    return QuantSpec(np.asarray(cmin, np.float32),
                     np.asarray(cmax, np.float32),
                     n_levels, channel_axis, ecsq)
