"""QuantBackend: one dispatch point for every quantization primitive.

The seed grew three parallel implementations of clip/quantize/histogram --
inline jnp in ``core``, Pallas kernels in ``repro.kernels`` that nothing
called, and numpy helpers on the host.  ``FeatureCodec`` (and everything
above it: split runtime, serving engine, examples) now routes through a
backend object so the hot path picks the fused Pallas kernels on TPU and
the plain-jnp reference everywhere else, from a single code path.

Backends implement four primitives over a :class:`QuantSpec`:

    quantize(x, spec)             -> int32 indices
    dequantize(idx, spec, dtype)  -> reconstructed values
    quantize_dequantize(x, spec)  -> (indices, reconstruction)  [fused]
    histogram(idx, n_levels)      -> (n_levels,) int32 counts

Selection: ``get_backend()`` picks "kernel" when JAX's default backend is
TPU and "jnp" otherwise; override per-codec via ``CodecConfig.backend`` or
globally with the ``REPRO_QUANT_BACKEND`` environment variable
("jnp" | "kernel" | "kernel_interpret", the latter forcing the Pallas
bodies through the interpreter for CPU validation).

Granularity: ``spec.channel_axis is None`` is the paper's per-tensor mode
(scalar cmin/cmax); otherwise cmin/cmax are per-channel vectors broadcast
along that axis ("channel" granularity, companion-paper tiling).  The two
backends produce bit-identical *indices* for both modes (so bitstreams
and rate accounting never depend on the backend); reconstructions agree
to ~1 ulp (fma/ordering differences in ``cmin + q*delta``).
Dequantize-only calls (receiver side) always use the jnp formula --
there is no dedicated kernel because on-device decode gets the
reconstruction from the fused quantize_dequantize pass.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from . import uniform

_CHANNEL_EPS = 1e-12  # degenerate-range guard, shared with the row kernel


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Everything a backend needs to quantize one tensor.

    ``cmin``/``cmax`` are floats (per-tensor) or (C,) arrays broadcast
    along ``channel_axis`` (per-channel).  ``ecsq`` optionally carries a
    designed non-uniform quantizer (per-tensor only).
    """

    cmin: Any
    cmax: Any
    n_levels: int
    channel_axis: int | None = None
    ecsq: Any = None

    @property
    def per_channel(self) -> bool:
        return self.channel_axis is not None


def _channel_shape(x_ndim: int, axis: int, n: int) -> tuple[int, ...]:
    axis = axis % x_ndim
    shape = [1] * x_ndim
    shape[axis] = n
    return tuple(shape)


def _broadcast_ranges(x, spec: QuantSpec):
    cmin = jnp.asarray(spec.cmin, jnp.float32)
    cmax = jnp.asarray(spec.cmax, jnp.float32)
    axis = spec.channel_axis % x.ndim
    if x.shape[axis] != cmin.shape[0]:
        raise ValueError(
            f"tensor has {x.shape[axis]} channels on axis {axis}, codec "
            f"was calibrated for {cmin.shape[0]}")
    shape = _channel_shape(x.ndim, spec.channel_axis, cmin.shape[0])
    return cmin.reshape(shape), cmax.reshape(shape)


class JnpBackend:
    """Pure-jnp reference path (CPU default; numerics identical to seed)."""

    name = "jnp"

    def quantize(self, x, spec: QuantSpec):
        # index-only path: eager host callers (encode/estimate_rate) would
        # otherwise materialize a discarded reconstruction tensor
        if spec.ecsq is not None:
            t = jnp.asarray(spec.ecsq.thresholds, jnp.float32)
            xc = jnp.clip(x.astype(jnp.float32), spec.cmin, spec.cmax)
            return jnp.searchsorted(t, xc, side="right").astype(jnp.int32)
        if not spec.per_channel:
            return uniform.quantize(x, spec.cmin, spec.cmax, spec.n_levels)
        cmin, cmax = _broadcast_ranges(x, spec)
        span = jnp.maximum(cmax - cmin, _CHANNEL_EPS)
        scale = (spec.n_levels - 1) / span
        xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
        return jnp.floor((xc - cmin) * scale + 0.5).astype(jnp.int32)

    def quantize_dequantize(self, x, spec: QuantSpec):
        if spec.ecsq is not None:
            t = jnp.asarray(spec.ecsq.thresholds, jnp.float32)
            lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
            xc = jnp.clip(x.astype(jnp.float32), spec.cmin, spec.cmax)
            idx = jnp.searchsorted(t, xc, side="right").astype(jnp.int32)
            return idx, lv[idx].astype(x.dtype)
        if not spec.per_channel:
            idx = uniform.quantize(x, spec.cmin, spec.cmax, spec.n_levels)
            deq = uniform.dequantize(idx, spec.cmin, spec.cmax,
                                     spec.n_levels, dtype=x.dtype)
            return idx, deq
        cmin, cmax = _broadcast_ranges(x, spec)
        span = jnp.maximum(cmax - cmin, _CHANNEL_EPS)
        scale = (spec.n_levels - 1) / span
        xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
        q = jnp.floor((xc - cmin) * scale + 0.5)
        idx = q.astype(jnp.int32)
        deq = (cmin + q * (span / (spec.n_levels - 1))).astype(x.dtype)
        return idx, deq

    def dequantize(self, idx, spec: QuantSpec, dtype=jnp.float32):
        if spec.ecsq is not None:
            lv = jnp.asarray(spec.ecsq.levels, jnp.float32)
            return lv[idx].astype(dtype)
        if not spec.per_channel:
            return uniform.dequantize(idx, spec.cmin, spec.cmax,
                                      spec.n_levels, dtype=dtype)
        cmin, cmax = _broadcast_ranges(idx, spec)
        span = jnp.maximum(cmax - cmin, _CHANNEL_EPS)
        delta = span / (spec.n_levels - 1)
        return (cmin + idx.astype(jnp.float32) * delta).astype(dtype)

    def histogram(self, idx, n_levels: int):
        from .rate_model import index_histogram
        return index_histogram(idx, n_levels)


class KernelBackend:
    """Pallas-kernel path (TPU hot path; interpretable on CPU).

    Quantization lowers through the fused clip+quant kernels in
    ``repro.kernels`` (scalar-range or per-row variant); histograms use
    the on-device reduction kernel.  Falls back to the jnp formulas only
    where no kernel exists (dequantize-only, N > 16 histograms).
    """

    name = "kernel"

    def __init__(self, interpret: bool | None = None) -> None:
        self.interpret = interpret
        self._jnp = JnpBackend()

    def quantize(self, x, spec: QuantSpec):
        return self.quantize_dequantize(x, spec)[0]

    def quantize_dequantize(self, x, spec: QuantSpec):
        from ..kernels import ops
        if spec.ecsq is not None:
            return ops.ecsq_quantize(
                x, jnp.asarray(spec.ecsq.thresholds, jnp.float32),
                jnp.asarray(spec.ecsq.levels, jnp.float32),
                cmin=float(spec.cmin), cmax=float(spec.cmax),
                interpret=self.interpret)
        if not spec.per_channel:
            return ops.clip_quantize(x, cmin=float(spec.cmin),
                                     cmax=float(spec.cmax),
                                     n_levels=spec.n_levels,
                                     interpret=self.interpret)
        axis = spec.channel_axis % x.ndim
        if x.shape[axis] != np.shape(spec.cmin)[0]:
            raise ValueError(
                f"tensor has {x.shape[axis]} channels on axis {axis}, codec "
                f"was calibrated for {np.shape(spec.cmin)[0]}")
        return ops.clip_quantize_channels(
            x, jnp.asarray(spec.cmin, jnp.float32),
            jnp.asarray(spec.cmax, jnp.float32),
            n_levels=spec.n_levels, channel_axis=spec.channel_axis,
            interpret=self.interpret)

    def dequantize(self, idx, spec: QuantSpec, dtype=jnp.float32):
        return self._jnp.dequantize(idx, spec, dtype=dtype)

    def histogram(self, idx, n_levels: int):
        from ..kernels import ops
        from ..kernels.rate_hist import MAX_LEVELS
        if n_levels > MAX_LEVELS:
            return self._jnp.histogram(idx, n_levels)
        return ops.index_histogram(idx, n_levels=n_levels,
                                   interpret=self.interpret)


_BACKENDS: dict[str, Any] = {}


def get_backend(name: str | None = None):
    """Resolve a backend by name, env override, or hardware default."""
    if name is None:
        name = os.environ.get("REPRO_QUANT_BACKEND")
    if name is None:
        name = "kernel" if jax.default_backend() == "tpu" else "jnp"
    if name not in _BACKENDS:
        if name == "jnp":
            _BACKENDS[name] = JnpBackend()
        elif name == "kernel":
            _BACKENDS[name] = KernelBackend()
        elif name == "kernel_interpret":
            _BACKENDS[name] = KernelBackend(interpret=True)
        else:
            raise ValueError(f"unknown quant backend {name!r}")
    return _BACKENDS[name]


def spec_from_numpy(cmin, cmax, n_levels: int, channel_axis: int | None,
                    ecsq=None) -> QuantSpec:
    """Build a QuantSpec from host (numpy/float) calibration state."""
    if channel_axis is None:
        return QuantSpec(float(cmin), float(cmax), n_levels, None, ecsq)
    return QuantSpec(np.asarray(cmin, np.float32),
                     np.asarray(cmax, np.float32),
                     n_levels, channel_axis, ecsq)
