"""In-graph (jnp) rate estimation for the lightweight codec.

The adaptive arithmetic coder's rate converges to the per-context empirical
entropy of the TU bit planes.  Given the histogram of quantizer indices we
can compute that bound entirely inside a jitted program -- this is what the
distributed runtime uses to account for inter-pod bandwidth without ever
materializing a bitstream on-device.

For context j (0 <= j < N-1):
    total_j = #{n >= j}   bits coded in that context
    ones_j  = #{n >  j}   of which are 1
    bits_j  = total_j * H2(ones_j / total_j)
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def index_histogram(idx, n_levels: int):
    """Histogram of quantizer indices, differentiable-safe (int path)."""
    one_hot = (idx[..., None] == jnp.arange(n_levels)).astype(jnp.int32)
    return one_hot.reshape(-1, n_levels).sum(axis=0)


def _binary_entropy(p):
    # eps must be representable in float32 near 1.0 (1e-12 rounds to 1.0
    # and yields 0 * log(0) = NaN); degenerate bins carry ~0 bits anyway
    eps = 1e-6
    p = jnp.clip(p, eps, 1.0 - eps)
    return -(p * jnp.log2(p) + (1 - p) * jnp.log2(1 - p))


def estimated_bits_from_hist(hist, n_levels: int):
    """Entropy-coded size estimate (bits) from an index histogram."""
    hist = hist.astype(jnp.float32)
    # suffix sums: ge[j] = #{n >= j}, gt[j] = #{n > j}
    rev_cum = jnp.cumsum(hist[::-1])[::-1]          # ge[j]
    ge = rev_cum[: n_levels - 1]
    gt = jnp.concatenate([rev_cum[1:], jnp.zeros((1,), hist.dtype)])[: n_levels - 1]
    p1 = gt / jnp.maximum(ge, 1)
    bits = ge * _binary_entropy(p1)
    return jnp.sum(jnp.where(ge > 0, bits, 0.0))


def estimated_bits_from_tile_hists(hists, n_levels: int,
                                   per_tile: bool = False):
    """Entropy-coded size estimate from per-tile index histograms.

    ``hists`` is (..., N) -- e.g. the (n_cgroups, n_sblocks, N) tables a
    fused encode pass emits.  Each tile's TU planes are modelled with
    tile-local probabilities (what the tile-aligned chunked coder
    actually uses), so the total is never above the single-histogram
    estimate.  Returns the summed bits, or per-tile bits of shape
    ``hists.shape[:-1]`` when ``per_tile`` is set.  Vectorized over
    tiles; jit-safe.
    """
    h = hists.astype(jnp.float32).reshape(-1, n_levels)
    rev_cum = jnp.cumsum(h[:, ::-1], axis=1)[:, ::-1]        # ge[t, j]
    ge = rev_cum[:, : n_levels - 1]
    gt = jnp.concatenate(
        [rev_cum[:, 1:], jnp.zeros((h.shape[0], 1), h.dtype)],
        axis=1)[:, : n_levels - 1]
    p1 = gt / jnp.maximum(ge, 1)
    bits = jnp.sum(jnp.where(ge > 0, ge * _binary_entropy(p1), 0.0), axis=1)
    if per_tile:
        return bits.reshape(jnp.shape(hists)[:-1])
    return jnp.sum(bits)


def estimated_bits_per_element(idx, n_levels: int):
    hist = index_histogram(idx, n_levels)
    n = jnp.maximum(idx.size, 1)
    return estimated_bits_from_hist(hist, n_levels) / n


def estimated_bits_np(idx: np.ndarray, n_levels: int) -> float:
    """Host-side reference of the same estimate."""
    idx = np.asarray(idx).ravel()
    hist = np.bincount(idx, minlength=n_levels).astype(np.float64)
    ge = np.cumsum(hist[::-1])[::-1]
    total = 0.0
    for j in range(n_levels - 1):
        tot = ge[j]
        if tot <= 0:
            continue
        ones = ge[j + 1] if j + 1 < n_levels else 0.0
        p = ones / tot
        if 0 < p < 1:
            total += tot * (-(p * np.log2(p) + (1 - p) * np.log2(1 - p)))
    return total
