"""Streaming statistics for codec calibration (paper Sec. III-E).

The clipping model needs only the sample mean and variance of the split
layer's output.  The paper notes these converge within a few hundred
calibration images; we provide a Welford accumulator for host-side
calibration and a mesh-aware in-graph reducer so calibration can run
sharded across pods (stats are psum-combined).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class RunningStats:
    """Chan/Welford parallel-merge mean & variance accumulator."""

    count: float = 0.0
    mean: float = 0.0
    m2: float = 0.0

    def update(self, x: np.ndarray) -> "RunningStats":
        x = np.asarray(x, dtype=np.float64).ravel()
        n_b = x.size
        if n_b == 0:
            return self
        mean_b = float(x.mean())
        m2_b = float(((x - mean_b) ** 2).sum())
        n_a, mean_a, m2_a = self.count, self.mean, self.m2
        n = n_a + n_b
        delta = mean_b - mean_a
        self.mean = mean_a + delta * n_b / n
        self.m2 = m2_a + m2_b + delta * delta * n_a * n_b / n
        self.count = n
        return self

    def merge(self, other: "RunningStats") -> "RunningStats":
        n = self.count + other.count
        if n == 0:
            return self
        delta = other.mean - self.mean
        self.mean += delta * other.count / n
        self.m2 += other.m2 + delta * delta * self.count * other.count / n
        self.count = n
        return self

    @property
    def var(self) -> float:
        return self.m2 / self.count if self.count > 0 else 0.0


def batch_stats(x):
    """In-graph (count, sum, sum_sq) for one sharded batch; float32-safe."""
    xf = x.astype(jnp.float32)
    return (jnp.asarray(xf.size, jnp.float32), jnp.sum(xf), jnp.sum(xf * xf))


def merge_stat_triples(*triples):
    n = sum(t[0] for t in triples)
    s = sum(t[1] for t in triples)
    ss = sum(t[2] for t in triples)
    return n, s, ss


def mean_var_from_triple(triple):
    n, s, ss = triple
    mean = s / n
    return mean, ss / n - mean * mean


def psum_stats(triple, axis_names):
    """Combine stat triples across mesh axes inside shard_map/pjit."""
    return tuple(jax.lax.psum(t, axis_names) for t in triple)
