"""Optimal clipping-range computation (paper Sec. III-B, eqs. 9-11).

Given the analytic post-activation model, the total reconstruction error of
an N-level uniform quantizer with *pinned* outer bins (values in the outer
half-bins reconstruct exactly at c_min / c_max) is

    e_tot(c_min, c_max) = e_quant + e_clip

with e_quant given by eq. (9) and e_clip by eq. (10).  Both are exact sums
of piecewise-exponential integrals, so no numeric quadrature is needed.
``optimal_cmax`` / ``optimal_range`` minimize e_tot, reproducing the
"model" columns of paper Table I.
"""

from __future__ import annotations

import numpy as np
from scipy import optimize

from .distributions import FeatureModel


def e_quant(model: FeatureModel, cmin: float, cmax: float, n_levels: int) -> float:
    """Quantization error inside [cmin, cmax], eq. (9). Outer bins are pinned."""
    if n_levels < 2:
        raise ValueError("need at least 2 levels")
    delta = (cmax - cmin) / (n_levels - 1)
    total = 0.0
    if model.atom > 0.0 and cmin <= 0.0 <= cmax:
        # atom at zero reconstructs at nearest level; error is deterministic
        q = int(np.clip(np.floor((0.0 - cmin) / delta + 0.5), 0, n_levels - 1))
        total += model.atom * (cmin + q * delta) ** 2
    for seg in model.segments:
        # outermost bins: reconstruct at the boundary itself
        total += seg.shifted_second_moment(cmin, lo=cmin, hi=cmin + delta / 2)
        total += seg.shifted_second_moment(cmax, lo=cmax - delta / 2, hi=cmax)
        for i in range(1, n_levels - 1):
            lo = cmin + delta / 2 + (i - 1) * delta
            hi = cmin + delta / 2 + i * delta
            total += seg.shifted_second_moment(cmin + i * delta, lo=lo, hi=hi)
    return total


def e_clip(model: FeatureModel, cmin: float, cmax: float) -> float:
    """Clipping error outside [cmin, cmax], eq. (10). No further quant error."""
    total = 0.0
    if model.atom > 0.0 and not (cmin <= 0.0 <= cmax):
        bound = cmin if 0.0 < cmin else cmax
        total += model.atom * bound ** 2
    for seg in model.segments:
        total += seg.shifted_second_moment(cmin, hi=cmin)
        total += seg.shifted_second_moment(cmax, lo=cmax)
    return total


def e_total(model: FeatureModel, cmin: float, cmax: float, n_levels: int) -> float:
    return e_quant(model, cmin, cmax, n_levels) + e_clip(model, cmin, cmax)


def optimal_cmax(model: FeatureModel, n_levels: int, cmin: float = 0.0,
                 hi: float = 100.0) -> float:
    """argmin_{c_max} e_tot(cmin, c_max) - the 'model' column of Table I."""
    res = optimize.minimize_scalar(
        lambda c: e_total(model, cmin, c, n_levels),
        bounds=(cmin + 1e-3, hi), method="bounded",
        options={"xatol": 1e-7})
    return float(res.x)


def optimal_range(model: FeatureModel, n_levels: int) -> tuple[float, float]:
    """Jointly optimal (c_min, c_max) - the 'unconstrained' column of Table I."""
    c0 = optimal_cmax(model, n_levels)
    res = optimize.minimize(
        lambda p: e_total(model, p[0], p[1], n_levels),
        x0=np.array([0.0, c0]), method="Nelder-Mead",
        options={"xatol": 1e-8, "fatol": 1e-14, "maxiter": 4000})
    lo, hi = float(res.x[0]), float(res.x[1])
    return (lo, hi) if lo < hi else (hi, lo)


def empirical_e_total(samples: np.ndarray, cmin: float, cmax: float,
                      n_levels: int) -> float:
    """Measured MSRE between raw samples and clip+quantize+dequantize output."""
    x = np.asarray(samples, dtype=np.float64)
    xc = np.clip(x, cmin, cmax)
    q = np.floor((xc - cmin) / (cmax - cmin) * (n_levels - 1) + 0.5)
    xh = cmin + q * (cmax - cmin) / (n_levels - 1)
    return float(np.mean((x - xh) ** 2))


def empirical_optimal_cmax(samples: np.ndarray, n_levels: int, cmin: float = 0.0,
                           grid: np.ndarray | None = None) -> float:
    """Grid-search c_max minimizing measured MSRE (the paper's 'empirical' mode)."""
    x = np.asarray(samples, dtype=np.float64)
    if x.size == 0:
        raise ValueError("cannot calibrate clip range from empty samples")
    if grid is None:
        lo = max(cmin + 1e-3, 0.1)
        # a dead / near-constant tile collapses the quantile anchor; keep
        # the grid non-degenerate so the search stays well-defined
        hi = max(float(np.quantile(x, 0.9999)) * 1.5, lo + 1e-6)
        grid = np.linspace(lo, hi, 200)
    errs = [empirical_e_total(x, cmin, c, n_levels) for c in grid]
    return float(grid[int(np.argmin(errs))])


def empirical_optimal_range(samples: np.ndarray, n_levels: int,
                            steps: int = 24) -> tuple[float, float]:
    """Two-sided grid search of (c_min, c_max) minimizing measured MSRE.

    The unconstrained analogue of :func:`empirical_optimal_cmax`, used by
    per-channel calibration where channel supports need not start at 0
    (BN-biased channels).  A coarse quantile-anchored grid over both ends
    is plenty: MSRE is smooth in the range and per-channel sample counts
    are small.
    """
    x = np.asarray(samples, dtype=np.float64)
    if x.size == 0:
        raise ValueError("cannot calibrate clip range from empty samples")
    lo0, hi0 = float(np.min(x)), float(np.max(x))
    if hi0 - lo0 < 1e-9:
        return lo0, lo0 + 1e-6
    lo_grid = np.linspace(lo0, float(np.quantile(x, 0.5)), steps)
    hi_grid = np.linspace(float(np.quantile(x, 0.5)), hi0, steps)
    best = (np.inf, lo0, hi0)
    for lo in lo_grid:
        for hi in hi_grid:
            if hi - lo < 1e-6:
                continue
            err = empirical_e_total(x, lo, hi, n_levels)
            if err < best[0]:
                best = (err, float(lo), float(hi))
    return best[1], best[2]
