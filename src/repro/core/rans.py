"""Vectorized entropy coder for TU bit planes (numpy batched rANS).

The seed CABAC (``cabac.BinaryArithmeticEncoder``) is bit-serial Python:
fine for correctness, orders of magnitude too slow for full activation
tensors.  This module codes the same position-major TU bit planes with an
*interleaved binary rANS* coder whose per-step state updates run batched
over numpy lanes, so host encode/decode cost is a short python loop over
``total_bits / lanes`` steps of vector ops instead of one python iteration
per bit.

Design (see DESIGN.md for the full layout):

  * One shared coder state of L lanes (L a power of two derived from the
    total bit count) codes the concatenation of all planes; bit i of the
    stream lives in lane ``i % L`` at step ``i // L``.
  * Each plane starts at a fresh step (planes are padded to a step
    boundary with their most-probable symbol) so a step never straddles
    two planes and the decoder -- which only learns plane j+1's length
    after decoding plane j -- always knows the active probability.
  * Probabilities are *chunk-static*: each plane is cut into spans of
    ``_CHUNK_STEPS`` steps; the encoder stores one 16-bit scaled
    probability per span (measured on the span's real bits).  This
    replaces CABAC's serial per-bit adaptation with side information of
    ~2 bytes per 256*L bits while coding at the span-local empirical
    entropy, which is what the adaptive coder converges to anyway.
  * rANS details: 32-bit states renormalized 16 bits at a time
    (``x in [2^16, 2^32)``), probability scale 2^14.  Encoding runs over
    steps in reverse with per-step emissions reversed lane-wise, so the
    byte-reversed word stream is exactly what the forward decoder
    consumes -- the standard interleaved-rANS construction, batched.

Round trips are exact for any bit content; rates sit within a percent or
two of the adaptive coder for stationary planes (see bench_codec.py).
"""

from __future__ import annotations

import os
import struct
from concurrent.futures import ThreadPoolExecutor

import numpy as np

_PROB_BITS = 14
_M = 1 << _PROB_BITS                   # probability scale (f0 + f1 = _M)
_STATE_LO = np.uint64(1 << 16)         # renormalized state lower bound
_CHUNK_STEPS = 256                     # steps per static-probability span
_HEADER_FMT = "<HI"                    # lanes, n_ftable_entries

_U16 = np.uint64(16)
_S64 = np.uint64(_PROB_BITS)
_EMIT_SHIFT = np.uint64(32 - _PROB_BITS)
_MASK_S = np.uint64(_M - 1)
_MASK_W = np.uint64(0xFFFF)


def rans_threads() -> int:
    """Worker count for sharded plane coding (``REPRO_RANS_THREADS``).

    Defaults to 1 (sharding off): the step loop is numpy-dispatch bound,
    and on CPython builds whose numpy holds the GIL through the small
    per-step ops a thread pool is measured *slower* than serial (see
    ``BENCH_codec.json``'s ``encode_rans_sharded`` row).  Opt in on
    hosts with a GIL-releasing numpy / free-threaded interpreter, where
    the independent shards scale to ``min(threads, shards)`` cores.
    """
    env = os.environ.get("REPRO_RANS_THREADS", "").strip()
    if env:
        return max(1, int(env))
    return 1


_POOL: ThreadPoolExecutor | None = None
_POOL_SIZE = 0


def _get_pool(n: int) -> ThreadPoolExecutor:
    global _POOL, _POOL_SIZE
    if _POOL is None or _POOL_SIZE < n:
        if _POOL is not None:
            _POOL.shutdown(wait=False)
        _POOL = ThreadPoolExecutor(max_workers=n, thread_name_prefix="rans")
        _POOL_SIZE = n
    return _POOL


def parallel_map(fn, items, n_threads: int | None = None) -> list:
    """Map ``fn`` over ``items`` on the rANS thread pool (ordered results).

    Falls back to a plain loop for a single item or a single-thread
    configuration, so callers need no special casing.
    """
    items = list(items)
    n = rans_threads() if n_threads is None else n_threads
    n = min(n, len(items))
    if n <= 1:
        return [fn(it) for it in items]
    return list(_get_pool(n).map(fn, items))


def lane_count(total_bits: int) -> int:
    """Lanes used for a stream of ``total_bits`` (both sides derive this).

    ~2048 bits per lane keeps the python step loop short while the fixed
    per-lane cost (4-byte state flush) stays a tiny fraction of the
    payload; clipped to [4, 1024].
    """
    return int(min(1024, max(4, 1 << (total_bits // 2048).bit_length())))


def _chunk_freqs(bits: np.ndarray, chunk_bits: int) -> np.ndarray:
    """Scaled P(bit=1) per chunk of ``chunk_bits``, measured on real bits."""
    n = bits.size
    nch = -(-n // chunk_bits)
    bounds = np.arange(nch, dtype=np.int64) * chunk_bits
    ones = np.add.reduceat(bits.astype(np.int64), bounds)
    sizes = np.minimum(bounds + chunk_bits, n) - bounds
    f1 = np.rint(ones / sizes * _M)
    return np.clip(f1, 1, _M - 1).astype(np.uint32)


def _plane_setup(planes: list[np.ndarray], lanes: int):
    """Pad/stack TU planes for a ``lanes``-wide coder.

    Returns (bits2d (n_steps, lanes) uint8, f1_steps (n_steps,) uint32,
    ftab uint16) -- the stream-independent setup shared by the serial and
    batched encode loops.
    """
    ftab = []          # per-chunk scaled probabilities, plane-major
    step_rows = []     # (steps_i, lanes) padded bit matrices
    step_f1 = []       # per-step probability (uint32)
    for p in planes:
        if p.size == 0:
            continue
        steps = -(-p.size // lanes)
        f1c = _chunk_freqs(p, _CHUNK_STEPS * lanes)
        ftab.append(f1c.astype(np.uint16))
        pad = steps * lanes - p.size
        if pad:
            mps = 1 if int(f1c[-1]) >= _M // 2 else 0
            p = np.concatenate([p, np.full(pad, mps, np.uint8)])
        step_rows.append(p.reshape(steps, lanes))
        step_f1.append(np.repeat(f1c, _CHUNK_STEPS)[:steps])
    return (np.concatenate(step_rows, axis=0),
            np.concatenate(step_f1),
            np.concatenate(ftab))


def _blob(lanes: int, ftab: np.ndarray, x: np.ndarray,
          words: np.ndarray) -> bytes:
    return (struct.pack(_HEADER_FMT, lanes, ftab.size)
            + ftab.astype("<u2").tobytes()
            + x.astype("<u4").tobytes()
            + words.astype("<u2").tobytes())


def encode_planes(planes: list[np.ndarray]) -> bytes:
    """Encode TU bit planes (uint8 0/1 arrays) into one rANS stream."""
    planes = [np.asarray(p, dtype=np.uint8).ravel() for p in planes]
    total_bits = int(sum(p.size for p in planes))
    if total_bits == 0:
        return struct.pack(_HEADER_FMT, 0, 0)
    lanes = lane_count(total_bits)
    bits2d, f1_steps, ftab = _plane_setup(planes, lanes)
    n_steps = bits2d.shape[0]

    x = np.full(lanes, _STATE_LO, dtype=np.uint64)
    emitted = []       # encode-order word bursts (reversed lane order)
    zero = np.uint64(0)
    for t in range(n_steps - 1, -1, -1):
        f1 = np.uint64(f1_steps[t])
        f0 = np.uint64(_M) - f1
        ones = bits2d[t] == 1
        f = np.where(ones, f1, f0)
        c = np.where(ones, f0, zero)
        over = x >= (f << _EMIT_SHIFT)
        if over.any():
            emitted.append((x[over] & _MASK_W).astype(np.uint16)[::-1])
            x[over] >>= _U16
        q = x // f
        x = (q << _S64) + (x - q * f) + c

    if emitted:
        words = np.concatenate(emitted)[::-1]
    else:
        words = np.empty(0, dtype=np.uint16)
    return _blob(lanes, ftab, x, words)


def _encode_group(lanes: int, setups: list) -> list[bytes]:
    """One batched step loop over S independent equal-lane-count streams.

    The streams are stacked on a leading axis, so every per-step state
    update runs as one (S, lanes) numpy op instead of S separate
    dispatches -- the per-step python cost no longer scales with the
    number of chunks.  Streams shorter than the longest are masked
    inactive for the leading (reverse-order) steps.  Output bytes are
    identical to :func:`encode_planes` per stream (asserted in tests).
    """
    s_count = len(setups)
    steps = np.array([b.shape[0] for b, _, _ in setups], dtype=np.int64)
    t_max = int(steps.max())
    bits = np.zeros((s_count, t_max, lanes), np.uint8)
    f1_all = np.ones((s_count, t_max), np.uint64)
    for s, (b2d, f1s, _) in enumerate(setups):
        bits[s, :b2d.shape[0]] = b2d
        f1_all[s, :f1s.size] = f1s.astype(np.uint64)

    x = np.full((s_count, lanes), _STATE_LO, dtype=np.uint64)
    em_words, em_stream, em_step, em_lane = [], [], [], []
    zero = np.uint64(0)
    m64 = np.uint64(_M)
    for t in range(t_max - 1, -1, -1):
        active = steps > t                      # (S,)
        f1 = f1_all[:, t][:, None]
        f0 = m64 - f1
        ones = bits[:, t, :] == 1
        f = np.where(ones, f1, f0)
        c = np.where(ones, f0, zero)
        over = (x >= (f << _EMIT_SHIFT)) & active[:, None]
        if over.any():
            sidx, lidx = np.nonzero(over)
            em_words.append((x[over] & _MASK_W).astype(np.uint16))
            em_stream.append(sidx)
            em_lane.append(lidx)
            em_step.append(np.full(sidx.size, t, np.int64))
            x[over] >>= _U16
        q = x // f
        x = np.where(active[:, None], (q << _S64) + (x - q * f) + c, x)

    # per-stream word order matching the serial coder: steps ascending,
    # lanes ascending within a step
    if em_words:
        w = np.concatenate(em_words)
        st = np.concatenate(em_stream)
        tt = np.concatenate(em_step)
        ln = np.concatenate(em_lane)
        order = np.lexsort((ln, tt, st))
        w, st = w[order], st[order]
        counts = np.bincount(st, minlength=s_count)
        offs = np.concatenate([[0], np.cumsum(counts)])
    else:
        w = np.empty(0, np.uint16)
        offs = np.zeros(s_count + 1, np.int64)
    return [_blob(lanes, setups[s][2], x[s], w[offs[s]:offs[s + 1]])
            for s in range(s_count)]


def encode_planes_batch(streams: list[list[np.ndarray]]) -> list[bytes]:
    """Encode many *independent* plane lists; one stream of bytes each.

    Byte-identical to ``[encode_planes(p) for p in streams]``, but
    streams with equal lane counts share one batched step loop --
    :meth:`FeatureCodec.encode_stream` uses this to cut the per-chunk
    python dispatch that otherwise dominates chunked encodes.
    """
    out: list[bytes | None] = [None] * len(streams)
    groups: dict[int, list] = {}
    for i, planes in enumerate(streams):
        planes = [np.asarray(p, dtype=np.uint8).ravel() for p in planes]
        total = int(sum(p.size for p in planes))
        if total == 0:
            out[i] = struct.pack(_HEADER_FMT, 0, 0)
            continue
        groups.setdefault(lane_count(total), []).append((i, planes))
    for lanes, members in groups.items():
        if len(members) == 1:
            i, planes = members[0]
            out[i] = encode_planes(planes)
            continue
        setups = [_plane_setup(planes, lanes) for _, planes in members]
        for (i, _), blob in zip(members, _encode_group(lanes, setups)):
            out[i] = blob
    return out


class PlaneStreamDecoder:
    """Forward decoder over a stream produced by :func:`encode_planes`.

    Planes are pulled one at a time with :meth:`next_plane`; the caller
    supplies each plane's bit count (the TU structure makes it computable
    from previously decoded planes, so it is not stored).
    """

    def __init__(self, data: bytes) -> None:
        lanes, n_ftab = struct.unpack_from(_HEADER_FMT, data)
        off = struct.calcsize(_HEADER_FMT)
        self.lanes = lanes
        self._ftab = np.frombuffer(data, "<u2", n_ftab, off)
        off += 2 * n_ftab
        self._fpos = 0
        if lanes:
            self._x = np.frombuffer(data, "<u4", lanes, off).astype(np.uint64)
            off += 4 * lanes
        self._words = np.frombuffer(data, "<u2", -1, off).astype(np.uint64)
        self._wpos = 0

    def next_plane(self, n_bits: int) -> np.ndarray:
        if n_bits == 0:
            return np.empty(0, dtype=np.uint8)
        if self.lanes == 0:
            raise ValueError("empty stream cannot hold a non-empty plane")
        lanes = self.lanes
        steps = -(-n_bits // lanes)
        nch = -(-steps // _CHUNK_STEPS)
        f1c = self._ftab[self._fpos:self._fpos + nch]
        if f1c.size != nch:
            raise ValueError("truncated probability table")
        self._fpos += nch

        x = self._x
        words, wpos = self._words, self._wpos
        out = np.empty((steps, lanes), dtype=np.uint8)
        zero = np.uint64(0)
        for t in range(steps):
            f1 = np.uint64(f1c[t // _CHUNK_STEPS])
            f0 = np.uint64(_M) - f1
            xm = x & _MASK_S
            bit = xm >= f0
            f = np.where(bit, f1, f0)
            c = np.where(bit, f0, zero)
            x = f * (x >> _S64) + xm - c
            low = x < _STATE_LO
            k = int(low.sum())
            if k:
                x[low] = (x[low] << _U16) | words[wpos:wpos + k]
                wpos += k
            out[t] = bit
        self._x, self._wpos = x, wpos
        return out.reshape(-1)[:n_bits]
