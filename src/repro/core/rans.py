"""Vectorized entropy coder for TU bit planes (numpy batched rANS).

The seed CABAC (``cabac.BinaryArithmeticEncoder``) is bit-serial Python:
fine for correctness, orders of magnitude too slow for full activation
tensors.  This module codes the same position-major TU bit planes with an
*interleaved binary rANS* coder whose per-step state updates run batched
over numpy lanes, so host encode/decode cost is a short python loop over
``total_bits / lanes`` steps of vector ops instead of one python iteration
per bit.

Design (see DESIGN.md for the full layout):

  * One shared coder state of L lanes (L a power of two derived from the
    total bit count) codes the concatenation of all planes; bit i of the
    stream lives in lane ``i % L`` at step ``i // L``.
  * Each plane starts at a fresh step (planes are padded to a step
    boundary with their most-probable symbol) so a step never straddles
    two planes and the decoder -- which only learns plane j+1's length
    after decoding plane j -- always knows the active probability.
  * Probabilities are *chunk-static*: each plane is cut into spans of
    ``_CHUNK_STEPS`` steps; the encoder stores one 16-bit scaled
    probability per span (measured on the span's real bits).  This
    replaces CABAC's serial per-bit adaptation with side information of
    ~2 bytes per 256*L bits while coding at the span-local empirical
    entropy, which is what the adaptive coder converges to anyway.
  * rANS details: 32-bit states renormalized 16 bits at a time
    (``x in [2^16, 2^32)``), probability scale 2^14.  Encoding runs over
    steps in reverse with per-step emissions reversed lane-wise, so the
    byte-reversed word stream is exactly what the forward decoder
    consumes -- the standard interleaved-rANS construction, batched.

Round trips are exact for any bit content; rates sit within ~5-8% of the
adaptive coder for stationary planes -- the per-lane state flush at the
speed-tuned lane count (see :func:`lane_count`) is the deliberate rate
cost of the >=20 Melem/s host hot path (both measured in bench_codec.py).
"""

from __future__ import annotations

import os
import struct
from concurrent.futures import ThreadPoolExecutor

import numpy as np

_PROB_BITS = 14
_M = 1 << _PROB_BITS                   # probability scale (f0 + f1 = _M)
_STATE_LO = np.uint64(1 << 16)         # renormalized state lower bound
_CHUNK_STEPS = 256                     # steps per static-probability span
_HEADER_FMT = "<HI"                    # lanes, n_ftable_entries

_U16 = np.uint64(16)
_S64 = np.uint64(_PROB_BITS)
_EMIT_SHIFT = np.uint64(32 - _PROB_BITS)
_MASK_S = np.uint64(_M - 1)
_MASK_W = np.uint64(0xFFFF)


def rans_threads() -> int:
    """Worker count for sharded plane coding (``REPRO_RANS_THREADS``).

    Defaults to 1 (sharding off): the step loop is numpy-dispatch bound,
    and on CPython builds whose numpy holds the GIL through the small
    per-step ops a thread pool is measured *slower* than serial (see
    ``BENCH_codec.json``'s ``encode_rans_sharded`` row).  Opt in on
    hosts with a GIL-releasing numpy / free-threaded interpreter, where
    the independent shards scale to ``min(threads, shards)`` cores.
    """
    env = os.environ.get("REPRO_RANS_THREADS", "").strip()
    if env:
        return max(1, int(env))
    return 1


_POOL: ThreadPoolExecutor | None = None
_POOL_SIZE = 0


def _get_pool(n: int) -> ThreadPoolExecutor:
    global _POOL, _POOL_SIZE
    if _POOL is None or _POOL_SIZE < n:
        if _POOL is not None:
            _POOL.shutdown(wait=False)
        _POOL = ThreadPoolExecutor(max_workers=n, thread_name_prefix="rans")
        _POOL_SIZE = n
    return _POOL


def parallel_map(fn, items, n_threads: int | None = None) -> list:
    """Map ``fn`` over ``items`` on the rANS thread pool (ordered results).

    Falls back to a plain loop for a single item or a single-thread
    configuration, so callers need no special casing.
    """
    items = list(items)
    n = rans_threads() if n_threads is None else n_threads
    n = min(n, len(items))
    if n <= 1:
        return [fn(it) for it in items]
    return list(_get_pool(n).map(fn, items))


def proc_workers() -> int:
    """Worker count for process-pool shard coding (``REPRO_RANS_PROCS``).

    Defaults to 0 (off): worker processes pay fork + pickle transfer per
    shard, which only wins for multi-MB payloads on hosts whose numpy
    holds the GIL through the step loop (where the thread pool loses to
    serial -- see ``BENCH_codec.json``).  Opt in with
    ``REPRO_RANS_PROCS=<n>`` to code shards on ``n`` real cores.
    """
    env = os.environ.get("REPRO_RANS_PROCS", "").strip()
    if env:
        return max(0, int(env))
    return 0


_PROC_POOL = None
_PROC_SIZE = 0


def _shutdown_proc_pool() -> None:
    global _PROC_POOL, _PROC_SIZE
    if _PROC_POOL is not None:
        _PROC_POOL.shutdown(wait=False)
    _PROC_POOL, _PROC_SIZE = None, 0


def proc_map(fn, items, n_procs: int | None = None) -> list:
    """Map ``fn`` over ``items`` on the rANS process pool (ordered).

    ``fn`` must be a module-level (picklable) function.  Any pool
    failure -- a worker crash (BrokenProcessPool), fork/pickle errors --
    tears the pool down and recomputes *everything* serially in-process,
    so callers always get correct results: the pool is an optimization,
    never a correctness dependency.
    """
    import multiprocessing
    from concurrent.futures import ProcessPoolExecutor

    global _PROC_POOL, _PROC_SIZE
    items = list(items)
    n = proc_workers() if n_procs is None else n_procs
    n = min(n, len(items))
    if n <= 1:
        return [fn(it) for it in items]
    try:
        if _PROC_POOL is None or _PROC_SIZE < n:
            _shutdown_proc_pool()
            # spawn, not fork: the parent typically has jax's thread
            # pools running, and forking a multithreaded process can
            # deadlock; spawn pays a one-off worker import instead
            _PROC_POOL = ProcessPoolExecutor(
                max_workers=n, mp_context=multiprocessing.get_context(
                    "spawn"))
            _PROC_SIZE = n
        return list(_PROC_POOL.map(fn, items))
    except Exception:
        _shutdown_proc_pool()
        return [fn(it) for it in items]


def lane_count(total_bits: int) -> int:
    """Lanes used for a stream of ``total_bits`` (both sides derive this).

    The step loop runs ``total_bits / lanes`` python iterations whose
    per-step cost is nearly width-independent up to a few thousand lanes,
    so wall time is inversely proportional to the lane count while the
    fixed per-lane cost (4-byte state flush) grows linearly: ~640 bits
    per lane puts the flush at ~5-8% of the TU payload and buys the
    20 Melem/s encode/decode throughput the fused hot path targets
    (see BENCH_codec.json, which also reports the measured rate cost);
    clipped to [4, 4096] -- past a few Mbit the cap amortizes the flush
    back under 1%.  Encode-side policy only: the blob header records the
    count, so retuning never breaks old streams.
    """
    return int(min(4096, max(4, 1 << (total_bits // 640).bit_length())))


def _chunk_freqs(bits: np.ndarray, chunk_bits: int) -> np.ndarray:
    """Scaled P(bit=1) per chunk of ``chunk_bits``, measured on real bits."""
    n = bits.size
    nch = -(-n // chunk_bits)
    bounds = np.arange(nch, dtype=np.int64) * chunk_bits
    ones = np.add.reduceat(bits.astype(np.int64), bounds)
    sizes = np.minimum(bounds + chunk_bits, n) - bounds
    f1 = np.rint(ones / sizes * _M)
    return np.clip(f1, 1, _M - 1).astype(np.uint32)


def _plane_setup(planes: list[np.ndarray], lanes: int):
    """Pad/stack TU planes for a ``lanes``-wide coder.

    Returns (bits2d (n_steps, lanes) uint8, f1_steps (n_steps,) uint32,
    ftab uint16) -- the stream-independent setup shared by the serial and
    batched encode loops.
    """
    ftab = []          # per-chunk scaled probabilities, plane-major
    step_rows = []     # (steps_i, lanes) padded bit matrices
    step_f1 = []       # per-step probability (uint32)
    for p in planes:
        if p.size == 0:
            continue
        steps = -(-p.size // lanes)
        f1c = _chunk_freqs(p, _CHUNK_STEPS * lanes)
        ftab.append(f1c.astype(np.uint16))
        pad = steps * lanes - p.size
        if pad:
            mps = 1 if int(f1c[-1]) >= _M // 2 else 0
            p = np.concatenate([p, np.full(pad, mps, np.uint8)])
        step_rows.append(p.reshape(steps, lanes))
        step_f1.append(np.repeat(f1c, _CHUNK_STEPS)[:steps])
    return (np.concatenate(step_rows, axis=0),
            np.concatenate(step_f1),
            np.concatenate(ftab))


def _blob(lanes: int, ftab: np.ndarray, x: np.ndarray,
          words: np.ndarray) -> bytes:
    return (struct.pack(_HEADER_FMT, lanes, ftab.size)
            + ftab.astype("<u2").tobytes()
            + x.astype("<u4").tobytes()
            + words.astype("<u2").tobytes())


def encode_planes(planes: list[np.ndarray]) -> bytes:
    """Encode TU bit planes (uint8 0/1 arrays) into one rANS stream."""
    planes = [np.asarray(p, dtype=np.uint8).ravel() for p in planes]
    total_bits = int(sum(p.size for p in planes))
    if total_bits == 0:
        return struct.pack(_HEADER_FMT, 0, 0)
    lanes = lane_count(total_bits)
    bits2d, f1_steps, ftab = _plane_setup(planes, lanes)
    n_steps = bits2d.shape[0]

    # The loop carries only the sequential state update, built from the
    # step's scalar probabilities with bitwise mixes (f0 ^ (f0^f1)*bit)
    # rather than per-step np.where.  Word emission is deferred: each
    # step stores its pre-renorm low words and the emission mask, and
    # one boolean gather at the end collects the emitted words in
    # (step asc, lane asc) order -- exactly the order the old per-step
    # burst bookkeeping produced (bursts appended in reverse step order,
    # lane-reversed, then globally reversed), so the byte stream is
    # unchanged.
    bits_bool = bits2d.view(np.bool_)
    x = np.full(lanes, _STATE_LO, dtype=np.uint64)
    over_rows = np.empty((n_steps, lanes), np.bool_)
    w_rows = np.empty((n_steps, lanes), np.uint16)
    m64 = np.uint64(_M)
    for t in range(n_steps - 1, -1, -1):
        f1 = np.uint64(f1_steps[t])
        f0 = m64 - f1
        b = bits_bool[t]
        f = np.where(b, f1, f0)
        over = x >= (f << _EMIT_SHIFT)
        over_rows[t] = over
        w_rows[t] = x          # truncating uint16 store == x & 0xFFFF
        x >>= over * _U16                        # renorm emitting lanes
        q, r = np.divmod(x, f)
        x = (q << _S64) + r + f0 * b
    return _blob(lanes, ftab, x, w_rows[over_rows])


def _encode_group(lanes: int, setups: list) -> list[bytes]:
    """One batched step loop over S independent equal-lane-count streams.

    The streams are stacked on a leading axis, so every per-step state
    update runs as one (S, lanes) numpy op instead of S separate
    dispatches -- the per-step python cost no longer scales with the
    number of chunks.  Streams shorter than the longest are masked
    inactive for the leading (reverse-order) steps.  Output bytes are
    identical to :func:`encode_planes` per stream (asserted in tests).
    """
    s_count = len(setups)
    steps = np.array([b.shape[0] for b, _, _ in setups], dtype=np.int64)
    t_max = int(steps.max())
    bits = np.zeros((s_count, t_max, lanes), np.uint8)
    f1_all = np.ones((s_count, t_max), np.uint64)
    for s, (b2d, f1s, _) in enumerate(setups):
        bits[s, :b2d.shape[0]] = b2d
        f1_all[s, :f1s.size] = f1s.astype(np.uint64)

    x = np.full((s_count, lanes), _STATE_LO, dtype=np.uint64)
    em_words, em_stream, em_step, em_lane = [], [], [], []
    zero = np.uint64(0)
    m64 = np.uint64(_M)
    for t in range(t_max - 1, -1, -1):
        active = steps > t                      # (S,)
        f1 = f1_all[:, t][:, None]
        f0 = m64 - f1
        ones = bits[:, t, :] == 1
        f = np.where(ones, f1, f0)
        c = np.where(ones, f0, zero)
        over = (x >= (f << _EMIT_SHIFT)) & active[:, None]
        if over.any():
            sidx, lidx = np.nonzero(over)
            em_words.append((x[over] & _MASK_W).astype(np.uint16))
            em_stream.append(sidx)
            em_lane.append(lidx)
            em_step.append(np.full(sidx.size, t, np.int64))
            x[over] >>= _U16
        q = x // f
        x = np.where(active[:, None], (q << _S64) + (x - q * f) + c, x)

    # per-stream word order matching the serial coder: steps ascending,
    # lanes ascending within a step
    if em_words:
        w = np.concatenate(em_words)
        st = np.concatenate(em_stream)
        tt = np.concatenate(em_step)
        ln = np.concatenate(em_lane)
        order = np.lexsort((ln, tt, st))
        w, st = w[order], st[order]
        counts = np.bincount(st, minlength=s_count)
        offs = np.concatenate([[0], np.cumsum(counts)])
    else:
        w = np.empty(0, np.uint16)
        offs = np.zeros(s_count + 1, np.int64)
    return [_blob(lanes, setups[s][2], x[s], w[offs[s]:offs[s + 1]])
            for s in range(s_count)]


def encode_planes_batch(streams: list[list[np.ndarray]]) -> list[bytes]:
    """Encode many *independent* plane lists; one stream of bytes each.

    Byte-identical to ``[encode_planes(p) for p in streams]``, but
    streams with equal lane counts share one batched step loop --
    :meth:`FeatureCodec.encode_stream` uses this to cut the per-chunk
    python dispatch that otherwise dominates chunked encodes.
    """
    out: list[bytes | None] = [None] * len(streams)
    groups: dict[int, list] = {}
    for i, planes in enumerate(streams):
        planes = [np.asarray(p, dtype=np.uint8).ravel() for p in planes]
        total = int(sum(p.size for p in planes))
        if total == 0:
            out[i] = struct.pack(_HEADER_FMT, 0, 0)
            continue
        groups.setdefault(lane_count(total), []).append((i, planes))
    for lanes, members in groups.items():
        if len(members) == 1:
            i, planes = members[0]
            out[i] = encode_planes(planes)
            continue
        setups = [_plane_setup(planes, lanes) for _, planes in members]
        for (i, _), blob in zip(members, _encode_group(lanes, setups)):
            out[i] = blob
    return out


class PlaneStreamDecoder:
    """Forward decoder over a stream produced by :func:`encode_planes`.

    Planes are pulled one at a time with :meth:`next_plane`; the caller
    supplies each plane's bit count (the TU structure makes it computable
    from previously decoded planes, so it is not stored).
    """

    def __init__(self, data: bytes) -> None:
        lanes, n_ftab = struct.unpack_from(_HEADER_FMT, data)
        off = struct.calcsize(_HEADER_FMT)
        self.lanes = lanes
        self._ftab = np.frombuffer(data, "<u2", n_ftab, off)
        off += 2 * n_ftab
        self._fpos = 0
        if lanes:
            self._x = np.frombuffer(data, "<u4", lanes, off).astype(np.uint64)
            off += 4 * lanes
        self._words = np.frombuffer(data, "<u2", -1, off).astype(np.uint64)
        self._wpos = 0

    def next_plane(self, n_bits: int) -> np.ndarray:
        if n_bits == 0:
            return np.empty(0, dtype=np.uint8)
        if self.lanes == 0:
            raise ValueError("empty stream cannot hold a non-empty plane")
        lanes = self.lanes
        steps = -(-n_bits // lanes)
        nch = -(-steps // _CHUNK_STEPS)
        f1c = self._ftab[self._fpos:self._fpos + nch]
        if f1c.size != nch:
            raise ValueError("truncated probability table")
        self._fpos += nch

        x = self._x
        words, wpos = self._words, self._wpos
        out = np.empty((steps, lanes), dtype=np.uint8)
        for s0 in range(0, steps, _CHUNK_STEPS):
            # probabilities are chunk-static: hoist the span's scalars and
            # select f via a bitwise mix (f0 ^ (f0^f1)*bit) -- cheaper
            # than per-step np.where at these widths
            f1 = np.uint64(f1c[s0 // _CHUNK_STEPS])
            f0 = np.uint64(_M) - f1
            fx = f0 ^ f1
            for t in range(s0, min(s0 + _CHUNK_STEPS, steps)):
                xm = x & _MASK_S
                bit = xm >= f0
                f = f0 ^ (fx * bit)
                x = f * (x >> _S64) + (xm - f0 * bit)
                low = x < _STATE_LO
                k = int(low.sum())
                if k:
                    x[low] = (x[low] << _U16) | words[wpos:wpos + k]
                    wpos += k
                out[t] = bit
        self._x, self._wpos = x, wpos
        return out.reshape(-1)[:n_bits]


class BatchPlaneDecoder:
    """Forward decoder over S *independent* equal-lane-count streams.

    The decode-side mirror of :func:`_encode_group`: the S coder states
    are stacked on a leading axis so every per-step update runs as one
    (S, lanes) numpy op -- the per-stream python dispatch that dominates
    chunked decodes collapses into one step loop per plane round.
    Per-stream results are bit-identical to S separate
    :class:`PlaneStreamDecoder` walks (asserted in tests): streams
    shorter than the longest are masked inactive for the trailing steps
    and word refills are gathered per stream in lane order, exactly the
    serial consumption order.
    """

    def __init__(self, blobs: list[bytes]) -> None:
        self.n = len(blobs)
        lanes = None
        ftabs, states, words, woff = [], [], [], []
        for blob in blobs:
            ln, n_ftab = struct.unpack_from(_HEADER_FMT, blob)
            if lanes is None:
                lanes = ln
            elif ln != lanes:
                raise ValueError("batched streams must share a lane count")
            if ln == 0:
                raise ValueError("empty stream cannot join a batch")
            off = struct.calcsize(_HEADER_FMT)
            ftabs.append(np.frombuffer(blob, "<u2", n_ftab, off))
            off += 2 * n_ftab
            states.append(np.frombuffer(blob, "<u4", ln, off))
            off += 4 * ln
            w = np.frombuffer(blob, "<u2", -1, off)
            woff.append(sum(x.size for x in words))
            words.append(w)
        self.lanes = lanes
        self._ftabs = ftabs
        self._fpos = np.zeros(self.n, np.int64)
        self._x = np.stack(states).astype(np.uint64)       # (S, lanes)
        self._words = (np.concatenate(words).astype(np.uint64)
                       if words else np.empty(0, np.uint64))
        self._wpos = np.asarray(woff, np.int64)            # absolute
        self._wend = self._wpos + np.asarray(
            [w.size for w in words], np.int64)

    def next_planes(self, n_bits: list[int]) -> list[np.ndarray]:
        """Decode one plane from every stream (``n_bits[s]`` may be 0)."""
        lanes = self.lanes
        steps = np.asarray([-(-b // lanes) for b in n_bits], np.int64)
        t_max = int(steps.max()) if steps.size else 0
        if t_max == 0:
            return [np.empty(0, np.uint8) for _ in n_bits]
        f1_all = np.ones((self.n, t_max), np.uint64)
        for s, nb in enumerate(n_bits):
            if nb == 0:
                continue
            nch = -(-int(steps[s]) // _CHUNK_STEPS)
            f1c = self._ftabs[s][self._fpos[s]:self._fpos[s] + nch]
            if f1c.size != nch:
                raise ValueError("truncated probability table")
            self._fpos[s] += nch
            f1_all[s, :steps[s]] = \
                np.repeat(f1c.astype(np.uint64), _CHUNK_STEPS)[:steps[s]]

        x = self._x
        words, wpos = self._words, self._wpos.copy()
        out = np.empty((self.n, t_max, lanes), dtype=np.uint8)
        m64 = np.uint64(_M)
        zero = np.uint64(0)
        for t in range(t_max):
            active = steps > t                         # (S,)
            f1 = f1_all[:, t][:, None]
            f0 = m64 - f1
            xm = x & _MASK_S
            bit = xm >= f0
            f = np.where(bit, f1, f0)
            c = np.where(bit, f0, zero)
            x = np.where(active[:, None], f * (x >> _S64) + xm - c, x)
            low = (x < _STATE_LO) & active[:, None]
            if low.any():
                sidx, _ = np.nonzero(low)              # s asc, lane asc
                counts = np.bincount(sidx, minlength=self.n)
                if np.any(wpos + counts > self._wend):
                    # per-stream bound: a truncated member must raise
                    # (like the single-stream decoder), never silently
                    # consume its neighbour's words
                    raise ValueError("truncated word stream in batch")
                starts = np.cumsum(counts) - counts
                rank = np.arange(sidx.size) - starts[sidx]
                x[low] = (x[low] << _U16) | words[wpos[sidx] + rank]
                wpos += counts
            out[:, t, :] = bit
        self._x, self._wpos = x, wpos
        return [out[s, :steps[s]].reshape(-1)[:n_bits[s]]
                for s in range(self.n)]
