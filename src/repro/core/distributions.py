"""Analytic models of split-layer feature distributions (paper Sec. III-B).

The input to the split layer's activation is modeled as an asymmetric
Laplace distribution, eq. (2):

    f_L(x) = lam / (kappa + 1/kappa) * { exp( lam (x - mu) / kappa)   x <  mu
                                       { exp(-lam kappa (x - mu))     x >= mu

The activation is leaky ReLU with negative slope ``s`` (eq. 4); the
post-activation density f_Y (eq. 5) is piecewise exponential.  All moments
and clipping/quantization error integrals therefore have exact closed
forms, which we compute via :class:`ExpSegment` antiderivatives instead of
numeric quadrature.  ``s = 0`` (plain ReLU, AlexNet case) is supported via
a point mass at 0.

Reference values from the paper (used in tests):
  ResNet-50 layer 21: mean 1.1235656, var 4.9280124, kappa 0.5, s 0.1
      -> lam 0.7716595, mu -1.4350621   (eq. 8)
  YOLOv3 layer 12:   mean 0.4484323, var 0.5742644
      -> lam 2.3900,   mu -0.30888      (eq. 12)
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np
from scipy import optimize


# ---------------------------------------------------------------------------
# Exact integration of c * exp(alpha * y) segments
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExpSegment:
    """Density segment f(y) = coef * exp(alpha * y) on [lo, hi)."""

    coef: float
    alpha: float
    lo: float      # may be -inf
    hi: float      # may be +inf

    def _anti0(self, y: float) -> float:
        # antiderivative of exp(alpha y)
        if np.isinf(y):
            return 0.0  # valid only when exp decays toward that limit
        return math.exp(self.alpha * y) / self.alpha

    def _anti1(self, y: float) -> float:
        # antiderivative of y exp(alpha y)
        if np.isinf(y):
            return 0.0
        a = self.alpha
        return math.exp(a * y) * (y / a - 1.0 / (a * a))

    def _anti2(self, y: float) -> float:
        # antiderivative of y^2 exp(alpha y)
        if np.isinf(y):
            return 0.0
        a = self.alpha
        return math.exp(a * y) * (y * y / a - 2.0 * y / (a * a) + 2.0 / (a ** 3))

    def moment(self, power: int, lo: float | None = None, hi: float | None = None) -> float:
        """Integral of y^power * f(y) over [lo, hi] intersected with segment."""
        a = self.lo if lo is None else max(lo, self.lo)
        b = self.hi if hi is None else min(hi, self.hi)
        if b <= a:
            return 0.0
        anti = (self._anti0, self._anti1, self._anti2)[power]
        return self.coef * (anti(b) - anti(a))

    def shifted_second_moment(self, r: float, lo: float | None = None,
                              hi: float | None = None) -> float:
        """Integral of (y - r)^2 * f(y) over [lo, hi] within segment."""
        a = self.lo if lo is None else max(lo, self.lo)
        b = self.hi if hi is None else min(hi, self.hi)
        if b <= a:
            return 0.0
        m0 = self.coef * (self._anti0(b) - self._anti0(a))
        m1 = self.coef * (self._anti1(b) - self._anti1(a))
        m2 = self.coef * (self._anti2(b) - self._anti2(a))
        return m2 - 2.0 * r * m1 + r * r * m0


# ---------------------------------------------------------------------------
# Post-activation feature model
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FeatureModel:
    """Analytic model of Y = leaky_relu_s(X), X ~ AsymmetricLaplace(lam, mu, kappa).

    ``atom`` is the probability mass concentrated exactly at y = 0 (non-zero
    only for plain ReLU, s == 0).
    """

    lam: float
    mu: float
    kappa: float
    slope: float
    segments: tuple[ExpSegment, ...]
    atom: float = 0.0

    # -- constructors --------------------------------------------------------

    @staticmethod
    def from_params(lam: float, mu: float, kappa: float, slope: float) -> "FeatureModel":
        if lam <= 0 or kappa <= 0:
            raise ValueError("lam and kappa must be positive")
        norm = lam / (kappa + 1.0 / kappa)
        s = slope
        segs: list[ExpSegment] = []
        atom = 0.0
        if s > 0:
            # y < 0 region: x = y / s, extra 1/s Jacobian.
            if mu < 0:
                # below s*mu: rising exponential; [s*mu, 0): falling
                a1 = lam / (kappa * s)
                segs.append(ExpSegment(norm / s * math.exp(-lam * mu / kappa), a1, -math.inf, s * mu))
                a2 = -lam * kappa / s
                segs.append(ExpSegment(norm / s * math.exp(lam * kappa * mu), a2, s * mu, 0.0))
                # y >= 0: x = y (> 0 > mu): falling branch
                segs.append(ExpSegment(norm * math.exp(lam * kappa * mu), -lam * kappa, 0.0, math.inf))
            else:
                a1 = lam / (kappa * s)
                segs.append(ExpSegment(norm / s * math.exp(-lam * mu / kappa), a1, -math.inf, 0.0))
                segs.append(ExpSegment(norm * math.exp(-lam * mu / kappa), lam / kappa, 0.0, mu))
                segs.append(ExpSegment(norm * math.exp(lam * kappa * mu), -lam * kappa, mu, math.inf))
        else:
            # plain ReLU: all x < 0 mass collapses onto the atom at 0.
            if mu < 0:
                atom = (kappa ** 2) / (1 + kappa ** 2) * math.exp(0.0)  # P(X < mu)
                # P(X < mu) = kappa^2/(1+kappa^2); plus P(mu <= X < 0)
                p_lo = (kappa ** 2) / (1 + kappa ** 2)
                seg_mid = ExpSegment(norm * math.exp(lam * kappa * mu), -lam * kappa, mu, 0.0)
                atom = p_lo + seg_mid.moment(0)
                segs.append(ExpSegment(norm * math.exp(lam * kappa * mu), -lam * kappa, 0.0, math.inf))
            else:
                p_lo_seg = ExpSegment(norm * math.exp(-lam * mu / kappa), lam / kappa, -math.inf, 0.0)
                atom = p_lo_seg.moment(0)
                segs.append(ExpSegment(norm * math.exp(-lam * mu / kappa), lam / kappa, 0.0, mu))
                segs.append(ExpSegment(norm * math.exp(lam * kappa * mu), -lam * kappa, mu, math.inf))
        return FeatureModel(lam, mu, kappa, slope, tuple(segs), atom)

    @staticmethod
    def fit(sample_mean: float, sample_var: float, kappa: float = 0.5,
            slope: float = 0.1, init: tuple[float, float] = (1.0, -1.0)) -> "FeatureModel":
        """Solve (lam, mu) s.t. model mean/var match the sample stats (eqs. 6-7)."""

        def eqs(p):
            lam, mu = p
            if lam <= 1e-6:
                return [1e6, 1e6]
            m = FeatureModel.from_params(lam, mu, kappa, slope)
            return [m.mean() - sample_mean, m.var() - sample_var]

        sol = optimize.root(eqs, init, method="hybr", tol=1e-13)
        if not sol.success:  # retry from a grid of inits
            for lam0 in (0.3, 1.0, 3.0, 10.0):
                for mu0 in (-3.0, -1.0, -0.3, 0.3):
                    sol = optimize.root(eqs, (lam0, mu0), method="hybr", tol=1e-13)
                    if sol.success:
                        break
                if sol.success:
                    break
        if not sol.success:
            raise RuntimeError(f"FeatureModel.fit failed: {sol.message}")
        lam, mu = sol.x
        return FeatureModel.from_params(float(lam), float(mu), kappa, slope)

    @staticmethod
    def fit_from_samples(samples: np.ndarray, kappa: float = 0.5,
                         slope: float = 0.1) -> "FeatureModel":
        samples = np.asarray(samples, dtype=np.float64).ravel()
        return FeatureModel.fit(float(samples.mean()), float(samples.var()), kappa, slope)

    # -- density / moments ----------------------------------------------------

    def pdf(self, y) -> np.ndarray:
        y = np.asarray(y, dtype=np.float64)
        out = np.zeros_like(y)
        for s in self.segments:
            m = (y >= s.lo) & (y < s.hi)
            expo = np.clip(s.alpha * y, -700.0, 700.0)
            out = np.where(m, s.coef * np.exp(np.where(m, expo, 0.0)), out)
        return out

    def total_mass(self) -> float:
        return self.atom + sum(s.moment(0) for s in self.segments)

    def mean(self) -> float:
        return sum(s.moment(1) for s in self.segments)

    def second_moment(self) -> float:
        return sum(s.moment(2) for s in self.segments)

    def var(self) -> float:
        m = self.mean()
        return self.second_moment() - m * m

    def cdf_scalar(self, y: float) -> float:
        total = self.atom if y >= 0 else 0.0
        for s in self.segments:
            total += s.moment(0, hi=y)
        return total

    def quantile(self, q: float, bracket: tuple[float, float] = (-100.0, 1000.0)) -> float:
        return optimize.brentq(lambda y: self.cdf_scalar(y) - q, *bracket, xtol=1e-10)

    def median(self) -> float:
        return self.quantile(0.5)

    def mad_about_median(self) -> float:
        """Laplace-MLE scale: E|Y - median| (used by the ACIQ baseline)."""
        med = self.median()
        total = self.atom * abs(med)
        for s in self.segments:
            # |y - med| = (med - y) below med plus (y - med) above
            total += med * s.moment(0, hi=med) - s.moment(1, hi=med)
            total += s.moment(1, lo=med) - med * s.moment(0, lo=med)
        return total

    # -- sampling (for synthetic experiments) ---------------------------------

    def sample(self, n: int, rng: np.random.Generator | None = None) -> np.ndarray:
        """Draw samples of Y by sampling X ~ AL and applying leaky ReLU."""
        rng = rng or np.random.default_rng(0)
        k2 = self.kappa ** 2
        p_neg_branch = k2 / (1.0 + k2)  # P(X < mu)
        u = rng.random(n)
        e = rng.exponential(size=n)
        x = np.where(u < p_neg_branch,
                     self.mu - e * self.kappa / self.lam,
                     self.mu + e / (self.lam * self.kappa))
        return np.where(x < 0, self.slope * x, x)

    # -- closed-form mean/var (paper eqs. 6-7, kappa=0.5, s=0.1, mu<0) --------

    def mean_eq6(self) -> float:
        lam, mu = self.lam, self.mu
        return 0.1 * mu + (1 / lam) * (3 / 20 + (6 / 5) ** 2 * math.exp(0.5 * lam * mu))

    def var_eq7(self) -> float:
        lam, mu = self.lam, self.mu
        return (1 / lam ** 2) * ((5.904 - 0.288 * lam * mu) * math.exp(0.5 * lam * mu)
                                 - 2.0736 * math.exp(lam * mu) + 0.0425)


# Published reference fits ---------------------------------------------------

RESNET50_L21 = dict(sample_mean=1.1235656, sample_var=4.9280124, kappa=0.5, slope=0.1)
YOLOV3_L12 = dict(sample_mean=0.4484323, sample_var=0.5742644, kappa=0.5, slope=0.1)


def resnet50_layer21_model() -> FeatureModel:
    return FeatureModel.fit(**RESNET50_L21)


def yolov3_layer12_model() -> FeatureModel:
    return FeatureModel.fit(**YOLOV3_L12)
