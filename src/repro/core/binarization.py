"""Truncated-unary binarization (paper Sec. III-D).

Index n in [0, N) maps to n ones followed by a terminating zero, except the
maximum index N-1 which maps to N-1 ones (no terminator):

    N=4:  0 -> 0, 1 -> 10, 2 -> 110, 3 -> 111

One CABAC context is used per bit *position*, so for context j the bit
stream consists of, for every element with index n >= j (and j <= N-2),
a bit equal to (n > j).  This position-major ("bin-plane") ordering is what
``index_to_context_bits`` produces; it is decodable because the decoder
knows after plane j which elements are still "alive" in plane j+1.
"""

from __future__ import annotations

import numpy as np


def truncated_unary_lengths(n_levels: int) -> np.ndarray:
    """Codeword length in bits for each index of an N-level TU code."""
    lens = np.arange(1, n_levels + 1, dtype=np.int32)
    lens[-1] = n_levels - 1
    return lens


def encode_index(n: int, n_levels: int) -> str:
    if n < n_levels - 1:
        return "1" * n + "0"
    return "1" * (n_levels - 1)


def index_to_context_bits(idx: np.ndarray, n_levels: int) -> list[np.ndarray]:
    """Per-context (bit-position) planes of TU bits, vectorized.

    Returns a list of N-1 uint8 arrays; plane j holds the bits of every
    element whose codeword reaches position j (i.e. idx >= j), in element
    order.  Bit value is 1 iff idx > j.
    """
    cur = np.asarray(idx).ravel()
    planes = []
    for j in range(n_levels - 1):
        # iteratively compact the survivors: plane j+1's alive set is
        # exactly plane j's one-bits, so each selection runs over the
        # shrinking alive array instead of the full tensor
        bits = cur > j
        planes.append(bits.view(np.uint8))
        if j < n_levels - 2:
            cur = cur[bits]
    return planes


def context_bits_to_index(planes: list[np.ndarray], n_elems: int,
                          n_levels: int) -> np.ndarray:
    """Inverse of :func:`index_to_context_bits`."""
    idx = np.zeros(n_elems, dtype=np.int32)
    alive = np.ones(n_elems, dtype=bool)
    for j in range(n_levels - 1):
        bits = np.asarray(planes[j], dtype=np.uint8)
        if bits.size != int(alive.sum()):
            raise ValueError("plane size mismatch")
        cont = np.zeros(n_elems, dtype=bool)
        cont[alive] = bits.astype(bool)
        idx[cont] += 1
        alive = cont
    return idx


def total_tu_bits(idx: np.ndarray, n_levels: int) -> int:
    """Number of TU bits before entropy coding."""
    lens = truncated_unary_lengths(n_levels)
    return int(lens[np.asarray(idx).ravel()].sum())
