"""ACIQ baseline (Banner et al. [22,23]), as used for comparison in the paper.

For ReLU-style activations ACIQ fixes c_min = 0 and computes (paper eq. 13)

    c_max = b * W(12 * 2^(2M)),

where W is the Lambert W function, M the bit width, and b the Laplace scale
parameter estimated from data.  The paper allows fractional bit widths via
M = log2(N) for an N-level quantizer.

The paper does not state how b was estimated from the ResNet/YOLO feature
tensors; we provide the standard Laplace MLE (mean absolute deviation about
the median) from samples, and the model-based equivalent.  On data drawn
from the fitted analytic models this reproduces ACIQ's qualitative
behaviour reported in the paper: its c_max exceeds the model-optimal c_max
at coarse quantization (N small) and converges toward it as N grows.
"""

from __future__ import annotations

import numpy as np
from scipy import special

from .distributions import FeatureModel


# floor for the Laplace scale estimate: a dead (all-zero / constant)
# tile has b = 0, which would yield a zero clip range and a
# divide-by-zero step size downstream.  The floor keeps c_max positive
# and tiny, so a dead tile quantizes exactly to its constant.
B_FLOOR = 1e-8


def aciq_cmax(b: float, n_levels: int) -> float:
    """Eq. (13) with M = log2(n_levels) (fractional bit widths allowed)."""
    if not np.isfinite(b) or b < 0.0:
        raise ValueError(f"Laplace scale must be finite and >= 0, got {b}")
    m = np.log2(n_levels)
    return float(max(b, B_FLOOR)
                 * special.lambertw(12.0 * 2.0 ** (2.0 * m)).real)


def laplace_b_from_samples(samples: np.ndarray) -> float:
    """Laplace MLE scale: mean |x - median(x)|, floored at ``B_FLOOR``."""
    x = np.asarray(samples, dtype=np.float64).ravel()
    if x.size == 0:
        raise ValueError("cannot estimate Laplace scale from empty samples")
    return float(max(np.mean(np.abs(x - np.median(x))), B_FLOOR))


def laplace_b_from_model(model: FeatureModel) -> float:
    return model.mad_about_median()


def aciq_cmax_from_samples(samples: np.ndarray, n_levels: int) -> float:
    return aciq_cmax(laplace_b_from_samples(samples), n_levels)


def aciq_cmax_from_model(model: FeatureModel, n_levels: int) -> float:
    return aciq_cmax(laplace_b_from_model(model), n_levels)
