"""Modified entropy-constrained scalar quantizer design (paper Algorithm 1).

Differences from conventional ECSQ [Chou-Lookabaugh-Gray]:
  * the outermost reconstruction values are *pinned* to c_min / c_max so the
    decoded activations span the full clipping range (Step 4), and
  * the rate term uses the known truncated-unary codeword lengths b_n
    instead of -log2(p_n).

Note: the paper's Step 3 prints the Lagrangian as (x - x_n)^2 - lam*b_n; the
sign is a typo -- Step 6's threshold formula is the stationarity condition
of (x - x_n)^2 + lam*b_n, which is what we implement.

Design runs on the host (numpy) over a calibration sample; deployment-time
quantization is a threshold search (see ``repro.kernels.ecsq_assign``).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from .binarization import truncated_unary_lengths


@dataclasses.dataclass
class ECSQQuantizer:
    """Designed non-uniform quantizer: reconstruction levels + thresholds."""

    levels: np.ndarray       # (N,) reconstruction values, ascending
    thresholds: np.ndarray   # (N-1,) decision boundaries
    codeword_lengths: np.ndarray  # (N,) bits per index
    lagrangian: float        # lambda used at design time
    cmin: float
    cmax: float

    @property
    def n_levels(self) -> int:
        return len(self.levels)

    @classmethod
    def from_levels(cls, levels: np.ndarray, lagrangian: float = 0.0,
                    codeword_lengths: np.ndarray | None = None
                    ) -> "ECSQQuantizer":
        """Rebuild a usable quantizer from a reconstruction-level table.

        The bitstream header stores only the levels (that is all a
        receiver needs to dequantize); this reconstructs the matching
        decision thresholds -- Step 6's stationarity formula, reducing to
        midpoints when ``lagrangian`` is 0 -- so a receiver-side codec can
        also *re-encode* without the original calibration samples.
        """
        lv = np.asarray(levels, dtype=np.float64).ravel()
        n = lv.size
        if codeword_lengths is None:
            codeword_lengths = truncated_unary_lengths(n)
        b = np.asarray(codeword_lengths, dtype=np.float64)
        thresholds = np.empty(max(n - 1, 0), dtype=np.float64)
        for i in range(1, n):
            gap = lv[i] - lv[i - 1]
            if gap <= 1e-12:
                thresholds[i - 1] = lv[i]
            else:
                thresholds[i - 1] = (lv[i] + lv[i - 1]) / 2.0 \
                    + lagrangian * (b[i] - b[i - 1]) / (2.0 * gap)
        thresholds = np.maximum.accumulate(
            np.clip(thresholds, lv[0], lv[-1])) if n > 1 else thresholds
        return cls(levels=lv, thresholds=thresholds,
                   codeword_lengths=b.astype(np.int32),
                   lagrangian=lagrangian, cmin=float(lv[0]),
                   cmax=float(lv[-1]))

    def quantize_np(self, x: np.ndarray) -> np.ndarray:
        xc = np.clip(x, self.cmin, self.cmax)
        return np.searchsorted(self.thresholds, xc, side="right").astype(np.int32)

    def dequantize_np(self, idx: np.ndarray) -> np.ndarray:
        return self.levels[idx]


def design_ecsq(samples: np.ndarray, n_levels: int, lagrangian: float,
                cmin: float, cmax: float, *, pin_boundaries: bool = True,
                codeword_lengths: np.ndarray | None = None,
                max_iters: int = 200, tol: float = 1e-9) -> ECSQQuantizer:
    """Run Algorithm 1.

    ``pin_boundaries=False`` gives the conventional ECSQ design used as the
    paper's ablation baseline (Figs. 9-10, "conventional" curves).
    """
    x = np.clip(np.asarray(samples, dtype=np.float64).ravel(), cmin, cmax)  # Step 1
    n = n_levels
    if codeword_lengths is None:
        codeword_lengths = truncated_unary_lengths(n)
    b = np.asarray(codeword_lengths, dtype=np.float64)

    levels = np.linspace(cmin, cmax, n)  # Step 2: uniform init
    prev_cost = np.inf
    for _ in range(max_iters):
        # Step 3: assign samples minimizing (x - x_n)^2 + lam * b_n
        cost_mat = (x[:, None] - levels[None, :]) ** 2 + lagrangian * b[None, :]
        assign = np.argmin(cost_mat, axis=1)
        # Step 4: centroid update with pinned boundary bins
        new_levels = levels.copy()
        for i in range(n):
            sel = assign == i
            if np.any(sel):
                new_levels[i] = x[sel].mean()
        if pin_boundaries:
            new_levels[0] = cmin
            new_levels[-1] = cmax
        # enforce monotonicity (degenerate empty-bin cases)
        new_levels = np.maximum.accumulate(new_levels)
        levels = new_levels
        # Step 5: convergence check on the Lagrangian cost
        d = (x - levels[assign]) ** 2
        cost = float(d.mean() + lagrangian * b[assign].mean())
        if prev_cost - cost < tol:
            break
        prev_cost = cost

    # Step 6: decision thresholds between adjacent levels
    thresholds = np.empty(n - 1, dtype=np.float64)
    for i in range(1, n):
        gap = levels[i] - levels[i - 1]
        if gap <= 1e-12:
            thresholds[i - 1] = levels[i]
        else:
            thresholds[i - 1] = (levels[i] + levels[i - 1]) / 2.0 \
                + lagrangian * (b[i] - b[i - 1]) / (2.0 * gap)
    thresholds = np.maximum.accumulate(np.clip(thresholds, cmin, cmax))
    return ECSQQuantizer(levels=levels, thresholds=thresholds,
                         codeword_lengths=b.astype(np.int32),
                         lagrangian=lagrangian, cmin=cmin, cmax=cmax)
