"""TilePlan: one geometry object for every codec granularity.

A *tile* is a (channel-group x spatial-block) region of the feature
tensor: channels along ``channel_axis`` are grouped ``channel_group_size``
at a time, and the remaining (flattened, channel-major) spatial extent is
cut into spatial blocks.  Every tile carries its own clipping range (and
optionally its own ECSQ table), so the paper's per-tensor mode, the
companion paper's per-channel mosaic (arXiv 2105.06002) and full
channel x spatial tiling (the spatial redundancy of arXiv 1804.09963) are
all the *same* code path at different plan settings:

    per-tensor   1 tile              (no plan; scalar fast path)
    per-channel  plan(gc=g, bs=0)    n_sblocks == 1, spatial extent free
    tiled (1-D)  plan(gc=g, bs=b)    channel groups x flat spatial runs
    tiled (2-D)  plan(gc=g, bhw=(bh, bw))  channel groups x row x column
                                      blocks of the (H, W) spatial grid

``spatial_block_size == 0`` means "one spatial block spanning everything";
only then may ``spatial_extent`` stay ``None`` (the plan accepts tensors
of any spatial size, like the old per-channel mode).  With ``bs > 0`` the
spatial extent is fixed at calibration time: tile ranges are positional.

2-D mode (``spatial_block_hw``) views the flattened spatial extent as a
``spatial_hw = (H, W)`` grid (W = the innermost non-channel dim; H folds
everything else) and cuts it into (bh, bw) row x column blocks -- conv
feature maps keep their row x column structure instead of smearing it
across flat runs.  Edge blocks at non-multiple H/W are simply smaller
(``band_sizes``); spatial block id ``b = (row // bh) * n_cblocks +
(col // bw)`` and the flat tile id stays ``cgroup * n_sblocks + b``.

Coded order: tiled bitstreams serialize indices in *tile-major* (channel-
major) order.  For 1-D plans that is plain
``moveaxis(channel -> 0).reshape(C, M).ravel()``; 2-D plans additionally
permute each channel row so every tile's elements are contiguous
(row-major within the tile -- the stable sort of positions by block id,
:meth:`spatial_perm`).  Either way consecutive coded symbols share a tile
(aligned index distributions for the chunk-static entropy stage) and
chunk boundaries can align to tile runs (see :meth:`align_chunk_elems`).
"""

from __future__ import annotations

import dataclasses
import functools

import numpy as np


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Tile geometry for one calibrated codec.

    ``channel_axis`` is kept as configured (may be negative) and
    normalized per tensor; ``n_channels`` is the calibrated channel count;
    ``spatial_extent`` is the calibrated flattened spatial size (``None``
    only when ``spatial_block_size == 0``).
    """

    channel_axis: int
    channel_group_size: int
    spatial_block_size: int
    n_channels: int
    spatial_extent: int | None = None
    # 2-D (row x column) mode: the spatial extent is an (H, W) grid cut
    # into (bh, bw) blocks.  Mutually exclusive with spatial_block_size.
    spatial_hw: tuple[int, int] | None = None
    spatial_block_hw: tuple[int, int] | None = None

    def __post_init__(self):
        if self.channel_group_size < 1:
            raise ValueError("channel_group_size must be >= 1")
        if self.spatial_block_size < 0:
            raise ValueError("spatial_block_size must be >= 0")
        if self.spatial_block_hw is not None:
            bh, bw = self.spatial_block_hw
            if bh < 1 or bw < 1:
                raise ValueError("spatial_block_hw blocks must be >= 1")
            if self.spatial_block_size:
                raise ValueError("spatial_block_size and spatial_block_hw "
                                 "are mutually exclusive")
            if self.spatial_hw is None:
                raise ValueError("2-D tiling needs the spatial_hw grid")
            h, w = self.spatial_hw
            if h < 1 or w < 1:
                raise ValueError("spatial_hw dims must be >= 1")
            if self.spatial_extent != h * w:
                raise ValueError(
                    f"spatial_extent {self.spatial_extent} != "
                    f"spatial_hw product {h * w}")
        elif self.spatial_hw is not None:
            raise ValueError("spatial_hw is only meaningful with "
                             "spatial_block_hw")
        if self.spatial_block_size > 0 and self.spatial_extent is None:
            raise ValueError("spatial tiling needs a fixed spatial_extent")

    # -- derived geometry -----------------------------------------------------

    @property
    def is_2d(self) -> bool:
        return self.spatial_block_hw is not None

    @property
    def n_cgroups(self) -> int:
        return -(-self.n_channels // self.channel_group_size)

    @property
    def n_rblocks(self) -> int:
        """Row-block count of the 2-D spatial grid (1 for 1-D plans)."""
        if not self.is_2d:
            return 1
        return -(-self.spatial_hw[0] // self.spatial_block_hw[0])

    @property
    def n_cblocks(self) -> int:
        """Column-block count of the 2-D spatial grid (n_sblocks in 1-D)."""
        if not self.is_2d:
            return self.n_sblocks
        return -(-self.spatial_hw[1] // self.spatial_block_hw[1])

    @property
    def n_sblocks(self) -> int:
        if self.is_2d:
            return self.n_rblocks * self.n_cblocks
        if self.spatial_block_size == 0:
            return 1
        return -(-self.spatial_extent // self.spatial_block_size)

    @property
    def n_tiles(self) -> int:
        return self.n_cgroups * self.n_sblocks

    def block_extent(self, spatial_extent: int) -> int:
        """Elements per full spatial block (the whole extent when bs == 0;
        ``bh * bw`` in 2-D mode -- edge blocks may be smaller)."""
        if self.is_2d:
            bh, bw = self.spatial_block_hw
            return min(bh, self.spatial_hw[0]) * min(bw, self.spatial_hw[1])
        return self.spatial_block_size or spatial_extent

    # -- per-tensor validation ------------------------------------------------

    def resolve(self, shape: tuple[int, ...]) -> tuple[int, int, int]:
        """Validate ``shape`` against the plan; returns (axis, C, M)."""
        axis = self.channel_axis % len(shape)
        c = shape[axis]
        if c != self.n_channels:
            raise ValueError(
                f"axis {axis} has {c} channels, plan was calibrated "
                f"for {self.n_channels}")
        m = 1
        for d, s in enumerate(shape):
            if d != axis:
                m *= s
        if self.spatial_extent is not None and m != self.spatial_extent:
            raise ValueError(
                f"tensor has spatial extent {m}, plan was calibrated "
                f"for {self.spatial_extent}")
        if self.is_2d:
            # the (H, W) grid is positional, not just the extent: a
            # same-M tensor with a different row length would silently
            # mis-tile every block
            grid = spatial_grid(shape, self.channel_axis)
            if grid != self.spatial_hw:
                raise ValueError(
                    f"tensor has spatial grid {grid}, plan was "
                    f"calibrated for {self.spatial_hw}")
        return axis, c, m

    # -- element <-> tile maps (host/numpy; jit-constant under trace) ----------

    def cgroup_ids(self) -> np.ndarray:
        """(C,) int32: channel -> channel-group id."""
        return (np.arange(self.n_channels, dtype=np.int32)
                // self.channel_group_size)

    def sblock_ids(self, spatial_extent: int) -> np.ndarray:
        """(M,) int32: flattened spatial position -> spatial-block id."""
        if self.is_2d:
            if spatial_extent != self.spatial_extent:
                raise ValueError(
                    f"spatial extent {spatial_extent} != plan's "
                    f"{self.spatial_extent}")
            h, w = self.spatial_hw
            bh, bw = self.spatial_block_hw
            pos = np.arange(spatial_extent, dtype=np.int64)
            ids = (pos // w // bh) * self.n_cblocks + (pos % w) // bw
            return ids.astype(np.int32)
        return (np.arange(spatial_extent, dtype=np.int32)
                // self.block_extent(spatial_extent))

    def band_sizes(self, spatial_extent: int) -> np.ndarray:
        """(n_sblocks,) int64: valid element count of every spatial block
        (edge blocks at non-multiple extents are smaller)."""
        nb = self.n_sblocks
        if self.is_2d:
            h, w = self.spatial_hw
            bh, bw = self.spatial_block_hw
            rows = np.minimum(bh, h - np.arange(self.n_rblocks) * bh)
            cols = np.minimum(bw, w - np.arange(self.n_cblocks) * bw)
            return (rows[:, None] * cols[None, :]).reshape(-1) \
                .astype(np.int64)
        bs = self.block_extent(spatial_extent)
        sizes = np.full(nb, bs, np.int64)
        sizes[-1] = spatial_extent - (nb - 1) * bs
        return sizes

    def coded_band_bounds(self, spatial_extent: int) -> np.ndarray:
        """(n_sblocks + 1,) cumulative band boundaries in a channel row of
        the coded-order (C, M) view: block ``b`` occupies columns
        ``[bounds[b], bounds[b+1])`` of every coded row."""
        return np.concatenate(
            [[0], np.cumsum(self.band_sizes(spatial_extent))])

    def spatial_perm(self, spatial_extent: int) -> np.ndarray | None:
        """(M,) int64 coded-position -> original flat spatial position, or
        ``None`` when coded order is the identity (1-D plans: flat runs
        are already contiguous).  The permutation is the stable sort of
        positions by spatial block id, i.e. row-major within each tile."""
        if not self.is_2d:
            return None
        return _spatial_perm_2d(self, spatial_extent)

    def tile_ids_2d(self, spatial_extent: int) -> np.ndarray:
        """(C, M) int32 channel-major view of element -> flat tile id
        (cgroup-major, sblock-minor -- the header's table order)."""
        return (self.cgroup_ids()[:, None] * self.n_sblocks
                + self.sblock_ids(spatial_extent)[None, :])

    def tile_ids(self, shape: tuple[int, ...]) -> np.ndarray:
        """int32 array of ``shape``: element -> flat tile id."""
        axis, c, m = self.resolve(shape)
        tid = self.tile_ids_2d(m)                             # (C, M)
        moved = [shape[axis]] + [s for d, s in enumerate(shape) if d != axis]
        return np.moveaxis(tid.reshape(moved), 0, axis)

    def tile_slices(self, c: int, m: int):
        """Yield (tile_id, channel slice, spatial index) over the
        channel-major (C, M) view -- the calibration iteration order.
        The spatial index is a slice for 1-D plans (contiguous runs) and
        an int64 position array for 2-D plans (row x column blocks are
        strided in the flat view)."""
        gc = self.channel_group_size
        if self.is_2d:
            perm = self.spatial_perm(m)
            bounds = self.coded_band_bounds(m)
            for g in range(self.n_cgroups):
                cs = slice(g * gc, min((g + 1) * gc, c))
                for s in range(self.n_sblocks):
                    yield (g * self.n_sblocks + s, cs,
                           perm[bounds[s]:bounds[s + 1]])
            return
        bs = self.block_extent(m)
        for g in range(self.n_cgroups):
            for s in range(self.n_sblocks):
                yield (g * self.n_sblocks + s,
                       slice(g * gc, min((g + 1) * gc, c)),
                       slice(s * bs, min((s + 1) * bs, m)))

    # -- coded order ----------------------------------------------------------

    def to_coded_order(self, arr: np.ndarray) -> np.ndarray:
        """Tensor (original layout) -> flat tile-major coded order."""
        axis, c, m = self.resolve(arr.shape)
        rows = np.moveaxis(np.asarray(arr), axis, 0).reshape(c, m)
        perm = self.spatial_perm(m)
        if perm is not None:
            rows = rows[:, perm]
        return rows.reshape(-1)

    def from_coded_order(self, flat: np.ndarray,
                         shape: tuple[int, ...]) -> np.ndarray:
        """Inverse of :meth:`to_coded_order` for a known tensor shape."""
        axis, c, m = self.resolve(shape)
        rows = np.asarray(flat).reshape(c, m)
        perm = self.spatial_perm(m)
        if perm is not None:
            inv = np.empty_like(perm)
            inv[perm] = np.arange(m, dtype=perm.dtype)
            rows = rows[:, inv]
        moved = [shape[axis]] + [s for d, s in enumerate(shape) if d != axis]
        return np.moveaxis(rows.reshape(moved), 0, axis)

    def align_chunk_elems(self, chunk_elems: int, shape: tuple[int, ...]
                          ) -> int:
        """Round a streaming chunk size up so chunk boundaries never split
        a tile's contiguous run in coded order.

        In tile-major order the tile changes at every spatial-block
        boundary and at every row (channel) end, so a boundary-safe chunk
        period is the common block run length when every block has it
        (all bands equal -- 1-D rows tiling exactly, or a 2-D grid whose
        (H, W) are block multiples) and a whole row ``M`` otherwise.
        """
        _, _, m = self.resolve(shape)
        sizes = self.band_sizes(m)
        run = int(sizes[0]) if (sizes == sizes[0]).all() else m
        return max(run, -(-chunk_elems // run) * run)


@functools.lru_cache(maxsize=64)
def _spatial_perm_2d(plan: TilePlan, spatial_extent: int) -> np.ndarray:
    """Cached coded-order permutation (plans are frozen/hashable and the
    2-D extent is pinned, so one array per plan is ever built)."""
    perm = np.argsort(plan.sblock_ids(spatial_extent),
                      kind="stable").astype(np.int64)
    perm.setflags(write=False)   # shared cache entry: guard the coded order
    return perm


@dataclasses.dataclass(frozen=True)
class PaddedLayout:
    """Geometry of the lane-padded 2-D device view the fused encode pass
    writes, shared by the kernel wrappers (which build the view in-graph)
    and the host (which strips it after the single device->host transfer).

    Tiled view: channel-major rows, each spatial block padded to a whole
    ``sb_cols``-column band (``cols == n_sblocks * sb_cols``), rows padded
    to a sublane multiple.  Flat (per-tensor) view: ``flat_n`` is set and
    the data is simply the raveled tensor padded at the tail -- the pad
    fill is ``cmin`` so padding quantizes to index 0 (the histogram
    correction relies on this).
    """

    rows: int                 # padded row count of the device view
    cols: int                 # padded column count
    ch: int                   # valid rows (channels)
    m: int                    # valid flattened spatial extent per channel
    n_sblocks: int            # spatial bands
    sb_cols: int              # padded columns per band
    bs: int                   # valid elements per band (capacity: the
    #                           largest band when band_valid is set)
    channel_group_size: int = 1
    flat_n: int | None = None  # per-tensor flat view: valid element count
    # 2-D plans: per-band valid element counts (edge bands shorter); when
    # None every band holds `bs` elements except possibly the last
    band_valid: tuple[int, ...] | None = None

    @property
    def bs_last(self) -> int:
        """Valid elements in the last band (its tail may be padding)."""
        if self.band_valid is not None:
            return self.band_valid[-1]
        return self.m - (self.n_sblocks - 1) * self.bs

    def band_sizes(self) -> np.ndarray:
        """(n_sblocks,) valid element count per band."""
        if self.band_valid is not None:
            return np.asarray(self.band_valid, np.int64)
        sizes = np.full(self.n_sblocks, self.bs, np.int64)
        sizes[-1] = self.bs_last
        return sizes

    def coded_cols(self) -> np.ndarray:
        """(m,) padded-view column of the k-th coded element of a row:
        bands are left-aligned in their ``sb_cols`` column slot, so the
        concatenation of valid band columns is coded order."""
        sizes = self.band_sizes()
        return np.concatenate(
            [b * self.sb_cols + np.arange(s, dtype=np.int64)
             for b, s in enumerate(sizes)])

    def unpack_indices(self, idx2d: np.ndarray) -> np.ndarray:
        """Padded (rows, cols) index view -> flat coded-order indices."""
        idx2d = np.asarray(idx2d).reshape(self.rows, self.cols)
        if self.flat_n is not None:
            return idx2d.reshape(-1)[:self.flat_n]
        if self.band_valid is not None:
            return idx2d[:self.ch][:, self.coded_cols()].reshape(-1)
        a = idx2d[:self.ch].reshape(self.ch, self.n_sblocks, self.sb_cols)
        a = a[:, :, :self.bs].reshape(self.ch, -1)[:, :self.m]
        return a.reshape(-1)

    def group_hists(self, hist_raw: np.ndarray, n_levels: int,
                    hist_width: int) -> np.ndarray:
        """Kernel per-(row, band) histogram -> (n_cgroups, n_sblocks, N).

        ``hist_raw`` is the megakernel's (rows, n_sblocks * hist_width)
        output; padding rows are dropped and channel rows are summed into
        their groups.  For the flat view all rows collapse into the one
        tile and the tail padding (which quantized to index 0 by the
        cmin-fill contract) is subtracted from bin 0.
        """
        h = np.asarray(hist_raw).reshape(self.rows, self.n_sblocks,
                                         hist_width)[..., :n_levels]
        if self.flat_n is not None:
            out = h.sum(axis=(0, 1), dtype=np.int64)[None, None]
            out[0, 0, 0] -= self.rows * self.cols - self.flat_n
            return out.astype(np.int32)
        h = h[:self.ch]
        gs = max(1, self.channel_group_size)
        starts = np.arange(0, self.ch, gs)
        return np.add.reduceat(h, starts, axis=0).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class TileECSQ:
    """Per-tile non-uniform quantizer tables (row t = flat tile id t).

    The header ships only ``levels``; ``thresholds`` exist sender-side
    (rebuilt per tile via ``ECSQQuantizer.from_levels`` when a receiver
    wants to re-encode).
    """

    levels: np.ndarray       # (n_tiles, N) float32, rows ascending
    thresholds: np.ndarray   # (n_tiles, N-1) float32

    @property
    def n_levels(self) -> int:
        return self.levels.shape[1]


def spatial_grid(shape: tuple[int, ...], channel_axis: int
                 ) -> tuple[int, int]:
    """(H, W) view of the flattened non-channel extent: W is the
    innermost non-channel dim (the column period of the channel-major
    flat view -- W for both NHWC and NCHW conv maps) and H folds every
    other non-channel dim (image rows, plus batch when present)."""
    axis = channel_axis % len(shape)
    rest = [s for d, s in enumerate(shape) if d != axis]
    w = rest[-1] if rest else 1
    h = 1
    for s in rest[:-1]:
        h *= s
    return h, w


def plan_from_config(cfg, shape: tuple[int, ...]) -> TilePlan:
    """Build the plan a :class:`~repro.core.codec.CodecConfig` describes
    for calibration tensors of ``shape`` (granularity 'channel'|'tile')."""
    axis = cfg.channel_axis % len(shape)
    c = shape[axis]
    m = 1
    for d, s in enumerate(shape):
        if d != axis:
            m *= s
    bhw = getattr(cfg, "spatial_block_hw", None)
    if cfg.granularity == "tile" and bhw is not None:
        if cfg.spatial_block_size:
            raise ValueError("set spatial_block_size or spatial_block_hw, "
                             "not both")
        return TilePlan(channel_axis=cfg.channel_axis,
                        channel_group_size=max(1, cfg.channel_group_size),
                        spatial_block_size=0, n_channels=c,
                        spatial_extent=m,
                        spatial_hw=spatial_grid(shape, cfg.channel_axis),
                        spatial_block_hw=(int(bhw[0]), int(bhw[1])))
    bs = cfg.spatial_block_size if cfg.granularity == "tile" else 0
    return TilePlan(channel_axis=cfg.channel_axis,
                    channel_group_size=max(1, cfg.channel_group_size),
                    spatial_block_size=bs, n_channels=c,
                    spatial_extent=m if bs else None)
