"""TilePlan: one geometry object for every codec granularity.

A *tile* is a (channel-group x spatial-block) region of the feature
tensor: channels along ``channel_axis`` are grouped ``channel_group_size``
at a time, and the remaining (flattened, channel-major) spatial extent is
cut into contiguous blocks of ``spatial_block_size`` elements.  Every tile
carries its own clipping range (and optionally its own ECSQ table), so the
paper's per-tensor mode, the companion paper's per-channel mosaic
(arXiv 2105.06002) and full channel x spatial tiling (the spatial
redundancy of arXiv 1804.09963) are all the *same* code path at different
plan settings:

    per-tensor   1 tile            (no plan; scalar fast path)
    per-channel  plan(gc=g, bs=0)  n_sblocks == 1, spatial extent free
    tiled        plan(gc=g, bs=b)  channel groups x spatial blocks

``spatial_block_size == 0`` means "one spatial block spanning everything";
only then may ``spatial_extent`` stay ``None`` (the plan accepts tensors
of any spatial size, like the old per-channel mode).  With ``bs > 0`` the
spatial extent is fixed at calibration time: tile ranges are positional.

Coded order: tiled bitstreams serialize indices in *tile-major* (channel-
major) order -- ``moveaxis(channel -> 0).reshape(C, M).ravel()`` -- so
consecutive coded symbols share a tile (aligned index distributions for
the chunk-static entropy stage) and chunk boundaries can align to whole
channel rows (see :meth:`align_chunk_elems`).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Tile geometry for one calibrated codec.

    ``channel_axis`` is kept as configured (may be negative) and
    normalized per tensor; ``n_channels`` is the calibrated channel count;
    ``spatial_extent`` is the calibrated flattened spatial size (``None``
    only when ``spatial_block_size == 0``).
    """

    channel_axis: int
    channel_group_size: int
    spatial_block_size: int
    n_channels: int
    spatial_extent: int | None = None

    def __post_init__(self):
        if self.channel_group_size < 1:
            raise ValueError("channel_group_size must be >= 1")
        if self.spatial_block_size < 0:
            raise ValueError("spatial_block_size must be >= 0")
        if self.spatial_block_size > 0 and self.spatial_extent is None:
            raise ValueError("spatial tiling needs a fixed spatial_extent")

    # -- derived geometry -----------------------------------------------------

    @property
    def n_cgroups(self) -> int:
        return -(-self.n_channels // self.channel_group_size)

    @property
    def n_sblocks(self) -> int:
        if self.spatial_block_size == 0:
            return 1
        return -(-self.spatial_extent // self.spatial_block_size)

    @property
    def n_tiles(self) -> int:
        return self.n_cgroups * self.n_sblocks

    def block_extent(self, spatial_extent: int) -> int:
        """Elements per spatial block (the whole extent when bs == 0)."""
        return self.spatial_block_size or spatial_extent

    # -- per-tensor validation ------------------------------------------------

    def resolve(self, shape: tuple[int, ...]) -> tuple[int, int, int]:
        """Validate ``shape`` against the plan; returns (axis, C, M)."""
        axis = self.channel_axis % len(shape)
        c = shape[axis]
        if c != self.n_channels:
            raise ValueError(
                f"axis {axis} has {c} channels, plan was calibrated "
                f"for {self.n_channels}")
        m = 1
        for d, s in enumerate(shape):
            if d != axis:
                m *= s
        if self.spatial_extent is not None and m != self.spatial_extent:
            raise ValueError(
                f"tensor has spatial extent {m}, plan was calibrated "
                f"for {self.spatial_extent}")
        return axis, c, m

    # -- element <-> tile maps (host/numpy; jit-constant under trace) ----------

    def cgroup_ids(self) -> np.ndarray:
        """(C,) int32: channel -> channel-group id."""
        return (np.arange(self.n_channels, dtype=np.int32)
                // self.channel_group_size)

    def sblock_ids(self, spatial_extent: int) -> np.ndarray:
        """(M,) int32: flattened spatial position -> spatial-block id."""
        return (np.arange(spatial_extent, dtype=np.int32)
                // self.block_extent(spatial_extent))

    def tile_ids_2d(self, spatial_extent: int) -> np.ndarray:
        """(C, M) int32 channel-major view of element -> flat tile id
        (cgroup-major, sblock-minor -- the header's table order)."""
        return (self.cgroup_ids()[:, None] * self.n_sblocks
                + self.sblock_ids(spatial_extent)[None, :])

    def tile_ids(self, shape: tuple[int, ...]) -> np.ndarray:
        """int32 array of ``shape``: element -> flat tile id."""
        axis, c, m = self.resolve(shape)
        tid = self.tile_ids_2d(m)                             # (C, M)
        moved = [shape[axis]] + [s for d, s in enumerate(shape) if d != axis]
        return np.moveaxis(tid.reshape(moved), 0, axis)

    def tile_slices(self, c: int, m: int):
        """Yield (tile_id, channel slice, spatial slice) over the
        channel-major (C, M) view -- the calibration iteration order."""
        gc, bs = self.channel_group_size, self.block_extent(m)
        for g in range(self.n_cgroups):
            for s in range(self.n_sblocks):
                yield (g * self.n_sblocks + s,
                       slice(g * gc, min((g + 1) * gc, c)),
                       slice(s * bs, min((s + 1) * bs, m)))

    # -- coded order ----------------------------------------------------------

    def to_coded_order(self, arr: np.ndarray) -> np.ndarray:
        """Tensor (original layout) -> flat tile-major coded order."""
        axis, c, _ = self.resolve(arr.shape)
        return np.moveaxis(np.asarray(arr), axis, 0).reshape(-1)

    def from_coded_order(self, flat: np.ndarray,
                         shape: tuple[int, ...]) -> np.ndarray:
        """Inverse of :meth:`to_coded_order` for a known tensor shape."""
        axis, c, m = self.resolve(shape)
        moved = [shape[axis]] + [s for d, s in enumerate(shape) if d != axis]
        return np.moveaxis(np.asarray(flat).reshape(moved), 0, axis)

    def align_chunk_elems(self, chunk_elems: int, shape: tuple[int, ...]
                          ) -> int:
        """Round a streaming chunk size up so chunk boundaries never split
        a tile's contiguous run in coded order.

        In tile-major order, flat position ``c*M + m`` changes tile at
        every spatial-block boundary and at every row (channel) end, so a
        boundary-safe chunk period is ``bs`` when the rows tile exactly
        (``M % bs == 0``) and a whole row ``M`` otherwise.
        """
        _, _, m = self.resolve(shape)
        bs = self.block_extent(m)
        run = bs if m % bs == 0 else m
        return max(run, -(-chunk_elems // run) * run)


@dataclasses.dataclass(frozen=True)
class PaddedLayout:
    """Geometry of the lane-padded 2-D device view the fused encode pass
    writes, shared by the kernel wrappers (which build the view in-graph)
    and the host (which strips it after the single device->host transfer).

    Tiled view: channel-major rows, each spatial block padded to a whole
    ``sb_cols``-column band (``cols == n_sblocks * sb_cols``), rows padded
    to a sublane multiple.  Flat (per-tensor) view: ``flat_n`` is set and
    the data is simply the raveled tensor padded at the tail -- the pad
    fill is ``cmin`` so padding quantizes to index 0 (the histogram
    correction relies on this).
    """

    rows: int                 # padded row count of the device view
    cols: int                 # padded column count
    ch: int                   # valid rows (channels)
    m: int                    # valid flattened spatial extent per channel
    n_sblocks: int            # spatial bands
    sb_cols: int              # padded columns per band
    bs: int                   # valid elements per band
    channel_group_size: int = 1
    flat_n: int | None = None  # per-tensor flat view: valid element count

    @property
    def bs_last(self) -> int:
        """Valid elements in the last band (its tail may be padding)."""
        return self.m - (self.n_sblocks - 1) * self.bs

    def unpack_indices(self, idx2d: np.ndarray) -> np.ndarray:
        """Padded (rows, cols) index view -> flat coded-order indices."""
        idx2d = np.asarray(idx2d).reshape(self.rows, self.cols)
        if self.flat_n is not None:
            return idx2d.reshape(-1)[:self.flat_n]
        a = idx2d[:self.ch].reshape(self.ch, self.n_sblocks, self.sb_cols)
        a = a[:, :, :self.bs].reshape(self.ch, -1)[:, :self.m]
        return a.reshape(-1)

    def group_hists(self, hist_raw: np.ndarray, n_levels: int,
                    hist_width: int) -> np.ndarray:
        """Kernel per-(row, band) histogram -> (n_cgroups, n_sblocks, N).

        ``hist_raw`` is the megakernel's (rows, n_sblocks * hist_width)
        output; padding rows are dropped and channel rows are summed into
        their groups.  For the flat view all rows collapse into the one
        tile and the tail padding (which quantized to index 0 by the
        cmin-fill contract) is subtracted from bin 0.
        """
        h = np.asarray(hist_raw).reshape(self.rows, self.n_sblocks,
                                         hist_width)[..., :n_levels]
        if self.flat_n is not None:
            out = h.sum(axis=(0, 1), dtype=np.int64)[None, None]
            out[0, 0, 0] -= self.rows * self.cols - self.flat_n
            return out.astype(np.int32)
        h = h[:self.ch]
        gs = max(1, self.channel_group_size)
        starts = np.arange(0, self.ch, gs)
        return np.add.reduceat(h, starts, axis=0).astype(np.int32)


@dataclasses.dataclass(frozen=True)
class TileECSQ:
    """Per-tile non-uniform quantizer tables (row t = flat tile id t).

    The header ships only ``levels``; ``thresholds`` exist sender-side
    (rebuilt per tile via ``ECSQQuantizer.from_levels`` when a receiver
    wants to re-encode).
    """

    levels: np.ndarray       # (n_tiles, N) float32, rows ascending
    thresholds: np.ndarray   # (n_tiles, N-1) float32

    @property
    def n_levels(self) -> int:
        return self.levels.shape[1]


def plan_from_config(cfg, shape: tuple[int, ...]) -> TilePlan:
    """Build the plan a :class:`~repro.core.codec.CodecConfig` describes
    for calibration tensors of ``shape`` (granularity 'channel'|'tile')."""
    axis = cfg.channel_axis % len(shape)
    c = shape[axis]
    m = 1
    for d, s in enumerate(shape):
        if d != axis:
            m *= s
    bs = cfg.spatial_block_size if cfg.granularity == "tile" else 0
    return TilePlan(channel_axis=cfg.channel_axis,
                    channel_group_size=max(1, cfg.channel_group_size),
                    spatial_block_size=bs, n_channels=c,
                    spatial_extent=m if bs else None)
