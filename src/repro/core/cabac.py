"""Entropy coding of TU bit planes (paper Sec. III-D).

Two interchangeable host coders sit behind :func:`encode_indices` /
:func:`decode_indices`:

  * the seed *serial* coder: a carry-less binary range coder (Subbotin
    style) with an exponentially-adapting probability state per TU bit
    position -- functionally the HEVC m-coder without the LPS tables.
    Bit-serial Python, so it only stays on the hot path for small
    payloads (< ``_SERIAL_CUTOFF_BITS`` TU bits) where its 4-byte flush
    beats the vectorized coder's per-lane overhead;
  * the *vectorized* coder (``repro.core.rans``): numpy-batched
    interleaved binary rANS over the same planes with chunk-static
    probabilities.  Same plane structure, same exact round trip, ~two
    orders of magnitude faster on full activation tensors (measured by
    ``benchmarks/bench_codec.py``).

A one-byte coder id prefixes the payload so the decoder self-selects.
Streams written by the seed (no id byte) are still readable through
:func:`decode_indices_serial`, which ``FeatureCodec.decode`` uses for
legacy headers.  See DESIGN.md for the layout.
"""

from __future__ import annotations

import struct

import numpy as np

from . import rans

_TOP = 1 << 24
_BOT = 1 << 16
_MASK = 0xFFFFFFFF
_PROB_BITS = 16
_PROB_ONE = 1 << _PROB_BITS
_ADAPT_SHIFT = 5
_P_MIN, _P_MAX = 64, _PROB_ONE - 64


class _Context:
    __slots__ = ("p1",)

    def __init__(self) -> None:
        self.p1 = _PROB_ONE // 2

    def update(self, bit: int) -> None:
        if bit:
            self.p1 += (_PROB_ONE - self.p1) >> _ADAPT_SHIFT
        else:
            self.p1 -= self.p1 >> _ADAPT_SHIFT
        self.p1 = min(max(self.p1, _P_MIN), _P_MAX)


class BinaryArithmeticEncoder:
    def __init__(self, n_contexts: int) -> None:
        self.ctx = [_Context() for _ in range(n_contexts)]
        self.low = 0
        self.rng = _MASK
        self.out = bytearray()

    def _normalize(self) -> None:
        while True:
            if (self.low ^ (self.low + self.rng)) & _MASK < _TOP:
                pass
            elif self.rng < _BOT:
                self.rng = (-self.low) & (_BOT - 1)
            else:
                break
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
            self.rng = (self.rng << 8) & _MASK

    def encode(self, bit: int, ctx_id: int) -> None:
        c = self.ctx[ctx_id]
        r1 = (self.rng >> _PROB_BITS) * c.p1
        r1 = min(max(r1, 1), self.rng - 1)
        if bit:
            self.rng = r1
        else:
            self.low = (self.low + r1) & _MASK
            self.rng -= r1
        c.update(bit)
        self._normalize()

    def encode_plane(self, bits: np.ndarray, ctx_id: int) -> None:
        for b in np.asarray(bits, dtype=np.uint8):
            self.encode(int(b), ctx_id)

    def finish(self) -> bytes:
        for _ in range(4):
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
        return bytes(self.out)


class BinaryArithmeticDecoder:
    def __init__(self, data: bytes, n_contexts: int) -> None:
        self.ctx = [_Context() for _ in range(n_contexts)]
        self.data = data
        self.pos = 0
        self.low = 0
        self.rng = _MASK
        self.code = 0
        for _ in range(4):
            self.code = ((self.code << 8) | self._byte()) & _MASK

    def _byte(self) -> int:
        b = self.data[self.pos] if self.pos < len(self.data) else 0
        self.pos += 1
        return b

    def _normalize(self) -> None:
        while True:
            if (self.low ^ (self.low + self.rng)) & _MASK < _TOP:
                pass
            elif self.rng < _BOT:
                self.rng = (-self.low) & (_BOT - 1)
            else:
                break
            self.code = ((self.code << 8) | self._byte()) & _MASK
            self.low = (self.low << 8) & _MASK
            self.rng = (self.rng << 8) & _MASK

    def decode(self, ctx_id: int) -> int:
        c = self.ctx[ctx_id]
        r1 = (self.rng >> _PROB_BITS) * c.p1
        r1 = min(max(r1, 1), self.rng - 1)
        if ((self.code - self.low) & _MASK) < r1:
            bit = 1
            self.rng = r1
        else:
            bit = 0
            self.low = (self.low + r1) & _MASK
            self.rng -= r1
        c.update(bit)
        self._normalize()
        return bit

    def decode_plane(self, n_bits: int, ctx_id: int) -> np.ndarray:
        return np.fromiter((self.decode(ctx_id) for _ in range(n_bits)),
                           dtype=np.uint8, count=n_bits)


_CODER_SERIAL = 0
_CODER_RANS = 1
_CODER_RANS_SHARDED = 2
_CODER_RANS_PROC = 3    # same shard layout as 2, coded on a process pool
_CODER_RANS_DEVICE = 4  # single-shard coder-2 layout, coded on device
# Below this many TU bits the serial coder's 4-byte flush undercuts the
# vectorized coder's per-lane state overhead, and the python loop is cheap.
_SERIAL_CUTOFF_BITS = 1 << 16
# Above this many TU bits "auto" shards the payload across the rANS thread
# or process pool (multi-MB activation tensors); below it the per-shard
# state/table duplication and pool dispatch are not worth it.
_SHARD_MIN_BITS = 1 << 21


def encode_indices_serial(idx: np.ndarray, n_levels: int) -> bytes:
    """Seed bit-serial CABAC encode (no coder-id byte): the baseline path."""
    from .binarization import index_to_context_bits
    enc = BinaryArithmeticEncoder(n_contexts=max(n_levels - 1, 1))
    for j, plane in enumerate(index_to_context_bits(idx, n_levels)):
        enc.encode_plane(plane, j)
    return enc.finish()


def decode_indices_serial(data: bytes, n_elems: int,
                          n_levels: int) -> np.ndarray:
    """Inverse of :func:`encode_indices_serial` (also reads seed streams)."""
    dec = BinaryArithmeticDecoder(data, n_contexts=max(n_levels - 1, 1))
    return _decode_planes(lambda n, j: dec.decode_plane(n, j),
                          n_elems, n_levels)


def _as_bool(bits: np.ndarray) -> np.ndarray:
    return bits.view(np.bool_) if bits.dtype == np.uint8 \
        else bits.astype(bool)


def _decode_planes(next_plane, n_elems: int, n_levels: int) -> np.ndarray:
    """Shared TU plane-to-index reconstruction loop.

    Tracks the alive set as a compacted position array (mirroring the
    encoder's plane compaction): each round's scatter/gather runs over
    the shrinking survivor count, not the full tensor.
    """
    idx = np.zeros(n_elems, dtype=np.int32)
    pos = np.arange(n_elems, dtype=np.int64)
    for j in range(n_levels - 1):
        if pos.size == 0:
            break
        bits = next_plane(pos.size, j)
        pos = pos[_as_bool(bits)]
        idx[pos] += 1
    return idx


def _shard_bounds(n_elems: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous element ranges for sharded coding (last may be short)."""
    per = -(-n_elems // max(1, n_shards))
    return [(s * per, min((s + 1) * per, n_elems))
            for s in range(n_shards) if s * per < n_elems]


def _encode_shard_worker(args) -> bytes:
    """Encode one element shard to a standalone rANS stream (module-level
    so the process pool can pickle it)."""
    seg, n_levels = args
    from .binarization import index_to_context_bits
    return rans.encode_planes(index_to_context_bits(seg, n_levels))


def _decode_shard_worker(args) -> np.ndarray:
    """Decode one standalone shard stream (module-level, picklable)."""
    blob, count, n_levels = args
    d = rans.PlaneStreamDecoder(blob)
    return _decode_planes(lambda n, j: d.next_plane(n), count, n_levels)


def _shard_header(blobs: list[bytes]) -> bytes:
    head = struct.pack("<H", len(blobs))
    head += struct.pack(f"<{len(blobs)}I", *[len(b) for b in blobs])
    return head


def _split_shards(body: bytes, n_elems: int, n_levels: int) -> list:
    """Parse a sharded body into ``_decode_shard_worker`` jobs."""
    (n_shards,) = struct.unpack_from("<H", body)
    lens = struct.unpack_from(f"<{n_shards}I", body, 2)
    bounds = _shard_bounds(n_elems, n_shards)
    if len(bounds) != n_shards:
        raise ValueError("shard count does not match element count")
    off = 2 + 4 * n_shards
    jobs = []
    for (a, b), ln in zip(bounds, lens):
        jobs.append((body[off:off + ln], b - a, n_levels))
        off += ln
    return jobs


def wrap_device_blob(blob: bytes) -> bytes:
    """Coder-4 container for one device-coded (or host-fallback) rANS
    blob: the single-shard coder-2 layout under a distinct id byte, so
    device-coded streams are byte-identical to
    ``_encode_rans_sharded(idx, n_levels, n_shards=1)`` past the id.
    An empty ``blob`` means an empty stream (zero shards, like coder 2's
    empty payload)."""
    if not blob:
        return bytes([_CODER_RANS_DEVICE]) + struct.pack("<H", 0)
    return bytes([_CODER_RANS_DEVICE]) + _shard_header([blob]) + blob


def _encode_rans_sharded(idx: np.ndarray, n_levels: int, n_shards: int,
                         coder_id: int = _CODER_RANS_SHARDED) -> bytes:
    """Shard elements into independent rANS streams coded on the thread
    (coder id 2) or process (coder id 3) pool.  Layout: id byte |
    <H> n_shards | n_shards x <I> byte length | concatenated shard
    streams.  Each shard flushes its own coder state, so shards decode
    independently (and in parallel); both ids share one byte layout, so
    the shard bytes are identical whichever pool coded them."""
    bounds = _shard_bounds(idx.size, n_shards)
    jobs = [(idx[a:b], n_levels) for a, b in bounds]
    if coder_id == _CODER_RANS_PROC:
        blobs = rans.proc_map(_encode_shard_worker, jobs, n_shards)
    else:
        blobs = rans.parallel_map(_encode_shard_worker, jobs)
    return bytes([coder_id]) + _shard_header(blobs) + b"".join(blobs)


def _decode_rans_sharded(body: bytes, n_elems: int, n_levels: int,
                         use_procs: bool = False) -> np.ndarray:
    jobs = _split_shards(body, n_elems, n_levels)
    if not jobs:
        return np.zeros(n_elems, dtype=np.int32)
    if use_procs:
        # a proc-coded stream decodes on the pool when one is configured
        # (and in-process otherwise: ids are wire format, not policy)
        n = rans.proc_workers() or 1
        return np.concatenate(rans.proc_map(_decode_shard_worker, jobs, n))
    return np.concatenate(rans.parallel_map(_decode_shard_worker, jobs))


def encode_indices(idx: np.ndarray, n_levels: int, mode: str = "auto") -> bytes:
    """TU-binarize + entropy-code a flat index array (plane-major order).

    ``mode``: "auto" picks the serial coder below the size cutoff, the
    vectorized coder above it, and -- for multi-MB payloads -- the
    process-sharded coder when ``REPRO_RANS_PROCS`` configures workers,
    else the thread-sharded coder when the thread pool has more than one;
    "serial" / "rans" / "rans_sharded" / "rans_proc" force a coder.  The
    payload starts with a one-byte coder id; :func:`decode_indices`
    dispatches on it.
    """
    from .binarization import index_to_context_bits
    idx = np.asarray(idx).ravel()
    if mode == "auto":
        # every element codes at least one TU bit, so the exact bit count
        # (a full pass over the indices) is only needed when the element
        # count alone cannot settle the choice
        pooled = rans.proc_workers() > 1 or rans.rans_threads() > 1
        if idx.size >= _SERIAL_CUTOFF_BITS and not pooled:
            mode = "rans"
        else:
            from .binarization import total_tu_bits
            total = total_tu_bits(idx, n_levels)
            if total < _SERIAL_CUTOFF_BITS:
                mode = "serial"
            elif total >= _SHARD_MIN_BITS and rans.proc_workers() > 1:
                mode = "rans_proc"
            elif total >= _SHARD_MIN_BITS and rans.rans_threads() > 1:
                mode = "rans_sharded"
            else:
                mode = "rans"
    if mode == "serial":
        enc = BinaryArithmeticEncoder(n_contexts=max(n_levels - 1, 1))
        for j, plane in enumerate(index_to_context_bits(idx, n_levels)):
            enc.encode_plane(plane, j)
        return bytes([_CODER_SERIAL]) + enc.finish()
    if mode == "rans":
        return bytes([_CODER_RANS]) \
            + rans.encode_planes(index_to_context_bits(idx, n_levels))
    if mode == "rans_sharded":
        return _encode_rans_sharded(idx, n_levels, rans.rans_threads())
    if mode == "rans_proc":
        return _encode_rans_sharded(idx, n_levels,
                                    max(2, rans.proc_workers()),
                                    coder_id=_CODER_RANS_PROC)
    if mode == "rans_device":
        # in-graph coder (id 4); on host arrays this round-trips through
        # the device, so it is mainly the backends' emit_wire path that
        # reaches it with data already resident
        from ..kernels.rans_coder import encode_indices_device
        return encode_indices_device(idx, n_levels)
    raise ValueError(f"unknown coder mode {mode!r}")


def _levels_list(n_levels, count: int) -> list[int]:
    """Normalize an ``n_levels`` argument (scalar or per-item sequence)."""
    if np.ndim(n_levels) == 0:
        return [int(n_levels)] * count
    levels = [int(n) for n in n_levels]
    if len(levels) != count:
        raise ValueError(f"got {len(levels)} n_levels for {count} payloads")
    return levels


def encode_indices_batch(segments: list[np.ndarray], n_levels,
                         mode: str = "auto") -> list[bytes]:
    """Encode many independent index segments with shared dispatch.

    Payload-compatible with per-segment :func:`encode_indices` calls (each
    blob starts with its own coder-id byte and decodes in isolation), but
    all segments that land on the vectorized coder share one batched rANS
    step loop (:func:`repro.core.rans.encode_planes_batch`) -- the
    chunked-stream encoder's per-chunk python dispatch collapses to one
    loop per batch.  ``auto`` keeps the serial coder for small segments;
    the thread-sharded coder is not used here (batching already amortizes
    the dispatch the pool would target).  ``n_levels`` may be a scalar or
    one value per segment (cross-session ticks mix quantizer rungs).
    """
    from .binarization import index_to_context_bits, total_tu_bits
    segments = [np.asarray(s).ravel() for s in segments]
    levels = _levels_list(n_levels, len(segments))
    out: list[bytes | None] = [None] * len(segments)
    rans_ids = []
    for i, seg in enumerate(segments):
        m = mode
        if m == "auto":
            m = "rans" if seg.size >= _SERIAL_CUTOFF_BITS else \
                ("serial" if total_tu_bits(seg, levels[i])
                 < _SERIAL_CUTOFF_BITS else "rans")
        if m == "rans":
            rans_ids.append(i)
        else:
            out[i] = encode_indices(seg, levels[i], mode=m)
    blobs = rans.encode_planes_batch(
        [index_to_context_bits(segments[i], levels[i]) for i in rans_ids])
    for i, blob in zip(rans_ids, blobs):
        out[i] = bytes([_CODER_RANS]) + blob
    return out


def decode_indices(data: bytes, n_elems: int, n_levels: int) -> np.ndarray:
    """Inverse of :func:`encode_indices` (reads the coder-id byte)."""
    if len(data) == 0:
        raise ValueError("empty bitstream")
    coder, body = data[0], data[1:]
    if coder == _CODER_SERIAL:
        return decode_indices_serial(body, n_elems, n_levels)
    if coder == _CODER_RANS:
        dec = rans.PlaneStreamDecoder(body)
        return _decode_planes(lambda n, j: dec.next_plane(n),
                              n_elems, n_levels)
    if coder in (_CODER_RANS_SHARDED, _CODER_RANS_DEVICE):
        return _decode_rans_sharded(body, n_elems, n_levels)
    if coder == _CODER_RANS_PROC:
        return _decode_rans_sharded(body, n_elems, n_levels, use_procs=True)
    raise ValueError(f"unknown coder id {coder}")


def decode_indices_batch(payloads: list[bytes], counts: list[int],
                         n_levels) -> list[np.ndarray]:
    """Decode many independent payloads with shared dispatch.

    Result-identical to per-payload :func:`decode_indices` calls, but all
    payloads coded by the vectorized coder with a common lane count share
    one batched step loop per TU plane round
    (:class:`repro.core.rans.BatchPlaneDecoder`) -- the receive side's
    per-chunk python dispatch collapses the same way the batched encoder
    collapsed the send side's.  Serial and sharded payloads decode
    individually (they are small or already parallel).  ``n_levels`` may
    be a scalar or one value per payload: a cross-session drain mixes
    streams at different quantizer rungs in one call, and a stream whose
    TU planes are exhausted simply stops consuming plane rounds.
    """
    levels = _levels_list(n_levels, len(payloads))
    out: list[np.ndarray | None] = [None] * len(payloads)
    groups: dict[int, list[tuple[int, int]]] = {}
    for i, data in enumerate(payloads):
        # a device-coded payload is a single-shard container, so past
        # the 7-byte prefix it batches like a plain rANS blob
        off = 1 if len(data) > 1 and data[0] == _CODER_RANS else None
        if off is None and len(data) > 7 \
                and data[0] == _CODER_RANS_DEVICE \
                and struct.unpack_from("<H", data, 1)[0] == 1:
            off = 7
        if off is not None:
            (lanes,) = struct.unpack_from("<H", data, off)
            if lanes:
                groups.setdefault(lanes, []).append((i, off))
                continue
        out[i] = decode_indices(data, counts[i], levels[i])
    for lanes, members in groups.items():
        if len(members) == 1:
            i = members[0][0]
            out[i] = decode_indices(payloads[i], counts[i], levels[i])
            continue
        dec = rans.BatchPlaneDecoder([payloads[i][o:] for i, o in members])
        n = [counts[i] for i, _ in members]
        rounds = [levels[i] - 1 for i, _ in members]
        idxs = [np.zeros(c, dtype=np.int32) for c in n]
        poss = [np.arange(c, dtype=np.int64) for c in n]
        for r in range(max(rounds)):
            n_alive = [p.size if r < rounds[s] else 0
                       for s, p in enumerate(poss)]
            if not any(n_alive):
                break
            planes = dec.next_planes(n_alive)
            for s, bits in enumerate(planes):
                if n_alive[s] == 0:
                    continue
                poss[s] = poss[s][_as_bool(bits)]
                idxs[s][poss[s]] += 1
        for (i, _), idx in zip(members, idxs):
            out[i] = idx
    return out
