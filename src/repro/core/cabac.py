"""Simplified CABAC: adaptive binary arithmetic coding with one context per
TU bit position (paper Sec. III-D).

Implementation is a carry-less binary range coder (Subbotin style) with an
exponentially-adapting probability state per context -- functionally the
same structure as the HEVC m-coder but without the LPS lookup tables.  The
encoder/decoder pair round-trips bit-exactly; rates come out within a few
percent of the adaptive-entropy bound.

The coder runs on the host (it is inherently bit-serial; on a real edge
deployment it runs on the device CPU next to the NN accelerator -- see
DESIGN.md hardware-adaptation notes).
"""

from __future__ import annotations

import numpy as np

_TOP = 1 << 24
_BOT = 1 << 16
_MASK = 0xFFFFFFFF
_PROB_BITS = 16
_PROB_ONE = 1 << _PROB_BITS
_ADAPT_SHIFT = 5
_P_MIN, _P_MAX = 64, _PROB_ONE - 64


class _Context:
    __slots__ = ("p1",)

    def __init__(self) -> None:
        self.p1 = _PROB_ONE // 2

    def update(self, bit: int) -> None:
        if bit:
            self.p1 += (_PROB_ONE - self.p1) >> _ADAPT_SHIFT
        else:
            self.p1 -= self.p1 >> _ADAPT_SHIFT
        self.p1 = min(max(self.p1, _P_MIN), _P_MAX)


class BinaryArithmeticEncoder:
    def __init__(self, n_contexts: int) -> None:
        self.ctx = [_Context() for _ in range(n_contexts)]
        self.low = 0
        self.rng = _MASK
        self.out = bytearray()

    def _normalize(self) -> None:
        while True:
            if (self.low ^ (self.low + self.rng)) & _MASK < _TOP:
                pass
            elif self.rng < _BOT:
                self.rng = (-self.low) & (_BOT - 1)
            else:
                break
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
            self.rng = (self.rng << 8) & _MASK

    def encode(self, bit: int, ctx_id: int) -> None:
        c = self.ctx[ctx_id]
        r1 = (self.rng >> _PROB_BITS) * c.p1
        r1 = min(max(r1, 1), self.rng - 1)
        if bit:
            self.rng = r1
        else:
            self.low = (self.low + r1) & _MASK
            self.rng -= r1
        c.update(bit)
        self._normalize()

    def encode_plane(self, bits: np.ndarray, ctx_id: int) -> None:
        for b in np.asarray(bits, dtype=np.uint8):
            self.encode(int(b), ctx_id)

    def finish(self) -> bytes:
        for _ in range(4):
            self.out.append((self.low >> 24) & 0xFF)
            self.low = (self.low << 8) & _MASK
        return bytes(self.out)


class BinaryArithmeticDecoder:
    def __init__(self, data: bytes, n_contexts: int) -> None:
        self.ctx = [_Context() for _ in range(n_contexts)]
        self.data = data
        self.pos = 0
        self.low = 0
        self.rng = _MASK
        self.code = 0
        for _ in range(4):
            self.code = ((self.code << 8) | self._byte()) & _MASK

    def _byte(self) -> int:
        b = self.data[self.pos] if self.pos < len(self.data) else 0
        self.pos += 1
        return b

    def _normalize(self) -> None:
        while True:
            if (self.low ^ (self.low + self.rng)) & _MASK < _TOP:
                pass
            elif self.rng < _BOT:
                self.rng = (-self.low) & (_BOT - 1)
            else:
                break
            self.code = ((self.code << 8) | self._byte()) & _MASK
            self.low = (self.low << 8) & _MASK
            self.rng = (self.rng << 8) & _MASK

    def decode(self, ctx_id: int) -> int:
        c = self.ctx[ctx_id]
        r1 = (self.rng >> _PROB_BITS) * c.p1
        r1 = min(max(r1, 1), self.rng - 1)
        if ((self.code - self.low) & _MASK) < r1:
            bit = 1
            self.rng = r1
        else:
            bit = 0
            self.low = (self.low + r1) & _MASK
            self.rng -= r1
        c.update(bit)
        self._normalize()
        return bit

    def decode_plane(self, n_bits: int, ctx_id: int) -> np.ndarray:
        return np.fromiter((self.decode(ctx_id) for _ in range(n_bits)),
                           dtype=np.uint8, count=n_bits)


def encode_indices(idx: np.ndarray, n_levels: int) -> bytes:
    """TU-binarize + CABAC-encode a flat index array (plane-major order)."""
    from .binarization import index_to_context_bits
    enc = BinaryArithmeticEncoder(n_contexts=max(n_levels - 1, 1))
    for j, plane in enumerate(index_to_context_bits(idx, n_levels)):
        enc.encode_plane(plane, j)
    return enc.finish()


def decode_indices(data: bytes, n_elems: int, n_levels: int) -> np.ndarray:
    """Inverse of :func:`encode_indices`."""
    dec = BinaryArithmeticDecoder(data, n_contexts=max(n_levels - 1, 1))
    idx = np.zeros(n_elems, dtype=np.int32)
    alive = np.ones(n_elems, dtype=bool)
    for j in range(n_levels - 1):
        n_alive = int(alive.sum())
        if n_alive == 0:
            break
        bits = dec.decode_plane(n_alive, j)
        cont = np.zeros(n_elems, dtype=bool)
        cont[alive] = bits.astype(bool)
        idx[cont] += 1
        alive = cont
    return idx
