"""Uniform N-level quantizer with pinned outer bins (paper eq. 1).

    Q(x_clp) = round((x_clp - c_min) / (c_max - c_min) * (N - 1))

with round-half-away-from-zero.  Values clipped to c_min / c_max incur no
further quantization error (the outer reconstruction levels sit exactly on
the clipping boundaries).  N need not be a power of two.

These are the pure-jnp reference implementations; the Pallas fused kernel
in ``repro.kernels`` must match them bit-exactly.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def quantize(x, cmin: float, cmax: float, n_levels: int):
    """Clip + quantize to integer indices in [0, n_levels - 1] (int32)."""
    xc = jnp.clip(x, cmin, cmax)
    scale = (n_levels - 1) / (cmax - cmin)
    # scaled value is >= 0, so round-half-away == floor(q + 0.5)
    q = jnp.floor((xc - cmin) * scale + 0.5)
    return q.astype(jnp.int32)


def dequantize(idx, cmin: float, cmax: float, n_levels: int, dtype=jnp.float32):
    delta = (cmax - cmin) / (n_levels - 1)
    return (cmin + idx.astype(jnp.float32) * delta).astype(dtype)


def quantize_dequantize(x, cmin: float, cmax: float, n_levels: int):
    """Fake-quant: quantize then dequantize, preserving input dtype."""
    return dequantize(quantize(x, cmin, cmax, n_levels), cmin, cmax, n_levels,
                      dtype=x.dtype)


def straight_through_quant(x, cmin: float, cmax: float, n_levels: int):
    """y = qdq(x) in the forward pass; dy/dx = 1 on [cmin, cmax] else 0.

    Used for optional compression-aware fine-tuning (the paper itself is
    strictly post-training; this is an opt-in extension).
    """
    import jax
    xc = jnp.clip(x, cmin, cmax)
    y = quantize_dequantize(x, cmin, cmax, n_levels)
    return xc + jax.lax.stop_gradient(y - xc)


def quantize_np(x: np.ndarray, cmin: float, cmax: float, n_levels: int) -> np.ndarray:
    xc = np.clip(np.asarray(x, dtype=np.float64), cmin, cmax)
    q = np.floor((xc - cmin) / (cmax - cmin) * (n_levels - 1) + 0.5)
    return q.astype(np.int32)


def dequantize_np(idx: np.ndarray, cmin: float, cmax: float, n_levels: int) -> np.ndarray:
    delta = (cmax - cmin) / (n_levels - 1)
    return cmin + idx.astype(np.float64) * delta
