"""Config schema for the model zoo and the assigned input shapes.

Every architecture is expressed as a repeating ``pattern`` of layer specs
(mixer kind + locality); the model builder groups repeated periods into a
``lax.scan`` with stacked parameters, which keeps compile time flat in
depth even for 95-layer configs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Literal

MixerKind = Literal["attn", "rglru", "rwkv"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One layer of the repeating pattern: a mixer plus its MLP/channel-mix."""

    kind: MixerKind = "attn"
    window: int | None = None  # sliding-window size for local attention
    moe: bool = False          # MoE MLP instead of dense


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    # --- attention details ---
    attn_logit_softcap: float = 0.0
    final_logit_softcap: float = 0.0
    use_qk_norm: bool = False
    rope_theta: float = 10_000.0
    pos_emb: str = "rope"       # rope | sinusoidal
    # --- recurrent details ---
    rnn_dim: int = 0            # RG-LRU width
    conv_width: int = 4
    rwkv_head_dim: int = 64
    rwkv_lora_rank: int = 64
    # --- misc ---
    act: str = "silu"           # silu | gelu
    gated_mlp: bool = True      # SwiGLU/GeGLU vs plain 2-matrix FFN
    norm: str = "rmsnorm"       # rmsnorm | layernorm
    norm_eps: float = 1e-6
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm frontend stubs)
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # --- KV-cache compression (paper eq. 1 applied to K/V storage) ---
    kv_quant_bits: int = 0     # 0 = bf16 cache; 8 = uint8 quantized cache
    kv_clip: float = 8.0       # symmetric clip range for KV quantization
    # --- collaborative-intelligence split (paper integration) ---
    split_after_period: int = 0   # split boundary, in pattern periods (0 = mid)
    long_context_ok: bool = False  # may run the long_500k shape
    notes: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) not in (0,) and \
                self.num_layers < len(self.pattern):
            raise ValueError("pattern longer than num_layers")

    # -- derived ---------------------------------------------------------------

    @property
    def period(self) -> int:
        return len(self.pattern)

    @property
    def n_full_periods(self) -> int:
        return self.num_layers // self.period

    @property
    def remainder(self) -> tuple[LayerSpec, ...]:
        r = self.num_layers % self.period
        return self.pattern[:r]

    def layer_specs(self) -> list[LayerSpec]:
        return list(self.pattern) * self.n_full_periods + list(self.remainder)

    def param_count(self) -> int:
        """Total parameters (embedding included once if tied)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        h, k, hd = self.num_heads, self.num_kv_heads, self.head_dim
        norm_p = 2 * d if self.norm == "layernorm" else d  # scale (+ bias)
        total = v * d              # embedding
        if not self.tie_embeddings:
            total += d * v         # lm head
        total += norm_p            # final norm
        for spec in self.layer_specs():
            total += 2 * norm_p    # two norms
            if spec.kind == "attn":
                total += d * h * hd + 2 * d * k * hd + h * hd * d
                if self.use_qk_norm:
                    total += 2 * self.head_dim
            elif spec.kind == "rglru":
                r = self.rnn_dim
                # w_in,w_gate + conv(w,b) + wa,ba,wx,bx + lam + w_out
                total += 2 * d * r + self.conv_width * r + r \
                    + 2 * r * r + 2 * r + r + r * d
            elif spec.kind == "rwkv":
                m = self.num_heads * self.rwkv_head_dim
                # mu(5d) + wr/wk/wv/wg/wo + w0 + lora(A,B) + u + ln
                total += 5 * d + 5 * d * m + m \
                    + self.rwkv_lora_rank * (d + m) + m + m
            if spec.moe:
                e, ef = self.num_experts, self.moe_d_ff
                total += d * e + e * (2 * d * ef + ef * d)
            elif spec.kind == "rwkv":
                total += 2 * d + d * f + f * d + d * d  # channel mix
            else:
                total += (3 if self.gated_mlp else 2) * d * f
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, ef = self.d_model, self.moe_d_ff
        e, kk = self.num_experts, self.experts_per_token
        per_layer_all = e * (2 * d * ef + ef * d)
        per_layer_active = kk * (2 * d * ef + ef * d)
        n_moe = sum(1 for s in self.layer_specs() if s.moe)
        return self.param_count() - n_moe * (per_layer_all - per_layer_active)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def reduced(cfg: ModelConfig, *, layers: int | None = None, d_model: int = 64,
            seq_len_cap: int = 128) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving the family structure."""
    period = cfg.period
    if layers is not None:
        n_layers = layers
    else:
        # one full period + the true remainder, so both code paths are hit
        n_layers = (period if period > 1 else 2) + cfg.num_layers % period
    scale = d_model / cfg.d_model
    hd = 16
    heads = max(2, min(cfg.num_heads, 4))
    kv = max(1, min(cfg.num_kv_heads, heads))
    # shrink local windows so locality is exercised at tiny seq lens
    pattern = tuple(dataclasses.replace(
        s, window=(min(s.window, seq_len_cap // 2) if s.window else None))
        for s in cfg.pattern)
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=n_layers,
        d_model=d_model,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=hd,
        d_ff=d_model * 3,
        vocab_size=min(cfg.vocab_size, 512),
        pattern=pattern,
        num_experts=min(cfg.num_experts, 8) if cfg.num_experts else 0,
        experts_per_token=min(cfg.experts_per_token, 2) if cfg.experts_per_token else 0,
        moe_d_ff=d_model * 2 if cfg.num_experts else 0,
        # drop-free at smoke scale so decode == forward exactly; the
        # capacity-dropping path is unit-tested separately in test_moe.py
        capacity_factor=float(min(cfg.num_experts, 8)) if cfg.num_experts else 1.25,
        rnn_dim=d_model if cfg.rnn_dim else 0,
        rwkv_head_dim=16,
        rwkv_lora_rank=8,
        dtype="float32",
    )
