from .base import SHAPES, InputShape, LayerSpec, ModelConfig, reduced
from .registry import ARCHS, get_config

__all__ = ["SHAPES", "InputShape", "LayerSpec", "ModelConfig", "reduced",
           "ARCHS", "get_config"]
