"""The 10 assigned architectures (public-literature configs) + registry.

Every entry is selectable via ``--arch <id>`` in the launchers.  Sources are
cited per config; ``long_context_ok`` marks archs that may run the
``long_500k`` decode shape (sub-quadratic or windowed+global mixes whose
500k KV cache fits when sharded) -- pure full-attention archs skip it, see
DESIGN.md §Arch-applicability.
"""

from __future__ import annotations

from .base import LayerSpec, ModelConfig

_A = LayerSpec  # shorthand


MUSICGEN_LARGE = ModelConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    pattern=(_A(),),
    act="gelu", gated_mlp=False, norm="layernorm", pos_emb="sinusoidal",
    input_mode="embeddings",  # EnCodec frame embeddings (frontend stubbed)
    notes="Decoder-only over EnCodec tokens [arXiv:2306.05284]; modality "
          "frontend stubbed per assignment: input_specs() provides "
          "precomputed frame embeddings.",
)

DBRX_132B = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    pattern=(_A(moe=True),),
    num_experts=16, experts_per_token=4, moe_d_ff=10752,
    act="silu", norm="layernorm",
    notes="16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base].",
)

QWEN3_MOE_235B = ModelConfig(
    name="qwen3-moe-235b-a22b", family="moe",
    num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4, head_dim=128,
    d_ff=1536, vocab_size=151936,
    pattern=(_A(moe=True),),
    num_experts=128, experts_per_token=8, moe_d_ff=1536,
    use_qk_norm=True, rope_theta=1e6,
    notes="128-expert top-8 MoE with QK-norm [hf:Qwen/Qwen3-235B-A22B].",
)

DEEPSEEK_67B = ModelConfig(
    name="deepseek-67b", family="dense",
    num_layers=95, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=22016, vocab_size=102400,
    pattern=(_A(),),
    notes="Llama-architecture dense model [arXiv:2401.02954].",
)

GEMMA3_1B = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    pattern=(_A(window=512), _A(window=512), _A(window=512),
             _A(window=512), _A(window=512), _A()),  # 5 local : 1 global
    rope_theta=1e6, tie_embeddings=True, act="gelu",
    long_context_ok=True,
    notes="5:1 local:global, 512 window, 128k context [hf:google/gemma-3-1b-pt]."
          " long_500k allowed: only 1/6 layers keep a full KV cache.",
)

CODEQWEN15_7B = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32, head_dim=128,
    d_ff=13440, vocab_size=92416,
    rope_theta=1e6,
    notes="Qwen1.5 architecture (MHA) [hf:Qwen/CodeQwen1.5-7B].",
)

GEMMA2_9B = ModelConfig(
    name="gemma2-9b", family="dense",
    num_layers=42, d_model=3584, num_heads=16, num_kv_heads=8, head_dim=256,
    d_ff=14336, vocab_size=256000,
    pattern=(_A(window=4096), _A()),  # alternating local/global
    attn_logit_softcap=50.0, final_logit_softcap=30.0,
    act="gelu", tie_embeddings=True,
    long_context_ok=True,
    notes="Local+global alternating with logit softcaps [arXiv:2408.00118]."
          " long_500k allowed: half the layers cache only a 4k window.",
)

QWEN2_VL_2B = ModelConfig(
    name="qwen2-vl-2b", family="vlm",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2, head_dim=128,
    d_ff=8960, vocab_size=151936,
    rope_theta=1e6, tie_embeddings=True,
    input_mode="embeddings",  # ViT patch embeddings (frontend stubbed)
    notes="M-RoPE approximated by 1-D RoPE over provided patch/text embedding"
          " stream; dynamic-resolution ViT frontend stubbed per assignment"
          " [arXiv:2409.12191].",
)

RECURRENTGEMMA_2B = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256000,
    pattern=(_A(kind="rglru"), _A(kind="rglru"), _A(window=2048)),  # 2 RG-LRU : 1 local attn
    rnn_dim=2560, conv_width=4, act="gelu", tie_embeddings=True,
    long_context_ok=True,
    notes="Griffin: RG-LRU recurrent blocks + 2k-window local attention"
          " [arXiv:2402.19427]; O(1) state per recurrent layer.",
)

RWKV6_3B = ModelConfig(
    name="rwkv6-3b", family="ssm",
    num_layers=32, d_model=2560, num_heads=40, num_kv_heads=40, head_dim=64,
    d_ff=8960, vocab_size=65536,
    pattern=(_A(kind="rwkv"),),
    rwkv_head_dim=64, rwkv_lora_rank=64, norm="layernorm",
    long_context_ok=True,
    notes="RWKV-6 Finch: data-dependent decay, attention-free, O(1) state"
          " [arXiv:2404.05892].",
)


ARCHS: dict[str, ModelConfig] = {
    c.name: c for c in [
        MUSICGEN_LARGE, DBRX_132B, QWEN3_MOE_235B, DEEPSEEK_67B, GEMMA3_1B,
        CODEQWEN15_7B, GEMMA2_9B, QWEN2_VL_2B, RECURRENTGEMMA_2B, RWKV6_3B,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; available: {sorted(ARCHS)}")
    return ARCHS[name]
