"""Pallas TPU kernel: quantizer-index histogram for in-graph rate estimation.

The entropy model (repro.core.rate_model) needs only the N-bin histogram
of quantizer indices.  The kernel accumulates per-bin counts across the
sequential TPU grid into a single (1, N) output block (same block mapped
at every grid step; zero-initialized on the first step) -- the standard
Pallas reduction-output pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .fused_clip_quant import band_valid_array

DEFAULT_BLOCK = (256, 512)
MAX_LEVELS = 64


def _kernel(idx_ref, hist_ref, *, n_levels: int):
    i, j = pl.program_id(0), pl.program_id(1)

    @pl.when((i == 0) & (j == 0))
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    idx = idx_ref[...]
    # one-hot accumulate against a lane iota: the loop index appears only
    # in *values* (the select), never as a ref index, so the body stays
    # free of dynamic lane addressing (which Mosaic may refuse to lower)
    lane = jax.lax.broadcasted_iota(jnp.int32, hist_ref.shape, 1)

    def body(n, carry):                  # blocked: N scales to 64
        cnt = jnp.sum((idx == n).astype(jnp.int32))
        hist_ref[...] += jnp.where(lane == n, cnt, 0)
        return carry

    jax.lax.fori_loop(0, n_levels, body, 0)


def _kernel_tiles(idx_ref, valid_ref, hist_ref, *, n_levels: int, bc: int,
                  sb_cols: int):
    """Per-(row, spatial-band) histogram: the tile-resolved variant of
    :func:`_kernel`, sharing the fused encode megakernel's output layout
    (see ``fused_clip_quant._kernel_encode``) so tile-aware in-graph rate
    estimation needs no packed-bytes pass.  Band-column padding beyond
    the band's valid count (the (1, 1) ``valid_ref`` cell -- 2-D plans
    have ragged edge tiles, so every band carries its own count) is
    masked out; padded rows are dropped host-side."""
    j = pl.program_id(1)
    band_col = (j % (sb_cols // bc)) * bc

    @pl.when(band_col == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    idx = idx_ref[...]
    limit = valid_ref[0, 0]
    valid = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1) \
        + band_col < limit
    hlane = jax.lax.broadcasted_iota(jnp.int32, hist_ref.shape, 1)

    def body(n, carry):
        cnt = jnp.sum(jnp.where(valid & (idx == n), 1, 0), axis=1,
                      keepdims=True)
        hist_ref[...] += jnp.where(hlane == n, cnt, 0)
        return carry

    jax.lax.fori_loop(0, n_levels, body, 0)


def index_histogram_tiles_2d(idx, n_levels: int, sb_cols: int, bs: int,
                             bs_last: int | None = None, band_valid=None,
                             block=DEFAULT_BLOCK, interpret: bool = False):
    """idx: (R, C) int32 banded view, C == n_sblocks * sb_cols;
    ``band_valid`` (n_sblocks,) optionally gives explicit per-band valid
    counts (2-D ragged tiles).  Returns (R, n_sblocks * MAX_LEVELS) int32
    per-(row, band) counts."""
    if n_levels > MAX_LEVELS:
        raise ValueError(f"n_levels {n_levels} > {MAX_LEVELS}")
    r, c = idx.shape
    if c % sb_cols:
        raise ValueError(f"C {c} not a multiple of sb_cols {sb_cols}")
    n_sblocks = c // sb_cols
    br = min(block[0], r)
    bc = min(block[1], c, sb_cols)
    while sb_cols % bc:
        bc -= 128
    grid = (r // br, c // bc)
    bpb = sb_cols // bc
    valid = band_valid_array(n_sblocks, bs, bs_last, band_valid)
    return pl.pallas_call(
        functools.partial(_kernel_tiles, n_levels=n_levels, bc=bc,
                          sb_cols=sb_cols),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((1, 1), lambda i, j, bpb=bpb: (0, j // bpb))],
        out_specs=pl.BlockSpec((br, MAX_LEVELS),
                               lambda i, j, bpb=bpb: (i, j // bpb)),
        out_shape=jax.ShapeDtypeStruct((r, n_sblocks * MAX_LEVELS),
                                       jnp.int32),
        interpret=interpret,
    )(idx, valid)


def index_histogram_2d(idx, n_levels: int, block=DEFAULT_BLOCK,
                       interpret: bool = False):
    """idx: (R, C) int32, block-aligned. Returns (n_levels,) int32 counts."""
    if n_levels > MAX_LEVELS:
        raise ValueError(f"n_levels {n_levels} > {MAX_LEVELS}")
    r, c = idx.shape
    br, bc = min(block[0], r), min(block[1], c)
    grid = (r // br, c // bc)
    hist = pl.pallas_call(
        functools.partial(_kernel, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((1, MAX_LEVELS), lambda i, j: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, MAX_LEVELS), jnp.int32),
        interpret=interpret,
    )(idx)
    return hist[0, :n_levels]
