"""Pure-jnp oracles for every Pallas kernel (the correctness reference)."""

from __future__ import annotations

import jax.numpy as jnp


def clip_quant_ref(x, cmin: float, cmax: float, n_levels: int):
    scale = (n_levels - 1) / (cmax - cmin)
    inv_scale = (cmax - cmin) / (n_levels - 1)
    xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
    q = jnp.floor((xc - cmin) * scale + 0.5)
    return q.astype(jnp.int32), (cmin + q * inv_scale).astype(x.dtype)


def ecsq_assign_ref(x, thresholds, levels, cmin: float, cmax: float):
    xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
    idx = jnp.searchsorted(thresholds.astype(jnp.float32), xc,
                           side="right").astype(jnp.int32)
    return idx, levels.astype(jnp.float32)[idx].astype(x.dtype)


def index_histogram_ref(idx, n_levels: int):
    one_hot = (idx.reshape(-1)[:, None] ==
               jnp.arange(n_levels)[None, :]).astype(jnp.int32)
    return one_hot.sum(axis=0)
