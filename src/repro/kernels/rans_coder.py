"""Device-resident interleaved-rANS entropy stage (entropy coder id 4).

The host coder (:mod:`repro.core.rans`) runs the step loop in numpy, so
every fused encode ships the full packed-index tensor device->host before
a single wire byte exists.  This module moves the whole entropy stage
in-graph: TU bit-plane construction, the chunk-static probability build,
and the lane-parallel rANS step loop all run on device, and only the
coded bytes (plus the small probability table and per-lane state flush)
cross to the host.

Byte identity is the contract: for any coded-order index vector the blob
assembled here is identical to ``rans.encode_planes(
cabac.index_to_context_bits(idx, n_levels))`` -- the golden conformance
suite pins it.  That means every quirk of the host coder is reproduced
exactly:

  * planes are concatenated in TU order with empty planes skipped, each
    plane padded to a step boundary with its most-probable symbol;
  * per-chunk probabilities are ``rint(ones / size * 2^14)`` with
    float64 round-half-even semantics -- reproduced in exact integer
    arithmetic (two-step long division keeps every intermediate in
    int32, which is also what the TPU ALUs have);
  * the step loop runs in reverse with 32-bit states renormalized 16
    bits at a time, and emitted words are gathered in (step asc, lane
    asc) order.

The plane build is scatter-free (XLA scatters serialize; gathers and
scans vectorize): a TU plane's bit vector *is* the next plane's alive
mask, so one inclusive scan per plane yields both the chunk one-counts
and the compaction ranks, and each successive plane is materialized by
a sorted-rank binary search (``searchsorted``) into the previous one --
a pure gather.  Plane sizes ride back to the host on the same tiny
pre-pass that already decides the lane count, so every buffer is sized
to a power-of-two bucket of the live data instead of the all-planes-full
worst case (the bucket is the jit cache key, keeping retraces bounded).

The per-lane state update itself is float-free 32-bit integer arithmetic
(the renorm invariant keeps ``x < 2^32`` and ``q < 2^18``, so nothing
ever needs the uint64 the host coder uses), which is exactly the shape a
TPU vector lane wants.  Two interchangeable step-loop implementations:

  * :func:`_step_loop_jnp` -- a ``lax.while_loop`` over steps, used by
    the jnp backend (and as the reference for the kernel);
  * :func:`_step_loop_pallas` -- a Pallas kernel with a sequential grid
    over steps and the (1, lanes) state vector carried in a revisited
    output block, used by the kernel backend (interpret mode on CPU).

Per stream the stage is a size pre-pass (one reduction; the lane count
and buffer buckets derive from it, so it has to reach the host first),
the fused plane-build + step-loop graph dispatched async, and a
finalize that fetches one word count and launches a small gather to
compact the renorm words before slicing out ~wire-size bytes -- the
``wire_d2h`` span and the ``repro_codec_d2h_bytes_total`` counter
measure exactly that.
"""

from __future__ import annotations

import functools
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from ..core import rans
from ..obs.metrics import default_registry
from ..obs.tracing import span

_PROB_BITS = 14
_M = 1 << _PROB_BITS
_CHUNK_STEPS = 256
_STATE_LO = 1 << 16
_HEADER_FMT = "<HI"

# the in-graph plane build materializes one compacted array per TU
# plane; past this level count the host coder's compaction loop wins,
# so callers fall back (the wire container is identical either way)
MAX_DEVICE_LEVELS = 16


def _d2h_counter():
    return default_registry().counter(
        "repro_codec_d2h_bytes_total",
        "bytes fetched device->host by the encode path (wire payloads, "
        "probability side info and state flushes on the device-entropy "
        "path; full packed-index tensors on the host-coder path)")


def device_supported(n: int, n_levels: int) -> bool:
    """Can the device stage code this stream (host fallback otherwise)?"""
    return (2 <= n_levels <= MAX_DEVICE_LEVELS
            and n * (n_levels - 1) < (1 << 31) - 2)


def _next_pow2(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


@functools.partial(jax.jit, static_argnames=("n_levels",))
def _plane_sizes(coded, n_levels: int):
    """Per-plane element counts: ``sizes[j] = #{i : coded[i] >= j}``.

    The only data-dependent scalars the host needs before dispatch --
    total bits (their sum) picks the lane count, and the counts pick
    the per-plane buffer buckets.
    """
    jv = jnp.arange(n_levels - 1, dtype=jnp.int32)[:, None]
    return jnp.sum((coded[None, :] >= jv).astype(jnp.int8), axis=1,
                   dtype=jnp.int32)


def _round_half_even_div(ones, sizes):
    """Exact ``rint(ones / sizes * 2^14)`` (float64 semantics) in int32.

    ``ones * 2^14`` can reach 2^34, so the division runs as a two-step
    long division by 2^7 factors; the tie is broken to even like
    ``np.rint``.  Exactness of the float path: ``ones / sizes`` rounds
    once in f64, the *2^14 is an exponent shift (exact), and the
    quotient is at least 2^-21 away from any half-integer it is not
    exactly equal to (sizes <= 2^20), far beyond the 2^-39 f64 error.
    """
    t1 = ones * 128
    q1 = t1 // sizes
    t2 = (t1 - q1 * sizes) * 128
    q2 = t2 // sizes
    r2 = t2 - q2 * sizes
    q = q1 * 128 + q2
    twice = 2 * r2
    up = (twice > sizes) | ((twice == sizes) & ((q & 1) == 1))
    return q + up.astype(q.dtype)


def _iscan(v):
    """Inclusive int32 prefix sum (associative_scan lowers to log-depth
    passes, ~2x faster than the serial cumsum lowering on CPU)."""
    return jax.lax.associative_scan(jnp.add, v.astype(jnp.int32))


def _build_planes(coded, meta, n_levels: int, lanes: int, caps, t_cap: int,
                  f_cap: int):
    """In-graph mirror of ``index_to_context_bits`` + ``_plane_setup``.

    ``caps[j-1]`` is the (host-chosen, lane-padded) static capacity of
    plane ``j >= 1``; empty planes are already dropped by the host, so
    the chain covers exactly the planes the host coder keeps.  Returns
    the packed step matrix, the per-step probability vector and the
    uint16 probability table.

    Layout scalars (sizes/offsets, all exactly known to the host) come
    in through ``meta`` so they stay dynamic: the jit key is only the
    bucket tuple.  Each plane writes its lane-padded block with a
    dynamic_update_slice; a block's bucket overhang spills into the
    next plane's rows, and the ascending write order repairs it (the
    last plane's overhang lies past ``total_steps`` and is never
    coded).
    """
    n = coded.shape[0]
    chunk_bits = _CHUNK_STEPS * lanes
    n_planes = 1 + len(caps)

    def m(slot, j):
        return meta[1 + 4 * j + slot]

    size = [m(0, j) for j in range(n_planes)]
    off = [m(1, j) for j in range(n_planes)]
    foff = [m(2, j) for j in range(n_planes)]
    nch = [m(3, j) for j in range(n_planes)]

    # compaction chain: a plane's bit vector is the next plane's alive
    # mask, so cb (the masked ones scan) doubles as the rank array the
    # next plane's searchsorted gathers from
    rows0 = -(-n // lanes)
    bits, cbs = [], []
    cur = coded
    for j in range(n_planes):
        b = (cur > j).astype(jnp.int8)
        if j > 0:
            b = jnp.where(jnp.arange(b.shape[0], dtype=jnp.int32)
                          < size[j], b, 0)
        cb = _iscan(b)
        bits.append(b)
        cbs.append(cb)
        if j + 1 < n_planes:
            cap = caps[j]
            sel = jnp.searchsorted(cb, jnp.arange(1, cap + 1,
                                                  dtype=jnp.int32))
            cur = jnp.take(cur, sel, mode="clip")

    # probability table: chunk one-counts read straight off each
    # plane's scan at the (static-capped, dynamically masked) chunk
    # boundaries -- no scatter, sizes are a closed form
    ftab = jnp.zeros(f_cap, jnp.int32)
    for j in range(n_planes):
        cap = n if j == 0 else caps[j - 1]
        nchcap = max(1, -(-cap // chunk_bits))
        c = jnp.arange(nchcap, dtype=jnp.int32)
        start = c * chunk_bits
        hi_i = jnp.clip(jnp.minimum(start + chunk_bits, size[j]) - 1,
                        0, cap - 1)
        hi = jnp.take(cbs[j], hi_i, mode="clip")
        lo = jnp.where(c > 0,
                       jnp.take(cbs[j], jnp.clip(start - 1, 0, cap - 1),
                                mode="clip"),
                       0)
        csize = jnp.clip(size[j] - start, 1, chunk_bits)
        f1 = jnp.clip(_round_half_even_div(hi - lo, csize), 1, _M - 1)
        ftab = jax.lax.dynamic_update_slice(ftab, f1, (foff[j],))

    # step matrix + per-step probability, one padded block per plane
    bits2d = jnp.zeros((t_cap, lanes), jnp.int8)
    f1_steps = jnp.zeros(t_cap, jnp.int32)
    for j in range(n_planes):
        cap = n if j == 0 else caps[j - 1]
        rows = rows0 if j == 0 else cap // lanes
        mps = (jnp.take(ftab, jnp.clip(foff[j] + nch[j] - 1, 0, f_cap - 1))
               >= _M // 2).astype(jnp.int8)
        if j == 0:
            pad0 = rows0 * lanes - n
            vec = bits[0] if pad0 == 0 else jnp.concatenate(
                [bits[0], jnp.broadcast_to(mps, (pad0,))])
        else:
            vec = jnp.where(jnp.arange(cap, dtype=jnp.int32) < size[j],
                            bits[j], mps)
        bits2d = jax.lax.dynamic_update_slice(
            bits2d, vec.reshape(rows, lanes), (off[j], 0))
        fidx = jnp.clip(foff[j] + jnp.arange(rows, dtype=jnp.int32)
                        // _CHUNK_STEPS, 0, f_cap - 1)
        f1_steps = jax.lax.dynamic_update_slice(
            f1_steps, jnp.take(ftab, fidx), (off[j],))

    return bits2d, f1_steps, ftab.astype(jnp.uint16)


def _step_loop_jnp(bits2d, f1_steps, total_steps, lanes: int, t_cap: int):
    """Reverse rANS step loop as a ``lax.while_loop`` (uint32 states)."""
    u = jnp.uint32

    def body(carry):
        t, x, ov_buf, w_buf = carry
        f1 = f1_steps[t].astype(jnp.uint32)
        f0 = u(_M) - f1
        bb = jax.lax.dynamic_slice(bits2d, (t, 0), (1, lanes)) \
            .reshape(lanes).astype(jnp.uint32)
        f = jnp.where(bb == 1, f1, f0)
        over = x >= (f << u(18))    # 18 == 32 - _PROB_BITS
        w = (x & u(0xFFFF)).astype(jnp.uint16)
        x = jnp.where(over, x >> u(16), x)
        q = x // f
        x = (q << u(_PROB_BITS)) + (x - q * f) + f0 * bb
        ov_buf = jax.lax.dynamic_update_slice(
            ov_buf, over[None].astype(jnp.int8), (t, 0))
        w_buf = jax.lax.dynamic_update_slice(w_buf, w[None], (t, 0))
        return t - 1, x, ov_buf, w_buf

    init = (total_steps - 1,
            jnp.full((lanes,), _STATE_LO, jnp.uint32),
            jnp.zeros((t_cap, lanes), jnp.int8),
            jnp.zeros((t_cap, lanes), jnp.uint16))
    _, x, ov, w = jax.lax.while_loop(lambda c: c[0] >= 0, body, init)
    return x, ov, w


def _rans_step_kernel(ns_ref, bits_ref, f1_ref, x_ref, ov_ref, w_ref, *,
                      t_cap: int):
    """One grid step codes one (reversed) row of the step matrix.

    The grid is sequential, so the (1, lanes) state block -- an output
    revisited by every step -- carries the per-lane coder states across
    iterations; rows past the stream's dynamic step count are skipped
    (their output rows are zeroed so the word compaction can treat the
    full static buffer uniformly).
    """
    i = pl.program_id(0)
    t = t_cap - 1 - i
    u = jnp.uint32

    @pl.when(i == 0)
    def _init():
        x_ref[...] = jnp.full(x_ref.shape, _STATE_LO, jnp.uint32)

    n_steps = ns_ref[0, 0]

    @pl.when(t < n_steps)
    def _code():
        x = x_ref[...]                                   # (1, lanes)
        f1 = f1_ref[0, 0].astype(jnp.uint32)
        f0 = u(_M) - f1
        bb = bits_ref[...].astype(jnp.uint32)
        f = jnp.where(bb == 1, f1, f0)
        over = x >= (f << u(18))
        w_ref[...] = (x & u(0xFFFF)).astype(jnp.int32)
        x = jnp.where(over, x >> u(16), x)
        q = x // f
        x_ref[...] = (q << u(_PROB_BITS)) + (x - q * f) + f0 * bb
        ov_ref[...] = over.astype(jnp.int32)

    @pl.when(t >= n_steps)
    def _skip():
        ov_ref[...] = jnp.zeros(ov_ref.shape, jnp.int32)
        w_ref[...] = jnp.zeros(w_ref.shape, jnp.int32)


def _step_loop_pallas(bits2d, f1_steps, total_steps, lanes: int,
                      t_cap: int, interpret: bool):
    rev = lambda i: (t_cap - 1 - i, 0)  # noqa: E731
    x, ov, w = pl.pallas_call(
        functools.partial(_rans_step_kernel, t_cap=t_cap),
        grid=(t_cap,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0)),
                  pl.BlockSpec((1, lanes), rev),
                  pl.BlockSpec((1, 1), rev)],
        out_specs=[pl.BlockSpec((1, lanes), lambda i: (0, 0)),
                   pl.BlockSpec((1, lanes), rev),
                   pl.BlockSpec((1, lanes), rev)],
        out_shape=[jax.ShapeDtypeStruct((1, lanes), jnp.uint32),
                   jax.ShapeDtypeStruct((t_cap, lanes), jnp.int32),
                   jax.ShapeDtypeStruct((t_cap, lanes), jnp.int32)],
        interpret=interpret,
    )(total_steps.reshape(1, 1).astype(jnp.int32),
      bits2d.astype(jnp.int32),
      f1_steps.reshape(t_cap, 1).astype(jnp.int32))
    return x.reshape(lanes), ov.astype(jnp.int8), w.astype(jnp.uint16)


@functools.partial(jax.jit,
                   static_argnames=("n_levels", "lanes", "caps", "t_cap",
                                    "f_cap", "use_kernel", "interpret"))
def _entropy_stage(coded, meta, *, n_levels: int, lanes: int, caps,
                   t_cap: int, f_cap: int, use_kernel: bool,
                   interpret: bool):
    """Plane build + step loop + word scan, one fused graph.

    Returns ``(ftab, states, ov_scan, words_raw, n_words)``; the renorm
    words stay uncompacted here (their count is data-dependent), and
    finalize runs the small rank-gather once the count is known.
    """
    bits2d, f1_steps, ftab = _build_planes(
        coded.astype(jnp.int32), meta, n_levels, lanes, caps, t_cap,
        f_cap)
    total_steps = meta[0]
    loop = _step_loop_pallas if use_kernel else _step_loop_jnp
    if use_kernel:
        x, ov, w = loop(bits2d, f1_steps, total_steps, lanes, t_cap,
                        interpret)
    else:
        x, ov, w = loop(bits2d, f1_steps, total_steps, lanes, t_cap)
    ovc = _iscan(ov.reshape(-1))
    return ftab, x, ovc, w.reshape(-1), ovc[-1]


@functools.partial(jax.jit, static_argnames=("cap_w",))
def _compact_words(ovc, w, cap_w: int):
    """Emitted words in (step asc, lane asc) order -- the host coder's
    ``w_rows[over_rows]`` -- as a rank gather off the overflow scan."""
    sel = jnp.searchsorted(ovc, jnp.arange(1, cap_w + 1, dtype=jnp.int32))
    return jnp.take(w, sel, mode="clip")


def _dispatch(coded, n_levels: int, use_kernel: bool, interpret: bool):
    """Size pre-pass, host layout math, async stage launch.

    Returns the pending device buffers plus the host-side layout, or
    None for an empty stream.
    """
    n = int(coded.shape[0])
    if n == 0 or n_levels < 2:
        return None
    sizes = [int(s) for s in np.asarray(_plane_sizes(coded, n_levels))]
    lanes = rans.lane_count(sum(sizes))
    while sizes and sizes[-1] == 0:      # host coder skips empty planes
        sizes.pop()
    caps = tuple(lanes * _next_pow2(-(-s // lanes)) for s in sizes[1:])
    chunk_bits = _CHUNK_STEPS * lanes
    steps = [-(-s // lanes) for s in sizes]
    nch = [-(-s // chunk_bits) for s in sizes]
    t_cap = steps[0] + sum(c // lanes for c in caps)
    f_cap = max(1, -(-n // chunk_bits)) + sum(
        max(1, -(-c // chunk_bits)) for c in caps)
    meta, o, fo = [sum(steps)], 0, 0
    for s, st, nc in zip(sizes, steps, nch):
        meta += [s, o, fo, nc]
        o += st
        fo += nc
    out = _entropy_stage(coded, jnp.asarray(meta, jnp.int32),
                         n_levels=n_levels, lanes=lanes, caps=caps,
                         t_cap=t_cap, f_cap=f_cap, use_kernel=use_kernel,
                         interpret=interpret)
    return (lanes, fo) + tuple(out)


def _finalize(pending) -> bytes:
    """Fetch the word count, compact, slice-fetch, assemble the blob."""
    if pending is None:
        return struct.pack(_HEADER_FMT, 0, 0)
    lanes, nf, ftab, x, ovc, w, n_words = pending
    with span("wire_d2h", lanes=lanes):
        nw = int(n_words)
        words_h = np.asarray(
            _compact_words(ovc, w, _next_pow2(max(16, nw))))[:nw]
        ftab_h = np.asarray(ftab)[:nf]
        x_h = np.asarray(x)
    blob = (struct.pack(_HEADER_FMT, lanes, nf)
            + ftab_h.astype("<u2").tobytes()
            + x_h.astype("<u4").tobytes()
            + words_h.astype("<u2").tobytes())
    _d2h_counter().inc(len(blob) + 4)   # + the word-count scalar
    return blob


def encode_planes_device(coded, n_levels: int, *, use_kernel: bool = False,
                         interpret: bool = False) -> bytes:
    """Device-coded rANS blob, byte-identical to
    ``rans.encode_planes(index_to_context_bits(coded, n_levels))``.

    ``coded`` is a device (or host) coded-order index vector; only the
    coded bytes plus side info return to the host.
    """
    with span("device_entropy", n_elems=int(coded.shape[0])):
        pending = _dispatch(jnp.asarray(coded), n_levels, use_kernel,
                            interpret)
    return _finalize(pending)


def encode_chunks_device(coded, n_levels: int, bounds, *,
                         use_kernel: bool = False,
                         interpret: bool = False) -> list[bytes]:
    """Per-chunk device blobs for ``coded[s:e] for (s, e) in bounds``.

    Two phases so D2H overlaps compute: every chunk's stage is
    dispatched first (async), then the much smaller fetch+assemble pass
    drains them in order -- while chunk k's bytes cross the bus, chunk
    k+1's step loop is already running.
    """
    coded = jnp.asarray(coded)
    with span("device_entropy", chunks=len(bounds)):
        pend = [_dispatch(coded[s:e], n_levels, use_kernel, interpret)
                for s, e in bounds]
    return [_finalize(p) for p in pend]


def encode_indices_device(coded, n_levels: int, *, use_kernel: bool = False,
                          interpret: bool = False) -> bytes:
    """Full coder-id-4 payload for one coded-order index vector.

    Container bytes match host coder id 2 at one shard past the id
    byte; unsupported shapes fall back to the host step loop but keep
    the same container, so the wire format never depends on where the
    blob was coded.
    """
    from ..core import cabac
    n = int(coded.shape[0])
    if n == 0:
        return cabac.wrap_device_blob(b"")
    if not device_supported(n, n_levels):
        from ..core.binarization import index_to_context_bits
        blob = rans.encode_planes(
            index_to_context_bits(np.asarray(coded).ravel(), n_levels))
    else:
        blob = encode_planes_device(coded, n_levels, use_kernel=use_kernel,
                                    interpret=interpret)
    return cabac.wrap_device_blob(blob)


def encode_index_chunks_device(coded, n_levels: int, bounds, *,
                               use_kernel: bool = False,
                               interpret: bool = False) -> list[bytes]:
    """Coder-id-4 payloads for each chunk range, dispatch-all then
    finalize-all (the D2H-overlap shape of
    :func:`encode_chunks_device`)."""
    return finalize_index_chunks(dispatch_index_chunks(
        coded, n_levels, bounds, use_kernel=use_kernel,
        interpret=interpret))


def dispatch_index_chunks(coded, n_levels: int, bounds, *,
                          use_kernel: bool = False,
                          interpret: bool | None = None):
    """Async phase of :func:`encode_index_chunks_device`: launch every
    chunk's entropy stage and return an opaque pending list.

    Nothing blocks on device results here -- callers can dispatch many
    tensors' chunks back to back (a whole serving tick) and only then
    drain the bytes-only D2H with :func:`finalize_index_chunks`, so each
    payload's transfer overlaps the next tensor's step loops.
    Unsupported shapes are host-coded inline (their pending entries are
    already-finished payloads).
    """
    from ..core import cabac
    if interpret is None:
        interpret = jax.default_backend() == "cpu"
    n = int(coded.shape[0]) if hasattr(coded, "shape") else len(coded)
    if not device_supported(n, n_levels):
        from ..core.binarization import index_to_context_bits
        host = np.asarray(coded).ravel()
        return [("host", cabac.wrap_device_blob(
            b"" if s >= e else rans.encode_planes(
                index_to_context_bits(host[s:e], n_levels))))
            for s, e in bounds]
    coded = jnp.asarray(coded)
    with span("device_entropy", chunks=len(bounds)):
        return [("dev", None) if s >= e else
                ("dev", _dispatch(coded[s:e], n_levels, use_kernel,
                                  interpret))
                for s, e in bounds]


def finalize_index_chunks(pending) -> list[bytes]:
    """Drain phase of :func:`dispatch_index_chunks`: fetch each chunk's
    coded bytes (in order) and assemble coder-id-4 payloads."""
    from ..core import cabac
    out = []
    for kind, p in pending:
        if kind == "host":
            out.append(p)
        else:
            out.append(cabac.wrap_device_blob(
                b"" if p is None else _finalize(p)))
    return out
