"""Pallas TPU kernel: pack quantizer indices to the wire bit-width.

The packed split-runtime transport crosses ``ceil(log2 N)``-bit indices
over the inter-pod links as uint8 lanes (8/bits indices per byte).  With
this kernel the pack runs on device, so it fuses into the same pass as
the clip+quant output instead of round-tripping full-width int32 indices
through the host, and only wire-width bytes cross the interconnect.

This standalone kernel serves the packed split-runtime transport (pack
an existing index tensor); the host-bitstream encode path instead packs
*inside* the fused encode megakernel
(``fused_clip_quant._kernel_encode``), which emits the same byte layout
directly from the quantize pass so indices never materialize.

Bit layout (shared with the jnp host fallback in
:meth:`repro.core.backend.JnpBackend.pack_indices` and the megakernel):
byte ``k`` holds
indices ``k*per + j`` for ``j`` in ``[0, per)`` at bit offset
``j * bits`` -- little-end-first lanes.  The wrapper hands the kernel a
(8, n_bytes) view whose row ``j`` is lane ``j`` of every output byte
(rows past ``per`` are zero padding to the int32 sublane tile), so the
combine is ``per`` row-wise shift+adds on the VPU -- no lane-dimension
gathers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SUBLANES = 8          # int32 sublane tile; also the max lanes-per-byte
DEFAULT_BLOCK_COLS = 1024


def _kernel(idx_ref, out_ref, *, per: int, bits: int):
    acc = idx_ref[0:1, :]
    for j in range(1, per):               # unrolled: per in (2, 4, 8)
        acc = acc + (idx_ref[j:j + 1, :] << (j * bits))
    out_ref[...] = acc.astype(jnp.int32)


def pack_rows_2d(x, bits: int, block_cols: int = DEFAULT_BLOCK_COLS,
                 interpret: bool = False):
    """x: (8, N) int32 lane-view, N a multiple of ``block_cols``; rows
    ``per..8`` must be zero.  Returns (1, N) int32 packed bytes (values
    in [0, 255]; the caller casts to uint8 for the wire)."""
    per = 8 // bits
    r, n = x.shape
    if r != _SUBLANES:
        raise ValueError(f"lane view must have {_SUBLANES} rows, got {r}")
    bc = min(block_cols, n)
    grid = (n // bc,)
    return pl.pallas_call(
        functools.partial(_kernel, per=per, bits=bits),
        grid=grid,
        in_specs=[pl.BlockSpec((_SUBLANES, bc), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, bc), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(x)
