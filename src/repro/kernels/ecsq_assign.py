"""Pallas TPU kernel: non-uniform (ECSQ) quantization by decision thresholds.

Deploy-time counterpart of Algorithm 1: given the designed decision
thresholds t_1..t_{N-1} and reconstruction levels x_0..x_{N-1}, map each
activation to its bin (index = #{t_i < x}) and its reconstruction value.
The comparison/select passes run as ``lax.fori_loop`` bodies over the
threshold/level block (one iota-masked scalar extraction per step -- no
dynamic lane indexing), so N scales to 64 without unrolling the kernel
body and no gather over the data block is needed (TPU-friendly:
broadcast compare/select per level).

Thresholds/levels arrive as a (1, 64)-padded VMEM block shared by every
grid step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)
MAX_LEVELS = 64


def _kernel(x_ref, thr_ref, lvl_ref, idx_ref, deq_ref, *, n_levels: int,
            cmin: float, cmax: float):
    x = jnp.clip(x_ref[...].astype(jnp.float32), cmin, cmax)
    thr = thr_ref[...]
    lvl = lvl_ref[...]
    # iota-masked scalar extraction: the loop index appears only in the
    # select values, never as a ref/array index, so the bodies stay free
    # of dynamic lane addressing (which Mosaic may refuse to lower)
    lane = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)

    def thr_body(i, acc):
        t_i = jnp.sum(jnp.where(lane == i, thr, 0.0))
        # >= matches searchsorted(side='right'): ties go to the upper bin
        return acc + (x >= t_i).astype(jnp.int32)

    idx = jax.lax.fori_loop(0, n_levels - 1, thr_body,
                            jnp.zeros(x.shape, jnp.int32))

    def lvl_body(i, deq):
        l_i = jnp.sum(jnp.where(lane == i, lvl, 0.0))
        return jnp.where(idx == i, l_i, deq)

    deq = jax.lax.fori_loop(1, n_levels, lvl_body,
                            jnp.full(x.shape, lvl[0, 0], jnp.float32))
    idx_ref[...] = idx
    deq_ref[...] = deq.astype(deq_ref.dtype)


def _kernel_tiles(x_ref, cmin_ref, cmax_ref, thr_ref, lvl_ref, idx_ref,
                  deq_ref, *, n_levels: int):
    """Per-tile ECSQ assignment: every row of the (br, bc) data block
    carries its own threshold/level tables (the (br, MAX_LEVELS) blocks
    the grid mapped for this band), so per-tile designed quantizers run
    through the same blocked banded layout as the uniform tile kernel.
    Same iota-masked per-row scalar extraction as the per-tensor body --
    the fori_loop index never addresses a lane."""
    x = x_ref[...].astype(jnp.float32)
    lo = cmin_ref[...].astype(jnp.float32)          # (br, 1)
    hi = cmax_ref[...].astype(jnp.float32)
    xc = jnp.clip(x, lo, hi)
    thr = thr_ref[...]                              # (br, MAX_LEVELS)
    lvl = lvl_ref[...]
    lane = jax.lax.broadcasted_iota(jnp.int32, thr.shape, 1)

    def thr_body(i, acc):
        t_i = jnp.sum(jnp.where(lane == i, thr, 0.0), axis=1, keepdims=True)
        # >= matches searchsorted(side='right'): ties go to the upper bin
        return acc + (xc >= t_i).astype(jnp.int32)

    idx = jax.lax.fori_loop(0, n_levels - 1, thr_body,
                            jnp.zeros(x.shape, jnp.int32))

    l0 = jnp.sum(jnp.where(lane == 0, lvl, 0.0), axis=1, keepdims=True)

    def lvl_body(i, deq):
        l_i = jnp.sum(jnp.where(lane == i, lvl, 0.0), axis=1, keepdims=True)
        return jnp.where(idx == i, l_i, deq)

    deq = jax.lax.fori_loop(1, n_levels, lvl_body,
                            jnp.broadcast_to(l0, x.shape))
    idx_ref[...] = idx
    deq_ref[...] = deq.astype(deq_ref.dtype)


def ecsq_assign_tiles_2d(x, cmin, cmax, thresholds, levels, n_levels: int,
                         sb_cols: int, block=DEFAULT_BLOCK,
                         interpret: bool = False):
    """Blocked per-tile ECSQ quantize + dequantize.

    x: (R, C) banded view (C == n_sblocks * sb_cols); cmin/cmax:
    (R, n_sblocks) per-(row, band) clip ranges; thresholds/levels:
    (R, n_sblocks * MAX_LEVELS) per-row tables, thresholds padded with
    +inf and levels zero-padded past ``n_levels``.  Returns
    (idx int32, deq) of x's shape.
    """
    if n_levels > MAX_LEVELS:
        raise ValueError(f"n_levels {n_levels} > {MAX_LEVELS}")
    r, c = x.shape
    if c % sb_cols:
        raise ValueError(f"C {c} not a multiple of sb_cols {sb_cols}")
    br = min(block[0], r)
    bc = min(block[1], c, sb_cols)
    while sb_cols % bc:
        bc -= 128
    grid = (r // br, c // bc)
    band = lambda i, j: (i, j * bc // sb_cols)  # noqa: E731
    return pl.pallas_call(
        functools.partial(_kernel_tiles, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, 1), band),
                  pl.BlockSpec((br, 1), band),
                  pl.BlockSpec((br, MAX_LEVELS), band),
                  pl.BlockSpec((br, MAX_LEVELS), band)],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x, cmin, cmax, thresholds, levels)


def ecsq_assign_2d(x, thresholds, levels, cmin: float, cmax: float,
                   block=DEFAULT_BLOCK, interpret: bool = False):
    """x: (R, C) blocked-aligned; thresholds (N-1,), levels (N,)."""
    n_levels = levels.shape[0]
    if n_levels > MAX_LEVELS:
        raise ValueError(f"n_levels {n_levels} > {MAX_LEVELS}")
    r, c = x.shape
    br, bc = min(block[0], r), min(block[1], c)
    grid = (r // br, c // bc)
    thr = jnp.full((1, MAX_LEVELS), jnp.inf, jnp.float32) \
        .at[0, :n_levels - 1].set(thresholds.astype(jnp.float32))
    lvl = jnp.zeros((1, MAX_LEVELS), jnp.float32) \
        .at[0, :n_levels].set(levels.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_kernel, n_levels=n_levels, cmin=cmin, cmax=cmax),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((1, MAX_LEVELS), lambda i, j: (0, 0)),
                  pl.BlockSpec((1, MAX_LEVELS), lambda i, j: (0, 0))],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x, thr, lvl)
