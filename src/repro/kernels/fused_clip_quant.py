"""Pallas TPU kernel: fused clip + uniform quantize + dequantize (paper eq. 1).

This is the codec's deploy-time hot path, fused so the feature tensor is
read from HBM exactly once and both outputs (the int index stream for the
entropy coder and the dequantized activations for the next layer /
fake-quant path) are produced in one VMEM pass.  On the edge device this
op fuses into the split layer's output, matching the paper's Sec. III-E
"operations could be fused into the layer" note.

Tiling: 2-D grid over (rows, cols) with (8k, 128m)-aligned blocks sized to
keep input + both outputs within a small fraction of VMEM
(default 256 x 512: f32 in 512 KB + i32 idx 512 KB + out 512 KB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _kernel(x_ref, idx_ref, deq_ref, *, cmin: float, cmax: float,
            n_levels: int):
    x = x_ref[...]
    scale = (n_levels - 1) / (cmax - cmin)
    inv_scale = (cmax - cmin) / (n_levels - 1)
    xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
    q = jnp.floor((xc - cmin) * scale + 0.5)  # round-half-away (q >= 0)
    idx_ref[...] = q.astype(jnp.int32)
    deq_ref[...] = (cmin + q * inv_scale).astype(deq_ref.dtype)


def clip_quant_2d(x, cmin: float, cmax: float, n_levels: int,
                  block=DEFAULT_BLOCK, interpret: bool = False):
    """x: (R, C) with R % block[0] == 0 and C % block[1] == 0."""
    r, c = x.shape
    br, bc = min(block[0], r), min(block[1], c)
    grid = (r // br, c // bc)
    return pl.pallas_call(
        functools.partial(_kernel, cmin=cmin, cmax=cmax, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x)


def _kernel_tiles(x_ref, cmin_ref, cmax_ref, idx_ref, deq_ref, *,
                  n_levels: int):
    """Per-tile clipping ranges: row r of the (br, bc) data block uses the
    (cmin[r], cmax[r]) column the grid mapped for this column block.

    This is the codec's TilePlan hot path: the tensor is laid out
    channel-major with spatial blocks padded to whole column blocks, so
    every (row, column-block) cell of the grid is covered by exactly one
    tile and the (br, 1) range columns broadcast against the data block on
    the VPU -- the fused pass stays a single HBM read like the
    scalar-range kernel.  Per-row ranges (per-channel granularity) are the
    one-spatial-block special case.
    """
    x = x_ref[...].astype(jnp.float32)
    cmin = cmin_ref[...].astype(jnp.float32)        # (br, 1)
    cmax = cmax_ref[...].astype(jnp.float32)
    span = jnp.maximum(cmax - cmin, 1e-12)
    scale = (n_levels - 1) / span
    xc = jnp.clip(x, cmin, cmax)
    q = jnp.floor((xc - cmin) * scale + 0.5)        # round-half-away (q >= 0)
    idx_ref[...] = q.astype(jnp.int32)
    deq_ref[...] = (cmin + q * (span / (n_levels - 1))).astype(deq_ref.dtype)


def clip_quant_tiles_2d(x, cmin, cmax, n_levels: int, sblock_cols: int,
                        block=DEFAULT_BLOCK, interpret: bool = False):
    """Blocked per-tile clip+quant+dequant.

    x: (R, C) block-aligned, channel-major, spatial blocks padded to
    ``sblock_cols`` columns each (so C == n_sblocks * sblock_cols);
    cmin/cmax: (R, n_sblocks) float32 per-(row, spatial-block) ranges.
    The kernel's column block size divides ``sblock_cols``, so the range
    column for grid step (i, j) is simply ``j * bc // sblock_cols``.
    """
    r, c = x.shape
    if c % sblock_cols:
        raise ValueError(f"C {c} not a multiple of sblock_cols {sblock_cols}")
    br = min(block[0], r)
    bc = min(block[1], c, sblock_cols)
    while sblock_cols % bc:        # largest lane-multiple divisor <= block[1]
        bc -= 128
    grid = (r // br, c // bc)
    return pl.pallas_call(
        functools.partial(_kernel_tiles, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sblock_cols)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sblock_cols))],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x, cmin, cmax)


def clip_quant_rows_2d(x, cmin, cmax, n_levels: int, block=DEFAULT_BLOCK,
                       interpret: bool = False):
    """x: (R, C) block-aligned; cmin/cmax: (R, 1) float32 per-row ranges.

    The one-spatial-block case of :func:`clip_quant_tiles_2d`, kept as the
    named per-channel entry point.
    """
    return clip_quant_tiles_2d(x, cmin, cmax, n_levels,
                               sblock_cols=x.shape[1], block=block,
                               interpret=interpret)
