"""Pallas TPU kernel: fused clip + uniform quantize + dequantize (paper eq. 1).

This is the codec's deploy-time hot path, fused so the feature tensor is
read from HBM exactly once and both outputs (the int index stream for the
entropy coder and the dequantized activations for the next layer /
fake-quant path) are produced in one VMEM pass.  On the edge device this
op fuses into the split layer's output, matching the paper's Sec. III-E
"operations could be fused into the layer" note.

Tiling: 2-D grid over (rows, cols) with (8k, 128m)-aligned blocks sized to
keep input + both outputs within a small fraction of VMEM
(default 256 x 512: f32 in 512 KB + i32 idx 512 KB + out 512 KB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 512)


def _kernel(x_ref, idx_ref, deq_ref, *, cmin: float, cmax: float,
            n_levels: int):
    x = x_ref[...]
    scale = (n_levels - 1) / (cmax - cmin)
    inv_scale = (cmax - cmin) / (n_levels - 1)
    xc = jnp.clip(x.astype(jnp.float32), cmin, cmax)
    q = jnp.floor((xc - cmin) * scale + 0.5)  # round-half-away (q >= 0)
    idx_ref[...] = q.astype(jnp.int32)
    deq_ref[...] = (cmin + q * inv_scale).astype(deq_ref.dtype)


def clip_quant_2d(x, cmin: float, cmax: float, n_levels: int,
                  block=DEFAULT_BLOCK, interpret: bool = False):
    """x: (R, C) with R % block[0] == 0 and C % block[1] == 0."""
    r, c = x.shape
    br, bc = min(block[0], r), min(block[1], c)
    grid = (r // br, c // bc)
    return pl.pallas_call(
        functools.partial(_kernel, cmin=cmin, cmax=cmax, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x)


def _kernel_tiles(x_ref, cmin_ref, cmax_ref, idx_ref, deq_ref, *,
                  n_levels: int):
    """Per-tile clipping ranges: row r of the (br, bc) data block uses the
    (cmin[r], cmax[r]) column the grid mapped for this column block.

    This is the codec's TilePlan hot path: the tensor is laid out
    channel-major with spatial blocks padded to whole column blocks, so
    every (row, column-block) cell of the grid is covered by exactly one
    tile and the (br, 1) range columns broadcast against the data block on
    the VPU -- the fused pass stays a single HBM read like the
    scalar-range kernel.  Per-row ranges (per-channel granularity) are the
    one-spatial-block special case.
    """
    x = x_ref[...].astype(jnp.float32)
    cmin = cmin_ref[...].astype(jnp.float32)        # (br, 1)
    cmax = cmax_ref[...].astype(jnp.float32)
    span = jnp.maximum(cmax - cmin, 1e-12)
    scale = (n_levels - 1) / span
    xc = jnp.clip(x, cmin, cmax)
    q = jnp.floor((xc - cmin) * scale + 0.5)        # round-half-away (q >= 0)
    idx_ref[...] = q.astype(jnp.int32)
    deq_ref[...] = (cmin + q * (span / (n_levels - 1))).astype(deq_ref.dtype)


def clip_quant_tiles_2d(x, cmin, cmax, n_levels: int, sblock_cols: int,
                        block=DEFAULT_BLOCK, interpret: bool = False):
    """Blocked per-tile clip+quant+dequant.

    x: (R, C) block-aligned, channel-major, spatial blocks padded to
    ``sblock_cols`` columns each (so C == n_sblocks * sblock_cols);
    cmin/cmax: (R, n_sblocks) float32 per-(row, spatial-block) ranges.
    The kernel's column block size divides ``sblock_cols``, so the range
    column for grid step (i, j) is simply ``j * bc // sblock_cols``.
    """
    r, c = x.shape
    if c % sblock_cols:
        raise ValueError(f"C {c} not a multiple of sblock_cols {sblock_cols}")
    br = min(block[0], r)
    bc = min(block[1], c, sblock_cols)
    while sblock_cols % bc:        # largest lane-multiple divisor <= block[1]
        bc -= 128
    grid = (r // br, c // bc)
    return pl.pallas_call(
        functools.partial(_kernel_tiles, n_levels=n_levels),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sblock_cols)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sblock_cols))],
        out_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                   pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.int32),
                   jax.ShapeDtypeStruct((r, c), x.dtype)],
        interpret=interpret,
    )(x, cmin, cmax)


def clip_quant_rows_2d(x, cmin, cmax, n_levels: int, block=DEFAULT_BLOCK,
                       interpret: bool = False):
    """x: (R, C) block-aligned; cmin/cmax: (R, 1) float32 per-row ranges.

    The one-spatial-block case of :func:`clip_quant_tiles_2d`, kept as the
    named per-channel entry point.
    """
    return clip_quant_tiles_2d(x, cmin, cmax, n_levels,
                               sblock_cols=x.shape[1], block=block,
                               interpret=interpret)


# -- fused single-pass encode megakernel --------------------------------------

HIST_WIDTH = 64        # lane width of the per-(row, band) histogram output


def _kernel_encode(x_ref, cmin_ref, cmax_ref, valid_ref, packed_ref,
                   hist_ref, *, n_levels: int, bits: int, bc: int,
                   sb_cols: int):
    """One fused pass per block: clip -> quantize -> bit-pack -> histogram.

    The encode hot path's whole device side: the feature block is read
    from HBM once and leaves as wire-width packed bytes plus a
    per-(row, spatial-band) index histogram -- no int32 index tensor ever
    reaches HBM or the host.  Ranges are (br, 1) per-row columns exactly
    as in :func:`_kernel_tiles`; the scalar per-tensor mode is the
    constant-range one-band case.

    Packing combines ``per = 8 // bits`` adjacent lane values into one
    byte (same little-end-first layout as ``pack_bits.py`` / the jnp host
    fallback) via a minor-dim reshape; ``per == 1`` (bit widths 3/5/6)
    stores one index per byte.  The histogram masks band-column padding
    against the band's valid count (the (1, 1) ``valid_ref`` cell the
    grid mapped for this band -- 2-D plans have ragged edge tiles, so
    every band carries its own count) so tiles see only real elements;
    padded rows are dropped host-side.  Like the rest of the kernel
    backend this is validated in interpret mode in CI; the TPU lowering
    of the lane-dim reshape is part of the ROADMAP's TPU-validation
    follow-up.
    """
    per = 8 // bits if bits in (1, 2, 4) else 1
    j = pl.program_id(1)
    band_col = (j % (sb_cols // bc)) * bc    # block's column offset in band
    x = x_ref[...].astype(jnp.float32)
    cmin = cmin_ref[...].astype(jnp.float32)
    cmax = cmax_ref[...].astype(jnp.float32)
    span = jnp.maximum(cmax - cmin, 1e-12)
    scale = (n_levels - 1) / span
    q = jnp.floor((jnp.clip(x, cmin, cmax) - cmin) * scale + 0.5) \
        .astype(jnp.int32)

    if per == 1:
        packed_ref[...] = q
    else:
        q3 = q.reshape(q.shape[0], q.shape[1] // per, per)
        acc = q3[:, :, 0]
        for k in range(1, per):                 # unrolled: per in (2, 4, 8)
            acc = acc + (q3[:, :, k] << (k * bits))
        packed_ref[...] = acc

    @pl.when(band_col == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    # mask band-column padding: each band's tail beyond its valid count
    # holds layout padding, not feature elements
    limit = valid_ref[0, 0]
    valid = jax.lax.broadcasted_iota(jnp.int32, q.shape, 1) \
        + band_col < limit
    hlane = jax.lax.broadcasted_iota(jnp.int32, hist_ref.shape, 1)

    def body(n, carry):                         # blocked: N scales to 64
        cnt = jnp.sum(jnp.where(valid & (q == n), 1, 0), axis=1,
                      keepdims=True)
        hist_ref[...] += jnp.where(hlane == n, cnt, 0)
        return carry

    jax.lax.fori_loop(0, n_levels, body, 0)


def band_valid_array(n_sblocks: int, bs: int, bs_last: int | None,
                     band_valid=None):
    """(1, n_sblocks) int32 per-band valid element counts: explicit
    ``band_valid`` (2-D ragged tiles) or the uniform-but-for-the-last
    1-D rule."""
    if band_valid is not None:
        v = jnp.asarray(band_valid, jnp.int32)
    else:
        v = jnp.full((n_sblocks,), bs, jnp.int32) \
            .at[-1].set(bs if bs_last is None else bs_last)
    return v.reshape(1, n_sblocks)


def encode_tiles_2d(x, cmin, cmax, n_levels: int, bits: int, sb_cols: int,
                    bs: int, bs_last: int | None = None, band_valid=None,
                    block=DEFAULT_BLOCK, interpret: bool = False):
    """Fused encode over a banded 2-D view (see ``_kernel_encode``).

    x: (R, C) block-aligned with C == n_sblocks * sb_cols; cmin/cmax:
    (R, n_sblocks) per-(row, band) ranges; ``bs`` is the valid element
    count per band (<= sb_cols) and ``bs_last`` the last band's (its
    tail may be padding when the spatial extent is not a block
    multiple); ``band_valid`` (n_sblocks,) overrides both with explicit
    per-band counts (2-D plans: ragged edge tiles).  Returns
    (packed (R, C // per) int32 byte values,
    hist (R, n_sblocks * HIST_WIDTH) int32).
    """
    if n_levels > HIST_WIDTH:
        raise ValueError(f"n_levels {n_levels} > {HIST_WIDTH}")
    per = 8 // bits if bits in (1, 2, 4) else 1
    r, c = x.shape
    if c % sb_cols:
        raise ValueError(f"C {c} not a multiple of sb_cols {sb_cols}")
    n_sblocks = c // sb_cols
    br = min(block[0], r)
    bc = min(block[1], c, sb_cols)
    while sb_cols % bc:            # largest lane-multiple divisor <= block[1]
        bc -= 128
    grid = (r // br, c // bc)
    bpb = sb_cols // bc            # column blocks per band
    valid = band_valid_array(n_sblocks, bs, bs_last, band_valid)
    return pl.pallas_call(
        functools.partial(_kernel_encode, n_levels=n_levels, bits=bits,
                          bc=bc, sb_cols=sb_cols),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sb_cols)),
                  pl.BlockSpec((br, 1), lambda i, j: (i, j * bc
                                                      // sb_cols)),
                  pl.BlockSpec((1, 1), lambda i, j, bpb=bpb: (0, j // bpb))],
        out_specs=[pl.BlockSpec((br, bc // per), lambda i, j: (i, j)),
                   pl.BlockSpec((br, HIST_WIDTH),
                                lambda i, j, bpb=bpb: (i, j // bpb))],
        out_shape=[jax.ShapeDtypeStruct((r, c // per), jnp.int32),
                   jax.ShapeDtypeStruct((r, n_sblocks * HIST_WIDTH),
                                        jnp.int32)],
        interpret=interpret,
    )(x, cmin, cmax, valid)
