"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary input shapes (pad + reshape to block-aligned 2-D views),
and select interpret mode automatically on CPU (the kernels' TARGET is
TPU; interpret=True executes the kernel body in Python for validation, as
this container is CPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ecsq_assign import ecsq_assign_2d
from .fused_clip_quant import (clip_quant_2d, clip_quant_rows_2d,
                               clip_quant_tiles_2d)
from .pack_bits import pack_rows_2d
from .rate_hist import index_histogram_2d

_LANE = 128
_ROW = 8


def _pad_lane(n: int, big: int = 512) -> int:
    """Round ``n`` up to a lane multiple; large sizes to a ``big`` multiple
    so the default column block tiles exactly."""
    cols = max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE)
    if cols > big:
        cols = ((cols + big - 1) // big) * big
    return cols


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _to_2d(x, fill: float):
    """Flatten + pad to a block-divisible (R, C) view. Returns (x2d, n_valid).

    C is a power-of-two multiple of 128 (<= 1024) and R is rounded up to a
    multiple of min(R, 256), so the (min(256,R), min(512,C)) block grids in
    the wrappers always tile exactly (hypothesis found the n=513 case where
    a 640-wide view left 128 columns outside the grid).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = max(1, (n + _LANE - 1) // _LANE)
    cols = _LANE * min(8, 1 << max(0, (k - 1).bit_length()))
    rows = (n + cols - 1) // cols
    align = _ROW if rows <= 256 else 256
    rows = ((rows + align - 1) // align) * align
    padded = jnp.full((rows * cols,), fill, x.dtype).at[:n].set(flat)
    return padded.reshape(rows, cols), n


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "n_levels",
                                             "interpret"))
def clip_quantize(x, *, cmin: float, cmax: float, n_levels: int,
                  interpret: bool | None = None):
    """Fused clip+quantize+dequantize. Returns (idx int32, dequantized)."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = clip_quant_2d(x2d, cmin, cmax, n_levels,
                             block=(br, min(512, x2d.shape[1])),
                             interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("n_levels", "channel_axis",
                                             "channel_group_size",
                                             "spatial_block_size",
                                             "interpret"))
def clip_quantize_tiled(x, lo, hi, *, n_levels: int, channel_axis: int = -1,
                        channel_group_size: int = 1,
                        spatial_block_size: int = 0,
                        interpret: bool | None = None):
    """TilePlan fused clip+quantize+dequantize (channel x spatial tiling).

    ``lo``/``hi`` are (n_cgroups, n_sblocks) range tables: channel group
    ``c // channel_group_size`` x spatial block ``m // spatial_block_size``
    of the channel-major (C, M) view (``spatial_block_size == 0`` = one
    block spanning M).  The view is laid out with each spatial block
    padded to a whole lane-aligned column block, so the blocked per-tile
    kernel reads one range cell per grid step; rows pad to the sublane
    multiple with a dummy [0, 1] range.  Per-channel granularity is the
    one-spatial-block case.
    """
    interpret = _on_cpu() if interpret is None else interpret
    axis = channel_axis % x.ndim
    xm = jnp.moveaxis(x, axis, 0)
    moved_shape = xm.shape
    ch = moved_shape[0]
    x2 = xm.reshape(ch, -1)
    m = x2.shape[1]
    n_cgroups, n_sblocks = lo.shape
    bs = spatial_block_size or m

    sb_cols = _pad_lane(bs)
    cols = n_sblocks * sb_cols
    align = _ROW if ch <= 256 else 256
    rows = ((ch + align - 1) // align) * align

    # scatter each spatial block into its padded column band
    mp = n_sblocks * bs
    if mp != m:
        x2 = jnp.concatenate(
            [x2, jnp.zeros((ch, mp - m), x.dtype)], axis=1)
    x3 = jnp.zeros((ch, n_sblocks, sb_cols), x.dtype) \
        .at[:, :, :bs].set(x2.reshape(ch, n_sblocks, bs))
    xp = jnp.zeros((rows, cols), x.dtype).at[:ch].set(x3.reshape(ch, cols))

    # expand the group-level tables to per-row (channel) range columns
    cg = np.arange(ch) // max(1, channel_group_size)
    lo_r = jnp.zeros((rows, n_sblocks), jnp.float32) \
        .at[:ch].set(lo.astype(jnp.float32)[cg])
    hi_r = jnp.ones((rows, n_sblocks), jnp.float32) \
        .at[:ch].set(hi.astype(jnp.float32)[cg])
    br = min(256, rows)
    idx, deq = clip_quant_tiles_2d(xp, lo_r, hi_r, n_levels, sb_cols,
                                   block=(br, min(512, cols)),
                                   interpret=interpret)

    def unpad(a):
        a = a[:ch].reshape(ch, n_sblocks, sb_cols)[:, :, :bs]
        return jnp.moveaxis(a.reshape(ch, mp)[:, :m].reshape(moved_shape),
                            0, axis)
    return unpad(idx), unpad(deq)


def clip_quantize_channels(x, cmin, cmax, *, n_levels: int,
                           channel_axis: int = -1,
                           interpret: bool | None = None):
    """Per-channel fused clip+quantize+dequantize: the one-spatial-block
    case of :func:`clip_quantize_tiled` (kept as a named entry point)."""
    return clip_quantize_tiled(x, cmin.reshape(-1, 1), cmax.reshape(-1, 1),
                               n_levels=n_levels, channel_axis=channel_axis,
                               channel_group_size=1, spatial_block_size=0,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "interpret"))
def ecsq_quantize(x, thresholds, levels, *, cmin: float, cmax: float,
                  interpret: bool | None = None):
    """Threshold-based non-uniform quantize + dequantize."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = ecsq_assign_2d(x2d, thresholds, levels, cmin, cmax,
                              block=(br, min(512, x2d.shape[1])),
                              interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_indices(idx, *, bits: int, interpret: bool | None = None):
    """Pack int32 indices to ``bits``-wide uint8 lanes on device.

    Same byte layout as the jnp host fallback (see ``pack_bits.py``);
    ``bits`` must be 1, 2 or 4 (wire widths where a byte holds several
    indices).  Returns a flat uint8 array of ``ceil(n / (8 // bits))``
    bytes, zero-padded in the last byte.
    """
    if bits not in (1, 2, 4):
        raise ValueError(f"packable bit widths are 1/2/4, got {bits}")
    interpret = _on_cpu() if interpret is None else interpret
    per = 8 // bits
    flat = idx.reshape(-1).astype(jnp.int32)
    n_out = -(-flat.shape[0] // per)
    cols = _pad_lane(n_out, big=1024)
    lanes = jnp.zeros((cols * per,), jnp.int32).at[:flat.shape[0]].set(flat)
    # lane-view: row j holds the j-th index of every output byte
    lanes = lanes.reshape(cols, per).T                       # (per, cols)
    rows = jnp.zeros((8, cols), jnp.int32).at[:per].set(lanes)
    packed = pack_rows_2d(rows, bits, block_cols=min(1024, cols),
                          interpret=interpret)
    return packed.reshape(-1)[:n_out].astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("n_levels", "interpret"))
def index_histogram(idx, *, n_levels: int, interpret: bool | None = None):
    """Histogram of quantizer indices (padding assigned to bin 0, corrected)."""
    interpret = _on_cpu() if interpret is None else interpret
    idx2d, n = _to_2d(idx, 0)
    br = min(256, idx2d.shape[0])
    hist = index_histogram_2d(idx2d, n_levels,
                              block=(br, min(512, idx2d.shape[1])),
                              interpret=interpret)
    pad = idx2d.size - n
    return hist.at[0].add(-pad)


def estimate_rate_bits(idx, n_levels: int) -> jax.Array:
    """Bits/element the CABAC stage needs, from the kernel histogram."""
    from ..core.rate_model import estimated_bits_from_hist
    hist = index_histogram(idx, n_levels=n_levels)
    return estimated_bits_from_hist(hist, n_levels) / idx.size
