"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary input shapes (pad + reshape to block-aligned 2-D views),
and select interpret mode automatically on CPU (the kernels' TARGET is
TPU; interpret=True executes the kernel body in Python for validation, as
this container is CPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .ecsq_assign import ecsq_assign_2d
from .fused_clip_quant import clip_quant_2d, clip_quant_rows_2d
from .rate_hist import index_histogram_2d

_LANE = 128
_ROW = 8


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def _to_2d(x, fill: float):
    """Flatten + pad to a block-divisible (R, C) view. Returns (x2d, n_valid).

    C is a power-of-two multiple of 128 (<= 1024) and R is rounded up to a
    multiple of min(R, 256), so the (min(256,R), min(512,C)) block grids in
    the wrappers always tile exactly (hypothesis found the n=513 case where
    a 640-wide view left 128 columns outside the grid).
    """
    flat = x.reshape(-1)
    n = flat.shape[0]
    k = max(1, (n + _LANE - 1) // _LANE)
    cols = _LANE * min(8, 1 << max(0, (k - 1).bit_length()))
    rows = (n + cols - 1) // cols
    align = _ROW if rows <= 256 else 256
    rows = ((rows + align - 1) // align) * align
    padded = jnp.full((rows * cols,), fill, x.dtype).at[:n].set(flat)
    return padded.reshape(rows, cols), n


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "n_levels",
                                             "interpret"))
def clip_quantize(x, *, cmin: float, cmax: float, n_levels: int,
                  interpret: bool | None = None):
    """Fused clip+quantize+dequantize. Returns (idx int32, dequantized)."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = clip_quant_2d(x2d, cmin, cmax, n_levels,
                             block=(br, min(512, x2d.shape[1])),
                             interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("n_levels", "channel_axis",
                                             "interpret"))
def clip_quantize_channels(x, cmin, cmax, *, n_levels: int,
                           channel_axis: int = -1,
                           interpret: bool | None = None):
    """Per-channel fused clip+quantize+dequantize (tiled granularity).

    ``cmin``/``cmax`` are (C,) vectors for axis ``channel_axis`` of ``x``.
    The tensor is viewed channel-major as (C, M); each row is coded with
    its own range by the per-row kernel.  Rows pad to the sublane multiple
    with a dummy [0, 1] range, columns to the 128-lane multiple.
    """
    interpret = _on_cpu() if interpret is None else interpret
    axis = channel_axis % x.ndim
    xm = jnp.moveaxis(x, axis, 0)
    moved_shape = xm.shape
    ch = moved_shape[0]
    x2 = xm.reshape(ch, -1)
    m = x2.shape[1]

    cols = max(_LANE, ((m + _LANE - 1) // _LANE) * _LANE)
    if cols > 512:
        cols = ((cols + 511) // 512) * 512
    align = _ROW if ch <= 256 else 256
    rows = ((ch + align - 1) // align) * align

    xp = jnp.zeros((rows, cols), x.dtype).at[:ch, :m].set(x2)
    lo = jnp.zeros((rows, 1), jnp.float32) \
        .at[:ch, 0].set(cmin.astype(jnp.float32))
    hi = jnp.ones((rows, 1), jnp.float32) \
        .at[:ch, 0].set(cmax.astype(jnp.float32))
    br = min(256, rows)
    idx, deq = clip_quant_rows_2d(xp, lo, hi, n_levels,
                                  block=(br, min(512, cols)),
                                  interpret=interpret)
    idx = jnp.moveaxis(idx[:ch, :m].reshape(moved_shape), 0, axis)
    deq = jnp.moveaxis(deq[:ch, :m].reshape(moved_shape), 0, axis)
    return idx, deq


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "interpret"))
def ecsq_quantize(x, thresholds, levels, *, cmin: float, cmax: float,
                  interpret: bool | None = None):
    """Threshold-based non-uniform quantize + dequantize."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = ecsq_assign_2d(x2d, thresholds, levels, cmin, cmax,
                              block=(br, min(512, x2d.shape[1])),
                              interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("n_levels", "interpret"))
def index_histogram(idx, *, n_levels: int, interpret: bool | None = None):
    """Histogram of quantizer indices (padding assigned to bin 0, corrected)."""
    interpret = _on_cpu() if interpret is None else interpret
    idx2d, n = _to_2d(idx, 0)
    br = min(256, idx2d.shape[0])
    hist = index_histogram_2d(idx2d, n_levels,
                              block=(br, min(512, idx2d.shape[1])),
                              interpret=interpret)
    pad = idx2d.size - n
    return hist.at[0].add(-pad)


def estimate_rate_bits(idx, n_levels: int) -> jax.Array:
    """Bits/element the CABAC stage needs, from the kernel histogram."""
    from ..core.rate_model import estimated_bits_from_hist
    hist = index_histogram(idx, n_levels=n_levels)
    return estimated_bits_from_hist(hist, n_levels) / idx.size
