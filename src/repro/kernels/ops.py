"""Jitted public wrappers around the Pallas kernels.

Handle arbitrary input shapes (pad + reshape to block-aligned 2-D views),
and select interpret mode automatically on CPU (the kernels' TARGET is
TPU; interpret=True executes the kernel body in Python for validation, as
this container is CPU-only).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from ..core.tiling import PaddedLayout, TilePlan
from . import ref
from .ecsq_assign import ecsq_assign_2d, ecsq_assign_tiles_2d
from .ecsq_assign import MAX_LEVELS as ECSQ_MAX_LEVELS
from .fused_clip_quant import (HIST_WIDTH, clip_quant_2d, clip_quant_rows_2d,
                               clip_quant_tiles_2d, encode_tiles_2d)
from .pack_bits import pack_rows_2d
from .rate_hist import index_histogram_2d, index_histogram_tiles_2d

_LANE = 128
_ROW = 8


def _pad_lane(n: int, big: int = 512) -> int:
    """Round ``n`` up to a lane multiple; large sizes to a ``big`` multiple
    so the default column block tiles exactly."""
    cols = max(_LANE, ((n + _LANE - 1) // _LANE) * _LANE)
    if cols > big:
        cols = ((cols + big - 1) // big) * big
    return cols


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


def flat_layout(n: int) -> PaddedLayout:
    """Geometry of the flat padded (R, C) view ``_to_2d`` builds.

    C is a power-of-two multiple of 128 (<= 1024) and R is rounded up to a
    multiple of min(R, 256), so the (min(256,R), min(512,C)) block grids in
    the wrappers always tile exactly (hypothesis found the n=513 case where
    a 640-wide view left 128 columns outside the grid).
    """
    k = max(1, (n + _LANE - 1) // _LANE)
    cols = _LANE * min(8, 1 << max(0, (k - 1).bit_length()))
    rows = (n + cols - 1) // cols
    align = _ROW if rows <= 256 else 256
    rows = ((rows + align - 1) // align) * align
    return PaddedLayout(rows=rows, cols=cols, ch=rows, m=cols,
                        n_sblocks=1, sb_cols=cols, bs=cols, flat_n=n)


def _to_2d(x, fill: float):
    """Flatten + pad to a block-divisible (R, C) view (see
    :func:`flat_layout`).  Returns (x2d, n_valid)."""
    flat = x.reshape(-1)
    n = flat.shape[0]
    lay = flat_layout(n)
    padded = jnp.full((lay.rows * lay.cols,), fill, x.dtype).at[:n].set(flat)
    return padded.reshape(lay.rows, lay.cols), n


def banded_layout(shape, plan: TilePlan) -> PaddedLayout:
    """Geometry of the channel-major banded view the tiled kernels use:
    each spatial block padded to a whole lane-aligned column band, rows
    padded to the sublane multiple.  2-D plans have one band per
    (row-block, column-block) cell, sized for the largest tile; ragged
    edge tiles record their true sizes in ``band_valid``."""
    axis = plan.channel_axis % len(shape)
    ch = shape[axis]
    m = 1
    for d, s in enumerate(shape):
        if d != axis:
            m *= s
    sizes = plan.band_sizes(m)
    bs = int(sizes.max())
    sb_cols = _pad_lane(bs)
    align = _ROW if ch <= 256 else 256
    rows = ((ch + align - 1) // align) * align
    return PaddedLayout(rows=rows, cols=plan.n_sblocks * sb_cols, ch=ch,
                        m=m, n_sblocks=plan.n_sblocks, sb_cols=sb_cols,
                        bs=bs,
                        channel_group_size=max(1, plan.channel_group_size),
                        band_valid=tuple(int(s) for s in sizes)
                        if plan.is_2d else None)


@functools.lru_cache(maxsize=64)
def _padded_cols(plan: TilePlan, lay: PaddedLayout) -> np.ndarray:
    """(m,) original flat spatial position -> column of the banded padded
    view (2-D plans: tile elements land contiguously in their band)."""
    perm = plan.spatial_perm(lay.m)
    out = np.empty(lay.m, np.int64)
    out[perm] = lay.coded_cols()
    out.setflags(write=False)    # shared cache entry: guard the layout map
    return out


def _banded_view(x, lay: PaddedLayout, plan: TilePlan):
    """Scatter ``x`` into the banded device view ``lay`` describes.
    Returns (xp (rows, cols), moved_shape) -- padding is zero-filled and
    masked/stripped downstream.  2-D plans scatter through the coded-
    order column map (each row x column tile contiguous in its band);
    1-D plans keep the cheap reshape path."""
    axis = plan.channel_axis % x.ndim
    xm = jnp.moveaxis(x, axis, 0)
    moved_shape = xm.shape
    x2 = xm.reshape(lay.ch, -1)
    if lay.band_valid is not None:
        pcols = _padded_cols(plan, lay)
        xp = jnp.zeros((lay.rows, lay.cols), x.dtype) \
            .at[:lay.ch, pcols].set(x2)
        return xp, moved_shape
    mp = lay.n_sblocks * lay.bs
    if mp != lay.m:
        x2 = jnp.concatenate(
            [x2, jnp.zeros((lay.ch, mp - lay.m), x.dtype)], axis=1)
    x3 = jnp.zeros((lay.ch, lay.n_sblocks, lay.sb_cols), x.dtype) \
        .at[:, :, :lay.bs].set(x2.reshape(lay.ch, lay.n_sblocks, lay.bs))
    xp = jnp.zeros((lay.rows, lay.cols), x.dtype) \
        .at[:lay.ch].set(x3.reshape(lay.ch, lay.cols))
    return xp, moved_shape


def _row_ranges(lo, hi, lay: PaddedLayout):
    """Expand (n_cgroups, n_sblocks) range tables to per-row columns;
    padding rows get a dummy [0, 1] range."""
    cg = np.arange(lay.ch) // lay.channel_group_size
    lo_r = jnp.zeros((lay.rows, lay.n_sblocks), jnp.float32) \
        .at[:lay.ch].set(lo.astype(jnp.float32)[cg])
    hi_r = jnp.ones((lay.rows, lay.n_sblocks), jnp.float32) \
        .at[:lay.ch].set(hi.astype(jnp.float32)[cg])
    return lo_r, hi_r


def _unband(a, lay: PaddedLayout, moved_shape, axis: int,
            plan: TilePlan | None = None):
    """Inverse of :func:`_banded_view` for a same-shape kernel output."""
    if lay.band_valid is not None:
        pcols = _padded_cols(plan, lay)
        return jnp.moveaxis(
            a[:lay.ch][:, pcols].reshape(moved_shape), 0, axis)
    a = a[:lay.ch].reshape(lay.ch, lay.n_sblocks, lay.sb_cols)[:, :, :lay.bs]
    mp = lay.n_sblocks * lay.bs
    return jnp.moveaxis(
        a.reshape(lay.ch, mp)[:, :lay.m].reshape(moved_shape), 0, axis)


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "n_levels",
                                             "interpret"))
def clip_quantize(x, *, cmin: float, cmax: float, n_levels: int,
                  interpret: bool | None = None):
    """Fused clip+quantize+dequantize. Returns (idx int32, dequantized)."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = clip_quant_2d(x2d, cmin, cmax, n_levels,
                             block=(br, min(512, x2d.shape[1])),
                             interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("n_levels", "plan",
                                             "interpret"))
def clip_quantize_tiled(x, lo, hi, *, n_levels: int, plan: TilePlan,
                        interpret: bool | None = None):
    """TilePlan fused clip+quantize+dequantize (channel x spatial tiling).

    ``lo``/``hi`` are (n_cgroups, n_sblocks) range tables over the plan's
    channel-major (C, M) view (``plan`` is a static argument: frozen,
    hashable geometry).  The view is laid out with each spatial block
    padded to a whole lane-aligned column band, so the blocked per-tile
    kernel reads one range cell per grid step; rows pad to the sublane
    multiple with a dummy [0, 1] range.  Per-channel granularity is the
    one-spatial-block case; 2-D plans place each row x column tile
    contiguously in its own band (coded-order scatter), so the kernel is
    identical for flat and 2-D spatial splits.
    """
    interpret = _on_cpu() if interpret is None else interpret
    axis = plan.channel_axis % x.ndim
    lay = banded_layout(x.shape, plan)
    xp, moved_shape = _banded_view(x, lay, plan)
    lo_r, hi_r = _row_ranges(lo, hi, lay)
    br = min(256, lay.rows)
    idx, deq = clip_quant_tiles_2d(xp, lo_r, hi_r, n_levels, lay.sb_cols,
                                   block=(br, min(512, lay.cols)),
                                   interpret=interpret)
    return (_unband(idx, lay, moved_shape, axis, plan),
            _unband(deq, lay, moved_shape, axis, plan))


def clip_quantize_channels(x, cmin, cmax, *, n_levels: int,
                           channel_axis: int = -1,
                           interpret: bool | None = None):
    """Per-channel fused clip+quantize+dequantize: the one-spatial-block
    case of :func:`clip_quantize_tiled` (kept as a named entry point)."""
    plan = TilePlan(channel_axis=channel_axis, channel_group_size=1,
                    spatial_block_size=0, n_channels=cmin.size)
    return clip_quantize_tiled(x, cmin.reshape(-1, 1), cmax.reshape(-1, 1),
                               n_levels=n_levels, plan=plan,
                               interpret=interpret)


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "interpret"))
def ecsq_quantize(x, thresholds, levels, *, cmin: float, cmax: float,
                  interpret: bool | None = None):
    """Threshold-based non-uniform quantize + dequantize."""
    interpret = _on_cpu() if interpret is None else interpret
    x2d, n = _to_2d(x, cmin)
    br = min(256, x2d.shape[0])
    idx, deq = ecsq_assign_2d(x2d, thresholds, levels, cmin, cmax,
                              block=(br, min(512, x2d.shape[1])),
                              interpret=interpret)
    shape = x.shape
    return (idx.reshape(-1)[:n].reshape(shape),
            deq.reshape(-1)[:n].reshape(shape))


@functools.partial(jax.jit, static_argnames=("cmin", "cmax", "n_levels",
                                             "bits", "interpret"))
def _encode_fused_flat(x, *, cmin: float, cmax: float, n_levels: int,
                       bits: int, interpret: bool):
    """Jitted flat (per-tensor) megakernel pass.  Pads with ``cmin`` so
    the tail quantizes to index 0 (the histogram correction contract)."""
    x2d, _ = _to_2d(x, cmin)
    r, c = x2d.shape
    lo_r = jnp.full((r, 1), cmin, jnp.float32)
    hi_r = jnp.full((r, 1), cmax, jnp.float32)
    packed, hist = encode_tiles_2d(x2d, lo_r, hi_r, n_levels, bits,
                                   sb_cols=c, bs=c,
                                   block=(min(256, r), min(512, c)),
                                   interpret=interpret)
    return packed.astype(jnp.uint8), hist


@functools.partial(jax.jit, static_argnames=("n_levels", "bits", "plan",
                                             "interpret"))
def _encode_fused_tiled(x, lo, hi, *, n_levels: int, bits: int,
                        plan: TilePlan, interpret: bool):
    """Jitted tiled megakernel pass over the banded view."""
    lay = banded_layout(x.shape, plan)
    xp, _ = _banded_view(x, lay, plan)
    lo_r, hi_r = _row_ranges(lo, hi, lay)
    packed, hist = encode_tiles_2d(xp, lo_r, hi_r, n_levels, bits,
                                   sb_cols=lay.sb_cols, bs=lay.bs,
                                   bs_last=lay.bs_last,
                                   band_valid=lay.band_valid,
                                   block=(min(256, lay.rows),
                                          min(512, lay.cols)),
                                   interpret=interpret)
    return packed.astype(jnp.uint8), hist


def encode_fused(x, lo, hi, *, n_levels: int, bits: int,
                 plan: TilePlan | None = None,
                 interpret: bool | None = None):
    """Single-pass fused encode: clip + quantize + bit-pack + histogram.

    One megakernel dispatch per tile block; the only arrays that leave
    the device are wire-width packed bytes and the per-(row, band)
    histogram -- the encode path's single device->host transfer.
    Returns (packed uint8, hist_raw int32, :class:`PaddedLayout`); the
    host recovers coded-order indices with ``layout.unpack_indices`` and
    per-tile counts with ``layout.group_hists``.

    ``plan is None`` is the per-tensor mode (``lo``/``hi`` floats);
    otherwise ``lo``/``hi`` are (n_cgroups, n_sblocks) range tables over
    the plan's banded view (1-D flat runs or 2-D row x column tiles --
    the megakernel sees only bands either way).
    """
    interpret = _on_cpu() if interpret is None else interpret
    if plan is None:
        lay = flat_layout(int(np.prod(np.shape(x))))
        packed, hist = _encode_fused_flat(x, cmin=float(lo), cmax=float(hi),
                                          n_levels=n_levels, bits=bits,
                                          interpret=interpret)
        return packed, hist, lay
    lay = banded_layout(np.shape(x), plan)
    packed, hist = _encode_fused_tiled(
        x, jnp.asarray(lo, jnp.float32), jnp.asarray(hi, jnp.float32),
        n_levels=n_levels, bits=bits, plan=plan, interpret=interpret)
    return packed, hist, lay


def unpack_bytes(packed: np.ndarray, bits: int) -> np.ndarray:
    """Host inverse of the kernel bit-pack: uint8 byte values -> int32
    indices, ``per = 8 // bits`` per byte (identity for ``per == 1``).
    Same little-end-first lane layout as ``FeatureCodec.unpack``."""
    packed = np.asarray(packed, np.uint8)
    per = 8 // bits if bits in (1, 2, 4) else 1
    if per == 1:
        return packed.astype(np.int32)
    shifts = (np.arange(per, dtype=np.uint8) * bits)[None, :]
    mask = np.uint8((1 << bits) - 1)
    vals = (packed.reshape(-1, 1) >> shifts) & mask
    return vals.reshape(packed.shape[:-1] + (-1,)).astype(np.int32)


@functools.partial(jax.jit, static_argnames=("n_levels", "plan",
                                             "interpret"))
def index_histogram_tiled(idx, *, n_levels: int, plan: TilePlan,
                          interpret: bool | None = None):
    """Per-tile index histogram, in-graph: (n_cgroups, n_sblocks, N).

    The tile-resolved counterpart of :func:`index_histogram` for
    tile-aware rate estimation; runs the banded reduction kernel and
    folds channel rows into their groups in-graph, so per-tile rate
    choices never need the indices on the host.
    """
    interpret = _on_cpu() if interpret is None else interpret
    n_sblocks = plan.n_sblocks
    lay = banded_layout(idx.shape, plan)
    idx_p, _ = _banded_view(idx.astype(jnp.int32), lay, plan)
    hist = index_histogram_tiles_2d(idx_p, n_levels, lay.sb_cols, lay.bs,
                                    bs_last=lay.bs_last,
                                    band_valid=lay.band_valid,
                                    block=(min(256, lay.rows),
                                           min(512, lay.cols)),
                                    interpret=interpret)
    from .rate_hist import MAX_LEVELS
    h = hist.reshape(lay.rows, n_sblocks, MAX_LEVELS)[:lay.ch, :, :n_levels]
    gs = lay.channel_group_size
    n_cgroups = -(-lay.ch // gs)
    pad = n_cgroups * gs - lay.ch
    if pad:
        h = jnp.concatenate(
            [h, jnp.zeros((pad,) + h.shape[1:], h.dtype)], axis=0)
    return h.reshape(n_cgroups, gs, n_sblocks, n_levels).sum(axis=1)


@functools.partial(jax.jit, static_argnames=("n_levels", "plan",
                                             "interpret"))
def ecsq_quantize_tiled(x, lo, hi, thresholds, levels, *, n_levels: int,
                        plan: TilePlan,
                        interpret: bool | None = None):
    """Per-tile ECSQ quantize + dequantize through the Pallas kernel.

    ``thresholds`` (n_tiles, N-1) / ``levels`` (n_tiles, N) are the
    :class:`TileECSQ` tables (flat tile id = cgroup * n_sblocks + sblock);
    ``lo``/``hi`` the (n_cgroups, n_sblocks) clip ranges.  Bit-exact
    indices vs the jnp threshold-compare path (same ``xc >= t`` formula).
    """
    interpret = _on_cpu() if interpret is None else interpret
    axis = plan.channel_axis % x.ndim
    n_sblocks = plan.n_sblocks
    lay = banded_layout(x.shape, plan)
    xp, moved_shape = _banded_view(x, lay, plan)
    lo_r, hi_r = _row_ranges(lo, hi, lay)
    # expand the flat-tile tables to per-(row, band) MAX_LEVELS-wide rows:
    # thresholds pad with +inf (no bin past N), levels zero-pad
    cg = np.arange(lay.ch) // lay.channel_group_size
    thr = jnp.asarray(thresholds, jnp.float32) \
        .reshape(-1, n_sblocks, n_levels - 1)[cg]     # (ch, nb, N-1)
    lvl = jnp.asarray(levels, jnp.float32) \
        .reshape(-1, n_sblocks, n_levels)[cg]
    thr_r = jnp.full((lay.rows, n_sblocks, ECSQ_MAX_LEVELS), jnp.inf,
                     jnp.float32).at[:lay.ch, :, :n_levels - 1].set(thr)
    lvl_r = jnp.zeros((lay.rows, n_sblocks, ECSQ_MAX_LEVELS), jnp.float32) \
        .at[:lay.ch, :, :n_levels].set(lvl)
    idx, deq = ecsq_assign_tiles_2d(
        xp, lo_r, hi_r,
        thr_r.reshape(lay.rows, n_sblocks * ECSQ_MAX_LEVELS),
        lvl_r.reshape(lay.rows, n_sblocks * ECSQ_MAX_LEVELS),
        n_levels, lay.sb_cols,
        block=(min(256, lay.rows), min(512, lay.cols)),
        interpret=interpret)
    return (_unband(idx, lay, moved_shape, axis, plan),
            _unband(deq, lay, moved_shape, axis, plan))


@functools.partial(jax.jit, static_argnames=("bits", "interpret"))
def pack_indices(idx, *, bits: int, interpret: bool | None = None):
    """Pack int32 indices to ``bits``-wide uint8 lanes on device.

    Same byte layout as the jnp host fallback (see ``pack_bits.py``);
    ``bits`` must be 1, 2 or 4 (wire widths where a byte holds several
    indices).  Returns a flat uint8 array of ``ceil(n / (8 // bits))``
    bytes, zero-padded in the last byte.
    """
    if bits not in (1, 2, 4):
        raise ValueError(f"packable bit widths are 1/2/4, got {bits}")
    interpret = _on_cpu() if interpret is None else interpret
    per = 8 // bits
    flat = idx.reshape(-1).astype(jnp.int32)
    n_out = -(-flat.shape[0] // per)
    cols = _pad_lane(n_out, big=1024)
    lanes = jnp.zeros((cols * per,), jnp.int32).at[:flat.shape[0]].set(flat)
    # lane-view: row j holds the j-th index of every output byte
    lanes = lanes.reshape(cols, per).T                       # (per, cols)
    rows = jnp.zeros((8, cols), jnp.int32).at[:per].set(lanes)
    packed = pack_rows_2d(rows, bits, block_cols=min(1024, cols),
                          interpret=interpret)
    return packed.reshape(-1)[:n_out].astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("n_levels", "interpret"))
def index_histogram(idx, *, n_levels: int, interpret: bool | None = None):
    """Histogram of quantizer indices (padding assigned to bin 0, corrected)."""
    interpret = _on_cpu() if interpret is None else interpret
    idx2d, n = _to_2d(idx, 0)
    br = min(256, idx2d.shape[0])
    hist = index_histogram_2d(idx2d, n_levels,
                              block=(br, min(512, idx2d.shape[1])),
                              interpret=interpret)
    pad = idx2d.size - n
    return hist.at[0].add(-pad)


def estimate_rate_bits(idx, n_levels: int) -> jax.Array:
    """Bits/element the CABAC stage needs, from the kernel histogram."""
    from ..core.rate_model import estimated_bits_from_hist
    hist = index_histogram(idx, n_levels=n_levels)
    return estimated_bits_from_hist(hist, n_levels) / idx.size
