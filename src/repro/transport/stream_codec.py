"""Chunked tensor <-> frame stream glue.

Maps :meth:`FeatureCodec.encode_stream` payloads onto wire frames
(HEADER, CHUNK..., END) for one session, and reassembles/decodes the
frames on the receiving side with :class:`TensorAssembler` --
entropy-decoding arrived chunks in batches (one batched rANS step loop
per ``STREAM_CHUNK_BATCH`` chunks, mirroring the batched send side), so
decode overlaps the transfer and only the final dequantize plus at most
one remainder batch waits for END.

FEEDBACK frame payloads (link stats the cloud reports back for the
edge-side rate controller) are also defined here so both halves share
one layout.
"""

from __future__ import annotations

import dataclasses
import struct

import numpy as np

from ..core.codec import STREAM_CHUNK_BATCH, ChunkStreamDecoder, FeatureCodec
from .framing import (FT_CHUNK, FT_END, FT_FEEDBACK, FT_HEADER, Frame,
                      encode_frame)

# Chunk size balances pipeline granularity against per-chunk coder cost:
# the vectorized coder's python step loop runs ~bits/lanes iterations with
# lanes capped by payload size, so many small chunks multiply loop
# overhead -- though the batched chunk encoder (one rANS step loop per
# STREAM_CHUNK_BATCH chunks, see core/rans.encode_planes_batch) now
# amortizes most of it.  256Ki elements still gives a multi-MB tensor a
# several-stage pipeline at near-one-shot encode cost.  Tiled codecs
# round the chunk size up to the tile run length in coded order
# (TilePlan.align_chunk_elems: the uniform block run when every spatial
# block -- flat 1-D run or 2-D row x column tile -- has the same element
# count, a whole channel row otherwise), so chunk boundaries align to
# tiles and each chunk's chunk-static entropy probabilities see
# tile-homogeneous statistics; ChunkStreamDecoder stays bit-exact and
# out-of-order tolerant either way (chunks address element ranges, not
# tiles).
DEFAULT_CHUNK_ELEMS = 1 << 18

_END_FMT = "<I"            # n_chunks sent (completeness check)
_FEEDBACK_FMT = "<ddII"    # recv_bytes_per_s, decode_s, queue_depth, sessions


def tensor_to_frames(codec: FeatureCodec, x: np.ndarray, session: int,
                     chunk_elems: int = DEFAULT_CHUNK_ELEMS,
                     coder_mode: str = "auto"):
    """Yield wire-ready frame bytes for one tensor (HEADER, CHUNKs, END).

    A generator on purpose: the sender can put each frame on the socket
    while the next chunk is still being entropy-coded, which is the
    overlap ``benchmarks/bench_transport.py`` measures.
    """
    seq = 0
    for payload in codec.encode_stream(x, chunk_elems=chunk_elems,
                                       coder_mode=coder_mode):
        ftype = FT_HEADER if seq == 0 else FT_CHUNK
        yield encode_frame(ftype, session, seq, payload)
        seq += 1
    yield encode_frame(FT_END, session, seq, struct.pack(_END_FMT, seq - 1))


def payloads_to_frames(payloads: list[bytes], session: int) -> list[bytes]:
    """Wire frames (HEADER, CHUNKs, END) for an already-encoded payload
    list (the cross-session batcher's per-session output).  Frame-for-
    frame identical to :func:`tensor_to_frames` over the same payloads --
    the batched and per-session send paths put the same bytes on the
    wire."""
    frames = [encode_frame(FT_HEADER if i == 0 else FT_CHUNK, session, i, p)
              for i, p in enumerate(payloads)]
    frames.append(encode_frame(FT_END, session, len(payloads),
                               struct.pack(_END_FMT, len(payloads) - 1)))
    return frames


class TensorAssembler:
    """Per-session receiver: feed frames, get the reconstructed tensor.

    ``feed`` returns the reconstruction (a float32 ndarray, bit-exact
    with the in-process ``codec.decode(codec.encode(x))`` path) when the
    END frame completes the tensor, else None.  Chunk frames are
    entropy-decoded in arrival batches (see :class:`ChunkStreamDecoder`).

    ``defer=True`` is the serving-tick mode: ``feed`` never decodes or
    finishes (it always returns None; chunks accumulate in a
    ``chunk_batch=0`` decoder for a cross-session ``flush_decoders``
    drain), completion is polled via :attr:`ready` and the reconstruction
    fetched with :meth:`finish`.  ``header_cache`` shares parsed headers
    across a worker's sessions.
    """

    def __init__(self, *, backend=None, ecsq=None, defer: bool = False,
                 header_cache=None) -> None:
        self._backend = backend
        self._ecsq = ecsq
        self._defer = defer
        self._header_cache = header_cache
        self._dec: ChunkStreamDecoder | None = None
        self._end_chunks: int | None = None
        self.chunk_bytes = 0          # coded payload bytes seen so far

    @property
    def started(self) -> bool:
        return self._dec is not None

    @property
    def decoder(self) -> ChunkStreamDecoder | None:
        """The underlying stream decoder (what a cross-session drain
        registers with a :class:`~repro.serving.batcher.DecodeBatcher`)."""
        return self._dec

    @property
    def n_elems(self) -> int:
        if self._dec is None:
            raise ValueError("no HEADER frame yet")
        return self._dec.header.n_elems

    @property
    def ready(self) -> bool:
        """END seen and every chunk arrived (entropy work may still be
        pending in deferred mode)."""
        return (self._end_chunks is not None and self._dec is not None
                and self._dec.complete)

    def finish(self) -> np.ndarray:
        """Reconstruct (deferred mode; drains any still-pending chunks)."""
        if not self.ready:
            raise ValueError("tensor stream not complete")
        return self._dec.finish()

    def _maybe_finish(self) -> np.ndarray | None:
        if self._defer or not self.ready:
            return None
        return self._dec.finish()

    def feed(self, frame: Frame) -> np.ndarray | None:
        if frame.ftype == FT_HEADER:
            if self._dec is not None:
                raise ValueError("duplicate HEADER frame")
            self._dec = ChunkStreamDecoder(
                frame.payload, backend=self._backend, ecsq=self._ecsq,
                chunk_batch=0 if self._defer else STREAM_CHUNK_BATCH,
                header_cache=self._header_cache)
            self.chunk_bytes += len(frame.payload)
            return self._maybe_finish()
        if frame.ftype == FT_CHUNK:
            if self._dec is None:
                raise ValueError("CHUNK before HEADER")
            self._dec.add_chunk(frame.payload)
            self.chunk_bytes += len(frame.payload)
            return self._maybe_finish()
        if frame.ftype == FT_END:
            (n_chunks,) = struct.unpack(_END_FMT, frame.payload)
            if self._dec is None or n_chunks != self._dec.n_chunks:
                raise ValueError("END does not match stream header")
            self._end_chunks = n_chunks
            return self._maybe_finish()
        raise ValueError(f"unexpected frame type {frame.ftype} in tensor "
                         "stream")


@dataclasses.dataclass
class Feedback:
    """Cloud-side link stats, one per completed tensor (FEEDBACK frames)."""

    recv_bytes_per_s: float
    decode_s: float
    queue_depth: int
    active_sessions: int

    def encode(self, session: int, seq: int) -> bytes:
        payload = struct.pack(_FEEDBACK_FMT, self.recv_bytes_per_s,
                              self.decode_s, self.queue_depth,
                              self.active_sessions)
        return encode_frame(FT_FEEDBACK, session, seq, payload)

    @classmethod
    def decode(cls, frame: Frame) -> "Feedback":
        if frame.ftype != FT_FEEDBACK:
            raise ValueError("not a FEEDBACK frame")
        r, d, q, s = struct.unpack(_FEEDBACK_FMT, frame.payload)
        return cls(recv_bytes_per_s=r, decode_s=d, queue_depth=q,
                   active_sessions=s)
