"""Streaming split-inference transport: chunked bitstream framing, async
edge<->cloud sessions, and bandwidth-adaptive rate control.

Layering (bottom up):

  framing      -- length-prefixed CRC'd frames, incremental FrameReader
  errors       -- structured FT_ERROR codes (retryable vs fatal)
  faultinject  -- deterministic chaos at the frame-writer seam
  stream_codec -- tensor <-> frame streams (chunked FeatureCodec payloads)
  rate_control -- bits/element budget tracking + quantizer rung selection
  server       -- asyncio cloud half (incremental decode + model tail)
  client       -- asyncio edge half (multiplexed sessions, retry/resume,
                  sync facade)
  worker       -- standalone CloudServer subprocess entrypoint
  dispatcher   -- session-affine front-end over a pool of workers

The chunked codec itself (``FeatureCodec.encode_stream`` /
``decode_stream``) lives in :mod:`repro.core.codec`; this package is the
wire protocol and session machinery around it.  See DESIGN.md,
"Transport framing and streaming sessions" and "Hardened scale-out
serving".
"""

from .client import (EdgeClient, RetryPolicy, SubmitResult, SyncEdgeClient,
                     TransportError)
from .dispatcher import Dispatcher
from .errors import (CODE_NAMES, E_BUSY, E_CORRUPT_STREAM, E_DEADLINE,
                     E_DECODE, E_PROTOCOL, E_SHUTDOWN, E_UNAUTHORIZED,
                     E_UNSPECIFIED, E_WORKER_RESTART, RETRYABLE_CODES,
                     decode_error, encode_error)
from .faultinject import ChaosReset, ChaosWriter, FaultPlan, wrap_writer
from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_FEEDBACK, FT_HEADER,
                      FT_HELLO, FT_METRICS, FT_PING, FT_RESULT, Frame,
                      FrameReader, FramingError, encode_frame, pack_arrays,
                      unpack_arrays)
from .rate_control import (DEFAULT_LADDER, CodecBank, RateControlConfig,
                           RateController, Rung, as_rung, bank_cache_stats,
                           clear_bank_cache, rung_of_codec, shared_bank)
from .server import CloudServer, hello_auth
from .stream_codec import (DEFAULT_CHUNK_ELEMS, Feedback, TensorAssembler,
                           payloads_to_frames, tensor_to_frames)

__all__ = [
    "EdgeClient", "SyncEdgeClient", "SubmitResult", "TransportError",
    "RetryPolicy",
    "Frame", "FrameReader", "FramingError", "encode_frame",
    "pack_arrays", "unpack_arrays",
    "FT_HEADER", "FT_CHUNK", "FT_END", "FT_RESULT", "FT_FEEDBACK",
    "FT_ERROR", "FT_METRICS", "FT_HELLO", "FT_PING",
    "E_UNSPECIFIED", "E_PROTOCOL", "E_CORRUPT_STREAM", "E_DECODE",
    "E_UNAUTHORIZED", "E_BUSY", "E_WORKER_RESTART", "E_SHUTDOWN",
    "E_DEADLINE", "RETRYABLE_CODES", "CODE_NAMES",
    "encode_error", "decode_error",
    "FaultPlan", "ChaosWriter", "ChaosReset", "wrap_writer",
    "CodecBank", "RateControlConfig", "RateController", "DEFAULT_LADDER",
    "Rung", "as_rung", "rung_of_codec",
    "shared_bank", "bank_cache_stats", "clear_bank_cache",
    "CloudServer", "hello_auth", "Dispatcher",
    "TensorAssembler", "tensor_to_frames",
    "payloads_to_frames", "Feedback", "DEFAULT_CHUNK_ELEMS",
]
