"""Streaming split-inference transport: chunked bitstream framing, async
edge<->cloud sessions, and bandwidth-adaptive rate control.

Layering (bottom up):

  framing      -- length-prefixed CRC'd frames, incremental FrameReader
  stream_codec -- tensor <-> frame streams (chunked FeatureCodec payloads)
  rate_control -- bits/element budget tracking + quantizer rung selection
  server       -- asyncio cloud half (incremental decode + model tail)
  client       -- asyncio edge half (multiplexed sessions, sync facade)

The chunked codec itself (``FeatureCodec.encode_stream`` /
``decode_stream``) lives in :mod:`repro.core.codec`; this package is the
wire protocol and session machinery around it.  See DESIGN.md,
"Transport framing and streaming sessions".
"""

from .client import EdgeClient, SubmitResult, SyncEdgeClient, TransportError
from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_FEEDBACK, FT_HEADER,
                      FT_METRICS, FT_RESULT, Frame, FrameReader,
                      FramingError, encode_frame, pack_arrays,
                      unpack_arrays)
from .rate_control import (DEFAULT_LADDER, CodecBank, RateControlConfig,
                           RateController, Rung, as_rung, bank_cache_stats,
                           clear_bank_cache, rung_of_codec, shared_bank)
from .server import CloudServer
from .stream_codec import (DEFAULT_CHUNK_ELEMS, Feedback, TensorAssembler,
                           payloads_to_frames, tensor_to_frames)

__all__ = [
    "EdgeClient", "SyncEdgeClient", "SubmitResult", "TransportError",
    "Frame", "FrameReader", "FramingError", "encode_frame",
    "pack_arrays", "unpack_arrays",
    "FT_HEADER", "FT_CHUNK", "FT_END", "FT_RESULT", "FT_FEEDBACK",
    "FT_ERROR", "FT_METRICS",
    "CodecBank", "RateControlConfig", "RateController", "DEFAULT_LADDER",
    "Rung", "as_rung", "rung_of_codec",
    "shared_bank", "bank_cache_stats", "clear_bank_cache",
    "CloudServer", "TensorAssembler", "tensor_to_frames",
    "payloads_to_frames", "Feedback", "DEFAULT_CHUNK_ELEMS",
]
