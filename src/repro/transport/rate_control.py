"""Bandwidth-adaptive rate control for streamed split-layer tensors.

The self-describing bitstream header makes every tensor independently
decodable, so the edge is free to re-pick the quantizer *per request*.
:class:`RateController` chooses a :class:`Rung` of a calibrated codec
ladder (:class:`CodecBank`) so that

  * the *running average* bits/element tracks a target budget (a leaky
    bucket over coded bits: if the stream has been running hot the next
    tensor is coded coarser, and vice versa -- this is what keeps the
    long-run rate within a few percent of the budget even though the
    ladder is discrete), and
  * sustained link pressure (send queue building up, or measured
    throughput falling below what the current rate needs) steps the rung
    down ahead of the bucket, so a bandwidth drop degrades quantization
    instead of stalling the pipeline.

A rung is no longer just ``n_levels``: it spans ``(n_levels,
granularity, channel_group_size, spatial_block_size)``, so the ladder can
trade level count against tile granularity -- e.g. step from per-tensor
N=8 to per-channel N=4 (similar rate, lower MSE on channel-biased
features) before dropping to per-tensor N=4.  Plain ints in a ladder are
accepted and mean per-tensor rungs, so existing configs keep working.

Per-rung bits/element is learned online from the actual coded sizes
(EWMA per rung, log2-scaled estimates for unvisited rungs), so the
controller needs no a-priori rate model of the feature distribution.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from ..obs.metrics import BPE_BUCKETS, MetricsRegistry, default_registry


@dataclasses.dataclass(frozen=True, order=True)
class Rung:
    """One codec operating point on the rate-control ladder.

    ``granularity="base"`` (what a bare int normalizes to) means "inherit
    the CodecBank's base config" -- only ``n_levels`` is overridden, so
    int ladders keep their pre-Rung semantics whatever granularity the
    bank was built with.  ``spatial_block_hw=(bh, bw)`` makes a "tile"
    rung a 2-D (row x column) split of the conv feature map's spatial
    grid (v4 streams); ``(0, 0)`` keeps the 1-D flat-run split of
    ``spatial_block_size``.
    """

    n_levels: int
    granularity: str = "base"
    channel_group_size: int = 1
    spatial_block_size: int = 0
    spatial_block_hw: tuple[int, int] = (0, 0)

    def __str__(self) -> str:
        if self.granularity in ("base", "tensor"):
            return f"N{self.n_levels}"
        tag = f"N{self.n_levels}/{self.granularity}" \
              f"@g{self.channel_group_size}"
        if self.spatial_block_hw != (0, 0):
            tag += f"s{self.spatial_block_hw[0]}x{self.spatial_block_hw[1]}"
        elif self.spatial_block_size:
            tag += f"s{self.spatial_block_size}"
        return tag


def as_rung(r) -> Rung:
    """Normalize a ladder entry: ints are base-granularity rungs."""
    if isinstance(r, Rung):
        return r
    return Rung(n_levels=int(r))


def rung_of_codec(codec) -> Rung:
    """The rung a calibrated codec actually operates at (for attributing
    measured rates to the right ladder entry)."""
    cfg = codec.config
    bhw = getattr(cfg, "spatial_block_hw", None)
    return Rung(n_levels=cfg.n_levels, granularity=cfg.granularity,
                channel_group_size=max(1, cfg.channel_group_size),
                spatial_block_size=cfg.spatial_block_size,
                spatial_block_hw=(0, 0) if bhw is None
                else (int(bhw[0]), int(bhw[1])))


DEFAULT_LADDER = (2, 3, 4, 6, 8, 12, 16, 24, 32)


@dataclasses.dataclass
class RateControlConfig:
    target_bpe: float                     # budget, bits per element on the wire
    ladder: tuple = DEFAULT_LADDER        # ints and/or Rungs
    ewma: float = 0.4                     # per-rung bpe measurement smoothing
    window_elems: int = 1 << 22           # leaky-bucket horizon (elements)
    queue_high: int = 8                   # frames queued => link pressure
    throughput_ewma: float = 0.3


class RateController:
    def __init__(self, cfg: RateControlConfig) -> None:
        if cfg.target_bpe <= 0:
            raise ValueError("target_bpe must be positive")
        self.cfg = cfg
        self.ladder = tuple(sorted(set(as_rung(r) for r in cfg.ladder)))
        self._bpe = {}                    # Rung -> EWMA measured bits/elem
        self._seeded = set()              # rungs whose _bpe is an estimate
        self._bucket_bits = 0.0           # leaky bucket: coded bits
        self._bucket_elems = 0.0
        self._queue_depth = 0
        self._throughput = None           # EWMA bytes/s of the link
        self._last_rung: Rung | None = None
        self.history: list[dict] = []
        self._m = None                    # see bind_metrics

    def bind_metrics(self, registry: MetricsRegistry) -> None:
        """Register RD-telemetry instruments: the paper's central
        trade-off (measured bits/element vs. the budget), per-tensor rate
        distribution, rung occupancy, and the learned link state."""
        m = {
            "target": registry.gauge("repro_rate_target_bpe",
                                     "bits/element budget"),
            "measured": registry.gauge(
                "repro_rate_measured_bpe",
                "leaky-bucket running average of coded bits/element"),
            "tensor_bpe": registry.histogram(
                "repro_rate_tensor_rate_bpe",
                "coded bits/element per tensor", labelnames=("rung",),
                buckets=BPE_BUCKETS),
            "rung_picks": registry.counter(
                "repro_rate_rung_picks_total",
                "next_rung decisions per ladder rung",
                labelnames=("rung",)),
            "throughput": registry.gauge(
                "repro_rate_link_throughput_bytes",
                "EWMA link throughput (bytes per second)"),
            "queue": registry.gauge("repro_rate_queue_depth_count",
                                    "last observed send-queue depth"),
        }
        m["target"].set(self.cfg.target_bpe)
        self._m = m

    def _resolve(self, rung) -> Rung:
        """Accept a Rung or a bare n_levels int (legacy callers).

        Int resolution mirrors :meth:`CodecBank._resolve` exactly
        (base/tensor rung first, then ladder order): a legacy
        ``next_levels() -> bank.get(n) -> on_tensor(n)`` loop therefore
        attributes its measurement to the same rung whose codec the bank
        actually handed out, even on a mixed-granularity ladder.
        """
        if isinstance(rung, Rung):
            return rung
        matches = [r for r in self.ladder if r.n_levels == rung]
        if matches:
            plain = [r for r in matches
                     if r.granularity in ("base", "tensor")]
            return plain[0] if plain else matches[0]
        return Rung(n_levels=int(rung))

    # -- measurements ---------------------------------------------------------

    def on_tensor(self, rung, coded_bytes: int, n_elems: int,
                  send_seconds: float | None = None) -> None:
        """Record one coded tensor (and optionally its send time)."""
        if n_elems <= 0:
            return
        rung = self._resolve(rung)
        bpe = 8.0 * coded_bytes / n_elems
        # a seeded value is an estimate, not a measurement: the first
        # real coded size replaces it outright instead of blending
        prev = None if rung in self._seeded else self._bpe.get(rung)
        self._seeded.discard(rung)
        a = self.cfg.ewma
        self._bpe[rung] = bpe if prev is None else a * bpe + (1 - a) * prev
        self._bucket_bits += 8.0 * coded_bytes
        self._bucket_elems += n_elems
        # leak so that only ~window_elems of history steers the bucket
        if self._bucket_elems > self.cfg.window_elems:
            scale = self.cfg.window_elems / self._bucket_elems
            self._bucket_bits *= scale
            self._bucket_elems *= scale
        if send_seconds and send_seconds > 0:
            tput = coded_bytes / send_seconds
            t = self.cfg.throughput_ewma
            self._throughput = tput if self._throughput is None \
                else t * tput + (1 - t) * self._throughput
        self.history.append({"rung": str(rung), "n_levels": rung.n_levels,
                             "bpe": bpe, "cum_bpe": self.measured_bpe,
                             "queue_depth": self._queue_depth})
        if self._m is not None:
            self._m["measured"].set(self.measured_bpe)
            self._m["tensor_bpe"].observe(bpe, rung=str(rung))
            if self._throughput is not None:
                self._m["throughput"].set(self._throughput)

    def seed_estimate(self, rung, bpe: float) -> None:
        """Prime a rung's expected rate with an *estimate* (e.g. the
        in-graph tile-aware entropy estimate from one fused quantization
        pass over calibration features).  Only fills rungs with no
        measurement yet: real coded sizes always win, estimates just let
        the very first ladder walks order tiled rungs correctly instead
        of falling back to the log2(N) scaling."""
        rung = self._resolve(rung)
        if rung not in self._bpe and bpe > 0:
            self._bpe[rung] = float(bpe)
            self._seeded.add(rung)

    def on_queue_depth(self, depth: int) -> None:
        self._queue_depth = int(depth)
        if self._m is not None:
            self._m["queue"].set(self._queue_depth)

    def on_feedback(self, recv_bytes_per_s: float, queue_depth: int) -> None:
        """Cloud-side FEEDBACK frame: receiver-measured link throughput."""
        if recv_bytes_per_s > 0:
            t = self.cfg.throughput_ewma
            self._throughput = recv_bytes_per_s if self._throughput is None \
                else t * recv_bytes_per_s + (1 - t) * self._throughput
        self._queue_depth = max(self._queue_depth, int(queue_depth))

    # -- decisions ------------------------------------------------------------

    @property
    def measured_bpe(self) -> float:
        if self._bucket_elems <= 0:
            return 0.0
        return self._bucket_bits / self._bucket_elems

    @property
    def link_bytes_per_s(self) -> float | None:
        return self._throughput

    def estimate_bpe(self, rung) -> float:
        """Expected coded bits/element at a rung: measured EWMA when the
        rung has been used, else scaled from the nearest measured rung by
        the log2(N) ratio (exact for uniform indices, adequate to order
        the ladder), else the TU-coded upper bound log2(N)."""
        rung = self._resolve(rung)
        if rung in self._bpe:
            return self._bpe[rung]
        n_levels = rung.n_levels
        if self._bpe:
            ref = min(self._bpe,
                      key=lambda r: abs(math.log2(r.n_levels / n_levels)))
            return self._bpe[ref] * math.log2(n_levels) \
                / math.log2(ref.n_levels)
        return math.log2(n_levels)

    def next_rung(self) -> Rung:
        """Rung for the next tensor against the budget + link state.

        The ladder is walked in ascending *estimated-rate* order (not
        n_levels order: a per-channel rung often codes cheaper than a
        per-tensor rung one level count up), taking the most expensive
        rung still under the bucket's desired rate.
        """
        # leaky bucket: aim the next tensor at 2*target - running average,
        # so rate errors are actively paid back instead of persisting
        desired = 2 * self.cfg.target_bpe - self.measured_bpe \
            if self._bucket_elems > 0 else self.cfg.target_bpe
        desired = float(np.clip(desired, 0.25 * self.cfg.target_bpe,
                                2.0 * self.cfg.target_bpe))
        by_rate = sorted(self.ladder, key=self.estimate_bpe)
        choice = by_rate[0]
        for r in by_rate:
            if self.estimate_bpe(r) <= desired:
                choice = r
        if self._queue_depth >= self.cfg.queue_high \
                and self._last_rung is not None:
            # sustained backpressure: step below the last rung regardless
            last = self.estimate_bpe(self._last_rung)
            below = [r for r in by_rate if self.estimate_bpe(r) < last]
            if below:
                cheaper = min(choice, below[-1],
                              key=self.estimate_bpe)
                choice = cheaper
        self._last_rung = choice
        if self._m is not None:
            self._m["rung_picks"].inc(rung=str(choice))
        return choice

    def next_levels(self) -> int:
        """Legacy view of :meth:`next_rung` (the chosen level count)."""
        return self.next_rung().n_levels


class CodecBank:
    """Calibrated codecs at every ladder rung, sharing one sample set.

    Calibration is per-rung because the optimal clipping range depends on
    N and on the tile granularity (coarser quantizers clip tighter);
    codecs are built lazily and cached, so switching rungs mid-stream
    costs nothing after first use.  Tiled rungs need ``samples`` to carry
    the channel axis (pass the calibration activations un-flattened).
    """

    def __init__(self, base_config, samples: np.ndarray,
                 ladder: tuple = DEFAULT_LADDER) -> None:
        from ..core.codec import calibrate
        self._calibrate = calibrate
        self.base_config = base_config
        self.samples = np.asarray(samples, np.float32)
        self.ladder = tuple(sorted(set(as_rung(r) for r in ladder)))
        self._codecs = {}

    def _resolve(self, rung) -> Rung:
        if isinstance(rung, Rung):
            if rung not in self.ladder:
                raise KeyError(f"{rung} not in ladder {self.ladder}")
            return rung
        matches = [r for r in self.ladder if r.n_levels == rung]
        if not matches:
            raise KeyError(f"{rung} not in ladder {self.ladder}")
        # legacy int lookups prefer the base-config rung over explicitly
        # tiled rungs at the same level count
        plain = [r for r in matches if r.granularity in ("base", "tensor")]
        return plain[0] if plain else matches[0]

    def rung_for(self, codec) -> Rung | None:
        """The ladder rung whose cached codec *is* ``codec`` (identity),
        else None.  Lets a caller that was handed a bank codec attribute
        its rate measurements to the exact ladder key -- including
        'base'-granularity rungs, which :func:`rung_of_codec` cannot name
        (it only sees the codec's resolved config)."""
        for r, c in self._codecs.items():
            if c is codec:
                return r
        return None

    def get(self, rung):
        """Codec for a :class:`Rung` (or a bare n_levels int)."""
        rung = self._resolve(rung)
        if rung not in self._codecs:
            if rung.granularity == "base":
                cfg = dataclasses.replace(self.base_config,
                                          n_levels=rung.n_levels)
            else:
                cfg = dataclasses.replace(
                    self.base_config, n_levels=rung.n_levels,
                    granularity=rung.granularity,
                    channel_group_size=rung.channel_group_size,
                    spatial_block_size=rung.spatial_block_size,
                    spatial_block_hw=None
                    if rung.spatial_block_hw == (0, 0)
                    else rung.spatial_block_hw)
            self._codecs[rung] = self._calibrate(cfg, samples=self.samples)
        return self._codecs[rung]

    def prime_controller(self, controller: RateController,
                         x: np.ndarray | None = None) -> None:
        """Seed every ladder rung's expected bits/element from the
        in-graph entropy estimate of one quantization pass over ``x``
        (default: the calibration samples).

        Tiled rungs estimate per tile and sum (the tile histograms the
        fused encode pass emits), so a mixed-granularity ladder is
        rate-ordered correctly from the very first
        :meth:`RateController.next_rung` call -- no coded tensors, no
        host round trip, no log2(N) guessing.
        """
        feats = self.samples if x is None else np.asarray(x, np.float32)
        for rung in self.ladder:
            codec = self.get(rung)
            controller.seed_estimate(rung,
                                     float(codec.estimate_rate(feats)))


# -- worker-level bank sharing ------------------------------------------------

_BANKS: dict[tuple, CodecBank] = {}
# worker-level instruments: bank reuse is per-process, so these live in
# the process-wide default registry (scraped alongside every server)
_BANK_HITS = default_registry().counter(
    "repro_bank_cache_hits_total", "shared_bank cache hits")
_BANK_MISSES = default_registry().counter(
    "repro_bank_cache_misses_total",
    "shared_bank cache misses (fresh calibration)")
_BANK_ENTRIES = default_registry().gauge(
    "repro_bank_cache_entries_count", "distinct cached codec banks")


def _bank_key(base_config, samples: np.ndarray, ladder: tuple) -> tuple:
    import hashlib
    return (dataclasses.astuple(base_config), samples.shape,
            hashlib.sha1(np.ascontiguousarray(samples).tobytes()).hexdigest(),
            tuple(sorted(set(as_rung(r) for r in ladder))))


def shared_bank(base_config, samples: np.ndarray,
                ladder: tuple = DEFAULT_LADDER) -> CodecBank:
    """Worker-level :class:`CodecBank` cache.

    Rung calibration tables are immutable, so every session of one
    worker with the same (config, calibration samples, ladder) can share
    one bank -- calibration runs once per worker instead of once per
    session.  Keyed by config fields + samples content hash, so a
    *different* calibration set still gets its own bank.  Hit/miss
    counts via :func:`bank_cache_stats`.
    """
    samples = np.asarray(samples, np.float32)
    key = _bank_key(base_config, samples, ladder)
    bank = _BANKS.get(key)
    if bank is not None:
        _BANK_HITS.inc()
        return bank
    _BANK_MISSES.inc()
    bank = _BANKS[key] = CodecBank(base_config, samples, ladder)
    _BANK_ENTRIES.set(len(_BANKS))
    return bank


def bank_cache_stats() -> dict:
    """Legacy dict view of the ``repro_bank_cache_*`` instruments."""
    return {"hits": int(_BANK_HITS.value()),
            "misses": int(_BANK_MISSES.value()),
            "entries": len(_BANKS)}


def clear_bank_cache() -> None:
    """Tests only: drop cached banks and zero the counters."""
    _BANKS.clear()
    _BANK_HITS.clear()
    _BANK_MISSES.clear()
    _BANK_ENTRIES.set(0)
