"""Bandwidth-adaptive rate control for streamed split-layer tensors.

The self-describing bitstream header makes every tensor independently
decodable, so the edge is free to re-pick the quantizer *per request*.
:class:`RateController` chooses the ``n_levels`` rung of a calibrated
codec ladder (:class:`CodecBank`) so that

  * the *running average* bits/element tracks a target budget (a leaky
    bucket over coded bits: if the stream has been running hot the next
    tensor is coded coarser, and vice versa -- this is what keeps the
    long-run rate within a few percent of the budget even though the
    ladder is discrete), and
  * sustained link pressure (send queue building up, or measured
    throughput falling below what the current rate needs) steps the rung
    down ahead of the bucket, so a bandwidth drop degrades quantization
    instead of stalling the pipeline.

Per-rung bits/element is learned online from the actual coded sizes
(EWMA per rung, log2-scaled estimates for unvisited rungs), so the
controller needs no a-priori rate model of the feature distribution.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

DEFAULT_LADDER = (2, 3, 4, 6, 8, 12, 16, 24, 32)


@dataclasses.dataclass
class RateControlConfig:
    target_bpe: float                     # budget, bits per element on the wire
    ladder: tuple[int, ...] = DEFAULT_LADDER
    ewma: float = 0.4                     # per-rung bpe measurement smoothing
    window_elems: int = 1 << 22           # leaky-bucket horizon (elements)
    queue_high: int = 8                   # frames queued => link pressure
    throughput_ewma: float = 0.3


class RateController:
    def __init__(self, cfg: RateControlConfig) -> None:
        if cfg.target_bpe <= 0:
            raise ValueError("target_bpe must be positive")
        self.cfg = cfg
        self.ladder = tuple(sorted(set(cfg.ladder)))
        self._bpe = {}                    # rung -> EWMA measured bits/elem
        self._bucket_bits = 0.0           # leaky bucket: coded bits
        self._bucket_elems = 0.0
        self._queue_depth = 0
        self._throughput = None           # EWMA bytes/s of the link
        self._last_levels = None
        self.history: list[dict] = []

    # -- measurements ---------------------------------------------------------

    def on_tensor(self, n_levels: int, coded_bytes: int, n_elems: int,
                  send_seconds: float | None = None) -> None:
        """Record one coded tensor (and optionally its send time)."""
        if n_elems <= 0:
            return
        bpe = 8.0 * coded_bytes / n_elems
        prev = self._bpe.get(n_levels)
        a = self.cfg.ewma
        self._bpe[n_levels] = bpe if prev is None else a * bpe + (1 - a) * prev
        self._bucket_bits += 8.0 * coded_bytes
        self._bucket_elems += n_elems
        # leak so that only ~window_elems of history steers the bucket
        if self._bucket_elems > self.cfg.window_elems:
            scale = self.cfg.window_elems / self._bucket_elems
            self._bucket_bits *= scale
            self._bucket_elems *= scale
        if send_seconds and send_seconds > 0:
            tput = coded_bytes / send_seconds
            t = self.cfg.throughput_ewma
            self._throughput = tput if self._throughput is None \
                else t * tput + (1 - t) * self._throughput
        self.history.append({"n_levels": n_levels, "bpe": bpe,
                             "cum_bpe": self.measured_bpe,
                             "queue_depth": self._queue_depth})

    def on_queue_depth(self, depth: int) -> None:
        self._queue_depth = int(depth)

    def on_feedback(self, recv_bytes_per_s: float, queue_depth: int) -> None:
        """Cloud-side FEEDBACK frame: receiver-measured link throughput."""
        if recv_bytes_per_s > 0:
            t = self.cfg.throughput_ewma
            self._throughput = recv_bytes_per_s if self._throughput is None \
                else t * recv_bytes_per_s + (1 - t) * self._throughput
        self._queue_depth = max(self._queue_depth, int(queue_depth))

    # -- decisions ------------------------------------------------------------

    @property
    def measured_bpe(self) -> float:
        if self._bucket_elems <= 0:
            return 0.0
        return self._bucket_bits / self._bucket_elems

    @property
    def link_bytes_per_s(self) -> float | None:
        return self._throughput

    def estimate_bpe(self, n_levels: int) -> float:
        """Expected coded bits/element at a rung: measured EWMA when the
        rung has been used, else scaled from the nearest measured rung by
        the log2(N) ratio (exact for uniform indices, adequate to order
        the ladder), else the TU-coded upper bound log2(N)."""
        if n_levels in self._bpe:
            return self._bpe[n_levels]
        if self._bpe:
            ref = min(self._bpe, key=lambda n: abs(math.log2(n / n_levels)))
            return self._bpe[ref] * math.log2(n_levels) / math.log2(ref)
        return math.log2(n_levels)

    def next_levels(self) -> int:
        """Rung for the next tensor against the budget + link state."""
        # leaky bucket: aim the next tensor at 2*target - running average,
        # so rate errors are actively paid back instead of persisting
        desired = 2 * self.cfg.target_bpe - self.measured_bpe \
            if self._bucket_elems > 0 else self.cfg.target_bpe
        desired = float(np.clip(desired, 0.25 * self.cfg.target_bpe,
                                2.0 * self.cfg.target_bpe))
        choice = self.ladder[0]
        for n in self.ladder:
            if self.estimate_bpe(n) <= desired:
                choice = n
        if self._queue_depth >= self.cfg.queue_high \
                and self._last_levels is not None:
            # sustained backpressure: step below the last rung regardless
            below = [n for n in self.ladder if n < self._last_levels]
            if below:
                choice = min(choice, below[-1])
        self._last_levels = choice
        return choice


class CodecBank:
    """Calibrated codecs at every ladder rung, sharing one sample set.

    Calibration is per-rung because the optimal clipping range depends on
    N (coarser quantizers clip tighter); codecs are built lazily and
    cached, so switching rungs mid-stream costs nothing after first use.
    """

    def __init__(self, base_config, samples: np.ndarray,
                 ladder: tuple[int, ...] = DEFAULT_LADDER) -> None:
        from ..core.codec import calibrate
        self._calibrate = calibrate
        self.base_config = base_config
        self.samples = np.asarray(samples, np.float32)
        self.ladder = tuple(sorted(set(ladder)))
        self._codecs = {}

    def get(self, n_levels: int):
        if n_levels not in self.ladder:
            raise KeyError(f"{n_levels} not in ladder {self.ladder}")
        if n_levels not in self._codecs:
            cfg = dataclasses.replace(self.base_config, n_levels=n_levels)
            self._codecs[n_levels] = self._calibrate(cfg,
                                                     samples=self.samples)
        return self._codecs[n_levels]
