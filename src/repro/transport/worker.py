"""Standalone CloudServer worker process (``python -m repro.transport.worker``).

The :class:`~repro.transport.dispatcher.Dispatcher` spawns N of these as
subprocesses for real fault isolation (a worker SIGKILL cannot take the
front-end down).  The worker binds an ephemeral loopback port, prints
``PORT <n>`` on stdout (the parent's only startup handshake), then
serves the ordinary frame protocol until killed.

``--tail module:attr`` resolves an importable callable to use as the
cloud-side ``tail_fn``; ``--echo`` echoes the reconstructed split-layer
tensor back (what the chaos tests and the degraded-mode benchmark use,
since a closure can't cross a process boundary).
"""

from __future__ import annotations

import argparse
import asyncio
import importlib
import sys

from ..serving.batcher import TickConfig
from .server import CloudServer


def resolve_tail(spec: str):
    """``module:attr`` -> callable (the worker's ``tail_fn``)."""
    mod, _, attr = spec.partition(":")
    if not mod or not attr:
        raise SystemExit(f"--tail wants module:attr, got {spec!r}")
    fn = getattr(importlib.import_module(mod), attr)
    if not callable(fn):
        raise SystemExit(f"--tail target {spec!r} is not callable")
    return fn


def build_server(args: argparse.Namespace) -> CloudServer:
    return CloudServer(
        tail_fn=resolve_tail(args.tail) if args.tail else None,
        echo_features=args.echo,
        host=args.host, port=args.port,
        tick=None if args.no_tick else TickConfig(),
        max_queue=args.max_queue,
        secret=args.secret,
        resume_ttl_s=args.resume_ttl_s,
    )


async def amain(args: argparse.Namespace) -> None:
    server = await build_server(args).start()
    print(f"PORT {server.port}", flush=True)
    await server.wait_closed()


def main(argv=None) -> None:
    p = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=0,
                   help="listen port (0 = ephemeral, printed on stdout)")
    p.add_argument("--echo", action="store_true",
                   help="echo the reconstructed tensor in RESULT")
    p.add_argument("--tail", default=None, metavar="MODULE:ATTR",
                   help="importable callable to run as the cloud tail")
    p.add_argument("--no-tick", action="store_true",
                   help="per-session decode instead of tick batching")
    p.add_argument("--max-queue", type=int, default=None,
                   help="admission bound (sessions in flight)")
    p.add_argument("--secret", default=None,
                   help="require an authenticated HELLO")
    p.add_argument("--resume-ttl-s", type=float, default=30.0,
                   help="how long disconnected sessions stay resumable")
    args = p.parse_args(argv)
    try:
        asyncio.run(amain(args))
    except KeyboardInterrupt:
        pass


if __name__ == "__main__":
    main(sys.argv[1:])
