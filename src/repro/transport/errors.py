"""Structured frame-level error codes (FT_ERROR payloads).

The seed transport shipped errors as bare stringified exceptions, which
left the edge unable to tell "the cloud is briefly saturated, try again"
from "this stream is corrupt, give up".  Every FT_ERROR payload now
carries a typed triple::

    <B magic=0xEE> <H code> <B flags> <utf-8 message>

``flags`` bit 0 is the *retryable* bit: the sender's statement that the
same submission may succeed later (admission-control sheds, a worker
restarting or draining).  Fatal codes (corrupt stream, protocol
violation, auth failure) mean the client must not replay the same bytes.

Legacy bare-text payloads (streams from a pre-hardening peer) still
parse: :func:`decode_error` falls back to ``E_UNSPECIFIED`` + the raw
text, non-retryable -- the conservative reading.

The codes travel on control frames only; codec stream bytes (HEADER /
CHUNK payloads, the conformance-gated wire format) are untouched.
"""

from __future__ import annotations

import struct

# -- codes --------------------------------------------------------------------

E_UNSPECIFIED = 0        # legacy bare-text error (unknown cause)
E_PROTOCOL = 1           # malformed frames / protocol violation   (fatal)
E_CORRUPT_STREAM = 2     # CRC / entropy-decode failure            (fatal)
E_DECODE = 3             # reconstruction or tail_fn failed        (fatal)
E_UNAUTHORIZED = 4       # HELLO auth missing or rejected          (fatal)
E_BUSY = 5               # admission control shed                  (retryable)
E_WORKER_RESTART = 6     # worker died / restarting mid-session    (retryable)
E_SHUTDOWN = 7           # planned drain: no new sessions here     (retryable)
E_DEADLINE = 8           # client-side submit deadline expired     (fatal)

#: codes whose *default* retryable flag is set (the wire flag wins when
#: a peer says otherwise)
RETRYABLE_CODES = frozenset({E_BUSY, E_WORKER_RESTART, E_SHUTDOWN})

CODE_NAMES = {
    E_UNSPECIFIED: "UNSPECIFIED",
    E_PROTOCOL: "PROTOCOL",
    E_CORRUPT_STREAM: "CORRUPT_STREAM",
    E_DECODE: "DECODE",
    E_UNAUTHORIZED: "UNAUTHORIZED",
    E_BUSY: "BUSY",
    E_WORKER_RESTART: "WORKER_RESTART",
    E_SHUTDOWN: "SHUTDOWN",
    E_DEADLINE: "DEADLINE",
}

_ERR_MAGIC = 0xEE
_ERR_FMT = "<BHB"        # magic, code, flags
_FLAG_RETRYABLE = 1


class TransportError(RuntimeError):
    """Typed transport failure.

    ``code`` is one of the ``E_*`` constants; ``retryable`` says whether
    the same submission may be retried (BUSY, worker restart, drain).
    """

    def __init__(self, message: str, *, code: int = E_UNSPECIFIED,
                 retryable: bool | None = None) -> None:
        super().__init__(message)
        self.code = code
        self.retryable = (code in RETRYABLE_CODES if retryable is None
                          else bool(retryable))

    @property
    def code_name(self) -> str:
        return CODE_NAMES.get(self.code, f"E_{self.code}")

    def __str__(self) -> str:  # "[BUSY retryable] queue full"
        kind = "retryable" if self.retryable else "fatal"
        return f"[{self.code_name} {kind}] {super().__str__()}"


def encode_error(code: int, message: str,
                 retryable: bool | None = None) -> bytes:
    """FT_ERROR payload bytes for a typed error."""
    if retryable is None:
        retryable = code in RETRYABLE_CODES
    flags = _FLAG_RETRYABLE if retryable else 0
    return struct.pack(_ERR_FMT, _ERR_MAGIC, code, flags) \
        + message.encode("utf-8", "replace")


def decode_error(payload: bytes) -> TransportError:
    """Parse an FT_ERROR payload (structured or legacy bare text)."""
    if len(payload) >= struct.calcsize(_ERR_FMT) \
            and payload[0] == _ERR_MAGIC:
        _, code, flags = struct.unpack_from(_ERR_FMT, payload)
        msg = payload[struct.calcsize(_ERR_FMT):].decode("utf-8", "replace")
        return TransportError(msg, code=code,
                              retryable=bool(flags & _FLAG_RETRYABLE))
    return TransportError(payload.decode("utf-8", "replace"),
                          code=E_UNSPECIFIED, retryable=False)


def error_for_exception(exc: BaseException) -> tuple[int, bool]:
    """(code, retryable) classification for a server-side exception."""
    if isinstance(exc, TransportError):
        return exc.code, exc.retryable
    name = type(exc).__name__
    text = str(exc).lower()
    if name == "FramingError" or "crc" in text or "magic" in text:
        return E_CORRUPT_STREAM, False
    if isinstance(exc, ValueError):
        # stream-shape violations (bad chunk ids, END mismatch, ...)
        return E_CORRUPT_STREAM, False
    return E_DECODE, False
