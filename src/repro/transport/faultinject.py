"""Deterministic fault injection at the frame-writer seam.

Chaos scenarios (lossy links, flaky middleboxes, dying workers) must be
*reproducible* to live in tier-1, so faults are injected at one
deterministic seam: a :class:`ChaosWriter` wraps an asyncio
``StreamWriter``, splits the outgoing byte stream back into frames (the
only unit the transport ever writes), and applies a :class:`FaultPlan`
keyed by the per-writer frame counter -- frame *i* is dropped,
duplicated, corrupted, delayed, or the connection is reset after *i*
frames, identically on every run.  Rate-based faults draw from a seeded
RNG, so they too replay bit-identically.

Config is programmatic (tests pass a ``FaultPlan``) or env-driven::

    REPRO_CHAOS='{"client": {"reset_after": 5}, "server": {"drop_frames": [3]}}'

keys are injection *roles*: ``client`` (EdgeClient's writer), ``server``
(CloudServer's per-connection writer), ``edge`` / ``upstream`` (the
dispatcher's two sides).  Worker-kill chaos is a process-level fault and
lives on the dispatcher (:meth:`~repro.transport.dispatcher.Dispatcher.
kill_worker`), not here.

Corruption flips one payload byte of the already-CRC'd frame, so the
receiver sees a genuine CRC mismatch -- exactly the wire fault the
framing layer exists to catch.  Nothing here touches codec payload
construction; golden streams are unaffected.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import random
import struct

from .framing import _FRAME_FMT, _FRAME_HEAD

CHAOS_ENV = "REPRO_CHAOS"


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """What to do to the frame stream of one writer.

    Indices count frames written through this writer, starting at 0.
    ``*_rate`` faults draw per-frame from ``random.Random(seed)`` --
    deterministic for a fixed seed and frame sequence.
    """

    drop_frames: tuple[int, ...] = ()        # swallow frame i entirely
    dup_frames: tuple[int, ...] = ()         # write frame i twice
    corrupt_frames: tuple[int, ...] = ()     # flip a payload byte of i
    delay_frames: tuple[tuple[int, float], ...] = ()   # (i, seconds)
    reset_after: int | None = None           # abort the conn after i frames
    drop_rate: float = 0.0
    corrupt_rate: float = 0.0
    seed: int = 0

    @classmethod
    def from_env(cls, role: str,
                 env: str | None = None) -> "FaultPlan | None":
        """Plan for ``role`` out of the ``REPRO_CHAOS`` JSON (or None)."""
        raw = env if env is not None else os.environ.get(CHAOS_ENV)
        if not raw:
            return None
        spec = json.loads(raw).get(role)
        if not spec:
            return None
        kw = dict(spec)
        for key in ("drop_frames", "dup_frames", "corrupt_frames"):
            if key in kw:
                kw[key] = tuple(int(i) for i in kw[key])
        if "delay_frames" in kw:
            kw["delay_frames"] = tuple(
                (int(i), float(s)) for i, s in
                (kw["delay_frames"].items()
                 if isinstance(kw["delay_frames"], dict)
                 else kw["delay_frames"]))
        return cls(**kw)

    def is_noop(self) -> bool:
        return not (self.drop_frames or self.dup_frames
                    or self.corrupt_frames or self.delay_frames
                    or self.reset_after is not None
                    or self.drop_rate or self.corrupt_rate)


def _corrupt(frame: bytes) -> bytes:
    """Flip one byte *after* the CRC was computed: payload if any, else
    the CRC itself -- the receiver must see a framing-level fault."""
    out = bytearray(frame)
    out[-1] ^= 0xFF
    return bytes(out)


class ChaosReset(ConnectionResetError):
    """The fault plan reset this connection (so tests can tell an
    injected reset from a real one)."""


class ChaosWriter:
    """StreamWriter proxy applying a :class:`FaultPlan` frame-by-frame.

    Only whole frames ever cross ``write`` in this transport, but the
    splitter is incremental anyway (a torn write worst-case defers one
    frame to the next write call).  ``delay_frames`` are realized inside
    :meth:`drain` (every frame write in the transport is followed by an
    awaited drain, so delays land on the wire in order).
    """

    def __init__(self, writer: asyncio.StreamWriter, plan: FaultPlan,
                 on_fault=None) -> None:
        self._w = writer
        self.plan = plan
        self._rng = random.Random(plan.seed)
        self._buf = bytearray()
        self._n = 0                  # frames seen (pre-fault count)
        self._delay_s = 0.0          # accumulated delay for next drain
        self._reset = False
        self._on_fault = on_fault    # callable(kind: str, frame_idx: int)
        self.faults: list[tuple[str, int]] = []
        self._delays = dict(plan.delay_frames)

    # -- proxy ----------------------------------------------------------------

    def __getattr__(self, name):
        return getattr(self._w, name)

    @property
    def transport(self):
        return self._w.transport

    def _note(self, kind: str, idx: int) -> None:
        self.faults.append((kind, idx))
        if self._on_fault is not None:
            self._on_fault(kind, idx)

    def _split_frames(self):
        """Pop complete raw frames off the buffer (no CRC validation --
        faults are applied to whatever bytes the sender produced)."""
        while len(self._buf) >= _FRAME_HEAD:
            length = struct.unpack_from(_FRAME_FMT, self._buf)[5]
            total = _FRAME_HEAD + length
            if len(self._buf) < total:
                return
            frame = bytes(self._buf[:total])
            del self._buf[:total]
            yield frame

    def write(self, data: bytes) -> None:
        if self._reset:
            raise ChaosReset("fault injection: connection reset")
        self._buf.extend(data)
        for frame in self._split_frames():
            i = self._n
            self._n += 1
            if self.plan.reset_after is not None \
                    and i >= self.plan.reset_after:
                self._note("reset", i)
                self._reset = True
                self._w.transport.abort()
                raise ChaosReset("fault injection: connection reset "
                                 f"after {self.plan.reset_after} frames")
            if i in self._delays:
                self._note("delay", i)
                self._delay_s += self._delays[i]
            if i in self.plan.drop_frames or (
                    self.plan.drop_rate
                    and self._rng.random() < self.plan.drop_rate):
                self._note("drop", i)
                continue
            if i in self.plan.corrupt_frames or (
                    self.plan.corrupt_rate
                    and self._rng.random() < self.plan.corrupt_rate):
                self._note("corrupt", i)
                frame = _corrupt(frame)
            self._w.write(frame)
            if i in self.plan.dup_frames:
                self._note("dup", i)
                self._w.write(frame)

    async def drain(self) -> None:
        if self._delay_s:
            delay, self._delay_s = self._delay_s, 0.0
            await asyncio.sleep(delay)
        if self._reset:
            raise ChaosReset("fault injection: connection reset")
        await self._w.drain()

    def close(self) -> None:
        self._w.close()

    async def wait_closed(self) -> None:
        try:
            await self._w.wait_closed()
        except ConnectionError:
            pass


def wrap_writer(writer: asyncio.StreamWriter, role: str,
                plan: FaultPlan | None = None, on_fault=None):
    """The transport's single injection hook: returns the writer
    unchanged unless a plan was passed or ``REPRO_CHAOS`` names ``role``.
    """
    if plan is None:
        plan = FaultPlan.from_env(role)
    if plan is None or plan.is_noop():
        return writer
    return ChaosWriter(writer, plan, on_fault=on_fault)
