"""Session-affine front-end over a pool of CloudServer workers.

The :class:`Dispatcher` owns the edge-facing listening socket (and its
TLS context + HELLO auth, so workers stay plain loopback) and routes
frames to N workers speaking the ordinary frame protocol:

* **Session affinity**: a session's HEADER picks the least-loaded
  healthy worker; every later frame of that session follows it.  Each
  edge connection gets its *own* upstream connection per worker, so
  worker-side session-id namespaces never collide across edges.
* **Health**: a per-worker heartbeat task sends FT_PING probes over a
  control connection; ``hb_misses`` consecutive misses (or a crashed
  subprocess) declare the worker dead, its in-flight sessions get a
  retryable ``WORKER_RESTART`` error (the client's retry path replays
  them onto a surviving worker), and the worker restarts with
  exponential backoff.
* **Admission**: with ``max_queue`` set, new sessions beyond that many
  in flight across the pool are shed with a retryable ``BUSY`` error;
  no healthy worker at all sheds the same way.  With ``shed_depth``
  set, admission additionally tracks the *decode-stage* saturation
  signal: the workers' tick-drain queue depth (the
  ``repro_server_queue_depth_count`` gauge, read live from in-process
  workers and polled over FT_METRICS for subprocesses).  When the
  pool-wide depth reaches ``shed_depth`` new sessions shed BUSY until
  it drains back below ``shed_resume_depth`` (hysteresis, so admission
  does not flap at the threshold).  ``max_queue`` stays as the static
  hard cap on in-flight sessions.
* **Resume**: the edge's HELLO is forwarded to every healthy worker and
  the acks merge, so sessions parked on any worker after an edge
  disconnect revive on reconnect, wherever they live.
* **Drain**: :meth:`drain` stops admitting (``SHUTDOWN`` errors, the
  client treats them as retryable) and waits for in-flight sessions to
  finish before :meth:`close`.

Workers are either **subprocesses** (``worker_cmd``, see
:mod:`repro.transport.worker`; real SIGKILL isolation, used by the
degraded-mode benchmark) or **in-process** CloudServers on loopback
ports (``worker_factory``; fast enough for tier-1 chaos tests).
:meth:`kill_worker` is the chaos hook either way.

Wire bytes through the dispatcher are byte-identical to a direct
connection: frames are re-emitted with the same type/session/seq/payload
and codec payloads are never touched.
"""

from __future__ import annotations

import asyncio
import hmac
import json
import logging
import signal
import subprocess
import time

from ..obs.metrics import MetricsRegistry
from .errors import (E_BUSY, E_PROTOCOL, E_SHUTDOWN, E_UNAUTHORIZED,
                     E_WORKER_RESTART, encode_error)
from .faultinject import FaultPlan, wrap_writer
from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_FEEDBACK, FT_HEADER,
                      FT_HELLO, FT_METRICS, FT_PING, FT_RESULT, FrameReader,
                      FramingError, encode_frame)
from .server import hello_auth

log = logging.getLogger(__name__)

_HELLO_MERGE_TIMEOUT_S = 3.0
_SPAWN_TIMEOUT_S = 60.0


class _Worker:
    __slots__ = ("idx", "port", "healthy", "misses", "restarts", "active",
                 "depth", "proc", "server", "hb_reader", "hb_writer",
                 "hb_frames", "hb_seq")

    def __init__(self, idx: int) -> None:
        self.idx = idx
        self.port: int | None = None
        self.healthy = False
        self.misses = 0
        self.restarts = 0          # lifetime restarts (drives backoff)
        self.active = 0            # sessions currently routed here
        self.depth = 0             # last observed decode-stage queue depth
        self.proc: subprocess.Popen | None = None
        self.server = None         # in-process CloudServer
        self.hb_reader = None
        self.hb_writer = None
        self.hb_frames: FrameReader | None = None
        self.hb_seq = 0


class _EdgeConn:
    """Per edge-connection routing state."""

    __slots__ = ("writer", "wlock", "session_worker", "upstreams", "pumps",
                 "hello_raw", "hello_waiters", "metrics_worker")

    def __init__(self, writer) -> None:
        self.writer = writer
        self.wlock = asyncio.Lock()
        self.session_worker: dict[int, int] = {}   # session id -> worker idx
        self.upstreams: dict[int, asyncio.StreamWriter] = {}
        self.pumps: dict[int, asyncio.Task] = {}
        self.hello_raw: bytes | None = None        # replayed on lazy opens
        self.hello_waiters: dict[int, asyncio.Future] = {}
        self.metrics_worker: int | None = None     # FT_METRICS affinity


class Dispatcher:
    """``async with Dispatcher(workers=4, worker_factory=...) as d: ...``

    Exactly one of ``worker_factory`` (``idx -> CloudServer``, unstarted,
    in-process) or ``worker_cmd`` (argv prefix for
    ``python -m repro.transport.worker``-style subprocesses; ``--host`` /
    ``--port`` are appended) must be given.
    """

    def __init__(self, *, workers: int = 2,
                 worker_factory=None,
                 worker_cmd: list[str] | None = None,
                 host: str = "127.0.0.1", port: int = 0,
                 ssl=None, secret: str | None = None,
                 max_queue: int | None = None,
                 shed_depth: int | None = None,
                 shed_resume_depth: int | None = None,
                 hb_interval_s: float = 0.25,
                 hb_timeout_s: float = 1.0,
                 hb_misses: int = 3,
                 restart_backoff_s: float = 0.05,
                 restart_backoff_max_s: float = 2.0,
                 fault_plan_edge: FaultPlan | None = None,
                 fault_plan_upstream: FaultPlan | None = None,
                 metrics: MetricsRegistry | None = None) -> None:
        if (worker_factory is None) == (worker_cmd is None):
            raise ValueError("need exactly one of worker_factory or "
                             "worker_cmd")
        self._worker_factory = worker_factory
        self._worker_cmd = worker_cmd
        self.host, self.port = host, port
        self.ssl_context = ssl
        self.secret = secret
        self.max_queue = max_queue
        if shed_resume_depth is not None and shed_depth is not None \
                and shed_resume_depth >= shed_depth:
            raise ValueError("shed_resume_depth must be < shed_depth "
                             "(hysteresis band)")
        self.shed_depth = shed_depth
        self.shed_resume_depth = (shed_resume_depth
                                  if shed_resume_depth is not None
                                  else (max(0, shed_depth // 2)
                                        if shed_depth is not None else 0))
        self._shed_latched = False
        self.hb_interval_s = hb_interval_s
        self.hb_timeout_s = hb_timeout_s
        self.hb_misses = hb_misses
        self.restart_backoff_s = restart_backoff_s
        self.restart_backoff_max_s = restart_backoff_max_s
        self._fault_edge = fault_plan_edge
        self._fault_upstream = fault_plan_upstream
        self._workers = [_Worker(i) for i in range(workers)]
        self._server: asyncio.AbstractServer | None = None
        self._monitors: list[asyncio.Task] = []
        self._conns: set[_EdgeConn] = set()
        self._closing = False
        self.draining = False
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_routed = m.counter("repro_dispatcher_routed_sessions_total",
                                   "sessions assigned to a worker")
        self._m_shed = m.counter(
            "repro_dispatcher_shed_sessions_total",
            "sessions answered BUSY/SHUTDOWN at the front-end")
        self._m_failed = m.counter(
            "repro_dispatcher_failed_sessions_total",
            "in-flight sessions failed by a worker death (sent a "
            "retryable WORKER_RESTART error)")
        self._m_restarts = m.counter("repro_dispatcher_worker_restarts_total",
                                     "worker processes/servers restarted")
        self._m_hb_miss = m.counter("repro_dispatcher_heartbeat_misses_total",
                                    "missed worker heartbeats")
        self._m_auth_fail = m.counter(
            "repro_dispatcher_auth_failures_total",
            "edge connections rejected at the HELLO auth check")
        self._m_active = m.gauge("repro_dispatcher_active_sessions_count",
                                 "sessions currently in flight via the pool")
        self._m_healthy = m.gauge("repro_dispatcher_healthy_workers_count",
                                  "workers currently passing heartbeats")
        self._m_depth = m.gauge(
            "repro_dispatcher_pool_queue_depth_count",
            "pool-wide decode-stage queue depth (sum of the workers' "
            "tick-drain backlog; drives the dynamic shed threshold)")
        self._m_shedding = m.gauge(
            "repro_dispatcher_shedding_count",
            "1 while the dynamic shed latch is engaged (depth crossed "
            "shed_depth and has not yet drained to shed_resume_depth)")

    # -- lifecycle -------------------------------------------------------------

    @property
    def active_sessions(self) -> int:
        return sum(w.active for w in self._workers)

    @property
    def healthy_workers(self) -> int:
        return sum(1 for w in self._workers if w.healthy)

    @property
    def pool_queue_depth(self) -> int:
        """Pool-wide decode-stage backlog.  In-process workers are read
        live (``CloudServer.queue_depth``); subprocess workers report the
        value the monitor last polled over FT_METRICS."""
        total = 0
        for w in self._workers:
            if not w.healthy:
                continue
            if w.server is not None:
                try:
                    w.depth = w.server.queue_depth
                except Exception:                   # noqa: BLE001
                    pass                            # mid-restart
            total += w.depth
        return total

    def _depth_shedding(self) -> bool:
        """Dynamic admission: latch BUSY when the decode stage saturates,
        release only once it drains below the resume threshold."""
        if self.shed_depth is None:
            return False
        depth = self.pool_queue_depth
        self._m_depth.set(depth)
        if self._shed_latched:
            if depth <= self.shed_resume_depth:
                self._shed_latched = False
        elif depth >= self.shed_depth:
            self._shed_latched = True
        self._m_shedding.set(1 if self._shed_latched else 0)
        return self._shed_latched

    def _sync_gauges(self) -> None:
        self._m_active.set(self.active_sessions)
        self._m_healthy.set(self.healthy_workers)

    async def start(self) -> "Dispatcher":
        await asyncio.gather(*(self._spawn(w) for w in self._workers))
        self._server = await asyncio.start_server(self._handle_edge,
                                                  self.host, self.port,
                                                  ssl=self.ssl_context)
        self.port = self._server.sockets[0].getsockname()[1]
        self._monitors = [asyncio.ensure_future(self._monitor(w))
                          for w in self._workers]
        log.info("dispatcher on %s:%d%s over %d worker(s): ports %s",
                 self.host, self.port,
                 " (TLS)" if self.ssl_context is not None else "",
                 len(self._workers), [w.port for w in self._workers])
        return self

    async def __aenter__(self) -> "Dispatcher":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def drain(self, timeout_s: float = 10.0) -> bool:
        """Planned shutdown: shed new sessions with SHUTDOWN (retryable),
        wait for in-flight ones.  True when the pool went idle in time."""
        self.draining = True
        deadline = time.monotonic() + timeout_s
        while self.active_sessions and time.monotonic() < deadline:
            await asyncio.sleep(0.02)
        return self.active_sessions == 0

    async def close(self) -> None:
        self._closing = True
        for t in self._monitors:
            t.cancel()
        for t in self._monitors:
            try:
                await t
            except (asyncio.CancelledError, Exception):  # noqa: BLE001
                pass
        self._monitors = []
        for conn in list(self._conns):
            await self._close_upstreams(conn)
        for w in self._workers:
            self._close_hb(w)
            await self._kill(w, graceful=True)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    # -- worker lifecycle ------------------------------------------------------

    async def _spawn(self, w: _Worker) -> None:
        if self._worker_factory is not None:
            w.server = self._worker_factory(w.idx)
            await w.server.start()
            w.port = w.server.port
        else:
            cmd = list(self._worker_cmd) + ["--host", "127.0.0.1",
                                            "--port", "0"]
            w.proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                                      stderr=subprocess.DEVNULL, text=True)
            line = await asyncio.wait_for(
                asyncio.to_thread(w.proc.stdout.readline), _SPAWN_TIMEOUT_S)
            if not line.startswith("PORT "):
                raise RuntimeError(f"worker {w.idx} failed to start "
                                   f"(got {line!r})")
            w.port = int(line.split()[1])
        w.healthy = True
        w.misses = 0
        w.depth = 0
        self._sync_gauges()
        log.info("worker %d up on port %d", w.idx, w.port)

    async def _kill(self, w: _Worker, graceful: bool = False) -> None:
        w.healthy = False
        self._close_hb(w)
        if w.proc is not None:
            try:
                w.proc.send_signal(signal.SIGTERM if graceful
                                   else signal.SIGKILL)
                await asyncio.to_thread(w.proc.wait, 10)
            except Exception:                       # noqa: BLE001
                pass
            w.proc = None
        if w.server is not None:
            if graceful:
                await w.server.close()
            else:
                w.server.abort()
            w.server = None
        self._sync_gauges()

    def kill_worker(self, idx: int) -> None:
        """Chaos hook: hard-kill worker ``idx`` (SIGKILL / abort).  The
        monitor restarts it with backoff; its in-flight sessions fail
        with retryable WORKER_RESTART errors as their pumps collapse."""
        w = self._workers[idx]
        w.healthy = False
        if w.proc is not None:
            try:
                w.proc.kill()
            except Exception:                       # noqa: BLE001
                pass
        if w.server is not None:
            w.server.abort()
            w.server = None
        self._close_hb(w)
        self._sync_gauges()
        log.info("worker %d killed (chaos)", idx)

    async def _monitor(self, w: _Worker) -> None:
        """Heartbeat + restart loop for one worker."""
        while not self._closing:
            try:
                if not w.healthy:
                    await self._respawn(w)
                elif w.proc is not None and w.proc.poll() is not None:
                    # subprocess died without us killing it
                    log.warning("worker %d exited (code %s)", w.idx,
                                w.proc.returncode)
                    await self._kill(w)
                else:
                    if await self._ping(w):
                        w.misses = 0
                        if w.server is None and self.shed_depth is not None:
                            await self._probe_depth(w)
                    else:
                        w.misses += 1
                        self._m_hb_miss.inc()
                        if w.misses >= self.hb_misses:
                            log.warning("worker %d missed %d heartbeats",
                                        w.idx, w.misses)
                            await self._kill(w)
            except asyncio.CancelledError:
                raise
            except Exception as e:                  # noqa: BLE001
                log.error("worker %d monitor error: %s", w.idx, e)
            await asyncio.sleep(self.hb_interval_s)

    async def _respawn(self, w: _Worker) -> None:
        backoff = min(self.restart_backoff_s * (2.0 ** min(w.restarts, 8)),
                      self.restart_backoff_max_s)
        w.restarts += 1
        await asyncio.sleep(backoff)
        if self._closing:
            return
        await self._kill(w)          # reap any half-dead remnant
        await self._spawn(w)
        self._m_restarts.inc()
        log.info("worker %d restarted (attempt %d, backoff %.3fs)",
                 w.idx, w.restarts, backoff)

    def _close_hb(self, w: _Worker) -> None:
        if w.hb_writer is not None:
            try:
                w.hb_writer.close()
            except Exception:                       # noqa: BLE001
                pass
        w.hb_reader = w.hb_writer = w.hb_frames = None

    async def _ping(self, w: _Worker) -> bool:
        try:
            if w.hb_writer is None:
                w.hb_reader, w.hb_writer = await asyncio.wait_for(
                    asyncio.open_connection("127.0.0.1", w.port),
                    self.hb_timeout_s)
                w.hb_frames = FrameReader()
            w.hb_seq += 1
            w.hb_writer.write(encode_frame(FT_PING, 0, w.hb_seq, b""))
            await w.hb_writer.drain()

            async def pong():
                while True:
                    data = await w.hb_reader.read(1 << 12)
                    if not data:
                        raise ConnectionError("worker closed control conn")
                    w.hb_frames.feed(data)
                    for f in w.hb_frames:
                        if f.ftype == FT_PING:
                            return True

            return await asyncio.wait_for(pong(), self.hb_timeout_s)
        except (OSError, asyncio.TimeoutError, FramingError,
                ConnectionError):
            self._close_hb(w)
            return False

    async def _probe_depth(self, w: _Worker) -> None:
        """Poll a subprocess worker's decode-stage queue depth over the
        control connection (in-band FT_METRICS snapshot; in-process
        workers are read directly and never need this).  A failed probe
        just keeps the previous sample -- health is the ping's job."""
        try:
            w.hb_writer.write(encode_frame(FT_METRICS, 0, 0, b""))
            await w.hb_writer.drain()

            async def snap():
                while True:
                    data = await w.hb_reader.read(1 << 16)
                    if not data:
                        raise ConnectionError("worker closed control conn")
                    w.hb_frames.feed(data)
                    for f in w.hb_frames:
                        if f.ftype == FT_METRICS:
                            return json.loads(f.payload.decode())

            payload = await asyncio.wait_for(snap(), self.hb_timeout_s)
            w.depth = int(payload.get("counters", {}).get("queue_depth", 0))
        except (OSError, asyncio.TimeoutError, FramingError,
                ConnectionError, ValueError):
            pass

    # -- edge connections ------------------------------------------------------

    async def _handle_edge(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        writer = wrap_writer(writer, "edge", self._fault_edge)
        conn = _EdgeConn(writer)
        self._conns.add(conn)
        frames = FrameReader()
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                frames.feed(data)
                for frame in frames:
                    await self._route(frame, conn)
        except (FramingError, ValueError) as e:
            await self._edge_error(conn, 0, E_PROTOCOL, str(e),
                                   retryable=False)
        except ConnectionError:
            pass
        finally:
            self._conns.discard(conn)
            await self._close_upstreams(conn)
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass

    async def _close_upstreams(self, conn: _EdgeConn) -> None:
        for t in conn.pumps.values():
            t.cancel()
        for t in list(conn.pumps.values()):
            try:
                await t
            except (asyncio.CancelledError, Exception):  # noqa: BLE001
                pass
        conn.pumps.clear()
        for up in conn.upstreams.values():
            up.close()
        conn.upstreams.clear()
        for sid in list(conn.session_worker):
            self._unmap(conn, sid)

    def _unmap(self, conn: _EdgeConn, sid: int) -> int | None:
        widx = conn.session_worker.pop(sid, None)
        if widx is not None:
            w = self._workers[widx]
            w.active = max(0, w.active - 1)
            self._sync_gauges()
        return widx

    async def _edge_error(self, conn: _EdgeConn, session: int, code: int,
                          msg: str, retryable: bool) -> None:
        try:
            async with conn.wlock:
                conn.writer.write(encode_frame(
                    FT_ERROR, session, 0,
                    encode_error(code, msg, retryable=retryable)))
                await conn.writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    # -- routing ---------------------------------------------------------------

    async def _route(self, frame, conn: _EdgeConn) -> None:
        if frame.ftype == FT_PING:
            async with conn.wlock:
                conn.writer.write(encode_frame(FT_PING, frame.session,
                                               frame.seq, frame.payload))
                await conn.writer.drain()
        elif frame.ftype == FT_HELLO:
            await self._on_hello(frame, conn)
        elif frame.ftype in (FT_HEADER, FT_CHUNK, FT_END):
            await self._route_tensor(frame, conn)
        elif frame.ftype == FT_METRICS:
            # telemetry affinity: all snapshots of one edge conn come
            # from the same worker, so counters are comparable
            widx = conn.metrics_worker
            if widx is None or not self._workers[widx].healthy:
                widx = next((w.idx for w in self._workers if w.healthy),
                            None)
                conn.metrics_worker = widx
            if widx is None:
                await self._edge_error(conn, frame.session, E_BUSY,
                                       "no healthy worker", retryable=True)
                return
            await self._forward(frame, conn, widx, sid=None)
        else:
            raise FramingError(f"unexpected frame type {frame.ftype} "
                               "from edge")

    def _pick_worker(self) -> _Worker | None:
        live = [w for w in self._workers if w.healthy]
        if not live:
            return None
        return min(live, key=lambda w: (w.active, w.idx))

    async def _route_tensor(self, frame, conn: _EdgeConn) -> None:
        sid = frame.session
        widx = conn.session_worker.get(sid)
        if widx is None:
            if frame.ftype != FT_HEADER:
                # frames of a session we failed over: the client already
                # got its WORKER_RESTART error, drop the stragglers
                return
            if self.draining:
                self._m_shed.inc()
                await self._edge_error(conn, sid, E_SHUTDOWN,
                                       "dispatcher draining",
                                       retryable=True)
                return
            if self.max_queue is not None \
                    and self.active_sessions >= self.max_queue:
                self._m_shed.inc()
                await self._edge_error(
                    conn, sid, E_BUSY,
                    f"pool saturated ({self.active_sessions} >= "
                    f"max_queue={self.max_queue})", retryable=True)
                return
            if self._depth_shedding():
                self._m_shed.inc()
                await self._edge_error(
                    conn, sid, E_BUSY,
                    f"decode stage saturated (pool queue depth "
                    f"{int(self._m_depth.value())} >= "
                    f"shed_depth={self.shed_depth}; admitting again "
                    f"at <= {self.shed_resume_depth})", retryable=True)
                return
            w = self._pick_worker()
            if w is None:
                self._m_shed.inc()
                await self._edge_error(conn, sid, E_BUSY,
                                       "no healthy worker",
                                       retryable=True)
                return
            widx = w.idx
            conn.session_worker[sid] = widx
            w.active += 1
            self._m_routed.inc()
            self._sync_gauges()
        await self._forward(frame, conn, widx, sid=sid)

    async def _forward(self, frame, conn: _EdgeConn, widx: int,
                       sid: int | None) -> None:
        try:
            up = await self._upstream(conn, self._workers[widx])
            up.write(encode_frame(frame.ftype, frame.session, frame.seq,
                                  frame.payload))
            await up.drain()
        except (OSError, ConnectionError) as e:
            log.warning("forward to worker %d failed: %s", widx, e)
            self._drop_upstream(conn, widx)
            if sid is not None:
                self._unmap(conn, sid)
                self._m_failed.inc()
                await self._edge_error(
                    conn, sid, E_WORKER_RESTART,
                    f"worker {widx} unavailable mid-session",
                    retryable=True)

    async def _upstream(self, conn: _EdgeConn,
                        w: _Worker) -> asyncio.StreamWriter:
        up = conn.upstreams.get(w.idx)
        if up is not None:
            return up
        reader, up = await asyncio.open_connection("127.0.0.1", w.port)
        up = wrap_writer(up, "upstream", self._fault_upstream)
        conn.upstreams[w.idx] = up
        conn.pumps[w.idx] = asyncio.ensure_future(
            self._pump(conn, w.idx, reader))
        if conn.hello_raw is not None:
            # late-opened upstream: replay the edge's HELLO so this
            # worker sees the same resume token (its ack is swallowed by
            # the pump unless a merge is waiting)
            up.write(encode_frame(FT_HELLO, 0, 0, conn.hello_raw))
            await up.drain()
        return up

    def _drop_upstream(self, conn: _EdgeConn, widx: int) -> None:
        pump = conn.pumps.pop(widx, None)
        if pump is not None:
            pump.cancel()
        up = conn.upstreams.pop(widx, None)
        if up is not None:
            up.close()

    async def _pump(self, conn: _EdgeConn, widx: int,
                    up_reader: asyncio.StreamReader) -> None:
        """worker -> edge relay for one (edge conn, worker) pair."""
        frames = FrameReader()
        try:
            while True:
                data = await up_reader.read(1 << 16)
                if not data:
                    raise ConnectionError(f"worker {widx} closed")
                frames.feed(data)
                for f in frames:
                    if f.ftype == FT_HELLO:
                        fut = conn.hello_waiters.pop(widx, None)
                        if fut is not None and not fut.done():
                            fut.set_result(json.loads(f.payload.decode()))
                        continue
                    if f.ftype == FT_PING:
                        continue
                    if f.ftype in (FT_RESULT, FT_ERROR):
                        self._unmap(conn, f.session)
                    async with conn.wlock:
                        conn.writer.write(encode_frame(
                            f.ftype, f.session, f.seq, f.payload))
                        await conn.writer.drain()
        except asyncio.CancelledError:
            raise
        except (ConnectionError, OSError, FramingError) as e:
            # worker side died: fail this edge conn's sessions routed
            # there with a retryable error so clients replay elsewhere
            fut = conn.hello_waiters.pop(widx, None)
            if fut is not None and not fut.done():
                fut.set_exception(ConnectionError(str(e)))
            orphans = [sid for sid, wi in conn.session_worker.items()
                       if wi == widx]
            conn.pumps.pop(widx, None)
            up = conn.upstreams.pop(widx, None)
            if up is not None:
                up.close()
            for sid in orphans:
                self._unmap(conn, sid)
                self._m_failed.inc()
                await self._edge_error(
                    conn, sid, E_WORKER_RESTART,
                    f"worker {widx} died mid-session", retryable=True)

    # -- HELLO: front-end auth + fan-out resume --------------------------------

    async def _on_hello(self, frame, conn: _EdgeConn) -> None:
        try:
            hello = json.loads(frame.payload.decode())
            token = str(hello.get("token", ""))
        except (ValueError, UnicodeDecodeError):
            token, hello = "", None
        if self.secret is not None:
            proof = str(hello.get("auth", "")) if hello else ""
            if not token or not hmac.compare_digest(
                    proof, hello_auth(self.secret, token)):
                self._m_auth_fail.inc()
                await self._edge_error(conn, frame.session, E_UNAUTHORIZED,
                                       "HELLO auth rejected",
                                       retryable=False)
                raise ConnectionError("unauthorized edge")
        conn.hello_raw = frame.payload
        waiters: list[tuple[int, asyncio.Future]] = []
        loop = asyncio.get_running_loop()
        for w in self._workers:
            if not w.healthy:
                continue
            fut = loop.create_future()
            conn.hello_waiters[w.idx] = fut
            try:
                if w.idx in conn.upstreams:
                    up = conn.upstreams[w.idx]
                    up.write(encode_frame(FT_HELLO, 0, 0, frame.payload))
                    await up.drain()
                else:
                    await self._upstream(conn, w)   # sends hello_raw
            except (OSError, ConnectionError):
                conn.hello_waiters.pop(w.idx, None)
                continue
            waiters.append((w.idx, fut))
        resumed: set[int] = set()
        acked: dict[str, list[int]] = {}
        if waiters:
            await asyncio.wait([f for _, f in waiters],
                               timeout=_HELLO_MERGE_TIMEOUT_S)
            for widx, fut in waiters:
                if not fut.done() or fut.cancelled() \
                        or fut.exception() is not None:
                    continue
                ack = fut.result()
                for sid in ack.get("resumed", []):
                    resumed.add(sid)
                    # affinity: replayed frames of a revived session must
                    # land on the worker holding its parked state
                    if sid not in conn.session_worker:
                        conn.session_worker[sid] = widx
                        self._workers[widx].active += 1
                for sid, seqs in ack.get("acked", {}).items():
                    acked.setdefault(sid, []).extend(seqs)
            self._sync_gauges()
        reply = json.dumps({"ok": True, "resumed": sorted(resumed),
                            "acked": acked}).encode()
        async with conn.wlock:
            conn.writer.write(encode_frame(FT_HELLO, frame.session,
                                           frame.seq, reply))
            await conn.writer.drain()
