"""Cloud-side streaming server (asyncio).

Accepts one or more edge connections, demultiplexes interleaved tensor
sessions, and reconstructs split-layer tensors for the cloud half
(``tail_fn``).  The result arrays go back in a RESULT frame; a FEEDBACK
frame carries receiver-measured link throughput and queue depth for the
edge-side rate controller.

Two receive disciplines:

* **tick mode** (default, ``tick=TickConfig()``): arriving chunk frames
  only accumulate (deferred-mode :class:`TensorAssembler`); a bounded
  tick window (``max_wait_s`` / ``max_chunks``) then drains every
  pending chunk of every session -- across connections -- through ONE
  batched entropy call (:class:`~repro.serving.batcher.DecodeBatcher`),
  and completed tensors finish + run ``tail_fn`` together.  Stream
  headers are parsed once per distinct (shape, rung) via a shared
  :class:`~repro.core.codec.HeaderCache`.  Per-tick metrics land in
  :attr:`counters`.
* **per-session mode** (``tick=None``): the original path -- chunks
  entropy-decode on arrival so decode overlaps the transfer (what
  ``bench_overlap`` measures), one entropy call per session stream.

Hardening (see DESIGN.md, "Hardened scale-out serving"):

* **Admission control**: with ``max_queue`` set, a new HEADER arriving
  while ``max_queue`` sessions are already in flight is answered with a
  structured retryable BUSY error instead of accepted work the server
  would time out on; a draining server sheds with SHUTDOWN the same way.
* **Resumable sessions**: a connection that presented a resume token in
  its HELLO gets its in-flight sessions *parked* (not forgotten) on
  disconnect; a reconnect with the same token revives them, the HELLO
  ack reports the per-session frame seqs already received, and replayed
  frames dedup by seq -- so a mid-stream reconnect finishes bit-exactly.
* **Authentication / TLS**: ``secret`` requires an HMAC-authenticated
  HELLO before the first tensor frame; ``ssl`` wraps the listener.
* **Fault injection**: the per-connection writer routes through
  :func:`~repro.transport.faultinject.wrap_writer` (role ``server``).

Backpressure is the transport's: frames are processed in arrival order
per connection and the server only reads more bytes once the previous
batch is handled, so a slow cloud propagates to TCP flow control and
ultimately to the edge's bounded send path.

Decode and tail computation run via ``asyncio.to_thread`` so heartbeats
and other connections stay responsive while numpy/jax work runs.
"""

from __future__ import annotations

import asyncio
import hashlib
import hmac
import json
import logging
import time
from typing import Callable

import numpy as np

from ..core.codec import HeaderCache
from ..obs.exposition import MetricsExposition
from ..obs.metrics import MetricsRegistry, default_registry
from ..obs.tracing import span
from ..serving.batcher import DecodeBatcher, TickConfig
from .errors import (E_BUSY, E_SHUTDOWN, E_UNAUTHORIZED, encode_error,
                     error_for_exception)
from .faultinject import FaultPlan, wrap_writer
from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_HEADER, FT_HELLO,
                      FT_METRICS, FT_PING, FT_RESULT, FrameReader,
                      FramingError, encode_frame, pack_arrays)
from .stream_codec import Feedback, TensorAssembler

log = logging.getLogger(__name__)

_DEFAULT_TICK = TickConfig()


def hello_auth(secret: str, token: str) -> str:
    """The HELLO auth proof: HMAC-SHA256 of the resume token under the
    shared secret (both sides compute it; with TLS on top the token is
    never observable to a third party either)."""
    return hmac.new(secret.encode(), token.encode(),
                    hashlib.sha256).hexdigest()


class _Session:
    __slots__ = ("assembler", "t_first", "decode_s", "seq", "obs_key",
                 "seen_seqs")

    def __init__(self, assembler: TensorAssembler,
                 obs_key: str = "") -> None:
        self.assembler = assembler
        self.t_first = time.perf_counter()
        self.decode_s = 0.0
        self.seq = 0
        self.obs_key = obs_key      # per-session metrics label value
        self.seen_seqs: set[int] = set()   # replay/duplicate dedup

    def touch(self) -> None:
        """Reset the latency clock on resume so feedback stats describe
        the live connection, not the outage."""
        self.t_first = time.perf_counter()


class _ConnState:
    """Per-connection mutable state (sessions, auth, shed set)."""

    __slots__ = ("writer", "conn_id", "sessions", "shed", "token", "authed")

    def __init__(self, writer, conn_id: int) -> None:
        self.writer = writer
        self.conn_id = conn_id
        self.sessions: dict[int, _Session] = {}
        self.shed: set[int] = set()     # session ids answered BUSY/SHUTDOWN
        self.token: str | None = None   # resume token from HELLO
        self.authed = False


class _Unauthorized(Exception):
    pass


class CloudServer:
    """``async with CloudServer(tail_fn=...) as srv: await srv.wait_closed()``

    ``tail_fn``: reconstruction -> ndarray (or list of ndarrays), the
    cloud half of the split network.  None echoes nothing back beyond
    what ``echo_features`` selects.
    ``echo_features``: prepend the reconstructed split-layer tensor to
    the RESULT arrays (used by the demo/tests for the bit-exactness
    check and by the loopback serving transport).
    ``tick``: cross-session batching bounds; ``None`` selects the
    per-session decode-on-arrival path.
    ``header_cache``: share a :class:`HeaderCache` across servers of one
    worker (a fresh one is made per server otherwise).
    ``max_queue``: admission bound -- new sessions beyond this many in
    flight are shed with a retryable BUSY error (None = accept all).
    ``secret``: require an HMAC-authenticated HELLO before the first
    tensor frame (see :func:`hello_auth`).
    ``ssl``: an ``ssl.SSLContext`` for the listener (TLS on the frame
    protocol; loopback worker pools skip it, edge-facing fronts use it).
    ``resume_ttl_s``: how long a disconnected connection's sessions stay
    parked awaiting a resume before being dropped.
    ``fault_plan``: explicit chaos plan for per-connection writers
    (tests); the ``REPRO_CHAOS`` env var reaches the same seam.
    ``metrics``: the :class:`MetricsRegistry` this server's
    ``repro_server_*`` / ``repro_decode_*`` instruments register in
    (fresh per server by default, so co-hosted servers and tests never
    share series).
    ``metrics_port``: when not None, :meth:`start` also serves a
    Prometheus-text ``GET /metrics`` endpoint (plus the tracer's JSON
    span log at ``/events``) on this port (0 = pick a free one; the
    bound port lands back in ``metrics_port``).
    """

    def __init__(self, *, tail_fn: Callable | None = None,
                 echo_features: bool = False, host: str = "127.0.0.1",
                 port: int = 0, backend=None,
                 tick: TickConfig | None = _DEFAULT_TICK,
                 header_cache: HeaderCache | None = None,
                 max_queue: int | None = None,
                 secret: str | None = None,
                 ssl=None,
                 resume_ttl_s: float = 30.0,
                 fault_plan: FaultPlan | None = None,
                 metrics: MetricsRegistry | None = None,
                 metrics_port: int | None = None) -> None:
        self.tail_fn = tail_fn
        self.echo_features = echo_features
        self.host = host
        self.port = port
        self._backend = backend
        self._server: asyncio.AbstractServer | None = None
        self.sessions_served = 0
        self.open_connections = 0
        self.tick = tick
        self.max_queue = max_queue
        self.secret = secret
        self.ssl_context = ssl
        self.resume_ttl_s = resume_ttl_s
        self._fault_plan = fault_plan
        self.draining = False
        self._idle = asyncio.Event()        # set whenever no work in flight
        self._idle.set()
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._batcher = DecodeBatcher(metrics=self.metrics)
        self._header_cache = (header_cache if header_cache is not None
                              else HeaderCache())
        # tensors whose END arrived, awaiting the tick drain:
        # (sess, session_id, writer, sessions-dict of their connection)
        self._ready: list[tuple] = []
        self._drain_lock = asyncio.Lock()
        self._drain_timer: asyncio.TimerHandle | None = None
        # decoder id -> (sessions-dict, session_id, writer): lets a drain
        # failure evict + notify exactly the offending session
        self._dec_owner: dict[int, tuple] = {}
        # resume token -> {"sessions": {sid: _Session}, "ready":
        # [(sess, sid)], "handle": expiry TimerHandle}
        self._parked: dict[str, dict] = {}
        self._inflight_sessions = 0
        self._conn_seq = 0
        self._conn_writers: set = set()
        self._aborted = False
        self.metrics_port = metrics_port
        self.metrics_exposition: MetricsExposition | None = None
        m = self.metrics
        self._m_sessions = m.counter("repro_server_sessions_served_total",
                                     "tensors fully served (tail + RESULT)")
        self._m_conns = m.gauge("repro_server_open_connections_count",
                                "currently connected edge clients")
        self._m_ticks = m.counter("repro_server_ticks_total",
                                  "cross-session tick drains")
        self._m_tick_sessions = m.counter(
            "repro_server_tick_sessions_total",
            "completed sessions summed over tick drains (occupancy "
            "numerator)")
        self._m_coded = m.counter("repro_server_coded_bytes_total",
                                  "entropy-coded payload bytes received")
        self._m_elems = m.counter("repro_server_decoded_elements_total",
                                  "tensor elements reconstructed")
        self._m_errors = m.counter(
            "repro_server_decode_errors_total",
            "sessions failed in decode/tail (or protocol errors)")
        self._m_queue = m.gauge(
            "repro_server_queue_depth_count",
            "sessions with pending work (undrained chunks + awaiting "
            "tail)")
        self._m_pending = m.gauge(
            "repro_server_session_pending_chunks_count",
            "entropy-undecoded chunks per in-flight session",
            labelnames=("session",))
        self._m_bpe = m.gauge(
            "repro_server_measured_bpe",
            "running wire bits/element over served tensors")
        self._m_hc_hits = m.gauge("repro_server_header_cache_hits_count",
                                  "header-cache hits")
        self._m_hc_misses = m.gauge(
            "repro_server_header_cache_misses_count",
            "header-cache misses (fresh header parses)")
        self._m_hc_entries = m.gauge(
            "repro_server_header_cache_entries_count",
            "distinct parsed headers cached")
        self._m_shed = m.counter(
            "repro_server_shed_sessions_total",
            "new sessions answered BUSY/SHUTDOWN by admission control")
        self._m_dups = m.counter(
            "repro_server_duplicate_frames_total",
            "replayed/duplicated frames dropped by per-session seq dedup")
        self._m_resumed = m.counter(
            "repro_server_resumed_sessions_total",
            "parked sessions revived by a resume HELLO")
        self._m_parked = m.gauge(
            "repro_server_parked_sessions_count",
            "sessions parked awaiting a resume reconnect")
        self._m_auth_fail = m.counter(
            "repro_server_auth_failures_total",
            "connections rejected at the HELLO auth check")

    def _sync_gauges(self) -> None:
        """Pull-style sources -> gauges (run per scrape / counters read)."""
        self._m_conns.set(self.open_connections)
        self._m_queue.set(self.queue_depth)
        self._m_parked.set(sum(
            len(p["sessions"]) + len(p["ready"])
            for p in self._parked.values()))
        hc = self._header_cache.stats
        self._m_hc_hits.set(hc["hits"])
        self._m_hc_misses.set(hc["misses"])
        self._m_hc_entries.set(hc["entries"])
        coded, elems = self._m_coded.value(), self._m_elems.value()
        self._m_bpe.set(8.0 * coded / max(elems, 1))

    async def start(self) -> "CloudServer":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port,
                                                  ssl=self.ssl_context)
        self.port = self._server.sockets[0].getsockname()[1]
        log.info("cloud server listening on %s:%d%s", self.host, self.port,
                 " (TLS)" if self.ssl_context is not None else "")
        if self.metrics_port is not None:
            # the scrape sees this server's registry plus the worker-wide
            # default one (stage-latency histogram, bank cache)
            self.metrics_exposition = await MetricsExposition(
                [self.metrics, default_registry()],
                collectors=[self._sync_gauges], host=self.host,
                port=self.metrics_port).start()
            self.metrics_port = self.metrics_exposition.port
            log.info("metrics endpoint on %s:%d/metrics", self.host,
                     self.metrics_port)
        return self

    async def __aenter__(self) -> "CloudServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        if self._drain_timer is not None:
            self._drain_timer.cancel()
            self._drain_timer = None
        for token in list(self._parked):
            self._expire_parked(token)
        if self.metrics_exposition is not None:
            await self.metrics_exposition.close()
            self.metrics_exposition = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None
        for w in list(self._conn_writers):
            try:
                w.close()
            except Exception:                       # noqa: BLE001
                pass
        for _ in range(50):          # let handler tasks unwind before the
            if not self._conn_writers:      # caller tears down the loop
                break
            await asyncio.sleep(0.01)

    async def wait_closed(self) -> None:
        if self._server is not None:
            await self._server.serve_forever()

    def abort(self) -> None:
        """Hard-kill (chaos): drop every live connection and the
        listener with no ceremony -- the in-process equivalent of
        SIGKILLing a worker.  Parked sessions and timers die with it.
        Connections accepted by the OS whose handler task has not run
        yet are covered by the tombstone: ``_handle`` aborts them on
        entry, so nothing is served after the kill."""
        self._aborted = True
        for w in list(self._conn_writers):
            try:
                w.transport.abort()
            except Exception:                       # noqa: BLE001
                pass
        if self._drain_timer is not None:
            self._drain_timer.cancel()
            self._drain_timer = None
        for token in list(self._parked):
            self._expire_parked(token)
        if self.metrics_exposition is not None:
            exp, self.metrics_exposition = self.metrics_exposition, None
            try:
                loop = asyncio.get_running_loop()
                loop.create_task(exp.close())
            except RuntimeError:
                pass
        if self._server is not None:
            self._server.close()
            self._server = None

    # -- graceful drain --------------------------------------------------------

    @property
    def load(self) -> int:
        """Sessions with unfinished work: streaming, awaiting the tick
        drain, or parked for resume (the admission-control signal)."""
        return self._inflight_sessions

    @property
    def queue_depth(self) -> int:
        """Decode-stage backlog right now: sessions parked in the batcher
        plus drained-but-unfinished ones.  This is the tick-drain depth
        exported as ``repro_server_queue_depth_count`` -- the saturation
        signal a front-end (``transport.dispatcher``) polls to shed
        dynamically."""
        return self._batcher.pending_sessions + len(self._ready)

    async def drain(self, timeout_s: float = 10.0) -> bool:
        """Planned shutdown, phase 1: stop admitting new sessions (they
        get a retryable SHUTDOWN error) and wait for in-flight ones to
        finish.  Returns True when the server went idle inside the
        timeout.  Call :meth:`close` afterwards either way."""
        self.draining = True
        if self._inflight_sessions == 0:
            return True
        self._idle.clear()
        try:
            await asyncio.wait_for(self._idle.wait(), timeout_s)
            return True
        except asyncio.TimeoutError:
            return False

    def _session_opened(self) -> None:
        self._inflight_sessions += 1
        self._idle.clear()

    def _session_closed(self) -> None:
        self._inflight_sessions = max(0, self._inflight_sessions - 1)
        if self._inflight_sessions == 0:
            self._idle.set()

    @property
    def counters(self) -> dict:
        """Legacy dict view of the ``repro_server_*`` / ``repro_decode_*``
        registry instruments (the tick=None shape is pinned by
        tests/test_batcher.py; registry-only telemetry such as
        decode-error counts on the legacy path lives in
        :attr:`metrics`)."""
        self._sync_gauges()
        c = {"sessions_served": self.sessions_served,
             "open_connections": self.open_connections}
        if self.tick is None:
            return c
        b = self._batcher.counters
        ticks = int(self._m_ticks.value())
        c.update(
            ticks=ticks,
            batch_occupancy_avg=(self._m_tick_sessions.value()
                                 / max(ticks, 1)),
            queue_depth=int(self._m_queue.value()),
            entropy_calls=b["entropy_calls"],
            entropy_chunks=b["chunks"],
            entropy_melem_per_s=(b["elems"] / b["entropy_s"] / 1e6
                                 if b["entropy_s"] > 0 else 0.0),
            bpe_avg=self._m_bpe.value(),
            decode_errors=int(self._m_errors.value()),
            header_cache=self._header_cache.stats,
            shed_sessions=int(self._m_shed.value()),
            resumed_sessions=int(self._m_resumed.value()),
            duplicate_frames=int(self._m_dups.value()),
        )
        return c

    # -- connection handling --------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        if self._aborted:
            # connection accepted before abort() but handled after: a
            # SIGKILL'd worker would never have served it, so don't
            writer.transport.abort()
            return
        peer = writer.get_extra_info("peername")
        log.info("edge connected: %s", peer)
        self.open_connections += 1
        self._conn_seq += 1
        writer = wrap_writer(writer, "server", self._fault_plan)
        self._conn_writers.add(writer)
        conn = _ConnState(writer, self._conn_seq)
        frames = FrameReader()
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                frames.feed(data)
                for frame in frames:
                    if frame.ftype == FT_PING:
                        writer.write(encode_frame(FT_PING, frame.session,
                                                  frame.seq, frame.payload))
                        await writer.drain()
                    elif frame.ftype == FT_HELLO:
                        await self._on_hello(frame, conn)
                    elif frame.ftype in (FT_HEADER, FT_CHUNK, FT_END):
                        if self.secret is not None and not conn.authed:
                            raise _Unauthorized(
                                "tensor frame before authenticated HELLO")
                        await self._on_tensor_frame(frame, conn)
                    elif frame.ftype == FT_METRICS:
                        await self._send_metrics(writer, frame.session)
                    else:
                        raise FramingError(
                            f"unexpected frame type {frame.ftype} from edge")
        except _Unauthorized as e:
            self._m_auth_fail.inc()
            log.warning("unauthorized connection from %s: %s", peer, e)
            await self._send_error(writer, 0, e, code=E_UNAUTHORIZED,
                                   retryable=False)
            await self._linger(reader)
        except (FramingError, ValueError) as e:
            self._m_errors.inc()
            log.error("protocol error from %s: %s", peer, e)
            await self._send_error(writer, 0, e)
            await self._linger(reader)
        except ConnectionError:
            pass
        finally:
            self.open_connections -= 1
            self._conn_writers.discard(writer)
            if conn.token is not None and (conn.sessions or any(
                    e[2] is writer for e in self._ready)):
                self._park_connection(conn)
            else:
                self._forget_connection(conn.sessions, writer)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, RuntimeError):
                pass    # loop already torn down during process shutdown
            log.info("edge disconnected: %s", peer)

    @staticmethod
    async def _linger(reader: asyncio.StreamReader,
                      timeout_s: float = 1.0) -> None:
        """After a terminal error frame, keep draining (and discarding)
        inbound bytes briefly instead of closing at once -- closing while
        the peer is still mid-write triggers a TCP RST that can flush the
        error frame out of the peer's receive buffer before it reads it.
        """
        async def drain() -> None:
            while await reader.read(1 << 16):
                pass

        try:
            await asyncio.wait_for(drain(), timeout_s)
        except (asyncio.TimeoutError, ConnectionError):
            pass

    # -- HELLO: auth + resume --------------------------------------------------

    async def _on_hello(self, frame, conn: _ConnState) -> None:
        try:
            hello = json.loads(frame.payload.decode())
            token = str(hello.get("token", ""))
        except (ValueError, UnicodeDecodeError) as e:
            raise _Unauthorized(f"malformed HELLO: {e}") from e
        if self.secret is not None:
            proof = str(hello.get("auth", ""))
            if not token or not hmac.compare_digest(
                    proof, hello_auth(self.secret, token)):
                raise _Unauthorized("HELLO auth rejected")
        conn.authed = True
        conn.token = token or None
        resumed: list[int] = []
        acked: dict[str, list[int]] = {}
        parked = self._parked.pop(token, None) if token else None
        if parked is not None:
            parked["handle"].cancel()
            for sid, sess in parked["sessions"].items():
                conn.sessions[sid] = sess
                sess.touch()
                resumed.append(sid)
                acked[str(sid)] = sorted(sess.seen_seqs)
                dec = sess.assembler.decoder
                if dec is not None:
                    self._dec_owner[id(dec)] = (conn.sessions, sid,
                                                conn.writer)
            for sess, sid in parked["ready"]:
                self._ready.append((sess, sid, conn.writer, conn.sessions))
                resumed.append(sid)
                acked[str(sid)] = sorted(sess.seen_seqs)
            self._m_resumed.inc(len(resumed))
            log.info("resumed %d parked session(s) for token %s...",
                     len(resumed), token[:8])
        ack = json.dumps({"ok": True, "resumed": sorted(resumed),
                          "acked": acked}).encode()
        try:
            conn.writer.write(encode_frame(FT_HELLO, frame.session,
                                           frame.seq, ack))
            await conn.writer.drain()
        except (ConnectionError, RuntimeError):
            return
        # a revived complete session may be the only pending work: make
        # sure a tick drain is scheduled even if every replayed frame
        # dedups away
        if self.tick is not None and parked is not None and self._ready:
            self._arm_drain_timer()

    # -- admission -------------------------------------------------------------

    async def _admit(self, frame, conn: _ConnState) -> bool:
        """Admission check for a new session's HEADER.  False = shed
        (a structured retryable error was sent)."""
        if self.draining:
            code, msg = E_SHUTDOWN, "server draining, not accepting sessions"
        elif self.max_queue is not None and self.load >= self.max_queue:
            code, msg = E_BUSY, (f"queue full ({self.load} sessions in "
                                 f"flight >= max_queue={self.max_queue})")
        else:
            return True
        conn.shed.add(frame.session)
        self._m_shed.inc()
        await self._send_error(conn.writer, frame.session, msg, code=code,
                               retryable=True)
        return False

    def _dedup(self, frame, sess: _Session) -> bool:
        """True when this frame was already processed (replay after a
        resume, or a fault-injected duplicate)."""
        if frame.seq in sess.seen_seqs:
            self._m_dups.inc()
            return True
        return False

    async def _on_tensor_frame(self, frame, conn: _ConnState) -> None:
        if frame.session in conn.shed:
            if frame.ftype == FT_END:
                conn.shed.discard(frame.session)   # stream over, forget it
            return
        if self.tick is None:
            await self._on_tensor_frame_immediate(frame, conn)
            return
        sessions, writer = conn.sessions, conn.writer
        sess = sessions.get(frame.session)
        if sess is None:
            if frame.ftype == FT_HEADER and not await self._admit(frame,
                                                                  conn):
                return
            sess = sessions[frame.session] = _Session(
                TensorAssembler(backend=self._backend, defer=True,
                                header_cache=self._header_cache),
                obs_key=f"{conn.conn_id}:{frame.session}")
            self._session_opened()
        if self._dedup(frame, sess):
            return
        t0 = time.perf_counter()
        # deferred mode: no entropy work here, just buffering -- cheap
        # enough to run on-loop
        sess.assembler.feed(frame)
        sess.seen_seqs.add(frame.seq)
        sess.decode_s += time.perf_counter() - t0
        dec = sess.assembler.decoder
        if dec is not None:
            self._batcher.note(dec)
            self._m_pending.set(dec.pending_chunks, session=sess.obs_key)
            if id(dec) not in self._dec_owner:
                self._dec_owner[id(dec)] = (sessions, frame.session, writer)
        if sess.assembler.ready:
            del sessions[frame.session]
            self._ready.append((sess, frame.session, writer, sessions))
        if (len(self._ready) >= self.tick.max_batch
                or self._batcher.pending_chunks >= self.tick.max_chunks
                # a session is complete and no entropy work is queued:
                # nothing could batch with it, so waiting out the tick
                # window would be pure latency (hit after a max_chunks
                # mid-stream drain already flushed the chunks)
                or (self._ready and self._batcher.pending_chunks == 0)):
            await self._drain_tick()
        elif self._ready or self._batcher.pending_sessions:
            self._arm_drain_timer()

    def _arm_drain_timer(self) -> None:
        if self._drain_timer is not None:
            return
        loop = asyncio.get_running_loop()
        self._drain_timer = loop.call_later(
            self.tick.max_wait_s,
            lambda: loop.create_task(self._drain_tick()))

    async def _drain_tick(self) -> None:
        async with self._drain_lock:
            if self._drain_timer is not None:
                self._drain_timer.cancel()
                self._drain_timer = None
            ready, self._ready = self._ready, []
            if not ready and not self._batcher.pending_sessions:
                return
            with span("tick_drain", sessions=len(ready)):
                # ONE batched entropy pass over every pending chunk of
                # every session, across connections
                failures = await asyncio.to_thread(self._batcher.drain)
                for dec, exc in failures:
                    await self._evict_decoder(dec, exc)
                    kept = []
                    for e in ready:
                        if e[0].assembler.decoder is dec:
                            self._m_pending.remove(session=e[0].obs_key)
                            self._session_closed()
                        else:
                            kept.append(e)
                    ready = kept
                outs = await asyncio.to_thread(self._finish_ready,
                                               [e[0] for e in ready])
                self._m_ticks.inc()
                self._m_tick_sessions.inc(len(ready))
                for (sess, session_id, writer, sessions), out \
                        in zip(ready, outs):
                    dec = sess.assembler.decoder
                    self._dec_owner.pop(id(dec), None)
                    self._m_pending.remove(session=sess.obs_key)
                    self._session_closed()
                    if isinstance(out, Exception):
                        self._m_errors.inc()
                        await self._send_error(writer, session_id, out)
                        continue
                    arrays, work_s = out
                    sess.decode_s += work_s
                    self.sessions_served += 1
                    self._m_sessions.inc()
                    self._m_coded.inc(sess.assembler.chunk_bytes)
                    self._m_elems.inc(sess.assembler.n_elems)
                    await self._send_result(sess, session_id, writer,
                                            sessions, arrays)
            self._m_queue.set(self._batcher.pending_sessions
                              + len(self._ready))

    def _finish_ready(self, sesses: list[_Session]) -> list:
        """Reconstruct + run ``tail_fn`` for each drained session (worker
        thread; entropy is already done, so finish() is dequantize +
        reshape).  A per-session exception is returned in place so one
        bad stream cannot sink its tickmates."""
        outs = []
        for sess in sesses:
            t0 = time.perf_counter()
            try:
                tensor = sess.assembler.finish()
                arrays = [tensor] if self.echo_features else []
                if self.tail_fn is not None:
                    with span("tail", session=sess.obs_key):
                        out = self.tail_fn(tensor)
                    arrays.extend(out if isinstance(out, (list, tuple))
                                  else [out])
                outs.append((arrays, time.perf_counter() - t0))
            except Exception as e:                  # noqa: BLE001
                outs.append(e)
        return outs

    async def _evict_decoder(self, dec, exc) -> None:
        """A decoder failed the batched drain: evict + notify exactly
        that session, leave its tickmates untouched."""
        self._m_errors.inc()
        self._batcher.discard(dec)
        owner = self._dec_owner.pop(id(dec), None)
        if owner is None:
            return
        sessions, session_id, writer = owner
        gone = sessions.pop(session_id, None)
        if gone is not None:
            self._m_pending.remove(session=gone.obs_key)
            self._session_closed()
        log.error("decode failed for session %d: %s", session_id, exc)
        await self._send_error(writer, session_id, exc)

    async def _send_metrics(self, writer, session_id: int) -> None:
        """On-demand telemetry snapshot over the frame protocol: the edge
        sends an empty METRICS frame, the cloud replies with a JSON
        payload (never tensor bytes -- codec streams are untouched)."""
        self._sync_gauges()
        payload = json.dumps({
            "counters": self.counters,
            "metrics": self.metrics.snapshot(),
        }).encode()
        try:
            writer.write(encode_frame(FT_METRICS, session_id, 0, payload))
            await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    async def _send_error(self, writer, session_id: int, exc,
                          code: int | None = None,
                          retryable: bool | None = None) -> None:
        if code is None:
            code, retryable = error_for_exception(
                exc if isinstance(exc, BaseException)
                else RuntimeError(str(exc)))
        payload = encode_error(code, str(exc), retryable=retryable)
        try:
            writer.write(encode_frame(FT_ERROR, session_id, 0, payload))
            await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    async def _send_result(self, sess: _Session, session_id: int, writer,
                           sessions, arrays) -> None:
        elapsed = max(time.perf_counter() - sess.t_first, 1e-9)
        fb = Feedback(
            recv_bytes_per_s=sess.assembler.chunk_bytes / elapsed,
            decode_s=sess.decode_s,
            queue_depth=len(sessions),
            active_sessions=len(sessions),
        )
        # FEEDBACK goes out *before* RESULT: the client resolves the
        # session on RESULT, so in-order delivery guarantees the submit
        # sees its own link stats
        try:
            with span("socket_write", session=sess.obs_key):
                writer.write(fb.encode(session_id, sess.seq))
                writer.write(encode_frame(FT_RESULT, session_id,
                                          sess.seq + 1,
                                          pack_arrays([np.asarray(a)
                                                       for a in arrays])))
                await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    # -- disconnect: park (resumable) or forget --------------------------------

    def _park_connection(self, conn: _ConnState) -> None:
        """Connection with a resume token died: keep its sessions for
        ``resume_ttl_s`` so a reconnect can finish them bit-exactly.
        In-flight decoders stay registered with the batcher (their chunks
        may drain while parked; dedup skips them on replay)."""
        ready_mine, kept = [], []
        for entry in self._ready:
            if entry[2] is conn.writer:
                ready_mine.append((entry[0], entry[1]))
            else:
                kept.append(entry)
        self._ready = kept
        for sess in conn.sessions.values():
            dec = sess.assembler.decoder
            if dec is not None:
                self._dec_owner.pop(id(dec), None)
        loop = asyncio.get_running_loop()
        token = conn.token
        self._parked[token] = {
            "sessions": dict(conn.sessions),
            "ready": ready_mine,
            "handle": loop.call_later(self.resume_ttl_s,
                                      self._expire_parked, token),
        }
        conn.sessions.clear()
        self._cancel_idle_drain_timer()
        log.info("parked %d session(s) for token %s... (ttl %.1fs)",
                 len(self._parked[token]["sessions"]) + len(ready_mine),
                 token[:8], self.resume_ttl_s)

    def _expire_parked(self, token: str) -> None:
        parked = self._parked.pop(token, None)
        if parked is None:
            return
        parked["handle"].cancel()
        for sess in parked["sessions"].values():
            self._forget_session(sess)
        for sess, _sid in parked["ready"]:
            self._forget_session(sess)
        log.info("resume ttl expired for token %s...: dropped %d "
                 "session(s)", token[:8],
                 len(parked["sessions"]) + len(parked["ready"]))

    def _forget_connection(self, sessions, writer) -> None:
        """Connection gone (no resume token): unregister its in-flight
        decoders from the batcher so the next drain only sees live
        sessions, release their obs series, and disarm a drain timer
        that no longer has work behind it."""
        for sess in sessions.values():
            self._forget_session(sess)
        sessions.clear()
        kept = []
        for entry in self._ready:
            if entry[2] is writer:
                self._forget_session(entry[0])
            else:
                kept.append(entry)
        self._ready = kept
        self._cancel_idle_drain_timer()

    def _cancel_idle_drain_timer(self) -> None:
        """Disarm the tick timer when the dying connection was the only
        work source -- otherwise it fires into an empty batcher after the
        server may already be closing."""
        if (self._drain_timer is not None and not self._ready
                and not self._batcher.pending_sessions):
            self._drain_timer.cancel()
            self._drain_timer = None

    def _forget_session(self, sess: _Session) -> None:
        dec = sess.assembler.decoder
        if dec is not None:
            self._batcher.discard(dec)
            self._dec_owner.pop(id(dec), None)
        if sess.obs_key:
            self._m_pending.remove(session=sess.obs_key)
        self._session_closed()

    # -- per-session (tick=None) path -----------------------------------------

    async def _on_tensor_frame_immediate(self, frame,
                                         conn: _ConnState) -> None:
        sessions, writer = conn.sessions, conn.writer
        sess = sessions.get(frame.session)
        if sess is None:
            if frame.ftype == FT_HEADER and not await self._admit(frame,
                                                                  conn):
                return
            sess = sessions[frame.session] = _Session(
                TensorAssembler(backend=self._backend,
                                header_cache=self._header_cache),
                obs_key=f"{conn.conn_id}:{frame.session}")
            self._session_opened()
        if self._dedup(frame, sess):
            return
        t0 = time.perf_counter()
        tensor = await asyncio.to_thread(sess.assembler.feed, frame)
        sess.seen_seqs.add(frame.seq)
        sess.decode_s += time.perf_counter() - t0
        if tensor is None:
            return
        del sessions[frame.session]
        self._session_closed()
        self.sessions_served += 1
        self._m_sessions.inc()
        self._m_coded.inc(sess.assembler.chunk_bytes)
        self._m_elems.inc(sess.assembler.n_elems)
        arrays = [tensor] if self.echo_features else []
        if self.tail_fn is not None:
            t0 = time.perf_counter()
            out = await asyncio.to_thread(self.tail_fn, tensor)
            sess.decode_s += time.perf_counter() - t0
            arrays.extend(out if isinstance(out, (list, tuple)) else [out])
        await self._send_result(sess, frame.session, writer, sessions,
                                arrays)
