"""Cloud-side streaming server (asyncio).

Accepts one or more edge connections, demultiplexes interleaved tensor
sessions, entropy-decodes chunk frames *as they arrive* (the expensive
stage overlaps the transfer), and on each END frame reconstructs the
split-layer tensor and runs the cloud half (``tail_fn``).  The result
arrays go back in a RESULT frame; a FEEDBACK frame carries
receiver-measured link throughput and queue depth for the edge-side
rate controller.

Backpressure is the transport's: frames are processed in arrival order
per connection and the server only reads more bytes once the previous
batch is handled, so a slow cloud propagates to TCP flow control and
ultimately to the edge's bounded send path.

Decode and tail computation run via ``asyncio.to_thread`` so heartbeats
and other connections stay responsive while numpy/jax work runs.
"""

from __future__ import annotations

import asyncio
import logging
import time
from typing import Callable

import numpy as np

from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_HEADER, FT_RESULT,
                      FrameReader, FramingError, encode_frame, pack_arrays)
from .stream_codec import Feedback, TensorAssembler

log = logging.getLogger(__name__)


class _Session:
    __slots__ = ("assembler", "t_first", "decode_s", "seq")

    def __init__(self, assembler: TensorAssembler) -> None:
        self.assembler = assembler
        self.t_first = time.perf_counter()
        self.decode_s = 0.0
        self.seq = 0


class CloudServer:
    """``async with CloudServer(tail_fn=...) as srv: await srv.wait_closed()``

    ``tail_fn``: reconstruction -> ndarray (or list of ndarrays), the
    cloud half of the split network.  None echoes nothing back beyond
    what ``echo_features`` selects.
    ``echo_features``: prepend the reconstructed split-layer tensor to
    the RESULT arrays (used by the demo/tests for the bit-exactness
    check and by the loopback serving transport).
    """

    def __init__(self, *, tail_fn: Callable | None = None,
                 echo_features: bool = False, host: str = "127.0.0.1",
                 port: int = 0, backend=None) -> None:
        self.tail_fn = tail_fn
        self.echo_features = echo_features
        self.host = host
        self.port = port
        self._backend = backend
        self._server: asyncio.AbstractServer | None = None
        self.sessions_served = 0
        self.open_connections = 0

    async def start(self) -> "CloudServer":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        log.info("cloud server listening on %s:%d", self.host, self.port)
        return self

    async def __aenter__(self) -> "CloudServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def wait_closed(self) -> None:
        if self._server is not None:
            await self._server.serve_forever()

    # -- connection handling --------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        peer = writer.get_extra_info("peername")
        log.info("edge connected: %s", peer)
        self.open_connections += 1
        frames = FrameReader()
        sessions: dict[int, _Session] = {}
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                frames.feed(data)
                for frame in frames:
                    if frame.ftype in (FT_HEADER, FT_CHUNK, FT_END):
                        await self._on_tensor_frame(frame, sessions, writer)
                    else:
                        raise FramingError(
                            f"unexpected frame type {frame.ftype} from edge")
        except (FramingError, ValueError) as e:
            log.error("protocol error from %s: %s", peer, e)
            try:
                writer.write(encode_frame(FT_ERROR, 0, 0, str(e).encode()))
                await writer.drain()
            except ConnectionError:
                pass
        except ConnectionError:
            pass
        finally:
            self.open_connections -= 1
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass
            log.info("edge disconnected: %s", peer)

    async def _on_tensor_frame(self, frame, sessions, writer) -> None:
        sess = sessions.get(frame.session)
        if sess is None:
            sess = sessions[frame.session] = _Session(
                TensorAssembler(backend=self._backend))
        t0 = time.perf_counter()
        tensor = await asyncio.to_thread(sess.assembler.feed, frame)
        sess.decode_s += time.perf_counter() - t0
        if tensor is None:
            return
        del sessions[frame.session]
        self.sessions_served += 1
        arrays = [tensor] if self.echo_features else []
        if self.tail_fn is not None:
            t0 = time.perf_counter()
            out = await asyncio.to_thread(self.tail_fn, tensor)
            sess.decode_s += time.perf_counter() - t0
            arrays.extend(out if isinstance(out, (list, tuple)) else [out])
        elapsed = max(time.perf_counter() - sess.t_first, 1e-9)
        fb = Feedback(
            recv_bytes_per_s=sess.assembler.chunk_bytes / elapsed,
            decode_s=sess.decode_s,
            queue_depth=len(sessions),
            active_sessions=len(sessions),
        )
        # FEEDBACK goes out *before* RESULT: the client resolves the
        # session on RESULT, so in-order delivery guarantees the submit
        # sees its own link stats
        writer.write(fb.encode(frame.session, sess.seq))
        writer.write(encode_frame(FT_RESULT, frame.session, sess.seq + 1,
                                  pack_arrays([np.asarray(a) for a in arrays])))
        await writer.drain()
