"""Cloud-side streaming server (asyncio).

Accepts one or more edge connections, demultiplexes interleaved tensor
sessions, and reconstructs split-layer tensors for the cloud half
(``tail_fn``).  The result arrays go back in a RESULT frame; a FEEDBACK
frame carries receiver-measured link throughput and queue depth for the
edge-side rate controller.

Two receive disciplines:

* **tick mode** (default, ``tick=TickConfig()``): arriving chunk frames
  only accumulate (deferred-mode :class:`TensorAssembler`); a bounded
  tick window (``max_wait_s`` / ``max_chunks``) then drains every
  pending chunk of every session -- across connections -- through ONE
  batched entropy call (:class:`~repro.serving.batcher.DecodeBatcher`),
  and completed tensors finish + run ``tail_fn`` together.  Stream
  headers are parsed once per distinct (shape, rung) via a shared
  :class:`~repro.core.codec.HeaderCache`.  Per-tick metrics land in
  :attr:`counters`.
* **per-session mode** (``tick=None``): the original path -- chunks
  entropy-decode on arrival so decode overlaps the transfer (what
  ``bench_overlap`` measures), one entropy call per session stream.

Backpressure is the transport's: frames are processed in arrival order
per connection and the server only reads more bytes once the previous
batch is handled, so a slow cloud propagates to TCP flow control and
ultimately to the edge's bounded send path.

Decode and tail computation run via ``asyncio.to_thread`` so heartbeats
and other connections stay responsive while numpy/jax work runs.
"""

from __future__ import annotations

import asyncio
import json
import logging
import time
from typing import Callable

import numpy as np

from ..core.codec import HeaderCache
from ..obs.exposition import MetricsExposition
from ..obs.metrics import MetricsRegistry, default_registry
from ..obs.tracing import span
from ..serving.batcher import DecodeBatcher, TickConfig
from .framing import (FT_CHUNK, FT_END, FT_ERROR, FT_HEADER, FT_METRICS,
                      FT_RESULT, FrameReader, FramingError, encode_frame,
                      pack_arrays)
from .stream_codec import Feedback, TensorAssembler

log = logging.getLogger(__name__)

_DEFAULT_TICK = TickConfig()


class _Session:
    __slots__ = ("assembler", "t_first", "decode_s", "seq", "obs_key")

    def __init__(self, assembler: TensorAssembler,
                 obs_key: str = "") -> None:
        self.assembler = assembler
        self.t_first = time.perf_counter()
        self.decode_s = 0.0
        self.seq = 0
        self.obs_key = obs_key      # per-session metrics label value


class CloudServer:
    """``async with CloudServer(tail_fn=...) as srv: await srv.wait_closed()``

    ``tail_fn``: reconstruction -> ndarray (or list of ndarrays), the
    cloud half of the split network.  None echoes nothing back beyond
    what ``echo_features`` selects.
    ``echo_features``: prepend the reconstructed split-layer tensor to
    the RESULT arrays (used by the demo/tests for the bit-exactness
    check and by the loopback serving transport).
    ``tick``: cross-session batching bounds; ``None`` selects the
    per-session decode-on-arrival path.
    ``header_cache``: share a :class:`HeaderCache` across servers of one
    worker (a fresh one is made per server otherwise).
    ``metrics``: the :class:`MetricsRegistry` this server's
    ``repro_server_*`` / ``repro_decode_*`` instruments register in
    (fresh per server by default, so co-hosted servers and tests never
    share series).
    ``metrics_port``: when not None, :meth:`start` also serves a
    Prometheus-text ``GET /metrics`` endpoint (plus the tracer's JSON
    span log at ``/events``) on this port (0 = pick a free one; the
    bound port lands back in ``metrics_port``).
    """

    def __init__(self, *, tail_fn: Callable | None = None,
                 echo_features: bool = False, host: str = "127.0.0.1",
                 port: int = 0, backend=None,
                 tick: TickConfig | None = _DEFAULT_TICK,
                 header_cache: HeaderCache | None = None,
                 metrics: MetricsRegistry | None = None,
                 metrics_port: int | None = None) -> None:
        self.tail_fn = tail_fn
        self.echo_features = echo_features
        self.host = host
        self.port = port
        self._backend = backend
        self._server: asyncio.AbstractServer | None = None
        self.sessions_served = 0
        self.open_connections = 0
        self.tick = tick
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._batcher = DecodeBatcher(metrics=self.metrics)
        self._header_cache = (header_cache if header_cache is not None
                              else HeaderCache())
        # tensors whose END arrived, awaiting the tick drain:
        # (sess, session_id, writer, sessions-dict of their connection)
        self._ready: list[tuple] = []
        self._drain_lock = asyncio.Lock()
        self._drain_timer: asyncio.TimerHandle | None = None
        # decoder id -> (sessions-dict, session_id, writer): lets a drain
        # failure evict + notify exactly the offending session
        self._dec_owner: dict[int, tuple] = {}
        self._conn_seq = 0
        self.metrics_port = metrics_port
        self.metrics_exposition: MetricsExposition | None = None
        m = self.metrics
        self._m_sessions = m.counter("repro_server_sessions_served_total",
                                     "tensors fully served (tail + RESULT)")
        self._m_conns = m.gauge("repro_server_open_connections_count",
                                "currently connected edge clients")
        self._m_ticks = m.counter("repro_server_ticks_total",
                                  "cross-session tick drains")
        self._m_tick_sessions = m.counter(
            "repro_server_tick_sessions_total",
            "completed sessions summed over tick drains (occupancy "
            "numerator)")
        self._m_coded = m.counter("repro_server_coded_bytes_total",
                                  "entropy-coded payload bytes received")
        self._m_elems = m.counter("repro_server_decoded_elements_total",
                                  "tensor elements reconstructed")
        self._m_errors = m.counter(
            "repro_server_decode_errors_total",
            "sessions failed in decode/tail (or protocol errors)")
        self._m_queue = m.gauge(
            "repro_server_queue_depth_count",
            "sessions with pending work (undrained chunks + awaiting "
            "tail)")
        self._m_pending = m.gauge(
            "repro_server_session_pending_chunks_count",
            "entropy-undecoded chunks per in-flight session",
            labelnames=("session",))
        self._m_bpe = m.gauge(
            "repro_server_measured_bpe",
            "running wire bits/element over served tensors")
        self._m_hc_hits = m.gauge("repro_server_header_cache_hits_count",
                                  "header-cache hits")
        self._m_hc_misses = m.gauge(
            "repro_server_header_cache_misses_count",
            "header-cache misses (fresh header parses)")
        self._m_hc_entries = m.gauge(
            "repro_server_header_cache_entries_count",
            "distinct parsed headers cached")

    def _sync_gauges(self) -> None:
        """Pull-style sources -> gauges (run per scrape / counters read)."""
        self._m_conns.set(self.open_connections)
        self._m_queue.set(self._batcher.pending_sessions + len(self._ready))
        hc = self._header_cache.stats
        self._m_hc_hits.set(hc["hits"])
        self._m_hc_misses.set(hc["misses"])
        self._m_hc_entries.set(hc["entries"])
        coded, elems = self._m_coded.value(), self._m_elems.value()
        self._m_bpe.set(8.0 * coded / max(elems, 1))

    async def start(self) -> "CloudServer":
        self._server = await asyncio.start_server(self._handle, self.host,
                                                  self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        log.info("cloud server listening on %s:%d", self.host, self.port)
        if self.metrics_port is not None:
            # the scrape sees this server's registry plus the worker-wide
            # default one (stage-latency histogram, bank cache)
            self.metrics_exposition = await MetricsExposition(
                [self.metrics, default_registry()],
                collectors=[self._sync_gauges], host=self.host,
                port=self.metrics_port).start()
            self.metrics_port = self.metrics_exposition.port
            log.info("metrics endpoint on %s:%d/metrics", self.host,
                     self.metrics_port)
        return self

    async def __aenter__(self) -> "CloudServer":
        return await self.start()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        if self._drain_timer is not None:
            self._drain_timer.cancel()
            self._drain_timer = None
        if self.metrics_exposition is not None:
            await self.metrics_exposition.close()
            self.metrics_exposition = None
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def wait_closed(self) -> None:
        if self._server is not None:
            await self._server.serve_forever()

    @property
    def counters(self) -> dict:
        """Legacy dict view of the ``repro_server_*`` / ``repro_decode_*``
        registry instruments (the tick=None shape is pinned by
        tests/test_batcher.py; registry-only telemetry such as
        decode-error counts on the legacy path lives in
        :attr:`metrics`)."""
        self._sync_gauges()
        c = {"sessions_served": self.sessions_served,
             "open_connections": self.open_connections}
        if self.tick is None:
            return c
        b = self._batcher.counters
        ticks = int(self._m_ticks.value())
        c.update(
            ticks=ticks,
            batch_occupancy_avg=(self._m_tick_sessions.value()
                                 / max(ticks, 1)),
            queue_depth=int(self._m_queue.value()),
            entropy_calls=b["entropy_calls"],
            entropy_chunks=b["chunks"],
            entropy_melem_per_s=(b["elems"] / b["entropy_s"] / 1e6
                                 if b["entropy_s"] > 0 else 0.0),
            bpe_avg=self._m_bpe.value(),
            decode_errors=int(self._m_errors.value()),
            header_cache=self._header_cache.stats,
        )
        return c

    # -- connection handling --------------------------------------------------

    async def _handle(self, reader: asyncio.StreamReader,
                      writer: asyncio.StreamWriter) -> None:
        peer = writer.get_extra_info("peername")
        log.info("edge connected: %s", peer)
        self.open_connections += 1
        self._conn_seq += 1
        conn_id = self._conn_seq
        frames = FrameReader()
        sessions: dict[int, _Session] = {}
        try:
            while True:
                data = await reader.read(1 << 16)
                if not data:
                    break
                frames.feed(data)
                for frame in frames:
                    if frame.ftype in (FT_HEADER, FT_CHUNK, FT_END):
                        await self._on_tensor_frame(frame, sessions, writer,
                                                    conn_id)
                    elif frame.ftype == FT_METRICS:
                        await self._send_metrics(writer, frame.session)
                    else:
                        raise FramingError(
                            f"unexpected frame type {frame.ftype} from edge")
        except (FramingError, ValueError) as e:
            self._m_errors.inc()
            log.error("protocol error from %s: %s", peer, e)
            try:
                writer.write(encode_frame(FT_ERROR, 0, 0, str(e).encode()))
                await writer.drain()
            except ConnectionError:
                pass
        except ConnectionError:
            pass
        finally:
            self.open_connections -= 1
            self._forget_connection(sessions, writer)
            writer.close()
            try:
                await writer.wait_closed()
            except ConnectionError:
                pass
            log.info("edge disconnected: %s", peer)

    async def _on_tensor_frame(self, frame, sessions, writer,
                               conn_id: int = 0) -> None:
        if self.tick is None:
            await self._on_tensor_frame_immediate(frame, sessions, writer,
                                                  conn_id)
            return
        sess = sessions.get(frame.session)
        if sess is None:
            sess = sessions[frame.session] = _Session(
                TensorAssembler(backend=self._backend, defer=True,
                                header_cache=self._header_cache),
                obs_key=f"{conn_id}:{frame.session}")
        t0 = time.perf_counter()
        # deferred mode: no entropy work here, just buffering -- cheap
        # enough to run on-loop
        sess.assembler.feed(frame)
        sess.decode_s += time.perf_counter() - t0
        dec = sess.assembler.decoder
        if dec is not None:
            self._batcher.note(dec)
            self._m_pending.set(dec.pending_chunks, session=sess.obs_key)
            if id(dec) not in self._dec_owner:
                self._dec_owner[id(dec)] = (sessions, frame.session, writer)
        if sess.assembler.ready:
            del sessions[frame.session]
            self._ready.append((sess, frame.session, writer, sessions))
        if (len(self._ready) >= self.tick.max_batch
                or self._batcher.pending_chunks >= self.tick.max_chunks
                # a session is complete and no entropy work is queued:
                # nothing could batch with it, so waiting out the tick
                # window would be pure latency (hit after a max_chunks
                # mid-stream drain already flushed the chunks)
                or (self._ready and self._batcher.pending_chunks == 0)):
            await self._drain_tick()
        elif self._ready or self._batcher.pending_sessions:
            self._arm_drain_timer()

    def _arm_drain_timer(self) -> None:
        if self._drain_timer is not None:
            return
        loop = asyncio.get_running_loop()
        self._drain_timer = loop.call_later(
            self.tick.max_wait_s,
            lambda: loop.create_task(self._drain_tick()))

    async def _drain_tick(self) -> None:
        async with self._drain_lock:
            if self._drain_timer is not None:
                self._drain_timer.cancel()
                self._drain_timer = None
            ready, self._ready = self._ready, []
            if not ready and not self._batcher.pending_sessions:
                return
            with span("tick_drain", sessions=len(ready)):
                # ONE batched entropy pass over every pending chunk of
                # every session, across connections
                failures = await asyncio.to_thread(self._batcher.drain)
                for dec, exc in failures:
                    await self._evict_decoder(dec, exc)
                    kept = []
                    for e in ready:
                        if e[0].assembler.decoder is dec:
                            self._m_pending.remove(session=e[0].obs_key)
                        else:
                            kept.append(e)
                    ready = kept
                outs = await asyncio.to_thread(self._finish_ready,
                                               [e[0] for e in ready])
                self._m_ticks.inc()
                self._m_tick_sessions.inc(len(ready))
                for (sess, session_id, writer, sessions), out \
                        in zip(ready, outs):
                    dec = sess.assembler.decoder
                    self._dec_owner.pop(id(dec), None)
                    self._m_pending.remove(session=sess.obs_key)
                    if isinstance(out, Exception):
                        self._m_errors.inc()
                        await self._send_error(writer, session_id, out)
                        continue
                    arrays, work_s = out
                    sess.decode_s += work_s
                    self.sessions_served += 1
                    self._m_sessions.inc()
                    self._m_coded.inc(sess.assembler.chunk_bytes)
                    self._m_elems.inc(sess.assembler.n_elems)
                    await self._send_result(sess, session_id, writer,
                                            sessions, arrays)
            self._m_queue.set(self._batcher.pending_sessions
                              + len(self._ready))

    def _finish_ready(self, sesses: list[_Session]) -> list:
        """Reconstruct + run ``tail_fn`` for each drained session (worker
        thread; entropy is already done, so finish() is dequantize +
        reshape).  A per-session exception is returned in place so one
        bad stream cannot sink its tickmates."""
        outs = []
        for sess in sesses:
            t0 = time.perf_counter()
            try:
                tensor = sess.assembler.finish()
                arrays = [tensor] if self.echo_features else []
                if self.tail_fn is not None:
                    with span("tail", session=sess.obs_key):
                        out = self.tail_fn(tensor)
                    arrays.extend(out if isinstance(out, (list, tuple))
                                  else [out])
                outs.append((arrays, time.perf_counter() - t0))
            except Exception as e:                  # noqa: BLE001
                outs.append(e)
        return outs

    async def _evict_decoder(self, dec, exc) -> None:
        """A decoder failed the batched drain: evict + notify exactly
        that session, leave its tickmates untouched."""
        self._m_errors.inc()
        self._batcher.discard(dec)
        owner = self._dec_owner.pop(id(dec), None)
        if owner is None:
            return
        sessions, session_id, writer = owner
        gone = sessions.pop(session_id, None)
        if gone is not None:
            self._m_pending.remove(session=gone.obs_key)
        log.error("decode failed for session %d: %s", session_id, exc)
        await self._send_error(writer, session_id, exc)

    async def _send_metrics(self, writer, session_id: int) -> None:
        """On-demand telemetry snapshot over the frame protocol: the edge
        sends an empty METRICS frame, the cloud replies with a JSON
        payload (never tensor bytes -- codec streams are untouched)."""
        self._sync_gauges()
        payload = json.dumps({
            "counters": self.counters,
            "metrics": self.metrics.snapshot(),
        }).encode()
        try:
            writer.write(encode_frame(FT_METRICS, session_id, 0, payload))
            await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    async def _send_error(self, writer, session_id: int, exc) -> None:
        try:
            writer.write(encode_frame(FT_ERROR, session_id, 0,
                                      str(exc).encode()))
            await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    async def _send_result(self, sess: _Session, session_id: int, writer,
                           sessions, arrays) -> None:
        elapsed = max(time.perf_counter() - sess.t_first, 1e-9)
        fb = Feedback(
            recv_bytes_per_s=sess.assembler.chunk_bytes / elapsed,
            decode_s=sess.decode_s,
            queue_depth=len(sessions),
            active_sessions=len(sessions),
        )
        # FEEDBACK goes out *before* RESULT: the client resolves the
        # session on RESULT, so in-order delivery guarantees the submit
        # sees its own link stats
        try:
            with span("socket_write", session=sess.obs_key):
                writer.write(fb.encode(session_id, sess.seq))
                writer.write(encode_frame(FT_RESULT, session_id,
                                          sess.seq + 1,
                                          pack_arrays([np.asarray(a)
                                                       for a in arrays])))
                await writer.drain()
        except (ConnectionError, RuntimeError):
            pass

    def _forget_connection(self, sessions, writer) -> None:
        """Connection gone: unregister its in-flight decoders from the
        batcher so the next drain only sees live sessions."""
        for sess in sessions.values():
            self._forget_session(sess)
        sessions.clear()
        kept = []
        for entry in self._ready:
            if entry[2] is writer:
                self._forget_session(entry[0])
            else:
                kept.append(entry)
        self._ready = kept

    def _forget_session(self, sess: _Session) -> None:
        dec = sess.assembler.decoder
        if dec is not None:
            self._batcher.discard(dec)
            self._dec_owner.pop(id(dec), None)
        if sess.obs_key:
            self._m_pending.remove(session=sess.obs_key)

    # -- per-session (tick=None) path -----------------------------------------

    async def _on_tensor_frame_immediate(self, frame, sessions, writer,
                                         conn_id: int = 0) -> None:
        sess = sessions.get(frame.session)
        if sess is None:
            sess = sessions[frame.session] = _Session(
                TensorAssembler(backend=self._backend,
                                header_cache=self._header_cache),
                obs_key=f"{conn_id}:{frame.session}")
        t0 = time.perf_counter()
        tensor = await asyncio.to_thread(sess.assembler.feed, frame)
        sess.decode_s += time.perf_counter() - t0
        if tensor is None:
            return
        del sessions[frame.session]
        self.sessions_served += 1
        self._m_sessions.inc()
        self._m_coded.inc(sess.assembler.chunk_bytes)
        self._m_elems.inc(sess.assembler.n_elems)
        arrays = [tensor] if self.echo_features else []
        if self.tail_fn is not None:
            t0 = time.perf_counter()
            out = await asyncio.to_thread(self.tail_fn, tensor)
            sess.decode_s += time.perf_counter() - t0
            arrays.extend(out if isinstance(out, (list, tuple)) else [out])
        await self._send_result(sess, frame.session, writer, sessions, arrays)
