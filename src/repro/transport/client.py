"""Edge-side streaming client (asyncio) + a synchronous wrapper.

:class:`EdgeClient` streams split-layer tensors to a
:class:`~repro.transport.server.CloudServer` over one connection.  Any
number of :meth:`submit` coroutines may run concurrently: sessions are
multiplexed at frame granularity (a per-connection write lock keeps
frames atomic, ``await drain()`` after every frame bounds the send queue
and propagates TCP backpressure into the encoder).

Each chunk is entropy-coded in a worker thread while the previous frame
is on the wire, which is the encode/transfer overlap the transport
benchmark measures.  With a :class:`RateController` + :class:`CodecBank`
attached, every submit re-picks the quantizer rung against the
bits/element budget and the link state fed back by the cloud.

Hardening (see DESIGN.md, "Hardened scale-out serving"):

* **Retry + reconnect**: with a :class:`RetryPolicy`, a submit that dies
  on a *retryable* failure (connection loss, BUSY shed, worker restart)
  reconnects with exponential backoff + jitter and replays the session
  -- same session id, SAME codec (rate control is *not* re-consulted on
  a replay, so the re-encoded bytes are identical) -- and the server
  dedups replayed frames by seq, yielding a bit-exact result.  Fatal
  errors (corrupt stream, auth) raise immediately.
* **Deadlines**: ``submit(..., deadline_s=...)`` bounds the whole
  attempt+retry loop; expiry raises a typed ``DEADLINE`` error, never a
  hang.
* **HELLO / resume / TLS**: when a shared ``secret`` or a retry policy
  is configured, connect() performs a HELLO handshake (resume token +
  HMAC auth proof, :func:`~repro.transport.server.hello_auth`) before
  any tensor frame; ``ssl`` takes an ``ssl.SSLContext`` for TLS.

:class:`SyncEdgeClient` runs the event loop on a background thread so
blocking callers (the serving engine's loopback transport, scripts) get
a plain ``submit(x) -> arrays`` call.
"""

from __future__ import annotations

import asyncio
import dataclasses
import json
import os
import random
import threading
import time

import numpy as np

from ..core.codec import FeatureCodec
from ..obs.metrics import MetricsRegistry
from ..obs.tracing import span
from ..serving.batcher import TickConfig, encode_tick
from .errors import E_DEADLINE, TransportError, decode_error
from .faultinject import FaultPlan, wrap_writer
from .framing import (FT_ERROR, FT_FEEDBACK, FT_HELLO, FT_METRICS,
                      FT_RESULT, FrameReader, encode_frame, unpack_arrays)
from .rate_control import CodecBank, RateController, rung_of_codec
from .stream_codec import (DEFAULT_CHUNK_ELEMS, Feedback, payloads_to_frames,
                           tensor_to_frames)

_HELLO_TIMEOUT_S = 10.0


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff schedule for retryable submit failures.

    Delay before retry *k* (0-based) is
    ``min(base_delay_s * 2**k, max_delay_s)`` shrunk by up to ``jitter``
    (a uniform fraction), so a fleet of clients bounced by one worker
    restart doesn't reconnect in lockstep.
    """

    max_retries: int = 4
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.5

    def delay_s(self, attempt: int, rng: random.Random) -> float:
        d = min(self.base_delay_s * (2.0 ** attempt), self.max_delay_s)
        return d * (1.0 - self.jitter * rng.random())


def _as_transport_error(e: BaseException) -> TransportError:
    """Classify a raw client-side failure.  Connection loss is retryable
    (reconnect + replay is exactly what the retry path is for); framing
    errors mean the inbound stream is corrupt -- fatal."""
    if isinstance(e, TransportError):
        return e
    if isinstance(e, (ConnectionError, asyncio.IncompleteReadError)):
        return TransportError(f"connection lost: {e}", retryable=True)
    return TransportError(str(e) or type(e).__name__, retryable=False)


@dataclasses.dataclass
class SubmitResult:
    arrays: list[np.ndarray]      # RESULT arrays from the cloud
    n_levels: int
    coded_bytes: int
    n_elems: int
    bits_per_elem: float
    send_s: float                 # time spent encoding+writing frames
    total_s: float                # submit round-trip time
    feedback: Feedback | None = None
    retries: int = 0              # attempts beyond the first


class EdgeClient:
    def __init__(self, host: str, port: int, *,
                 codec: FeatureCodec | None = None,
                 codec_bank: CodecBank | None = None,
                 rate_controller: RateController | None = None,
                 chunk_elems: int = DEFAULT_CHUNK_ELEMS,
                 coder_mode: str = "auto",
                 tick: TickConfig | None = None,
                 retry: RetryPolicy | None = None,
                 secret: str | None = None,
                 ssl=None,
                 resume_token: str | None = None,
                 fault_plan: FaultPlan | None = None,
                 metrics: MetricsRegistry | None = None) -> None:
        if codec is None and codec_bank is None:
            raise ValueError("need a codec or a codec_bank")
        if rate_controller is not None and codec_bank is None:
            raise ValueError("rate control needs a codec_bank (per-rung "
                             "calibrated codecs)")
        self.host, self.port = host, port
        self.codec = codec
        self.codec_bank = codec_bank
        self.rate_controller = rate_controller
        self.chunk_elems = chunk_elems
        self.coder_mode = coder_mode
        self.tick = tick
        self.retry = retry
        self.secret = secret
        self.ssl_context = ssl
        # the resume token identifies this client across reconnects; the
        # server parks a token'd connection's in-flight sessions on
        # disconnect instead of dropping them
        self.resume_token = (resume_token if resume_token is not None
                             else os.urandom(16).hex())
        self._fault_plan = fault_plan
        self._rng = random.Random()
        self._reader: asyncio.StreamReader | None = None
        self._writer: asyncio.StreamWriter | None = None
        self._write_lock = asyncio.Lock()
        self._conn_lock = asyncio.Lock()
        self._pending: dict[int, asyncio.Future] = {}
        self._feedback: dict[int, Feedback] = {}
        # session 0 is reserved for connection-scoped control frames
        # (HELLO, connection-level errors), so tensors start at 1
        self._next_session = 1
        self._reader_task: asyncio.Task | None = None
        self._dead: TransportError | None = None
        self._hello_fut: asyncio.Future | None = None
        # per-session frame seqs the server acked in the last resume
        # HELLO (replay skips these)
        self._acked: dict[int, set[int]] = {}
        # encode-tick coalescing state (tick is not None):
        # (codec, tensor, session, sent-bytes future) entries await one
        # shared encode_tick launch
        self._encode_queue: list[tuple] = []
        self._encode_timer: asyncio.TimerHandle | None = None
        self._encode_lock = asyncio.Lock()
        # awaiters of an on-demand cloud telemetry snapshot (FT_METRICS)
        self._metrics_waiters: list[asyncio.Future] = []
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m = {
            "ticks": m.counter("repro_client_encode_ticks_total",
                               "coalesced encode-tick launches"),
            "sessions": m.counter("repro_client_sessions_total",
                                  "tensors encoded"),
            "stacked_sessions": m.counter(
                "repro_client_stacked_sessions_total",
                "tensors that shared a stacked fused launch"),
            "fused_launches": m.counter(
                "repro_client_fused_launches_total",
                "fused quantize+pack kernel launches"),
            "entropy_calls": m.counter(
                "repro_client_entropy_calls_total",
                "batched entropy-coder invocations"),
            "elems": m.counter("repro_client_encoded_elements_total",
                               "tensor elements encoded"),
            "coded_bytes": m.counter("repro_client_coded_bytes_total",
                                     "entropy-coded payload bytes produced"),
        }
        self._m_encode_s = m.counter("repro_client_encode_seconds_total",
                                     "wall-clock spent inside encode ticks")
        self._m_submit = m.histogram(
            "repro_client_submit_latency_seconds",
            "submit round-trip latency (encode -> RESULT)")
        self._m_retries = m.counter(
            "repro_client_retries_total",
            "submit attempts retried after a retryable failure")
        self._m_reconnects = m.counter(
            "repro_client_reconnects_total",
            "connections re-established after a failure")
        self._m_resumed = m.counter(
            "repro_client_resumed_sessions_total",
            "sessions the server reported revived on reconnect")
        self._m_skipped = m.counter(
            "repro_client_replay_skipped_frames_total",
            "replay frames skipped because the server acked their seqs")
        self._m_deadlines = m.counter(
            "repro_client_deadline_expired_total",
            "submits failed by their deadline")
        if rate_controller is not None:
            rate_controller.bind_metrics(m)

    @property
    def encode_counters(self) -> dict:
        """Legacy dict view of the ``repro_client_*`` instruments (same
        keys the pre-registry counters dict had; hardening telemetry --
        retries, reconnects, resumes -- is registry-only)."""
        c = {k: int(v.value()) for k, v in self._m.items()}
        c["encode_s"] = self._m_encode_s.value()
        return c

    @property
    def _wants_hello(self) -> bool:
        return self.secret is not None or self.retry is not None

    async def connect(self) -> "EdgeClient":
        await self._open_connection()
        return self

    async def _open_connection(self) -> None:
        self._reader, writer = await asyncio.open_connection(
            self.host, self.port, ssl=self.ssl_context)
        self._writer = wrap_writer(writer, "client", self._fault_plan)
        self._dead = None
        self._reader_task = asyncio.ensure_future(self._read_loop())
        if self._wants_hello:
            await self._send_hello()

    async def _send_hello(self) -> None:
        """Resume-token + auth handshake; must complete before the first
        tensor frame when the server requires a secret.  The ack lists
        revived sessions and their server-seen frame seqs."""
        from .server import hello_auth   # local: avoid import cycle cost
        hello = {"token": self.resume_token}
        if self.secret is not None:
            hello["auth"] = hello_auth(self.secret, self.resume_token)
        self._hello_fut = asyncio.get_running_loop().create_future()
        async with self._write_lock:
            self._writer.write(encode_frame(FT_HELLO, 0, 0,
                                            json.dumps(hello).encode()))
            await self._writer.drain()
        ack = await asyncio.wait_for(self._hello_fut, _HELLO_TIMEOUT_S)
        self._hello_fut = None
        self._acked = {int(sid): set(seqs)
                       for sid, seqs in ack.get("acked", {}).items()}
        resumed = ack.get("resumed", [])
        if resumed:
            self._m_resumed.inc(len(resumed))

    async def _ensure_connected(self) -> None:
        """Reconnect (once) if the connection is dead; concurrent submits
        coalesce on the lock and reuse the first success."""
        async with self._conn_lock:
            if (self._dead is None and self._writer is not None
                    and not self._writer.is_closing()):
                return
            await self._teardown_connection()
            try:
                await self._open_connection()
            except (OSError, asyncio.TimeoutError) as e:
                self._dead = _as_transport_error(
                    e if isinstance(e, ConnectionError)
                    else ConnectionError(str(e) or type(e).__name__))
                raise self._dead from e
            self._m_reconnects.inc()

    async def _settle_reader(self, timeout_s: float = 1.0) -> None:
        """Wait briefly for the read loop to finish when the connection
        is going down, so any final typed FT_ERROR is classified before
        a retry decision."""
        task = self._reader_task
        if task is None or (self._dead is None and self._writer is not None
                            and not self._writer.is_closing()):
            return
        try:
            await asyncio.wait_for(asyncio.shield(task), timeout_s)
        except (asyncio.TimeoutError, asyncio.CancelledError,
                ConnectionError):
            pass

    async def _teardown_connection(self) -> None:
        if self._reader_task is not None:
            self._reader_task.cancel()
            try:
                await self._reader_task
            except (asyncio.CancelledError, ConnectionError):
                pass
            self._reader_task = None
        if self._writer is not None:
            self._writer.close()
            try:
                await self._writer.wait_closed()
            except ConnectionError:
                pass
            self._writer = None

    async def __aenter__(self) -> "EdgeClient":
        return await self.connect()

    async def __aexit__(self, *exc) -> None:
        await self.close()

    async def close(self) -> None:
        if self._encode_timer is not None:
            self._encode_timer.cancel()
            self._encode_timer = None
        queue, self._encode_queue = self._encode_queue, []
        for *_, sent in queue:
            if not sent.done():
                sent.set_exception(TransportError("client closed"))
        await self._teardown_connection()

    # -- receive path ---------------------------------------------------------

    async def _read_loop(self) -> None:
        frames = FrameReader()
        try:
            while True:
                data = await self._reader.read(1 << 16)
                if not data:
                    raise ConnectionError("cloud closed the connection")
                frames.feed(data)
                for frame in frames:
                    if frame.ftype == FT_RESULT:
                        fut = self._pending.pop(frame.session, None)
                        if fut is not None and not fut.done():
                            fut.set_result(unpack_arrays(frame.payload))
                    elif frame.ftype == FT_FEEDBACK:
                        fb = Feedback.decode(frame)
                        self._feedback[frame.session] = fb
                        if self.rate_controller is not None:
                            self.rate_controller.on_feedback(
                                fb.recv_bytes_per_s, fb.queue_depth)
                    elif frame.ftype == FT_METRICS:
                        snap = json.loads(frame.payload.decode())
                        waiters, self._metrics_waiters = \
                            self._metrics_waiters, []
                        for fut in waiters:
                            if not fut.done():
                                fut.set_result(snap)
                    elif frame.ftype == FT_HELLO:
                        if self._hello_fut is not None \
                                and not self._hello_fut.done():
                            self._hello_fut.set_result(
                                json.loads(frame.payload.decode()))
                    elif frame.ftype == FT_ERROR:
                        err = decode_error(frame.payload)
                        fut = self._pending.pop(frame.session, None)
                        if fut is not None:
                            # session-scoped failure (shed, decode error):
                            # fail exactly that submit, tickmates live on
                            if not fut.done():
                                fut.set_exception(err)
                        else:
                            # connection-scoped (session 0 / unknown):
                            # the whole connection is unusable
                            raise err
        except asyncio.CancelledError:
            self._fail_pending(TransportError("client closed"))
            raise
        except Exception as e:  # framing errors, connection loss, ...
            # fail in-flight AND future submits: a dead reader must never
            # leave a submit() awaiting a result that cannot arrive
            self._fail_pending(_as_transport_error(e))

    def _fail_pending(self, err: TransportError) -> None:
        self._dead = err
        for fut in self._pending.values():
            if not fut.done():
                fut.set_exception(err)
        self._pending.clear()
        if self._hello_fut is not None and not self._hello_fut.done():
            self._hello_fut.set_exception(err)
        waiters, self._metrics_waiters = self._metrics_waiters, []
        for fut in waiters:
            if not fut.done():
                fut.set_exception(err)

    async def fetch_cloud_metrics(self) -> dict:
        """Ask the cloud for a telemetry snapshot over the frame protocol
        (an empty METRICS frame; the reply is JSON with the server's
        ``counters`` dict and full registry ``metrics`` snapshot) -- lets
        an edge observe cloud health without a separate scrape port."""
        if self._writer is None:
            raise TransportError("not connected")
        if self._dead is not None:
            raise self._dead
        fut = asyncio.get_running_loop().create_future()
        self._metrics_waiters.append(fut)
        async with self._write_lock:
            self._writer.write(encode_frame(FT_METRICS, 0, 0, b""))
            await self._writer.drain()
        return await fut

    # -- send path ------------------------------------------------------------

    def _pick_codec(self) -> tuple[FeatureCodec, object]:
        if self.rate_controller is not None:
            rung = self.rate_controller.next_rung()
            return self.codec_bank.get(rung), rung
        if self.codec is not None:
            return self.codec, self.codec.config.n_levels
        rung = max(self.codec_bank.ladder)
        return self.codec_bank.get(rung), rung

    async def _submit_tick(self, codec: FeatureCodec, x: np.ndarray,
                           session: int) -> int:
        """Queue one tensor for the next encode tick; resolves with the
        wire byte count once its frames are on the socket."""
        loop = asyncio.get_running_loop()
        sent: asyncio.Future = loop.create_future()
        self._encode_queue.append((codec, x, session, sent))
        if len(self._encode_queue) >= self.tick.max_batch:
            await self._flush_encode()
        elif self._encode_timer is None:
            self._encode_timer = loop.call_later(
                self.tick.max_wait_s,
                lambda: loop.create_task(self._flush_encode()))
        return await sent

    async def _flush_encode(self) -> None:
        """Encode everything queued since the last tick in one
        ``encode_tick`` call (stacked fused launches + ONE entropy call),
        then write each session's frames."""
        async with self._encode_lock:
            if self._encode_timer is not None:
                self._encode_timer.cancel()
                self._encode_timer = None
            queue, self._encode_queue = self._encode_queue, []
            if not queue:
                return
            cfg = dataclasses.replace(self.tick,
                                      chunk_elems=self.chunk_elems,
                                      coder_mode=self.coder_mode)
            try:
                payload_lists, stats = await asyncio.to_thread(
                    encode_tick, [(c, x) for c, x, _, _ in queue], cfg)
            except Exception as e:                  # noqa: BLE001
                for *_, sent in queue:
                    if not sent.done():
                        sent.set_exception(e)
                return
            self._m["ticks"].inc()
            self._m["sessions"].inc(stats.sessions)
            self._m["stacked_sessions"].inc(stats.stacked_sessions)
            self._m["fused_launches"].inc(stats.fused_launches)
            self._m["entropy_calls"].inc(stats.entropy_calls)
            self._m["elems"].inc(stats.elems)
            self._m["coded_bytes"].inc(stats.coded_bytes)
            self._m_encode_s.inc(stats.encode_s)
            for (_, _, session, sent), payloads in zip(queue, payload_lists):
                frames = payloads_to_frames(payloads, session)
                acked = self._acked.get(session, ())
                try:
                    async with self._write_lock:
                        with span("socket_write", session=str(session),
                                  frames=len(frames)):
                            for seq, frame_bytes in enumerate(frames):
                                if seq in acked:
                                    self._m_skipped.inc()
                                    continue
                                self._writer.write(frame_bytes)
                            await self._writer.drain()
                except Exception as e:              # noqa: BLE001
                    if not sent.done():
                        sent.set_exception(e)
                    continue
                if not sent.done():
                    sent.set_result(sum(len(f) for f in frames))

    async def submit(self, x: np.ndarray,
                     codec: FeatureCodec | None = None,
                     deadline_s: float | None = None) -> SubmitResult:
        """Stream one tensor; resolves when the cloud's RESULT arrives.

        With a :class:`RetryPolicy` attached, retryable failures
        reconnect + replay the session (same id, same codec) until the
        policy or ``deadline_s`` runs out.  ``deadline_s`` bounds the
        whole call; expiry raises ``TransportError`` code ``DEADLINE``.
        """
        if self._writer is None:
            raise TransportError("not connected")
        if codec is None:
            codec, rung = self._pick_codec()
        else:
            # attribute the measurement to the codec's actual operating
            # point: the exact ladder rung when the codec came from the
            # bank (so 'base'-granularity rungs don't fragment into a
            # second EWMA key), else the codec's own config
            rung = (self.codec_bank.rung_for(codec)
                    if self.codec_bank is not None else None) \
                or rung_of_codec(codec)
        session = self._next_session
        self._next_session += 1
        x = np.asarray(x, np.float32)
        t0 = time.perf_counter()
        deadline = (time.monotonic() + deadline_s
                    if deadline_s is not None else None)
        attempt = 0
        while True:
            try:
                if attempt > 0 or self._dead is not None:
                    if self.retry is None and self._dead is not None:
                        raise self._dead
                    await self._ensure_connected()
                budget = (None if deadline is None
                          else deadline - time.monotonic())
                if budget is not None and budget <= 0:
                    raise TransportError(
                        f"submit deadline ({deadline_s}s) expired",
                        code=E_DEADLINE, retryable=False)
                return await asyncio.wait_for(
                    self._submit_once(codec, rung, x, session, t0, attempt),
                    budget)
            except asyncio.TimeoutError:
                self._pending.pop(session, None)
                self._m_deadlines.inc()
                raise TransportError(
                    f"submit deadline ({deadline_s}s) expired",
                    code=E_DEADLINE, retryable=False) from None
            except Exception as e:                  # noqa: BLE001
                stale = self._pending.pop(session, None)
                if stale is not None and stale.done() \
                        and not stale.cancelled():
                    stale.exception()   # mark observed (no warning noise)
                err = _as_transport_error(e)
                if err.retryable and self.retry is not None:
                    # a write failure can race the server's typed error
                    # frame: let the reader drain to EOF, then prefer the
                    # structured verdict (a fatal error must not be
                    # laundered into a retryable connection loss)
                    await self._settle_reader()
                    if self._dead is not None and not self._dead.retryable:
                        err = self._dead
                if (self.retry is None or not err.retryable
                        or attempt >= self.retry.max_retries):
                    raise err from e
                self._m_retries.inc()
                delay = self.retry.delay_s(attempt, self._rng)
                if deadline is not None \
                        and time.monotonic() + delay >= deadline:
                    self._m_deadlines.inc()
                    raise TransportError(
                        f"submit deadline ({deadline_s}s) expired "
                        f"(last error: {err})",
                        code=E_DEADLINE, retryable=False) from e
                attempt += 1
                await asyncio.sleep(delay)

    async def _submit_once(self, codec: FeatureCodec, rung,
                           x: np.ndarray, session: int, t0: float,
                           attempt: int) -> SubmitResult:
        fut = asyncio.get_running_loop().create_future()
        self._pending[session] = fut
        if self.tick is not None:
            coded = await self._submit_tick(codec, x, session)
        else:
            coded = 0
            acked = self._acked.get(session, ()) if attempt else ()
            gen = tensor_to_frames(codec, x, session,
                                   chunk_elems=self.chunk_elems,
                                   coder_mode=self.coder_mode)
            seq = 0
            while True:
                # chunk entropy-coding runs off-loop, overlapping the
                # socket
                frame_bytes = await asyncio.to_thread(next, gen, None)
                if frame_bytes is None:
                    break
                coded += len(frame_bytes)
                if seq in acked:
                    # server already holds this frame from before the
                    # reconnect: replay skips it (still costs the encode,
                    # which keeps the byte accounting identical)
                    self._m_skipped.inc()
                    seq += 1
                    continue
                seq += 1
                async with self._write_lock:
                    with span("socket_write", session=str(session)):
                        self._writer.write(frame_bytes)
                        await self._writer.drain()
                if self.rate_controller is not None:
                    buf = self._writer.transport.get_write_buffer_size()
                    self.rate_controller.on_queue_depth(buf // (1 << 16))
        send_s = time.perf_counter() - t0

        arrays = await fut
        total_s = time.perf_counter() - t0
        self._m_submit.observe(total_s)
        fb = self._feedback.pop(session, None)
        if self.rate_controller is not None:
            self.rate_controller.on_tensor(rung, coded, x.size,
                                           send_seconds=send_s)
        return SubmitResult(arrays=arrays, n_levels=codec.config.n_levels,
                            coded_bytes=coded, n_elems=int(x.size),
                            bits_per_elem=8.0 * coded / max(x.size, 1),
                            send_s=send_s, total_s=total_s, feedback=fb,
                            retries=attempt)


class SyncEdgeClient:
    """Blocking facade: owns an event loop on a daemon thread.

    Used by the serving launcher's ``--transport loopback`` path, where
    the split-boundary callback runs inside a jitted step and cannot
    await.
    """

    def __init__(self, *args, **kwargs) -> None:
        self._client = EdgeClient(*args, **kwargs)
        self._loop = asyncio.new_event_loop()
        self._thread = threading.Thread(target=self._loop.run_forever,
                                        name="edge-client", daemon=True)
        self._thread.start()
        self._run(self._client.connect())

    def _run(self, coro):
        return asyncio.run_coroutine_threadsafe(coro, self._loop).result()

    def submit(self, x: np.ndarray,
               codec: FeatureCodec | None = None,
               deadline_s: float | None = None) -> SubmitResult:
        return self._run(self._client.submit(x, codec=codec,
                                             deadline_s=deadline_s))

    def fetch_cloud_metrics(self) -> dict:
        return self._run(self._client.fetch_cloud_metrics())

    @property
    def metrics(self) -> MetricsRegistry:
        return self._client.metrics

    @property
    def encode_counters(self) -> dict:
        return self._client.encode_counters

    def close(self) -> None:
        self._run(self._client.close())
        self._loop.call_soon_threadsafe(self._loop.stop)
        self._thread.join(timeout=5)
        self._loop.close()
