"""Framed wire format for streaming split-inference sessions.

Everything that crosses the edge<->cloud socket is a *frame*:

    <HBBIII I>  magic  ver  type  session  seq  length  crc32(payload)
    payload[length]

* ``magic`` (0xC01D) + CRC make torn or corrupted streams fail loudly
  instead of desynchronizing the parser.
* ``session`` multiplexes concurrent tensors over one connection; frames
  of different sessions interleave freely, ordering only matters within
  a session (and chunk payloads carry their own chunk id anyway).
* ``seq`` is a per-session counter used for diagnostics.
* frame types: HEADER (stream meta + self-describing codec header),
  CHUNK (one entropy-coded chunk), END (end-of-tensor marker, payload =
  ``<I`` chunk count), RESULT (cloud -> edge arrays), FEEDBACK
  (cloud -> edge link stats for the rate controller), ERROR (structured
  code + retryable flag + message, see :mod:`repro.transport.errors`;
  legacy bare utf-8 text still parses), METRICS (edge -> cloud: empty
  request; cloud -> edge: JSON snapshot of the cloud's metrics
  registry -- telemetry only, never tensor bytes), HELLO (authenticated
  session establishment + resume handshake), PING (liveness echo).

:class:`FrameReader` is an incremental parser: feed it arbitrary byte
slices (single bytes included) and iterate complete frames.  See
DESIGN.md ("Transport framing and streaming sessions") for the protocol
rules built on top.
"""

from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

MAGIC = 0xC01D
VERSION = 1
_FRAME_FMT = "<HBBIII"          # magic, ver, type, session, seq, length
_FRAME_HEAD = struct.calcsize(_FRAME_FMT) + 4  # + crc32
MAX_PAYLOAD = 1 << 26           # 64 MiB sanity bound per frame

FT_HEADER = 1
FT_CHUNK = 2
FT_END = 3
FT_RESULT = 4
FT_FEEDBACK = 5
FT_ERROR = 6
FT_METRICS = 7
# session establishment + resume (edge -> cloud: JSON {token, auth};
# cloud -> edge: JSON ack {ok, resumed, acked}) -- must precede the
# first HEADER when the server requires authentication
FT_HELLO = 8
# liveness probe: the receiver echoes the payload back in an FT_PING
# frame (dispatcher <-> worker heartbeats)
FT_PING = 9


class FramingError(ValueError):
    """Corrupted or malformed wire data (bad magic, CRC, version)."""


@dataclasses.dataclass
class Frame:
    ftype: int
    session: int
    seq: int
    payload: bytes

    def encode(self) -> bytes:
        if len(self.payload) > MAX_PAYLOAD:
            raise FramingError(f"payload too large: {len(self.payload)}")
        head = struct.pack(_FRAME_FMT, MAGIC, VERSION, self.ftype,
                           self.session, self.seq, len(self.payload))
        return head + struct.pack("<I", zlib.crc32(self.payload)) \
            + self.payload


def encode_frame(ftype: int, session: int, seq: int,
                 payload: bytes = b"") -> bytes:
    return Frame(ftype, session, seq, payload).encode()


class FrameReader:
    """Incremental frame parser tolerant of arbitrary delivery boundaries.

    >>> r = FrameReader()
    >>> for b in wire_bytes: r.feed(bytes([b]))   # torn delivery is fine
    >>> frames = list(r)
    """

    def __init__(self) -> None:
        self._buf = bytearray()

    def feed(self, data: bytes) -> None:
        self._buf.extend(data)

    @property
    def pending_bytes(self) -> int:
        return len(self._buf)

    def _try_pop(self) -> Frame | None:
        if len(self._buf) < _FRAME_HEAD:
            return None
        magic, ver, ftype, session, seq, length = struct.unpack_from(
            _FRAME_FMT, self._buf)
        if magic != MAGIC:
            raise FramingError(f"bad magic 0x{magic:04x}")
        if ver != VERSION:
            raise FramingError(f"unsupported frame version {ver}")
        if length > MAX_PAYLOAD:
            raise FramingError(f"frame length {length} exceeds bound")
        if len(self._buf) < _FRAME_HEAD + length:
            return None
        (crc,) = struct.unpack_from("<I", self._buf,
                                    struct.calcsize(_FRAME_FMT))
        payload = bytes(self._buf[_FRAME_HEAD:_FRAME_HEAD + length])
        if zlib.crc32(payload) != crc:
            raise FramingError(f"payload CRC mismatch (session {session}, "
                               f"seq {seq})")
        del self._buf[:_FRAME_HEAD + length]
        return Frame(ftype, session, seq, payload)

    def __iter__(self):
        while True:
            frame = self._try_pop()
            if frame is None:
                return
            yield frame


# -- small array (de)serializer for RESULT payloads --------------------------

_DTYPES = {0: np.dtype("<f4"), 1: np.dtype("<i4"), 2: np.dtype("<u1"),
           3: np.dtype("<f2")}
_DTYPE_IDS = {v: k for k, v in _DTYPES.items()}


def pack_arrays(arrays: list[np.ndarray]) -> bytes:
    """``<B n>`` then per array ``<BB dims...>`` dtype-id, ndim, u32 dims,
    raw little-endian bytes."""
    out = [struct.pack("<B", len(arrays))]
    for a in arrays:
        a = np.ascontiguousarray(a)
        dt = a.dtype.newbyteorder("<")
        if dt not in _DTYPE_IDS:
            raise FramingError(f"unsupported dtype {a.dtype}")
        out.append(struct.pack("<BB", _DTYPE_IDS[dt], a.ndim))
        out.append(np.asarray(a.shape, "<u4").tobytes())
        out.append(a.astype(dt).tobytes())
    return b"".join(out)


def unpack_arrays(data: bytes) -> list[np.ndarray]:
    (n,) = struct.unpack_from("<B", data)
    off = 1
    out = []
    for _ in range(n):
        did, ndim = struct.unpack_from("<BB", data, off)
        off += 2
        dims = tuple(int(d) for d in np.frombuffer(data, "<u4", ndim, off))
        off += 4 * ndim
        dt = _DTYPES[did]
        count = int(np.prod(dims)) if dims else 1
        arr = np.frombuffer(data, dt, count, off).reshape(dims)
        off += count * dt.itemsize
        out.append(arr)
    return out
