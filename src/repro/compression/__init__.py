from .grad_compression import (GradCompressionConfig, compress_grads,
                               init_error_feedback, wire_bytes_ratio)

__all__ = ["GradCompressionConfig", "compress_grads", "init_error_feedback",
           "wire_bytes_ratio"]
