"""Collaborative-intelligence split runtime: the paper's edge/cloud system
mapped onto TPU pods.

Pod 0 plays the *edge* (front half of the network), pod 1 the *cloud*
(back half).  At the split boundary the activations are clipped, coarsely
quantized (paper eq. 1), bit-packed to uint8 lanes (2x4b / 8x1b per byte),
and crossed over the inter-pod links with ``lax.ppermute`` -- so the
inter-pod wire bytes drop by 4-16x vs raw bf16, which the dry-run measures
directly in the HLO collective-permute sizes.

The codec ops all route through the codec's ``QuantBackend``
(``repro.core.backend``): inside the jitted superstep body the quantize
lowers to the fused Pallas clip+quant kernel on TPU (the blocked per-tile
variant when the codec carries a TilePlan) and the jnp reference on CPU
hosts, and tiled codecs (``granularity="channel"`` with the d_model axis
as the channel axis, or ``"tile"`` when the boundary shape is static, as
it is inside a fixed-shape decode step) work unchanged -- the per-tile
range tables are baked into the program as constants.  ``codec.pack``
likewise dispatches to the in-graph Pallas pack kernel on TPU, so
clip+quant+pack is a fused on-device pipeline and only wire-width bytes
cross the inter-pod links.

Execution model is the paper's *serial* edge->cloud flow expressed in SPMD
as two supersteps over a shard_map'd 'pod' axis (stage weights are
pod-sharded; each pod applies its own half):

  superstep A: y = stage_local(x_embed)       (pod0 result is real)
               t = ppermute(pack(quant(y)), pod0 -> pod1)
  superstep B: y = stage_local(select(pod==1, dequant(t), x_embed))
               (pod1 result is now cloud(edge(x)))

Caches are pod-sharded alongside the stage weights; each pod keeps the
cache update from its own real superstep.  Supported for homogeneous
(period-1) architectures with >= 2 layers; odd layer counts put the extra
tail layer on the cloud side.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..core.codec import FeatureCodec
from ..models import transformer as T
from ..models.context import (DistContext, SHARD_MAP_PARTIAL_AUTO,
                              shard_map)


def split_supported(cfg: ModelConfig) -> bool:
    return cfg.period == 1 and cfg.num_layers >= 2


def stage_layout(cfg: ModelConfig) -> tuple[int, int]:
    """(layers per stage, tail layers appended on the cloud side)."""
    half = cfg.num_layers // 2
    return half, cfg.num_layers - 2 * half


def init_split_params(cfg: ModelConfig, key):
    """Params with layer stack reshaped to (2, half, ...) + tail (t, ...)."""
    if not split_supported(cfg):
        raise ValueError(f"{cfg.name}: split runtime needs a period-1 arch")
    params = T.init_params(cfg, key)
    half, tail = stage_layout(cfg)
    stack = params["groups"][0]["layers"]

    def split_leaf(a):
        main = a[: 2 * half].reshape(2, half, *a.shape[1:])
        return main

    out = dict(params)
    out["stages"] = jax.tree.map(split_leaf, stack)
    out["tail"] = jax.tree.map(lambda a: a[2 * half:], stack) if tail else None
    del out["groups"]
    return out


def _stage_apply(cfg, layers, x, cache, pos, positions, ctx):
    group = T.Group(cfg.pattern, layers_n(layers))
    return T._apply_group(x, {"layers": layers}, group, cfg, pos=pos,
                          gcache=cache, ctx=ctx, positions=positions)


def layers_n(layers) -> int:
    return jax.tree.leaves(layers)[0].shape[0]


def make_split_decode_step(cfg: ModelConfig, mesh, codec: FeatureCodec,
                           *, transport: str = "packed"):
    """Returns a jittable (params, token, caches, pos) -> (logits, caches).

    transport: 'packed' (quantized uint8 lanes), 'quantized_f16' (fake-quant
    but full-width transfer, the ablation), or 'raw' (bf16 baseline).
    """
    assert "pod" in mesh.axis_names, "split runtime needs the multi-pod mesh"
    # Sharding-constraint hints inside the manual 'pod' region need the
    # partially-automatic region mode; fully-manual regions reject
    # full-mesh NamedShardings.  They are perf hints, not correctness,
    # so the fully-manual path drops them.
    inner_ctx = DistContext(mesh, ("data",)) if SHARD_MAP_PARTIAL_AUTO \
        else None
    half, tail = stage_layout(cfg)
    d = cfg.d_model

    def body(pod_arr, stages, tail_p, embed, final_norm, head, token,
             stage_cache, tail_cache, pos):
        # pod identity arrives as a pod-sharded iota instead of
        # lax.axis_index: identical value, but it avoids the PartitionId
        # instruction that pre-0.6 XLA SPMD rejects under auto axes.
        pod = pod_arr[0]
        my_layers = jax.tree.map(lambda a: a[0], stages)  # (half, ...)
        base = {"embed": embed, "final_norm": final_norm}
        if head is not None:
            base["head"] = head
        x = T._embed_in(cfg, base, token[:, None], pos0=pos, ctx=inner_ctx)
        positions = jnp.full((1,), pos, dtype=jnp.int32)
        my_cache = jax.tree.map(lambda a: a[0], stage_cache)

        # ---- superstep A: edge half (pod 0's result is the real one) ----
        y_a, cache_a = _stage_apply(cfg, my_layers, x, my_cache, pos,
                                    positions, inner_ctx)
        # ---- transfer across the pod boundary ----
        if transport == "raw":
            recv = lax.ppermute(y_a, "pod", [(0, 1)])
            x_b = recv
            rate_bits = jnp.float32(jnp.finfo(jnp.bfloat16).bits)
        else:
            # backend-dispatched: fused Pallas clip+quant on TPU, jnp on CPU
            idx = codec.quantize(y_a)
            if transport == "packed":
                payload = codec.pack(idx.reshape(-1))
                recv = lax.ppermute(payload, "pod", [(0, 1)])
                idx_r = codec.unpack(recv, idx.size).reshape(idx.shape)
            else:  # quantized transfer at full index width
                recv = lax.ppermute(idx, "pod", [(0, 1)])
                idx_r = recv
            x_b = codec.dequantize(idx_r, dtype=y_a.dtype)
            rate_bits = codec.rate_from_indices(idx, idx.shape)

        # ---- superstep B: cloud half ----
        x_in_b = jnp.where(pod == 1, x_b, x)
        y_b, cache_b = _stage_apply(cfg, my_layers, x_in_b, my_cache, pos,
                                    positions, inner_ctx)
        new_stage_cache = jax.tree.map(
            lambda a, b: jnp.where(pod == 0, a, b)[None], cache_a, cache_b)

        # ---- tail layers + head (valid on pod 1) ----
        y = y_b
        new_tail_cache = tail_cache
        if tail_p is not None:
            y, new_tail_cache = _stage_apply(
                cfg, tail_p, y, tail_cache, pos, positions, inner_ctx)
        logits = T._logits_out(cfg, base, y, ctx=inner_ctx)[:, 0]
        # broadcast pod 1's logits to everyone (pod 0 holds garbage);
        # bf16 is plenty for the sampler and halves the return-path bytes
        lb = logits.astype(jnp.bfloat16)
        lb = lax.ppermute(lb, "pod", [(1, 0)]) * (pod == 0) + lb * (pod == 1)
        return lb.astype(jnp.float32), new_stage_cache, new_tail_cache, rate_bits

    pod_spec = lambda tree: jax.tree.map(lambda _: P("pod"), tree)
    rep = lambda tree: jax.tree.map(lambda _: P(), tree)

    def step(params, token, caches, pos):
        stage_cache, tail_cache = caches
        head = params.get("head")
        n_pods = mesh.shape["pod"]
        pod_ids = jnp.arange(n_pods, dtype=jnp.int32)
        in_specs = (P("pod"), pod_spec(params["stages"]),
                    rep(params["tail"]) if params["tail"] is not None else None,
                    rep(params["embed"]), rep(params["final_norm"]),
                    rep(head) if head is not None else None,
                    P(), pod_spec(stage_cache),
                    rep(tail_cache) if tail_cache is not None else None, P())
        out_specs = (P(), pod_spec(stage_cache),
                     rep(tail_cache) if tail_cache is not None else None, P())
        logits, sc, tc, rate = shard_map(
            body, mesh, in_specs, out_specs, manual_axes={"pod"},
        )(pod_ids, params["stages"], params["tail"], params["embed"],
          params["final_norm"], head, token, stage_cache, tail_cache, pos)
        return logits, (sc, tc), rate

    return step


def init_split_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """Stage caches stacked (2, half, ...) + tail cache."""
    half, tail = stage_layout(cfg)
    dtype = jnp.dtype(cfg.dtype)
    one = T._init_spec_cache(cfg.pattern[0], cfg, batch, max_seq, dtype)
    # _apply_group expects a list with one cache entry per pattern position
    stage = [jax.tree.map(
        lambda a: jnp.broadcast_to(a, (2, half) + a.shape), one)]
    tail_c = None
    if tail:
        tail_c = [jax.tree.map(
            lambda a: jnp.broadcast_to(a, (tail,) + a.shape), one)]
    return stage, tail_c
