"""Gradient compression with error feedback (beyond-paper application of the
paper's quantizer to distributed training).

Each gradient tensor is clipped to a model-derived range and quantized to
N levels (paper eq. 1) before the data-parallel reduction; the residual
(g - deq(q(g))) is carried in an error-feedback buffer and added back the
next step, which keeps SGD/Adam convergence intact (Karimireddy et al.
style EF).  Clipping ranges come from per-tensor moment estimates --
gradients are roughly symmetric, so we use a symmetric range +/- c where
c = clip_sigmas * std (the asymmetric-Laplace machinery applies when the
distribution is skewed, e.g. for activation gradients).

On real hardware the wire format is the packed uint8 index stream (4x
smaller than f32); in this repo's simulation the quantize->dequantize
happens before the psum so accuracy effects are exactly reproduced while
the byte saving is documented analytically.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from ..core import uniform


@dataclasses.dataclass(frozen=True)
class GradCompressionConfig:
    n_levels: int = 16          # 4-bit gradients
    clip_sigmas: float = 4.0
    enabled: bool = True


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(cfg: GradCompressionConfig, grads, ef_state):
    """Returns (compressed grads, new ef_state, metrics)."""
    if not cfg.enabled:
        return grads, ef_state, {"grad_compress_mse": jnp.float32(0)}

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        std = jnp.std(gf) + 1e-12
        c = cfg.clip_sigmas * std
        deq = uniform.quantize_dequantize(gf, -c, c, cfg.n_levels)
        # the residual must be measured against what is actually summed
        # in the reduction -- the value *after* the cast back to g.dtype.
        # Under bf16 the cast rounds deq, and EF only preserves the
        # convergence guarantee when cg + new_e == gf exactly (in f32).
        cg = deq.astype(g.dtype)
        new_e = gf - cg.astype(jnp.float32)
        return cg, new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = tree.flatten_up_to(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    cg = tree.unflatten([o[0] for o in out])
    ne = tree.unflatten([o[1] for o in out])
    mse = sum(jnp.mean(o[1] ** 2) for o in out) / max(len(out), 1)
    return cg, ne, {"grad_compress_mse": mse}


def wire_bytes_ratio(cfg: GradCompressionConfig) -> float:
    """Analytic wire saving vs f32 all-reduce (packed index stream)."""
    import math
    bits = max(1, math.ceil(math.log2(cfg.n_levels)))
    return bits / 32.0
