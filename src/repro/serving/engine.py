"""Batched serving engine: continuous batched decode with the paper's
feature codec applied at the split layer.

Slots hold independent requests; each engine step decodes one token for
every active slot (static-shape friendly).  Finished slots are *refilled
from the queue mid-flight*: a freed slot gets the next queued request
prefilled (batch-1, left-padded to the batch's current absolute length so
its cache positions line up with the shared position counter) and
scattered into the batched cache, so short requests free capacity instead
of holding the batch until the longest request finishes.  When every slot
is idle the engine starts a fresh epoch with a full-batch prefill (which
also admits prompts longer than the current position).

The codec path reports bits/element of the split-layer transfer per step,
and per-request wall-clock latency lands in ``latency_log``.
"""

from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.codec import FeatureCodec
from ..models import (decode_from_boundary, decode_step, decode_to_boundary,
                      init_cache, prefill, prefill_from_boundary,
                      prefill_to_boundary)
from ..obs.metrics import BPE_BUCKETS, MetricsRegistry
from ..obs.tracing import span

log = logging.getLogger(__name__)


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    t_admit: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        if self.t_admit is None or self.t_done is None:
            return None
        return self.t_done - self.t_admit


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, ctx=None, codec_fn=None,
                 codec: FeatureCodec | None = None, codec_host_fn=None,
                 refill_align: int = 1,
                 metrics: MetricsRegistry | None = None,
                 latency_log_size: int = 4096):
        """``codec`` is the preferred split-layer hookup: a calibrated
        :class:`FeatureCodec` (any granularity/backend) whose fused
        fake-quant + rate estimate is applied at the boundary.  The raw
        ``codec_fn`` callable ``x -> (x', rate_bits)`` remains for custom
        transforms.

        ``codec_host_fn`` is the *host round-trip* variant for codecs
        that leave jax entirely (socket transports, subprocess codecs):
        a plain ``numpy (B, S, d) -> (numpy recon, rate_bits)`` callable.
        The engine then compiles each stage as two jitted halves split
        at the collaborative-intelligence boundary and runs the callable
        eagerly between them.  Unlike an ``io_callback`` codec_fn, no
        host work ever executes beneath an in-flight jitted program --
        which deadlocks on a single-CPU host when the callback itself
        dispatches jax computations (the callback holds XLA's only
        dispatch thread while the nested work waits for it).

        ``refill_align``: admit mid-epoch refills only at positions that
        are multiples of this.  Every refill prefills at the current
        absolute length, so each *distinct* length jit-compiles once;
        raising the alignment bounds the compile set to
        ``max_seq / refill_align`` at the cost of freed slots idling up
        to ``refill_align - 1`` steps.

        ``metrics``: a :class:`MetricsRegistry` to register this engine's
        instruments in (fresh per engine by default, so tests and
        co-hosted engines never share series).  ``latency_log_size``
        bounds the per-request ``latency_log`` ring buffer -- a
        long-lived serving process keeps the recent window (p50/p99 are
        exposed via the registry), not an unbounded list."""
        self.cfg, self.params, self.ctx = cfg, params, ctx
        if sum(x is not None for x in (codec, codec_fn, codec_host_fn)) > 1:
            raise ValueError("pass at most one of codec, codec_fn, "
                             "codec_host_fn")
        if codec is not None:
            codec_fn = codec.apply_with_rate
        self.codec_fn = codec_fn
        self.codec_host_fn = codec_host_fn
        self.slots = slots
        self.max_seq = max_seq
        self.refill_align = max(1, refill_align)
        self.rate_log: collections.deque = collections.deque(maxlen=1 << 16)
        self.latency_log: collections.deque = collections.deque(
            maxlen=max(1, latency_log_size))
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m = {
            "steps": m.counter("repro_engine_steps_total",
                               "batched decode steps"),
            "slot_steps": m.counter("repro_engine_slot_steps_total",
                                    "slots * decode steps"),
            "active_slot_steps": m.counter(
                "repro_engine_active_slot_steps_total",
                "decode steps weighted by occupied slots"),
            "prefills": m.counter("repro_engine_prefills_total",
                                  "prefill launches (epochs + refills)"),
            "refills": m.counter("repro_engine_refills_total",
                                 "mid-epoch slot refills"),
            "epochs": m.counter("repro_engine_epochs_total",
                                "full-batch prefill epochs"),
        }
        self._m_requests = m.counter("repro_engine_requests_total",
                                     "requests retired")
        self._m_latency = m.histogram(
            "repro_engine_request_latency_seconds",
            "request wall-clock latency (admit -> retire)")
        self._m_lat_p50 = m.gauge(
            "repro_engine_request_latency_p50_seconds",
            "p50 latency over the latency_log ring buffer")
        self._m_lat_p99 = m.gauge(
            "repro_engine_request_latency_p99_seconds",
            "p99 latency over the latency_log ring buffer")
        self._m_bpe = m.histogram(
            "repro_engine_split_rate_bpe",
            "split-layer coded bits/element per decode step",
            buckets=BPE_BUCKETS)

        if codec_host_fn is not None:
            self._prefill_pre = jax.jit(
                lambda p, t, c: prefill_to_boundary(cfg, p, t, c, ctx=ctx))
            self._prefill_post = jax.jit(
                lambda p, x, c: prefill_from_boundary(cfg, p, x, c, ctx=ctx))
            self._decode_pre = jax.jit(
                lambda p, t, c, pos: decode_to_boundary(cfg, p, t, c, pos,
                                                        ctx=ctx))
            self._decode_post = jax.jit(
                lambda p, x, c, pos: decode_from_boundary(cfg, p, x, c, pos,
                                                          ctx=ctx))
            self._prefill = self._split_prefill
            self._decode = self._split_decode
        else:
            self._prefill = jax.jit(
                lambda p, t, c: prefill(cfg, p, t, c, ctx=ctx,
                                        codec_fn=codec_fn))
            self._decode = jax.jit(
                lambda p, t, c, pos: decode_step(cfg, p, t, c, pos, ctx=ctx,
                                                 codec_fn=codec_fn))

    def _split_prefill(self, p, toks, cache):
        """Prefill as two jitted halves with the host codec round-trip
        run eagerly in between (``codec_host_fn`` mode)."""
        x, pre = self._prefill_pre(p, toks, cache)
        recon, _ = self.codec_host_fn(np.asarray(x, np.float32))
        logits, post = self._prefill_post(p, jnp.asarray(recon), cache)
        return logits, list(pre) + list(post)

    def _split_decode(self, p, cur, cache, pos):
        x, pre = self._decode_pre(p, cur, cache, pos)
        recon, rate = self.codec_host_fn(np.asarray(x, np.float32))
        logits, post = self._decode_post(p, jnp.asarray(recon), cache, pos)
        return logits, list(pre) + list(post), \
            {"codec_rate_bits": np.float32(rate)}

    # -- scheduling -----------------------------------------------------------

    def generate(self, requests: list[Request], greedy: bool = True):
        """Run all requests to completion (continuous batching with slot
        refill)."""
        for r in requests:
            if len(r.prompt) + r.max_new_tokens > self.max_seq:
                raise ValueError(
                    f"request needs {len(r.prompt) + r.max_new_tokens} "
                    f"cache positions, engine has max_seq={self.max_seq}")
        queue = list(requests)
        active: list[Request | None] = [None] * self.slots
        cache = None
        cur = None          # (slots,) next token per slot
        pos = 0             # shared absolute position of the next decode

        while queue or any(r is not None for r in active):
            if all(r is None for r in active):
                cache, cur, pos = self._start_epoch(queue, active)
                continue
            # one decode step for every slot (finished/empty slots ride
            # along; their logits are ignored)
            for i, r in enumerate(active):
                if r is None:
                    continue
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(cur[i]))
                if len(r.out_tokens) >= r.max_new_tokens:
                    self._retire(active, i)
            if all(r is None for r in active) and not queue:
                break
            if pos % self.refill_align == 0:
                for i in range(self.slots):
                    if active[i] is None and queue:
                        cache, cur = self._refill(queue, active, i, cache,
                                                  cur, pos)
            if all(r is None for r in active):
                continue    # nothing admitted (prompts too long for pos)
            self._m["steps"].inc()
            self._m["slot_steps"].inc(self.slots)
            self._m["active_slot_steps"].inc(sum(
                r is not None for r in active))
            lg, cache, aux = self._decode(self.params, cur, cache,
                                          jnp.int32(pos))
            if "codec_rate_bits" in aux:
                bpe = float(aux["codec_rate_bits"])
                self.rate_log.append(bpe)
                self._m_bpe.observe(bpe)
            cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
            pos += 1
        return requests

    def _retire(self, active: list, i: int) -> None:
        r = active[i]
        r.done = True
        r.t_done = time.perf_counter()
        self.latency_log.append({
            "slot": i, "prompt_len": int(len(r.prompt)),
            "new_tokens": len(r.out_tokens), "latency_s": r.latency_s,
        })
        self._m_requests.inc()
        self._m_latency.observe(r.latency_s)
        lat = [d["latency_s"] for d in self.latency_log]
        self._m_lat_p50.set(float(np.percentile(lat, 50)))
        self._m_lat_p99.set(float(np.percentile(lat, 99)))
        log.info("request done: slot=%d prompt_len=%d tokens=%d "
                 "latency=%.3fs", i, len(r.prompt), len(r.out_tokens),
                 r.latency_s)
        active[i] = None

    def _admissible(self, r: Request, plen: int) -> bool:
        """Can ``r`` be prefilled at padded length ``plen``?"""
        return len(r.prompt) <= plen \
            and plen + r.max_new_tokens <= self.max_seq

    @property
    def counters(self) -> dict:
        """Structured serving metrics (the observability satellite):
        slot occupancy of the continuous batch, admission churn, the
        split-layer rate actually spent, and request-latency percentiles
        over the ``latency_log`` window.  The same numbers live as
        ``repro_engine_*`` instruments in :attr:`metrics`."""
        t = {k: int(c.value()) for k, c in self._m.items()}
        return {
            **t,
            "batch_occupancy_avg": (t["active_slot_steps"]
                                    / max(t["slot_steps"], 1)),
            "split_bpe_avg": (float(np.mean(self.rate_log))
                              if self.rate_log else 0.0),
            "requests_done": int(self._m_requests.value()),
            "request_latency_p50_s": self._m_lat_p50.value(),
            "request_latency_p99_s": self._m_lat_p99.value(),
        }

    def _start_epoch(self, queue: list, active: list):
        """Full-batch prefill of up to ``slots`` queued requests."""
        batch = [queue.pop(0) for _ in range(min(self.slots, len(queue)))]
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((self.slots, plen), np.int32)
        t_admit = time.perf_counter()
        for i, r in enumerate(batch):
            toks[i, plen - len(r.prompt):] = r.prompt  # left-pad with 0
            r.t_admit = t_admit
            active[i] = r
        cache = init_cache(self.cfg, batch=self.slots, max_seq=self.max_seq,
                           split=self.codec_fn is not None
                           or self.codec_host_fn is not None)
        self._m["epochs"].inc()
        self._m["prefills"].inc()
        with span("prefill", batch=len(batch)):
            logits, cache = self._prefill(self.params, jnp.asarray(toks),
                                          cache)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # zero-token requests retire immediately
        for i, r in enumerate(batch):
            if r.max_new_tokens <= 0:
                self._retire(active, i)
        return cache, cur, plen

    def _refill(self, queue: list, active: list, slot: int, cache, cur,
                pos: int):
        """Admit the next queued request into a freed slot mid-epoch.

        The prompt is left-padded to the batch's current absolute length
        ``pos`` and prefilled at batch size 1, then its cache is scattered
        into the batched cache (batch is axis 1 of every cache leaf --
        leaves are stacked (n_periods, batch, ...)), so the shared
        position counter stays valid for every slot.  Requests whose
        prompt is longer than ``pos`` (or that would overflow ``max_seq``)
        wait for a fresh epoch.
        """
        k = next((j for j, r in enumerate(queue)
                  if self._admissible(r, pos)), None)
        if k is None:
            return cache, cur
        r = queue.pop(k)
        if r.max_new_tokens <= 0:
            r.t_admit = time.perf_counter()
            active[slot] = r
            self._retire(active, slot)
            return cache, cur
        toks = np.zeros((1, pos), np.int32)
        toks[0, pos - len(r.prompt):] = r.prompt
        one = init_cache(self.cfg, batch=1, max_seq=self.max_seq,
                         split=self.codec_fn is not None
                         or self.codec_host_fn is not None)
        r.t_admit = time.perf_counter()
        self._m["refills"].inc()
        self._m["prefills"].inc()
        with span("prefill", batch=1, refill=True):
            logits, one = self._prefill(self.params, jnp.asarray(toks), one)
        cache = jax.tree.map(lambda full, o: full.at[:, slot].set(o[:, 0]),
                             cache, one)
        first = jnp.argmax(logits[0]).astype(jnp.int32)
        cur = cur.at[slot].set(first)
        active[slot] = r
        # this iteration's append phase already ran, so the refilled
        # request's first generated token is recorded here (it is fed to
        # the model at this iteration's decode); the next append phase
        # then records token two
        r.out_tokens.append(int(first))
        if len(r.out_tokens) >= r.max_new_tokens:
            self._retire(active, slot)
        return cache, cur