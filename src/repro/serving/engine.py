"""Batched serving engine: continuous batched decode with the paper's
feature codec applied at the split layer.

Slots hold independent requests; each engine step decodes one token for
every active slot (static-shape friendly).  Finished slots are refilled
from the queue -- the standard continuous-batching pattern, kept minimal.
The codec path reports bits/element of the split-layer transfer per step.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.codec import FeatureCodec
from ..models import decode_step, init_cache, prefill


@dataclasses.dataclass
class Request:
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int = 16
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 256, ctx=None, codec_fn=None,
                 codec: FeatureCodec | None = None):
        """``codec`` is the preferred split-layer hookup: a calibrated
        :class:`FeatureCodec` (any granularity/backend) whose fused
        fake-quant + rate estimate is applied at the boundary.  The raw
        ``codec_fn`` callable ``x -> (x', rate_bits)`` remains for custom
        transforms."""
        self.cfg, self.params, self.ctx = cfg, params, ctx
        if codec is not None:
            if codec_fn is not None:
                raise ValueError("pass either codec or codec_fn, not both")
            codec_fn = codec.apply_with_rate
        self.codec_fn = codec_fn
        self.slots = slots
        self.max_seq = max_seq
        self.rate_log: list[float] = []

        self._prefill = jax.jit(
            lambda p, t, c: prefill(cfg, p, t, c, ctx=ctx, codec_fn=codec_fn))
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(cfg, p, t, c, pos, ctx=ctx,
                                             codec_fn=codec_fn))

    def generate(self, requests: list[Request], greedy: bool = True):
        """Run all requests to completion (simple same-length batching)."""
        for i in range(0, len(requests), self.slots):
            self._run_batch(requests[i:i + self.slots])
        return requests

    def _run_batch(self, batch: list[Request]):
        n = len(batch)
        plen = max(len(r.prompt) for r in batch)
        toks = np.zeros((n, plen), np.int32)
        for i, r in enumerate(batch):
            toks[i, -len(r.prompt):] = r.prompt  # left-pad with 0
        cache = init_cache(self.cfg, batch=n, max_seq=self.max_seq,
                           split=self.codec_fn is not None)
        logits, cache = self._prefill(self.params, jnp.asarray(toks), cache)
        cur = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        steps = max(r.max_new_tokens for r in batch)
        for t in range(steps):
            for i, r in enumerate(batch):
                if len(r.out_tokens) < r.max_new_tokens:
                    r.out_tokens.append(int(cur[i]))
            lg, cache, aux = self._decode(self.params, cur, cache,
                                          jnp.int32(plen + t))
            if "codec_rate_bits" in aux:
                self.rate_log.append(float(aux["codec_rate_bits"]))
            cur = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        for r in batch:
            r.done = True
