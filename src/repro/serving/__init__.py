from .batcher import (DecodeBatcher, TickConfig, TickStats, encode_tick,
                      split_coded, stack_group)
from .engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine", "TickConfig", "TickStats",
           "DecodeBatcher", "encode_tick", "stack_group", "split_coded"]
