"""Cross-session batching: one megakernel launch + one entropy call per
serving tick.

A cloud worker serving many concurrent edge sessions pays per-request
dispatch on today's per-session path: every boundary tensor is its own
``backend.encode_fused`` launch and its own entropy-coder call.  The
batched primitives underneath (``rans.encode_planes_batch``,
``cabac.encode_indices_batch``/``decode_indices_batch``, the fused
encode megakernel) all amortize per-call overhead across inputs -- this
module is the session-crossing layer that feeds them whole *ticks*
instead of single tensors:

    gather   -- concurrent sessions' tensors queue up for one tick
                (bounded by ``TickConfig.max_wait_s`` / ``max_batch``,
                so single-session latency degrades gracefully);
    group    -- tensors are keyed by (codec, shape): every group shares
                one TilePlan geometry, so the stacked launch stays
                jit-static and tile tables extend by pure replication;
    launch   -- each group stacks into one ``encode_fused`` call
                (``<= ceil(sessions / max_batch)`` launches per tick),
                and ALL groups' chunk segments share ONE batched entropy
                call (per-segment n_levels: mixed rungs coexist);
    scatter  -- per-session payload lists come back byte-identical to
                ``FeatureCodec.encode_stream`` (the v1-v4 conformance
                gate), so nothing on the wire changes.

The decode mirror (:class:`DecodeBatcher` + ``codec.flush_decoders``)
accumulates arrived chunks across sessions in deferred-mode
:class:`~repro.core.codec.ChunkStreamDecoder` instances and drains them
through one batched entropy pass per tick.

**Why byte-identity holds for stacked launches.**  Quantization is
elementwise with per-tile ranges, so stacking K same-shape tensors on a
new leading axis quantizes bit-exactly iff every element lands in a
stacked tile carrying its original tile's tables.  Build the stack from
channel-last views ``moveaxis(x, axis, -1)`` -- the coded-order spatial
enumeration of each tensor is preserved -- and extend the plan along the
spatial extent:

  * per-tensor (no plan): flat concatenation; any shapes mix;
  * "channel" (one spatial block): stacked (K, M, C) under an
    extent-free plan -- tiles are channel groups, tables unchanged;
  * 1-D tile: stackable iff ``M % block_size == 0`` (stacked blocks then
    never straddle tensors); tables tile K-fold along the block axis;
  * 2-D tile: stackable iff ``H % bh == 0`` (stacked row-blocks never
    straddle tensors) under a ``(K*H, W)`` grid; tables tile K-fold.

In every stacked case tensor k's spatial positions get block ids
``k * n_sblocks + s`` with ``s`` its per-tensor id, so the stable
coded-order sort keeps tensor k's elements contiguous and in per-tensor
order: the coded stack reshapes to (C, K, M) and session k's coded
indices are exactly ``[:, k, :]``.  Non-stackable groups (ragged tile
blocks) fall back to per-session launches but still join the tick's
single entropy call.
"""

from __future__ import annotations

import dataclasses
import os
import struct
import time

import jax.numpy as jnp
import numpy as np

from ..core import cabac
from ..core.backend import QuantSpec
from ..core.codec import _STREAM_META_FMT, FeatureCodec, flush_decoders
from ..core.tiling import TileECSQ, TilePlan
from ..obs.metrics import MetricsRegistry
from ..obs.tracing import span

# transport's DEFAULT_CHUNK_ELEMS without importing transport (serving
# must not depend on the wire layer); the value is asserted equal in
# tests/test_batcher.py
DEFAULT_CHUNK_ELEMS = 1 << 18


@dataclasses.dataclass(frozen=True)
class TickConfig:
    """Bounds of one batching tick.

    ``max_wait_s`` caps how long the first tensor of a tick waits for
    company (the single-session latency floor); ``max_batch`` caps how
    many sessions stack into one fused launch (device-memory bound);
    ``max_chunks`` is the decode-side drain trigger (a tick drains early
    once this many chunks pend across sessions).
    """

    max_wait_s: float = 0.002
    max_batch: int = 16
    max_chunks: int = 512
    chunk_elems: int = DEFAULT_CHUNK_ELEMS
    coder_mode: str = "auto"
    # device-resident entropy (coder id 4): None defers to the
    # REPRO_ENTROPY_DEVICE env opt-in (only with coder_mode "auto")
    device_entropy: bool | None = None

    def __post_init__(self):
        if self.max_wait_s < 0:
            raise ValueError("max_wait_s must be >= 0")
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_chunks < 1:
            raise ValueError("max_chunks must be >= 1")


@dataclasses.dataclass
class TickStats:
    """What one encode tick actually dispatched (observability + the
    launch-count acceptance gate)."""

    sessions: int = 0
    groups: int = 0
    fused_launches: int = 0
    entropy_calls: int = 0
    stacked_sessions: int = 0      # sessions that shared a stacked launch
    elems: int = 0
    coded_bytes: int = 0
    encode_s: float = 0.0


# -- stacked-launch construction ---------------------------------------------


def _tile_table_k(table: np.ndarray, k: int) -> np.ndarray:
    """(G, S) per-tile table -> (G, k*S): stacked block ``k*S + s``
    carries the tables of per-tensor block ``s``."""
    g = table.shape[0]
    return np.tile(table[:, None, :], (1, k, 1)).reshape(g, -1)


def _tile_ecsq_k(rows: np.ndarray, plan: TilePlan, k: int) -> np.ndarray:
    """(n_tiles, N) per-tile ECSQ rows -> stacked flat tile order
    (stacked tile ``g * k*S + (k'*S + s)`` = per-tensor tile
    ``g * S + s``)."""
    g, s = plan.n_cgroups, plan.n_sblocks
    a = np.asarray(rows).reshape(g, s, -1)
    return np.tile(a[:, None], (1, k, 1, 1)).reshape(g * k * s, -1)


def stack_group(codec: FeatureCodec, xs: list[np.ndarray]):
    """Build the one-launch view of ``len(xs)`` same-shape tensors.

    Returns ``(stacked_input, stacked_spec)`` quantizing bit-exactly like
    per-tensor passes (see module docstring), or ``None`` when the plan
    geometry cannot stack (ragged tile blocks) -- the caller then falls
    back to per-session launches.
    """
    plan = codec.plan
    k = len(xs)
    if plan is None:
        flat = np.concatenate([np.asarray(x).reshape(-1) for x in xs])
        return flat, codec.spec()
    shape = xs[0].shape
    axis, c, m = plan.resolve(shape)
    views = [np.moveaxis(np.asarray(x), axis, -1) for x in xs]
    if plan.is_2d:
        h, w = plan.spatial_hw
        bh, _ = plan.spatial_block_hw
        if h % bh:
            return None
        stacked = np.stack([v.reshape(h, w, c) for v in views])
        splan = TilePlan(channel_axis=-1,
                         channel_group_size=plan.channel_group_size,
                         spatial_block_size=0, n_channels=c,
                         spatial_extent=k * m, spatial_hw=(k * h, w),
                         spatial_block_hw=plan.spatial_block_hw)
        reps = k
    elif plan.spatial_block_size > 0:
        if m % plan.spatial_block_size:
            return None
        stacked = np.stack([v.reshape(m, c) for v in views])
        splan = TilePlan(channel_axis=-1,
                         channel_group_size=plan.channel_group_size,
                         spatial_block_size=plan.spatial_block_size,
                         n_channels=c, spatial_extent=k * m)
        reps = k
    else:   # "channel": one extent-free spatial block, tiles = ch groups
        stacked = np.stack([v.reshape(m, c) for v in views])
        splan = TilePlan(channel_axis=-1,
                         channel_group_size=plan.channel_group_size,
                         spatial_block_size=0, n_channels=c)
        reps = 1
    lo, hi = codec.tile_tables()
    if reps > 1:
        lo, hi = _tile_table_k(lo, reps), _tile_table_k(hi, reps)
    ecsq = codec.tile_ecsq
    if ecsq is not None and reps > 1:
        ecsq = TileECSQ(_tile_ecsq_k(ecsq.levels, plan, reps),
                        _tile_ecsq_k(ecsq.thresholds, plan, reps))
    return stacked, QuantSpec(lo, hi, codec.config.n_levels, -1, ecsq,
                              splan)


def split_coded(codec: FeatureCodec, coded: np.ndarray,
                xs: list[np.ndarray]) -> list[np.ndarray]:
    """Per-session coded-order indices out of a stacked launch's output
    (each slice byte-feeds the entropy stage identically to a per-tensor
    ``codec._fused_indices`` run)."""
    plan = codec.plan
    if plan is None:
        bounds = np.cumsum([0] + [int(np.asarray(x).size) for x in xs])
        return [coded[bounds[i]:bounds[i + 1]] for i in range(len(xs))]
    _, c, m = plan.resolve(xs[0].shape)
    rows = np.asarray(coded).reshape(c, len(xs), m)
    return [np.ascontiguousarray(rows[:, i, :]).reshape(-1)
            for i in range(len(xs))]


def split_coded_device(codec: FeatureCodec, coded,
                       xs: list[np.ndarray]) -> list:
    """:func:`split_coded` staying in-graph: device slices of a stacked
    launch's device coded-order output (the device-entropy tick path)."""
    plan = codec.plan
    if plan is None:
        bounds = np.cumsum([0] + [int(np.asarray(x).size) for x in xs])
        return [coded[int(bounds[i]):int(bounds[i + 1])]
                for i in range(len(xs))]
    _, c, m = plan.resolve(xs[0].shape)
    rows = coded.reshape(c, len(xs), m)
    return [rows[:, i, :].reshape(-1) for i in range(len(xs))]


# -- encode tick -------------------------------------------------------------


def encode_tick(items, cfg: TickConfig = TickConfig()
                ) -> tuple[list[list[bytes]], TickStats]:
    """Encode one tick of ``(codec, tensor)`` pairs.

    Returns one payload list per item, each byte-identical to
    ``list(codec.encode_stream(x, chunk_elems=cfg.chunk_elems,
    coder_mode=cfg.coder_mode))``, plus the tick's dispatch stats.
    Same-(codec, shape) items share stacked ``encode_fused`` launches of
    up to ``cfg.max_batch`` sessions; every chunk of every item is
    entropy-coded in ONE :func:`cabac.encode_indices_batch` call.
    """
    t0 = time.perf_counter()
    stats = TickStats(sessions=len(items))
    items = [(codec, np.asarray(x, np.float32)) for codec, x in items]
    coded: list = [None] * len(items)
    dev = cfg.device_entropy if cfg.device_entropy is not None else (
        cfg.coder_mode == "auto"
        and os.environ.get("REPRO_ENTROPY_DEVICE") == "1")

    groups: dict[tuple, list[int]] = {}
    for i, (codec, x) in enumerate(items):
        # per-tensor codecs concatenate flat, so any shapes mix; plans
        # are positional and need one geometry per group
        key = (id(codec),) if codec.plan is None else (id(codec), x.shape)
        groups.setdefault(key, []).append(i)
    stats.groups = len(groups)

    for members in groups.values():
        codec = items[members[0]][0]
        for b0 in range(0, len(members), cfg.max_batch):
            batch = members[b0:b0 + cfg.max_batch]
            xs = [items[i][1] for i in batch]
            with span("stack_scatter", sessions=len(batch)):
                stacked = stack_group(codec, xs) if len(batch) > 1 else None
            if stacked is None:
                for i in batch:
                    if dev:
                        coded[i] = codec.backend.coded_indices_device(
                            jnp.asarray(items[i][1]), codec.spec(),
                            codec.bits_per_index())
                    else:
                        coded[i] = codec._fused_indices(items[i][1])[0]
                    stats.fused_launches += 1
                continue
            x_s, spec_s = stacked
            if dev:
                out = codec.backend.coded_indices_device(
                    jnp.asarray(x_s), spec_s, codec.bits_per_index())
            else:
                out = codec.backend.encode_fused(jnp.asarray(x_s), spec_s,
                                                 codec.bits_per_index())[0]
            stats.fused_launches += 1
            stats.stacked_sessions += len(batch)
            with span("stack_scatter", sessions=len(batch)):
                split = split_coded_device if dev else split_coded
                for i, part in zip(batch, split(codec, out, xs)):
                    coded[i] = part

    # every chunk segment of the tick through one batched entropy call;
    # payloads are per-segment independent, so this is byte-identical to
    # encode_stream's per-stream batches.  The device-entropy path keeps
    # the same shape as one dispatch-all + finalize-all pass: every
    # session's chunk stages launch before any payload's (bytes-only)
    # D2H drains, so each transfer overlaps the next chunk's step loops.
    segments: list[np.ndarray] = []
    seg_levels: list[int] = []
    seg_owner: list[int] = []
    headers: list[bytes] = []
    chunking: list[tuple[int, int]] = []      # (chunk_elems, n_chunks)
    bounds_per: list[list[tuple[int, int]]] = []
    with span("framing", sessions=len(items)):
        for i, (codec, x) in enumerate(items):
            chunk_elems = cfg.chunk_elems
            if codec.plan is not None:
                chunk_elems = codec.plan.align_chunk_elems(chunk_elems,
                                                           x.shape)
            n = int(x.size)
            n_chunks = max(1, -(-n // chunk_elems))
            header, _ = codec._header(x)
            meta = struct.pack(_STREAM_META_FMT, chunk_elems, n_chunks,
                               x.ndim)
            meta += np.asarray(x.shape, "<u4").tobytes()
            headers.append(meta + header)
            chunking.append((chunk_elems, n_chunks))
            if dev:
                bounds_per.append(
                    [(c * chunk_elems, min((c + 1) * chunk_elems, n))
                     for c in range(n_chunks)])
            else:
                idx = coded[i]
                for c in range(n_chunks):
                    segments.append(
                        idx[c * chunk_elems:(c + 1) * chunk_elems])
                    seg_levels.append(codec.config.n_levels)
                    seg_owner.append(i)
            stats.elems += n
    if dev:
        from ..kernels import rans_coder
        with span("entropy_encode",
                  chunks=sum(len(b) for b in bounds_per)):
            pend = [rans_coder.dispatch_index_chunks(
                coded[i], codec.config.n_levels, bounds_per[i],
                use_kernel=codec.backend.name == "kernel",
                interpret=getattr(codec.backend, "interpret", None))
                for i, (codec, _) in enumerate(items)]
            blobs = [b for p in pend
                     for b in rans_coder.finalize_index_chunks(p)]
        seg_owner = [i for i, bl in enumerate(bounds_per) for _ in bl]
    else:
        with span("entropy_encode", chunks=len(segments)):
            blobs = cabac.encode_indices_batch(segments, seg_levels,
                                               mode=cfg.coder_mode)
    stats.entropy_calls = 1

    with span("framing", sessions=len(items)):
        payloads: list[list[bytes]] = [[h] for h in headers]
        next_cid = [0] * len(items)
        for owner, blob in zip(seg_owner, blobs):
            cid = next_cid[owner]
            next_cid[owner] += 1
            payloads[owner].append(struct.pack("<I", cid) + blob)
        stats.coded_bytes = sum(len(p) for pl in payloads for p in pl)
    stats.encode_s = time.perf_counter() - t0
    return payloads, stats


# -- decode tick -------------------------------------------------------------


class DecodeBatcher:
    """Cross-session decode coordinator (transport-agnostic).

    Deferred-mode :class:`ChunkStreamDecoder` instances register here as
    chunks arrive; :meth:`drain` runs ONE batched entropy pass over every
    pending chunk of every session (``codec.flush_decoders``) and
    reports per-decoder failures so one corrupt session never poisons a
    tick.  The event-loop scheduling around it (max-wait timers,
    max-chunk triggers) lives with the transport; this class only owns
    the registry and the counters.
    """

    def __init__(self, metrics: MetricsRegistry | None = None) -> None:
        self._decoders: dict[int, object] = {}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        m = self.metrics
        self._m_ticks = m.counter(
            "repro_decode_ticks_total", "batched decode drains")
        self._m_calls = m.counter(
            "repro_decode_entropy_calls_total",
            "batched entropy-decode calls (one per non-empty drain)")
        self._m_chunks = m.counter(
            "repro_decode_chunks_total", "entropy-decoded chunks")
        self._m_sessions = m.counter(
            "repro_decode_sessions_total", "sessions drained")
        self._m_elems = m.counter(
            "repro_decode_elements_total", "elements entropy-decoded")
        self._m_entropy_s = m.counter(
            "repro_decode_entropy_seconds_total",
            "wall time inside batched entropy decode")

    @property
    def counters(self) -> dict:
        """Legacy dict view of the registry instruments."""
        return {"ticks": int(self._m_ticks.value()),
                "entropy_calls": int(self._m_calls.value()),
                "chunks": int(self._m_chunks.value()),
                "sessions": int(self._m_sessions.value()),
                "elems": int(self._m_elems.value()),
                "entropy_s": self._m_entropy_s.value()}

    def note(self, decoder) -> None:
        """Register a decoder that has pending (undrained) chunks."""
        if decoder.pending_chunks:
            self._decoders[id(decoder)] = decoder

    def discard(self, decoder) -> None:
        """Forget a decoder (session disconnected mid-tick); the others
        are untouched."""
        self._decoders.pop(id(decoder), None)

    @property
    def pending_chunks(self) -> int:
        return sum(d.pending_chunks for d in self._decoders.values())

    @property
    def pending_sessions(self) -> int:
        return len(self._decoders)

    def drain(self) -> list:
        """One batched entropy pass over all registered decoders.
        Returns ``(decoder, exception)`` pairs for failed sessions."""
        decs = [d for d in self._decoders.values() if d.pending_chunks]
        self._decoders.clear()
        if not decs:
            return []
        t0 = time.perf_counter()
        n_chunks, n_elems, failures = flush_decoders(decs)
        self._m_ticks.inc()
        self._m_calls.inc()
        self._m_chunks.inc(n_chunks)
        self._m_sessions.inc(len(decs))
        self._m_elems.inc(n_elems)
        self._m_entropy_s.inc(time.perf_counter() - t0)
        return failures
