"""AdamW in plain JAX pytrees, shard-friendly.

Moments are float32 regardless of param dtype (mixed-precision master
statistics); states inherit the parameter sharding rules, so under
FSDP+TP the optimizer is fully ZeRO-sharded with no extra code.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip_norm: float = 1.0


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip_norm / (gnorm + 1e-9))
    lr = cfg.lr * lr_scale
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * g * g
        update = (mu / b1c) / (jnp.sqrt(nu / b2c) + cfg.eps)
        if p.ndim >= 2:  # decay matrices only, like standard practice
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * update).astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_state = {"mu": treedef.unflatten([o[1] for o in out]),
                 "nu": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_p, new_state, {"grad_norm": gnorm, "lr": lr}
