"""Fault-tolerant, mesh-agnostic checkpointing.

Layout: one .npz per pytree leaf-group + a JSON manifest; writes go to a
temp directory that is atomically renamed, so a crash mid-save never
corrupts the latest checkpoint.  Restore takes a *target* shape tree and
(optionally) shardings for a possibly different mesh -- elastic rescaling
is a restore with new shardings, nothing more.

Async mode snapshots to host memory and writes on a background thread so
the train loop never blocks on disk.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import numpy as np

import jax


def _flat_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(e, "key", getattr(e, "idx", e))) for e in path)
        out.append((key, leaf))
    return out


def save(ckpt_dir: str, step: int, tree, *, keep: int = 3,
         async_: bool = False):
    """Save pytree (arrays gathered to host) as checkpoint ``step``."""
    host = {k: np.asarray(v) for k, v in _flat_with_paths(tree)}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        tmp = final + ".tmp"
        os.makedirs(tmp, exist_ok=True)
        np.savez(os.path.join(tmp, "arrays.npz"), **host)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, "keys": sorted(host)}, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        _gc(ckpt_dir, keep)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_")
                   and not d.endswith(".tmp"))
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, target_tree, shardings=None):
    """Restore into the structure of ``target_tree``; reshard if given.

    ``shardings`` may come from a different mesh than the one that saved
    the checkpoint (elastic restore): arrays are device_put against the
    new shardings.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}", "arrays.npz")
    data = np.load(path)
    keys = [k for k, _ in _flat_with_paths(target_tree)]
    leaves = []
    for (k, ref_leaf) in _flat_with_paths(target_tree):
        arr = data[k]
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(f"{k}: ckpt shape {arr.shape} != target "
                             f"{ref_leaf.shape}")
        leaves.append(arr.astype(ref_leaf.dtype))
    treedef = jax.tree_util.tree_structure(target_tree)
    restored = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        restored = jax.tree.map(
            lambda a, s: jax.device_put(a, s), restored, shardings)
    return restored
