"""Training loop with the fault-tolerance substrate wired in:

  * periodic async checkpoints (atomic, mesh-agnostic);
  * automatic resume from the latest checkpoint (data stream replays
    deterministically from the restored step -- no data-state files);
  * failure injection for tests (raise at step k, restart, bit-exact
    continuation);
  * optional gradient compression with error feedback (the paper's
    quantizer applied to DP reductions);
  * straggler mitigation hook: a per-step watchdog records steps whose
    wall time exceeds ``straggler_factor`` x the running median -- on a
    real cluster this feeds the scheduler's replace/restart policy (here
    it is exercised by tests and logged).
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from ..compression import (GradCompressionConfig, compress_grads,
                           init_error_feedback)
from ..configs.base import ModelConfig
from ..data.pipeline import DataConfig, stream
from ..models import init_params, loss_fn
from ..optim import AdamWConfig, adamw_update, init_opt_state, warmup_cosine
from . import checkpoint as ckpt


@dataclasses.dataclass
class TrainerConfig:
    steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = False
    warmup_steps: int = 10
    log_every: int = 10
    straggler_factor: float = 3.0
    grad_compression: GradCompressionConfig | None = None
    seed: int = 0


class Trainer:
    def __init__(self, cfg: ModelConfig, tcfg: TrainerConfig,
                 data_cfg: DataConfig, opt_cfg: AdamWConfig | None = None,
                 ctx=None, codec_fn=None, fail_at_step: int | None = None):
        self.cfg, self.tcfg, self.data_cfg = cfg, tcfg, data_cfg
        self.opt_cfg = opt_cfg or AdamWConfig()
        self.ctx = ctx
        self.codec_fn = codec_fn
        self.fail_at_step = fail_at_step  # test hook
        self.metrics_log: list[dict] = []
        self.straggler_steps: list[int] = []
        gc = tcfg.grad_compression

        def step_fn(params, opt_state, ef, batch, step):
            def lf(p):
                return loss_fn(cfg, p, batch["tokens"], ctx=ctx,
                               inputs=batch.get("inputs"), codec_fn=codec_fn,
                               remat=False)
            (loss, _), grads = jax.value_and_grad(lf, has_aux=True)(params)
            if gc is not None and gc.enabled:
                grads, ef, cmetrics = compress_grads(gc, grads, ef)
            else:
                cmetrics = {}
            lr_scale = warmup_cosine(step, warmup_steps=tcfg.warmup_steps,
                                     total_steps=tcfg.steps)
            params, opt_state, m = adamw_update(self.opt_cfg, params, grads,
                                                opt_state, lr_scale)
            return params, opt_state, ef, {"loss": loss, **m, **cmetrics}

        self._step = jax.jit(step_fn)

    # -- state ------------------------------------------------------------------

    def init_state(self):
        params = init_params(self.cfg, jax.random.PRNGKey(self.tcfg.seed))
        return {"params": params, "opt": init_opt_state(params),
                "ef": init_error_feedback(params)}

    def run(self, resume: bool = True) -> dict:
        state = self.init_state()
        start = 0
        if resume:
            last = ckpt.latest_step(self.tcfg.ckpt_dir)
            if last is not None:
                state = ckpt.restore(self.tcfg.ckpt_dir, last, state)
                start = last
        durations: list[float] = []
        for step, batch in zip(range(start, self.tcfg.steps),
                               stream(self.data_cfg, start)):
            if self.fail_at_step is not None and step == self.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")
            t0 = time.time()
            p, o, e, metrics = self._step(state["params"], state["opt"],
                                          state["ef"], batch, step)
            metrics = {k: float(v) for k, v in metrics.items()}
            state = {"params": p, "opt": o, "ef": e}
            dt = time.time() - t0
            if durations and dt > self.tcfg.straggler_factor * np.median(durations):
                self.straggler_steps.append(step)
            durations.append(dt)
            metrics["step"] = step
            self.metrics_log.append(metrics)
            if (step + 1) % self.tcfg.ckpt_every == 0 or \
                    step + 1 == self.tcfg.steps:
                ckpt.save(self.tcfg.ckpt_dir, step + 1, state,
                          async_=self.tcfg.ckpt_async)
        return state
