from .context import DistContext
from .transformer import (build_groups, decode_from_boundary, decode_step,
                          decode_to_boundary, forward, forward_from_boundary,
                          forward_head, init_cache, init_params, loss_fn,
                          prefill, prefill_from_boundary, prefill_to_boundary)

__all__ = ["DistContext", "build_groups", "decode_from_boundary",
           "decode_step", "decode_to_boundary", "forward",
           "forward_from_boundary", "forward_head",
           "init_cache", "init_params", "loss_fn", "prefill",
           "prefill_from_boundary", "prefill_to_boundary"]
