from .context import DistContext
from .transformer import (build_groups, decode_step, forward,
                          forward_from_boundary, forward_head, init_cache,
                          init_params, loss_fn, prefill)

__all__ = ["DistContext", "build_groups", "decode_step", "forward",
           "forward_from_boundary", "forward_head",
           "init_cache", "init_params", "loss_fn", "prefill"]
