"""Distribution context threaded through model code."""

from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Mesh + axis-name conventions.  ``None`` context = single device.

    dp_axes: axes the batch is sharded over (('pod','data') or ('data',)).
    tp_axis: tensor/expert-parallel axis ('model').
    """

    mesh: Any = None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.tp_axis, 1)

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape.get(a, 1)
        return out


def constrain(x, ctx: DistContext | None, *axes):
    """Pin activation sharding: axes entries are None, 'dp', or 'tp'.

    Anchoring activations (batch on the dp axes, head/ffn dims on the tp
    axis) at layer boundaries is what forces GSPMD to resolve FSDP weight
    contractions by all-gathering the (small) weights instead of
    replicating the (large) activations.  Divisibility-checked: any axis
    that does not divide falls back to unsharded.
    """
    if ctx is None or ctx.mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = []
    used: set[str] = set()
    for dim, a in zip(x.shape, axes):
        # each axis group is assigned to the FIRST marked dim that divides,
        # so ('dp', None, 'tp', 'tp') = "heads if divisible, else head_dim"
        if a == "dp" and "dp" not in used and ctx.dp_size > 1 \
                and dim % ctx.dp_size == 0:
            spec.append(ctx.dp_axes)
            used.add("dp")
        elif a == "tp" and "tp" not in used and ctx.tp_size > 1 \
                and dim % ctx.tp_size == 0:
            spec.append(ctx.tp_axis)
            used.add("tp")
        else:
            spec.append(None)
    spec += [None] * (len(x.shape) - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_map_compat(body, mesh, in_specs, out_specs):
    """Fully-manual shard_map across jax versions.

    jax >= 0.6 has ``jax.shard_map``; the 0.4.x line spells it
    ``jax.experimental.shard_map.shard_map`` (``check_rep=False`` to skip
    the stricter replication verifier the old version applies to psum
    outputs).
    """
    import jax
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(body, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, check_rep=False)
