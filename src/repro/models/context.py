"""Distribution context threaded through model code."""

from __future__ import annotations

import dataclasses
from typing import Any

# Single feature detect for the shard_map API split.  jax >= 0.6 promotes
# shard_map to ``jax.shard_map`` and supports partially-manual regions
# (``axis_names=``, other mesh axes left to GSPMD); the 0.4.x/0.5.x line
# only has the fully-manual ``jax.experimental.shard_map.shard_map``.
# Every shard_map entry in the repo routes through :func:`shard_map`
# below, and SHARD_MAP_PARTIAL_AUTO is the one capability flag callers
# may branch on (the split runtime keys its in-region sharding hints off
# it) -- no other module should feature-detect jax versions itself.
try:
    from jax import shard_map as _native_shard_map  # jax >= 0.6
    SHARD_MAP_PARTIAL_AUTO = True
except ImportError:  # pragma: no cover - exercised on the 0.4.x line
    from jax.experimental.shard_map import shard_map as _legacy_shard_map
    _native_shard_map = None
    SHARD_MAP_PARTIAL_AUTO = False


@dataclasses.dataclass(frozen=True)
class DistContext:
    """Mesh + axis-name conventions.  ``None`` context = single device.

    dp_axes: axes the batch is sharded over (('pod','data') or ('data',)).
    tp_axis: tensor/expert-parallel axis ('model').
    """

    mesh: Any = None
    dp_axes: tuple[str, ...] = ("data",)
    tp_axis: str = "model"

    @property
    def tp_size(self) -> int:
        if self.mesh is None:
            return 1
        return self.mesh.shape.get(self.tp_axis, 1)

    @property
    def dp_size(self) -> int:
        if self.mesh is None:
            return 1
        out = 1
        for a in self.dp_axes:
            out *= self.mesh.shape.get(a, 1)
        return out


def constrain(x, ctx: DistContext | None, *axes):
    """Pin activation sharding: axes entries are None, 'dp', or 'tp'.

    Anchoring activations (batch on the dp axes, head/ffn dims on the tp
    axis) at layer boundaries is what forces GSPMD to resolve FSDP weight
    contractions by all-gathering the (small) weights instead of
    replicating the (large) activations.  Divisibility-checked: any axis
    that does not divide falls back to unsharded.
    """
    if ctx is None or ctx.mesh is None:
        return x
    import jax
    from jax.sharding import NamedSharding, PartitionSpec as P

    spec = []
    used: set[str] = set()
    for dim, a in zip(x.shape, axes):
        # each axis group is assigned to the FIRST marked dim that divides,
        # so ('dp', None, 'tp', 'tp') = "heads if divisible, else head_dim"
        if a == "dp" and "dp" not in used and ctx.dp_size > 1 \
                and dim % ctx.dp_size == 0:
            spec.append(ctx.dp_axes)
            used.add("dp")
        elif a == "tp" and "tp" not in used and ctx.tp_size > 1 \
                and dim % ctx.tp_size == 0:
            spec.append(ctx.tp_axis)
            used.add("tp")
        else:
            spec.append(None)
    spec += [None] * (len(x.shape) - len(spec))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, P(*spec)))


def shard_map(body, mesh, in_specs, out_specs, *, manual_axes=None):
    """The repo's one shard_map entry point.

    ``manual_axes=None`` maps fully manually over every mesh axis.  A
    frozenset (e.g. ``{'pod'}``) requests a partially-manual region with
    the remaining axes left automatic -- honoured when
    :data:`SHARD_MAP_PARTIAL_AUTO` is set (jax >= 0.6); the legacy line
    runs the body fully manual instead, which is equivalent for
    replicated in_specs (each device holds the full operand and simply
    runs the body replicated across the non-manual axes).
    ``check_rep=False`` on the legacy call skips the stricter
    replication verifier 0.4.x applies to psum outputs.
    """
    if _native_shard_map is not None:
        kwargs = {}
        if manual_axes is not None:
            kwargs = dict(axis_names=frozenset(manual_axes),
                          check_vma=False)
        return _native_shard_map(body, mesh=mesh, in_specs=in_specs,
                                 out_specs=out_specs, **kwargs)
    return _legacy_shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)
