"""Generic decoder LM assembled from the config's layer pattern.

Layers are grouped into repeated *periods* and executed with ``lax.scan``
over stacked parameters, so a 95-layer model compiles one period body.
The collaborative-intelligence split point (the paper's edge/cloud
boundary) falls between two scan groups, where the FeatureCodec fake-quant
(or real packed transport, in the split runtime) is applied.

Public entry points:
    init_params / forward / loss_fn / init_cache / prefill / decode_step
All take an optional ``ctx`` (DistContext) for expert parallelism and an
optional ``codec_fn`` applied at the split boundary.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import LayerSpec, ModelConfig
from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import rwkv6 as RW
from .context import DistContext, constrain


# ---------------------------------------------------------------------------
# group structure
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Group:
    specs: tuple[LayerSpec, ...]
    n_periods: int


def build_groups(cfg: ModelConfig, split: bool = False,
                 split_after: int | None = None) -> tuple[list[Group], int]:
    """Partition layers into scan groups.  Returns (groups, split_boundary)
    where the codec applies after ``groups[:split_boundary]`` (0 = no split).

    ``split_after`` overrides ``cfg.split_after_period`` for this call:
    the boundary lands after that many full periods.  Explicit values
    are validated (1 <= split_after <= n_full_periods - 1) rather than
    clamped, so a scenario sweep over split depths fails loudly on an
    out-of-range tap instead of silently evaluating a different one."""
    n_main = cfg.n_full_periods
    groups: list[Group] = []
    boundary = 0
    if split and n_main >= 2:
        if split_after is not None:
            if not 1 <= split_after <= n_main - 1:
                raise ValueError(
                    f"{cfg.name}: split_after={split_after} out of range "
                    f"(need 1 <= split_after <= {n_main - 1})")
            sp = split_after
        else:
            sp = cfg.split_after_period or max(1, n_main // 4)
            sp = min(sp, n_main - 1)
        groups.append(Group(cfg.pattern, sp))
        groups.append(Group(cfg.pattern, n_main - sp))
        boundary = 1
    else:
        groups.append(Group(cfg.pattern, n_main))
    if cfg.remainder:
        groups.append(Group(cfg.remainder, 1))
    return groups, boundary


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------

def _init_spec(key, spec: LayerSpec, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": L.init_norm(cfg.norm, cfg.d_model, dtype),
         "norm2": L.init_norm(cfg.norm, cfg.d_model, dtype)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    elif spec.kind == "rglru":
        p["rec"] = RG.init_rglru(ks[0], cfg, dtype)
    elif spec.kind == "rwkv":
        p["tmix"] = RW.init_rwkv(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.kind)
    if spec.moe:
        p["moe"] = MOE.init_moe(ks[1], cfg, dtype)
    elif spec.kind == "rwkv":
        p["cmix"] = RW.init_channel_mix(ks[1], cfg, dtype)
    else:
        gated = cfg.gated_mlp
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, gated, dtype)
    return p


def _init_period(key, specs, cfg, dtype):
    keys = jax.random.split(key, len(specs))
    return [_init_spec(k, s, cfg, dtype) for k, s in zip(keys, specs)]


def init_params(cfg: ModelConfig, key, split: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    groups, _ = build_groups(cfg, split)
    k_embed, k_head, key = jax.random.split(key, 3)
    params = {}
    # embedding table always exists: audio/vlm archs still have an output
    # vocabulary even though their *input* arrives as precomputed embeddings
    params["embed"] = {"table": jax.random.normal(
        k_embed, (cfg.vocab_size, cfg.d_model), dtype) * 0.02}
    params["final_norm"] = L.init_norm(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["head"] = {"w": jax.random.normal(
            k_head, (cfg.d_model, cfg.vocab_size), dtype) / math.sqrt(cfg.d_model)}
    gps = []
    for gi, g in enumerate(groups):
        gkey = jax.random.fold_in(key, gi)
        stacked = jax.vmap(
            lambda k: _init_period(k, g.specs, cfg, dtype)
        )(jax.random.split(gkey, g.n_periods))
        gps.append({"layers": stacked})
    params["groups"] = gps
    return params


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def _init_spec_cache(spec: LayerSpec, cfg: ModelConfig, batch: int,
                     max_seq: int, dtype):
    if spec.kind == "attn":
        s = min(spec.window, max_seq) if spec.window else max_seq
        kv = (batch, s, cfg.num_kv_heads, cfg.head_dim)
        if cfg.kv_quant_bits:
            # paper eq. 1 applied to the KV cache: uint8 index storage
            return {"k": jnp.zeros(kv, jnp.uint8), "v": jnp.zeros(kv, jnp.uint8)}
        return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype)}
    if spec.kind == "rglru":
        return RG.init_rglru_cache(cfg, batch, dtype)
    if spec.kind == "rwkv":
        return RW.init_rwkv_cache(cfg, batch)
    raise ValueError(spec.kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, split: bool = False):
    dtype = jnp.dtype(cfg.dtype)
    groups, _ = build_groups(cfg, split)
    caches = []
    for g in groups:
        per = [_init_spec_cache(s, cfg, batch, max_seq, dtype) for s in g.specs]
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (g.n_periods,) + a.shape), per)
        caches.append(stacked)
    return caches


def _align_param_groups(params, groups):
    """Slice stacked layer params to match a (possibly split) group layout.

    Lets the same params pytree serve both the unsplit and the codec-split
    execution paths: splitting a scan group is a zero-copy slice under jit.
    """
    gp = params["groups"]
    if len(gp) == len(groups):
        return gp
    out = []
    src = list(gp)
    main = src.pop(0)
    n_from_main = len(groups) - len(src)
    offset = 0
    for g in groups[:n_from_main]:
        lo = offset
        out.append({"layers": jax.tree.map(
            lambda a: a[lo:lo + g.n_periods], main["layers"])})
        offset += g.n_periods
    out.extend(src)
    return out


def _kv_enc(cfg: ModelConfig, t):
    """Quantize K/V for cache storage (pinned-boundary uniform, eq. 1)."""
    if not cfg.kv_quant_bits:
        return t
    from ..core import uniform
    n = 1 << cfg.kv_quant_bits
    return uniform.quantize(t, -cfg.kv_clip, cfg.kv_clip, n).astype(jnp.uint8)


def _kv_dec(cfg: ModelConfig, t, dtype):
    if not cfg.kv_quant_bits:
        return t
    from ..core import uniform
    n = 1 << cfg.kv_quant_bits
    return uniform.dequantize(t.astype(jnp.int32), -cfg.kv_clip, cfg.kv_clip,
                              n, dtype=dtype)


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------

def _apply_layer(x, p, spec: LayerSpec, cfg: ModelConfig, *, pos, cache,
                 ctx, positions):
    """x: (B,S,d). cache: per-spec cache dict or None. pos: scalar offset."""
    x = constrain(x, ctx, "dp", None, None)
    h = L.apply_norm(x, p["norm1"], cfg.norm, cfg.norm_eps)
    new_cache = None
    if spec.kind == "attn":
        q, k, v = L.attention_qkv(h, p["attn"], cfg, positions)
        tp_n = ctx.tp_size if ctx is not None else 1
        heads_div = cfg.num_heads % tp_n == 0
        kv_div = cfg.num_kv_heads % tp_n == 0
        expand_kv = False
        if tp_n == 1:
            pass
        elif heads_div and kv_div:
            # classic TP attention: q and kv heads sharded over 'model'
            q = constrain(q, ctx, "dp", None, "tp", None)
            k = constrain(k, ctx, "dp", None, "tp", None)
            v = constrain(v, ctx, "dp", None, "tp", None)
        elif heads_div:
            # GQA with kv_heads < tp: replicate the (small) K/V and expand
            # groups to full heads for train/prefill compute, so attention
            # shards cleanly on q heads.  hd-sharding K/V instead forces a
            # partial-sum AR of the f32 logits each chunk (16x worse in
            # the baseline dry-run).  Decode keeps the compact K-head
            # cache (sequence-sharded by the cache rules).
            q = constrain(q, ctx, "dp", None, "tp", None)
            k = constrain(k, ctx, "dp", None, None, None)
            v = constrain(v, ctx, "dp", None, None, None)
            expand_kv = q.shape[1] > 1
        elif spec.window is None and k.shape[1] % tp_n == 0 and k.shape[1] > 1:
            # sequence-parallel attention for head-indivisible archs:
            # K/V shard along the key axis; softmax reductions over the
            # sharded axis cost only tiny stat all-reduces, and attention
            # FLOPs split tp ways.
            q = constrain(q, ctx, "dp", None, None, None)
            k = constrain(k, ctx, "dp", "tp", None, None)
            v = constrain(v, ctx, "dp", "tp", None, None)
        else:
            # tiny-window fallback: replicate over tp (weights are
            # replicated too for these archs; see sharding.py)
            q = constrain(q, ctx, "dp", None, None, None)
            k = constrain(k, ctx, "dp", None, None, None)
            v = constrain(v, ctx, "dp", None, None, None)

        def _exp(t):
            if not expand_kv:
                return t
            g = cfg.num_heads // cfg.num_kv_heads
            t = jnp.repeat(t, g, axis=2)  # (B,S,K,hd) -> (B,S,H,hd)
            return constrain(t, ctx, "dp", None, "tp", None)

        if cache is None:
            attn = L.multi_head_attention(
                q, _exp(k), _exp(v), q_offset=0, window=spec.window,
                softcap=cfg.attn_logit_softcap)
        else:
            s_new = q.shape[1]
            s_cache = cache["k"].shape[1]
            if s_new == 1:
                # decode: write into ring/linear slot, attend over cache
                slot = pos % s_cache if spec.window else pos
                ck = lax.dynamic_update_slice_in_dim(
                    cache["k"], _kv_enc(cfg, k), slot, axis=1)
                cv = lax.dynamic_update_slice_in_dim(
                    cache["v"], _kv_enc(cfg, v), slot, axis=1)
                if spec.window:
                    idx = jnp.arange(s_cache, dtype=jnp.int32)
                    k_pos = pos - (pos - idx) % s_cache
                else:
                    k_pos = jnp.arange(s_cache, dtype=jnp.int32)
                attn = L.multi_head_attention(
                    q, _kv_dec(cfg, ck, q.dtype), _kv_dec(cfg, cv, q.dtype),
                    q_offset=pos, k_positions=k_pos,
                    window=spec.window, softcap=cfg.attn_logit_softcap)
                new_cache = {"k": ck, "v": cv}
            else:
                # prefill from scratch: attend over fresh K/V, then fill cache
                attn = L.multi_head_attention(
                    q, _exp(k), _exp(v), q_offset=0, window=spec.window,
                    softcap=cfg.attn_logit_softcap)
                kq, vq = _kv_enc(cfg, k), _kv_enc(cfg, v)
                if s_new >= s_cache:
                    tail_pos = jnp.arange(s_new - s_cache, s_new) % s_cache
                    ck = cache["k"].at[:, tail_pos].set(kq[:, -s_cache:])
                    cv = cache["v"].at[:, tail_pos].set(vq[:, -s_cache:])
                else:
                    ck = lax.dynamic_update_slice_in_dim(cache["k"], kq, 0, axis=1)
                    cv = lax.dynamic_update_slice_in_dim(cache["v"], vq, 0, axis=1)
                new_cache = {"k": ck, "v": cv}
        if tp_n == 1 or cfg.num_heads % tp_n == 0:
            attn = constrain(attn, ctx, "dp", None, "tp", "tp")
        else:
            attn = constrain(attn, ctx, "dp", None, None, None)
        x = x + L.attention_out(attn, p["attn"])
    elif spec.kind == "rglru":
        out, new_cache = RG.rglru_block_apply(h, p["rec"], cfg, cache, ctx=ctx)
        x = x + out
    elif spec.kind == "rwkv":
        out, new_tmix = RW.time_mix_apply(h, p["tmix"], cfg,
                                          cache["tmix"] if cache else None,
                                          ctx=ctx)
        x = x + out
        new_cache = {"tmix": new_tmix} if cache is not None else None

    h2 = L.apply_norm(x, p["norm2"], cfg.norm, cfg.norm_eps)
    if spec.moe:
        x = x + MOE.moe_apply(h2, p["moe"], cfg, ctx)
    elif spec.kind == "rwkv":
        out, new_cmix = RW.channel_mix_apply(h2, p["cmix"],
                                             cache["cmix"] if cache else None,
                                             ctx=ctx)
        x = x + out
        if cache is not None:
            new_cache["cmix"] = new_cmix
    else:
        x = x + L.mlp_apply(h2, p["mlp"], cfg.act, cfg.gated_mlp, ctx=ctx)
    return x, new_cache


# ---------------------------------------------------------------------------
# group scan
# ---------------------------------------------------------------------------

def _remat_group_size(n: int) -> int:
    """Largest small divisor g of n: layers are scanned in super-steps of g
    periods under one jax.checkpoint, so only n/g residual carries are
    saved (sqrt-style remat).  The fwd of a super-step is replayed once in
    the backward pass; transient memory grows by g layers' internals."""
    for g in (8, 7, 6, 5, 4, 3, 2):
        if n % g == 0 and n // g >= 2:
            return g
    return 1


def _apply_group(x, gparams, group: Group, cfg: ModelConfig, *, pos, gcache,
                 ctx, positions, remat: bool = False):
    """Scan one group of n_periods over stacked params/caches."""
    if remat and gcache is None and group.n_periods >= 64:
        # sqrt-remat pays off only when the residual-carry stack dominates
        # (deep stacks); for shallow models the recompute traffic regressed
        # the memory term in the dry-run (see EXPERIMENTS §Perf).
        g = _remat_group_size(group.n_periods)
        if g > 1:
            n1 = group.n_periods // g
            lay = jax.tree.map(
                lambda a: a.reshape(n1, g, *a.shape[1:]), gparams["layers"])

            @jax.checkpoint
            def super_body(carry, pp):
                xc = carry
                for j in range(g):
                    pj = jax.tree.map(lambda a: a[j], pp)
                    for si, spec in enumerate(group.specs):
                        xc, _ = _apply_layer(xc, pj[si], spec, cfg, pos=pos,
                                             cache=None, ctx=ctx,
                                             positions=positions)
                return xc, None

            x, _ = lax.scan(super_body, x, lay)
            return x, None

    def period_body(carry, xs):
        xc = carry
        pp, cc = xs
        new_cc = [] if cc is not None else None
        for j, spec in enumerate(group.specs):
            xc, ncj = _apply_layer(
                xc, pp[j], spec, cfg, pos=pos,
                cache=(cc[j] if cc is not None else None),
                ctx=ctx, positions=positions)
            if new_cc is not None:
                new_cc.append(ncj)
        return xc, new_cc

    body = jax.checkpoint(period_body) if remat else period_body
    if gcache is None:
        x, _ = lax.scan(lambda c, xs: (body(c, (xs, None))[0], None),
                        x, gparams["layers"])
        return x, None
    x, new_cache = lax.scan(lambda c, xs: body(c, xs),
                            x, (gparams["layers"], gcache))
    return x, new_cache


# ---------------------------------------------------------------------------
# full model
# ---------------------------------------------------------------------------

def _embed_in(cfg, params, batch_in, pos0=0, ctx=None):
    """batch_in: tokens (B,S) int32 or embeddings (B,S,d)."""
    if batch_in.ndim == 3:
        x = batch_in.astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"]["table"][batch_in]
    if cfg.pos_emb == "sinusoidal":
        s = x.shape[1]
        pe = L.sinusoidal_pos_emb(pos0 + jnp.arange(s), cfg.d_model, x.dtype)
        x = x + pe[None]
    return constrain(x, ctx, "dp", None, None)


def _logits_out(cfg, params, x, ctx=None):
    xn = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", xn, params["embed"]["table"])
    else:
        logits = xn @ params["head"]["w"]
    logits = constrain(logits, ctx, "dp", None, "tp")
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward(cfg: ModelConfig, params, batch_in, *, ctx: DistContext | None = None,
            codec_fn: Callable | None = None, split: bool = False,
            remat: bool = False):
    """Training/scoring forward pass (no cache).  Returns (logits, aux)."""
    groups, boundary = build_groups(cfg, split or codec_fn is not None)
    pgroups = _align_param_groups(params, groups)
    x = _embed_in(cfg, params, batch_in, ctx=ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    aux = {}
    for gi, g in enumerate(groups):
        x, _ = _apply_group(x, pgroups[gi], g, cfg, pos=0,
                            gcache=None, ctx=ctx, positions=positions,
                            remat=remat)
        if codec_fn is not None and boundary and gi == boundary - 1:
            x, rate = codec_fn(x)
            aux["codec_rate_bits"] = rate
    return _logits_out(cfg, params, x, ctx=ctx), aux


def forward_head(cfg: ModelConfig, params, batch_in, *,
                 ctx: DistContext | None = None,
                 split_after: int | None = None):
    """Edge half of the split forward: embed + the groups before the
    collaborative-intelligence boundary.  Returns the raw split-layer
    activations (B, S, d) that cross the edge->cloud link (the transport
    subsystem streams exactly this tensor).  ``split_after`` taps the
    boundary after that many full periods (default: the config's)."""
    groups, boundary = build_groups(cfg, split=True, split_after=split_after)
    if not boundary:
        raise ValueError(f"{cfg.name}: no split boundary (needs >= 2 "
                         "full periods)")
    pgroups = _align_param_groups(params, groups)
    x = _embed_in(cfg, params, batch_in, ctx=ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    for gi in range(boundary):
        x, _ = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=0,
                            gcache=None, ctx=ctx, positions=positions)
    return x


def forward_from_boundary(cfg: ModelConfig, params, x, *,
                          ctx: DistContext | None = None,
                          split_after: int | None = None):
    """Cloud half: the groups after the boundary + final norm/head.

    ``x`` is the (possibly decompressed) split-layer tensor from
    :func:`forward_head` (same ``split_after``); returns logits
    (B, S, V).  Together the two halves are numerically identical to
    :func:`forward` with an identity ``codec_fn`` -- asserted in
    tests/test_transport.py."""
    groups, boundary = build_groups(cfg, split=True, split_after=split_after)
    if not boundary:
        raise ValueError(f"{cfg.name}: no split boundary (needs >= 2 "
                         "full periods)")
    pgroups = _align_param_groups(params, groups)
    x = jnp.asarray(x, jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    for gi in range(boundary, len(groups)):
        x, _ = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=0,
                            gcache=None, ctx=ctx, positions=positions)
    return _logits_out(cfg, params, x, ctx=ctx)


def _hidden_forward(cfg, params, batch_in, *, ctx, codec_fn, split, remat):
    """Backbone only: returns final hidden states (B, S, d) + aux."""
    groups, boundary = build_groups(cfg, split or codec_fn is not None)
    pgroups = _align_param_groups(params, groups)
    x = _embed_in(cfg, params, batch_in, ctx=ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    aux = {}
    for gi, g in enumerate(groups):
        x, _ = _apply_group(x, pgroups[gi], g, cfg, pos=0,
                            gcache=None, ctx=ctx, positions=positions,
                            remat=remat)
        if codec_fn is not None and boundary and gi == boundary - 1:
            x, rate = codec_fn(x)
            aux["codec_rate_bits"] = rate
    return x, aux


def sharded_xent(cfg: ModelConfig, params, x, labels, ctx: DistContext | None):
    """Vocab-sharded softmax cross entropy.

    The (B, S, V) logits never exist unsharded or in float32: they are
    pinned to P(dp, None, tp) so GSPMD keeps the vocab dimension sharded
    through the max / logsumexp / pick reductions (partial reduce + cheap
    scalar all-reduce) instead of all-gathering a vocab-wide tensor --
    the difference between 68 GB/device and 2 GB/device on a 256k vocab.
    """
    from jax.sharding import PartitionSpec as P

    xn = L.apply_norm(x, params["final_norm"], cfg.norm, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", xn, params["embed"]["table"])
    else:
        logits = xn @ params["head"]["w"]
    if cfg.final_logit_softcap > 0:
        c = cfg.final_logit_softcap
        logits = (c * jnp.tanh(logits.astype(jnp.float32) / c)).astype(logits.dtype)
    if ctx is not None and ctx.mesh is not None:
        import numpy as _np
        dp_n = int(_np.prod([ctx.mesh.shape[a] for a in ctx.dp_axes]))
        spec = P(ctx.dp_axes if labels.shape[0] % dp_n == 0 else None,
                 None, ctx.tp_axis if cfg.vocab_size % ctx.tp_size == 0 else None)
        logits = jax.lax.with_sharding_constraint(
            logits, jax.sharding.NamedSharding(ctx.mesh, spec))
    m = jax.lax.stop_gradient(jnp.max(logits, axis=-1, keepdims=True))
    shifted = logits - m
    sumexp = jnp.sum(jnp.exp(shifted.astype(jnp.float32)), axis=-1)
    lse = m[..., 0].astype(jnp.float32) + jnp.log(sumexp)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
    picked = jnp.sum(jnp.where(vocab_iota == labels[..., None],
                               shifted.astype(jnp.float32), 0.0), axis=-1)
    picked = picked + m[..., 0].astype(jnp.float32)
    return jnp.mean(lse - picked)


def loss_fn(cfg: ModelConfig, params, tokens, *, ctx=None, codec_fn=None,
            split: bool = False, remat: bool = True, inputs=None):
    """Next-token cross entropy.  ``inputs`` overrides the embedded input
    stream (audio/vlm stubs); labels always come from ``tokens``."""
    batch_in = inputs if inputs is not None else tokens
    x, aux = _hidden_forward(cfg, params, batch_in, ctx=ctx, codec_fn=codec_fn,
                             split=split, remat=remat)
    loss = sharded_xent(cfg, params, x[:, :-1], tokens[:, 1:], ctx)
    return loss, aux


def prefill(cfg: ModelConfig, params, batch_in, cache, *, ctx=None,
            codec_fn=None, split: bool = False):
    """Process a prompt, filling the cache.  Returns (last_logits, cache)."""
    groups, boundary = build_groups(cfg, split or codec_fn is not None)
    pgroups = _align_param_groups(params, groups)
    x = _embed_in(cfg, params, batch_in, ctx=ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    new_caches = []
    for gi, g in enumerate(groups):
        x, nc = _apply_group(x, pgroups[gi], g, cfg, pos=0,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
        if codec_fn is not None and boundary and gi == boundary - 1:
            x, _ = codec_fn(x)
    logits = _logits_out(cfg, params, x[:, -1:], ctx=ctx)
    return logits[:, 0], new_caches


def prefill_to_boundary(cfg: ModelConfig, params, batch_in, cache, *,
                        ctx=None):
    """Edge half of a split prefill: embed + the pre-boundary groups.

    Returns (split-layer activations (B, S, d), pre-boundary caches).
    Together with :func:`prefill_from_boundary` this is :func:`prefill`
    cut at the collaborative-intelligence boundary, so a host round-trip
    (e.g. a real transport socket) can run *between* two jitted programs
    instead of inside one -- no host callback ever blocks an in-flight
    program while nested jax work waits for the dispatch thread.
    """
    groups, boundary = build_groups(cfg, split=True)
    if not boundary:
        raise ValueError(f"{cfg.name}: no split boundary (needs >= 2 "
                         "full periods)")
    pgroups = _align_param_groups(params, groups)
    x = _embed_in(cfg, params, batch_in, ctx=ctx)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    new_caches = []
    for gi in range(boundary):
        x, nc = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=0,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
    return x, new_caches


def prefill_from_boundary(cfg: ModelConfig, params, x, cache, *, ctx=None):
    """Cloud half of a split prefill: post-boundary groups + head.

    ``x`` is the (possibly codec-round-tripped) boundary tensor from
    :func:`prefill_to_boundary`; ``cache`` is the full per-group cache
    list (only the post-boundary entries are read).  Returns
    (last-token logits (B, V), post-boundary caches)."""
    groups, boundary = build_groups(cfg, split=True)
    pgroups = _align_param_groups(params, groups)
    x = jnp.asarray(x, jnp.dtype(cfg.dtype))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    new_caches = []
    for gi in range(boundary, len(groups)):
        x, nc = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=0,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
    logits = _logits_out(cfg, params, x[:, -1:], ctx=ctx)
    return logits[:, 0], new_caches


def decode_to_boundary(cfg: ModelConfig, params, token_in, cache, pos, *,
                       ctx=None):
    """Edge half of a split decode step (see :func:`prefill_to_boundary`).

    Returns (boundary activations (B, 1, d), pre-boundary caches)."""
    groups, boundary = build_groups(cfg, split=True)
    if not boundary:
        raise ValueError(f"{cfg.name}: no split boundary (needs >= 2 "
                         "full periods)")
    pgroups = _align_param_groups(params, groups)
    batch_in = token_in[:, None] if token_in.ndim == 1 else token_in
    x = _embed_in(cfg, params, batch_in, pos0=pos, ctx=ctx)
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    new_caches = []
    for gi in range(boundary):
        x, nc = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=pos,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
    return x, new_caches


def decode_from_boundary(cfg: ModelConfig, params, x, cache, pos, *,
                         ctx=None):
    """Cloud half of a split decode step: post-boundary groups + head.
    Returns (logits (B, V), post-boundary caches)."""
    groups, boundary = build_groups(cfg, split=True)
    pgroups = _align_param_groups(params, groups)
    x = jnp.asarray(x, jnp.dtype(cfg.dtype))
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    new_caches = []
    for gi in range(boundary, len(groups)):
        x, nc = _apply_group(x, pgroups[gi], groups[gi], cfg, pos=pos,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
    logits = _logits_out(cfg, params, x, ctx=ctx)
    return logits[:, 0], new_caches


def decode_step(cfg: ModelConfig, params, token_in, cache, pos, *, ctx=None,
                codec_fn=None, split: bool = False):
    """One decode step.  token_in: (B,) int32 or (B,1,d) embeddings;
    pos: scalar int32 absolute position.  Returns (logits (B,V), cache)."""
    groups, boundary = build_groups(cfg, split or codec_fn is not None)
    pgroups = _align_param_groups(params, groups)
    if token_in.ndim == 1:
        batch_in = token_in[:, None]
    else:
        batch_in = token_in
    x = _embed_in(cfg, params, batch_in, pos0=pos, ctx=ctx)
    positions = jnp.full((1,), pos, dtype=jnp.int32)
    aux = {}
    new_caches = []
    for gi, g in enumerate(groups):
        x, nc = _apply_group(x, pgroups[gi], g, cfg, pos=pos,
                             gcache=cache[gi], ctx=ctx, positions=positions)
        new_caches.append(nc)
        if codec_fn is not None and boundary and gi == boundary - 1:
            x, rate = codec_fn(x)
            aux["codec_rate_bits"] = rate
    logits = _logits_out(cfg, params, x, ctx=ctx)
    return logits[:, 0], new_caches, aux
