"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Block structure (one 'rglru' mixer):
    x -> [w_in -> causal conv1d(width 4) -> RG-LRU] * gelu(w_gate) -> w_out

RG-LRU recurrence (elementwise over the rnn width r):
    r_t = sigmoid(x_t @ W_a + b_a)          recurrence gate
    i_t = sigmoid(x_t @ W_x + b_x)          input gate
    log a_t = -c * softplus(Lambda) * r_t   (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

TPU adaptation: the linear recurrence is evaluated with
``jax.lax.associative_scan`` (log-depth, VPU-friendly) for train/prefill;
decode carries (h, conv state) and is a single fused elementwise step.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

_C = 8.0


def init_rglru(key, cfg, dtype):
    d, r, cw = cfg.d_model, cfg.rnn_dim, cfg.conv_width
    ks = jax.random.split(key, 6)
    s_d, s_r = 1.0 / math.sqrt(d), 1.0 / math.sqrt(r)
    return {
        "w_in": jax.random.normal(ks[0], (d, r), dtype) * s_d,
        "w_gate": jax.random.normal(ks[1], (d, r), dtype) * s_d,
        "conv_w": jax.random.normal(ks[2], (cw, r), dtype) * 0.3,
        "conv_b": jnp.zeros((r,), dtype),
        "wa": jax.random.normal(ks[3], (r, r), dtype) * s_r,
        "ba": jnp.full((r,), 2.0, dtype),   # bias toward remembering
        "wx": jax.random.normal(ks[4], (r, r), dtype) * s_r,
        "bx": jnp.zeros((r,), dtype),
        "lam": jnp.full((r,), 0.54, jnp.float32),  # softplus^-1-ish init
        "w_out": jax.random.normal(ks[5], (r, d), dtype) * s_r,
    }


def _causal_conv(x, w, b, state=None):
    """x: (B, S, r); w: (cw, r) depthwise. state: (B, cw-1, r) prior inputs."""
    cw = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)  # (B, S+cw-1, r)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1):, :]
    return out + b, new_state


def _gates(x, p):
    """a (decay) and gated input, elementwise. x: (..., r), float32 math."""
    xf = x.astype(jnp.float32)
    rg = jax.nn.sigmoid(xf @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    ig = jax.nn.sigmoid(xf @ p["wx"].astype(jnp.float32) + p["bx"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * rg
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12)) * (ig * xf)
    return a, gated


def rglru_scan(x, p, h0=None):
    """Full-sequence RG-LRU via associative scan. x: (B, S, r) post-conv."""
    a, bt = _gates(x, p)  # (B, S, r) f32
    if h0 is not None:
        # fold initial state into the first step: b_0 += a_0 * h0
        bt = bt.at[:, 0, :].add(a[:, 0, :] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = lax.associative_scan(combine, (a, bt), axis=1)
    return h.astype(x.dtype), h[:, -1, :]  # outputs, final state (f32)


def rglru_block_apply(x, p, cfg, cache=None, ctx=None):
    """Full mixer. x: (B, S, d). cache: {'h': (B,r) f32, 'conv': (B,cw-1,r)}.

    Returns (out (B,S,d), new_cache_or_None).
    """
    from .context import constrain
    gate = jax.nn.gelu(x @ p["w_gate"])
    u = x @ p["w_in"]
    gate = constrain(gate, ctx, "dp", None, "tp")
    u = constrain(u, ctx, "dp", None, "tp")
    conv_state = cache["conv"] if cache is not None else None
    u, new_conv = _causal_conv(u, p["conv_w"], p["conv_b"], conv_state)
    h0 = cache["h"] if cache is not None else None
    h, h_last = rglru_scan(u, p, h0)
    out = (gate * h) @ p["w_out"]
    new_cache = None
    if cache is not None:
        new_cache = {"h": h_last, "conv": new_conv.astype(cache["conv"].dtype)}
    return out, new_cache


def init_rglru_cache(cfg, batch: int, dtype):
    r, cw = cfg.rnn_dim, cfg.conv_width
    return {"h": jnp.zeros((batch, r), jnp.float32),
            "conv": jnp.zeros((batch, cw - 1, r), dtype)}
