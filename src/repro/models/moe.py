"""Top-k routed Mixture-of-Experts with capacity-based dispatch.

Three execution paths sharing the same routing math:

  * ``_moe_dense_ref``   -- every expert on every token (oracle for tests);
  * ``moe_local``        -- sort/scatter dispatch, no collectives (single
                            device or pure data parallelism);
  * ``moe_expert_parallel`` -- shard_map over the 'model' axis:
      - train/prefill: tokens are *sequence-sharded* across the expert axis,
        dispatched locally to (E, C, d) slots, exchanged with all_to_all so
        each device runs only its E/M local experts, and combined after the
        reverse all_to_all (the standard EP pipeline);
      - decode (or S not divisible): tokens replicated across the expert
        axis, each shard computes its local experts' contributions and the
        output is psum-combined (TP-style, cheap at small T).

Dropped-token semantics: assignments beyond an expert's capacity
C = ceil(T*k/E * capacity_factor) are dropped (standard capacity MoE;
dbrx/qwen3 are dropless -- noted in DESIGN.md §Arch-applicability).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def init_moe(key, cfg, dtype):
    d, e, ef = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    ks = jax.random.split(key, 4)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(ef)
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * s_in,
        "w1": jax.random.normal(ks[1], (e, d, ef), dtype) * s_in,
        "w3": jax.random.normal(ks[2], (e, d, ef), dtype) * s_in,
        "w2": jax.random.normal(ks[3], (e, ef, d), dtype) * s_out,
    }


def _route(x2d, router, k):
    """x2d: (T, d) -> (weights (T,k) f32, expert ids (T,k) i32)."""
    logits = (x2d.astype(jnp.float32) @ router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = lax.top_k(probs, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)  # renormalize
    return top_w, top_i.astype(jnp.int32)


def _capacity(t: int, k: int, e: int, cf: float) -> int:
    return max(8, int(math.ceil(t * k / e * cf)))


def _expert_ffn(buf, p, act_fn):
    """buf: (E_local, C, d); expert weights (E_local, d, ef)/(E_local, ef, d)."""
    h = act_fn(jnp.einsum("ecd,edf->ecf", buf, p["w1"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w3"])
    return jnp.einsum("ecf,efd->ecd", h, p["w2"])


def _dispatch_indices(top_i, k: int, e: int, cap: int):
    """Compute per-assignment (slot, keep, token) under capacity.

    Returns slot ids in [0, E*cap) with dropped assignments mapped
    out-of-range (for mode='drop' scatters).
    """
    t = top_i.shape[0]
    flat_e = top_i.reshape(-1)                          # (T*k,)
    token_of = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    counts = jnp.sum(jax.nn.one_hot(flat_e, e, dtype=jnp.int32), axis=0)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(t * k, dtype=jnp.int32) - starts[sorted_e]
    keep = pos_in_e < cap
    slot = jnp.where(keep, sorted_e * cap + pos_in_e, e * cap)  # OOB when dropped
    return slot, keep, token_of[order], order


def _moe_dense_ref(x2d, p, cfg):
    """Oracle: weighted sum over ALL experts (no capacity, no dropping)."""
    act_fn = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    w, i = _route(x2d, p["router"], cfg.experts_per_token)
    outs = []
    for ei in range(cfg.num_experts):
        h = act_fn(x2d @ p["w1"][ei]) * (x2d @ p["w3"][ei])
        outs.append(h @ p["w2"][ei])
    stacked = jnp.stack(outs, axis=1)  # (T, E, d)
    mask = jnp.sum(jax.nn.one_hot(i, cfg.num_experts, dtype=w.dtype)
                   * w[..., None], axis=1)  # (T, E)
    return jnp.einsum("te,ted->td", mask, stacked.astype(w.dtype)).astype(x2d.dtype)


def moe_local(x2d, p, cfg, cap: int | None = None):
    """Capacity dispatch without collectives. x2d: (T, d)."""
    act_fn = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    t, d = x2d.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    cap = cap or _capacity(t, k, e, cfg.capacity_factor)
    w, i = _route(x2d, p["router"], k)
    slot, keep, tok_sorted, order = _dispatch_indices(i, k, e, cap)
    buf = jnp.zeros((e * cap, d), x2d.dtype).at[slot].set(
        x2d[tok_sorted], mode="drop")
    y = _expert_ffn(buf.reshape(e, cap, d), p, act_fn).reshape(e * cap, d)
    contrib = jnp.where(keep[:, None], y.at[slot, :].get(mode="fill", fill_value=0.0), 0.0)
    w_sorted = w.reshape(-1)[order]
    out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
        contrib.astype(jnp.float32) * w_sorted[:, None])
    return out.astype(x2d.dtype)


# -- expert parallelism ------------------------------------------------------------


def moe_expert_parallel(x, p, cfg, mesh, dp_axes, ep_axis="model"):
    """x: (B, S, d) global. Returns (B, S, d).  See module docstring."""
    b, s, d = x.shape
    m = mesh.shape[ep_axis]
    e, k = cfg.num_experts, cfg.experts_per_token
    act_fn = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    if e % m != 0:
        raise ValueError(f"{e} experts not divisible by axis {ep_axis}={m}")

    if s % m == 0 and s >= m:
        # ---- sequence-sharded dispatch + all_to_all --------------------------
        def body(xl, router, w1, w3, w2):
            pl = {"router": router, "w1": w1, "w3": w3, "w2": w2}
            bl, sl, _ = xl.shape
            t = bl * sl
            x2d = xl.reshape(t, d)
            cap = _capacity(t, k, e, cfg.capacity_factor)
            w, i = _route(x2d, pl["router"], k)
            slot, keep, tok_sorted, order = _dispatch_indices(i, k, e, cap)
            buf = jnp.zeros((e * cap, d), x2d.dtype).at[slot].set(
                x2d[tok_sorted], mode="drop").reshape(e, cap, d)
            # exchange: each device keeps its E/M experts, all peers' tokens
            buf = lax.all_to_all(buf, ep_axis, split_axis=0, concat_axis=1,
                                 tiled=True)                    # (E/M, M*cap, d)
            y = _expert_ffn(buf, pl, act_fn)
            y = lax.all_to_all(y, ep_axis, split_axis=1, concat_axis=0,
                               tiled=True).reshape(e * cap, d)  # back to (E*cap, d)
            contrib = jnp.where(keep[:, None],
                                y.at[slot, :].get(mode="fill", fill_value=0.0), 0.0)
            w_sorted = w.reshape(-1)[order]
            out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
                contrib.astype(jnp.float32) * w_sorted[:, None])
            return out.reshape(bl, sl, d).astype(xl.dtype)

        from .context import shard_map
        return shard_map(
            body, mesh=mesh,
            in_specs=(P(dp_axes, ep_axis, None), P(), P(ep_axis), P(ep_axis),
                      P(ep_axis)),
            out_specs=P(dp_axes, ep_axis, None),
        )(x, p["router"], p["w1"], p["w3"], p["w2"])

    # ---- replicated tokens + local experts + psum (decode path) --------------
    def body_psum(xl, router, w1, w3, w2):
        pl = {"w1": w1, "w3": w3, "w2": w2}
        e_loc = w1.shape[0]
        j = lax.axis_index(ep_axis)
        bl, sl, _ = xl.shape
        t = bl * sl
        x2d = xl.reshape(t, d)
        cap = _capacity(t, k, e, cfg.capacity_factor)
        w, i = _route(x2d, router, k)
        # shift ids so local experts live in [0, e_loc); others go OOB
        i_loc = i - j * e_loc
        slot, keep, tok_sorted, order = _dispatch_indices(
            jnp.where((i_loc >= 0) & (i_loc < e_loc), i_loc, e_loc), k,
            e_loc + 1, cap)
        keep &= slot < e_loc * cap
        slot = jnp.where(keep, slot, e_loc * cap)
        buf = jnp.zeros((e_loc * cap, d), x2d.dtype).at[slot].set(
            x2d[tok_sorted], mode="drop").reshape(e_loc, cap, d)
        y = _expert_ffn(buf, pl, act_fn).reshape(e_loc * cap, d)
        contrib = jnp.where(keep[:, None],
                            y.at[slot, :].get(mode="fill", fill_value=0.0), 0.0)
        w_sorted = w.reshape(-1)[order]
        out = jnp.zeros((t, d), jnp.float32).at[tok_sorted].add(
            contrib.astype(jnp.float32) * w_sorted[:, None])
        out = lax.psum(out, ep_axis)
        return out.reshape(bl, sl, d).astype(xl.dtype)

    from .context import shard_map
    return shard_map(
        body_psum, mesh=mesh,
        in_specs=(P(dp_axes, None, None), P(), P(ep_axis), P(ep_axis),
                  P(ep_axis)),
        out_specs=P(dp_axes, None, None),
    )(x, p["router"], p["w1"], p["w3"], p["w2"])


def moe_apply(x, p, cfg, ctx=None):
    """Entry point: (B, S, d) -> (B, S, d); picks the right execution path."""
    if ctx is None or ctx.mesh is None or ctx.mesh.shape.get(ctx.tp_axis, 1) == 1 \
            or cfg.num_experts % ctx.mesh.shape[ctx.tp_axis] != 0:
        b, s, d = x.shape
        return moe_local(x.reshape(b * s, d), p, cfg).reshape(b, s, d)
    return moe_expert_parallel(x, p, cfg, ctx.mesh, ctx.dp_axes, ctx.tp_axis)
