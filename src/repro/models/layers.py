"""Shared neural layers: norms, RoPE, GQA attention (global/sliding-window,
query-chunked), gated MLPs.

TPU adaptations (see DESIGN.md):
  * attention is query-chunked via ``lax.scan`` so the S_q x S_k score matrix
    never materializes beyond (q_chunk x S_k) per head -- the XLA-level
    equivalent of Flash-style tiling, exact (full row softmax per chunk);
  * sliding-window layers additionally slice K/V to a (window + q_chunk)
    band per chunk, so local attention costs O(S * W) not O(S^2);
  * logits/softmax accumulate in float32 regardless of activation dtype.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax

_NEG_INF = -1e30
DEFAULT_Q_CHUNK = 512


# -- norms ---------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    return out.astype(x.dtype)


def layer_norm(x, scale, bias, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean((xf - mean) ** 2, axis=-1, keepdims=True)
    out = (xf - mean) * lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(x.dtype)


def apply_norm(x, params, kind: str, eps: float):
    if kind == "layernorm":
        return layer_norm(x, params["scale"], params["bias"], eps)
    return rms_norm(x, params["scale"], eps)


def init_norm(kind: str, d: int, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


# -- positional embeddings -------------------------------------------------------

def rope(x, positions, theta: float = 10_000.0):
    """Rotary embedding. x: (..., S, N, hd); positions: (S,) or (B, S)."""
    hd = x.shape[-1]
    freqs = theta ** (-jnp.arange(0, hd // 2, dtype=jnp.float32) / (hd // 2))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (S, hd/2) or (B,S,hd/2)
    if ang.ndim == 2:  # (S, hd/2) -> broadcast over batch and heads
        ang = ang[None, :, None, :]
    else:              # (B, S, hd/2)
        ang = ang[:, :, None, :]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_pos_emb(positions, d: int, dtype=jnp.float32):
    """Classic transformer sinusoidal embedding for given positions (S,)."""
    half = d // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# -- attention -------------------------------------------------------------------

def _attn_core(q, k, v, q_positions, k_positions, *, window, softcap, dtype):
    """Exact attention for one query block.

    q: (B, Sq, K, G, hd); k/v: (B, Sk, K, hd);
    q_positions: (Sq,), k_positions: (Sk,) (negative = invalid slot).
    """
    hd = q.shape[-1]
    logits = jnp.einsum("bskgh,btkh->bkgst", q, k,
                        preferred_element_type=jnp.float32)
    logits *= 1.0 / math.sqrt(hd)
    if softcap > 0.0:
        logits = softcap * jnp.tanh(logits / softcap)
    valid = (k_positions[None, :] <= q_positions[:, None]) & \
        (k_positions[None, :] >= 0)
    if window is not None:
        valid &= q_positions[:, None] - k_positions[None, :] < window
    logits = jnp.where(valid[None, None, None], logits, _NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgst,btkh->bskgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(dtype)


def multi_head_attention(q, k, v, *, q_offset=0, k_positions=None,
                         window=None, softcap: float = 0.0,
                         q_chunk: int = DEFAULT_Q_CHUNK):
    """GQA attention with optional sliding window and query chunking.

    q: (B, Sq, H, hd); k, v: (B, Sk, K, hd) with H % K == 0.
    ``q_offset``: absolute position of q[0] (int or traced scalar).
    ``k_positions``: absolute positions of cache slots, (Sk,); defaults to
    arange(Sk).  Entries < 0 are masked out (unwritten ring slots).
    """
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    g = h // kh
    qg = q.reshape(b, sq, kh, g, hd)
    dtype = q.dtype
    if k_positions is None:
        k_positions = jnp.arange(sk, dtype=jnp.int32)
    q_offset = jnp.asarray(q_offset, jnp.int32)

    use_chunks = sq > q_chunk and sq % q_chunk == 0
    if not use_chunks:
        q_pos = q_offset + jnp.arange(sq, dtype=jnp.int32)
        out = _attn_core(qg, k, v, q_pos, k_positions,
                         window=window, softcap=softcap, dtype=dtype)
        return out.reshape(b, sq, h, hd)

    n_chunks = sq // q_chunk
    q_scan = jnp.moveaxis(qg.reshape(b, n_chunks, q_chunk, kh, g, hd), 1, 0)
    band = None
    if window is not None:
        band = window + q_chunk
        if band >= sk:
            band = None  # window covers everything; no point slicing

    # checkpoint: never keep the (q_chunk x S_k) probs as scan residuals --
    # the backward pass recomputes them per chunk (flash-attention-style
    # memory behaviour at the XLA level)
    @jax.checkpoint
    def body(_, inp):
        ci, qc = inp
        start = ci * q_chunk + q_offset
        q_pos = start + jnp.arange(q_chunk, dtype=jnp.int32)
        if band is None:
            kc, vc, k_pos = k, v, k_positions
        else:
            s0 = jnp.clip(start - window, 0, sk - band)
            kc = lax.dynamic_slice_in_dim(k, s0, band, axis=1)
            vc = lax.dynamic_slice_in_dim(v, s0, band, axis=1)
            k_pos = lax.dynamic_slice_in_dim(k_positions, s0, band, axis=0)
        out = _attn_core(qc, kc, vc, q_pos, k_pos,
                         window=window, softcap=softcap, dtype=dtype)
        return None, out

    _, outs = lax.scan(body, None,
                       (jnp.arange(n_chunks, dtype=jnp.int32), q_scan))
    return jnp.moveaxis(outs, 0, 1).reshape(b, sq, h, hd)


# -- MLP -------------------------------------------------------------------------

def _act(name: str):
    return jax.nn.gelu if name == "gelu" else jax.nn.silu


def mlp_apply(x, p, act: str, gated: bool, ctx=None):
    from .context import constrain
    if gated:
        h = _act(act)(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = _act(act)(x @ p["w1"])
    h = constrain(h, ctx, "dp", None, "tp")
    return h @ p["w2"]


def init_mlp(key, d: int, f: int, gated: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    s_in, s_out = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    p = {"w1": jax.random.normal(k1, (d, f), dtype) * s_in,
         "w2": jax.random.normal(k2, (f, d), dtype) * s_out}
    if gated:
        p["w3"] = jax.random.normal(k3, (d, f), dtype) * s_in
    return p


# -- attention parameter block ----------------------------------------------------

def init_attention(key, cfg, dtype):
    d, h, kh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
        "wk": jax.random.normal(ks[1], (d, kh, hd), dtype) * s,
        "wv": jax.random.normal(ks[2], (d, kh, hd), dtype) * s,
        "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * (1.0 / math.sqrt(h * hd)),
    }
    if cfg.use_qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def attention_qkv(x, p, cfg, positions):
    """Project + RoPE.  x: (B,S,d) -> q (B,S,H,hd), k/v (B,S,K,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.use_qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    if cfg.pos_emb == "rope":
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attention_out(attn, p):
    return jnp.einsum("bshk,hkd->bsd", attn, p["wo"])
