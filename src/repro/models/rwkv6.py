"""RWKV-6 "Finch" time-mix and channel-mix (arXiv:2404.05892).

Per head (head dim n), with data-dependent per-channel decay w_t:

    o_t = r_t @ (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t

where w_t = exp(-exp(w0 + tanh(x_w A) B)) is the Finch low-rank
data-dependent decay.  Token shift mixes x_{t-1} into each projection
input with learned per-channel ratios mu_*.

Execution: projections/LoRA are parallel over the sequence; the state
recurrence runs as a ``lax.scan`` over *time chunks* whose inner body is a
short unrolled loop (chunk 16) of rank-1 state updates batched over
(B, H).  This keeps the sequential depth at S/16 while staying exact; the
matmul-heavy parts remain fully parallel.  Decode is a single state update.

Simplifications vs the reference implementation (noted in DESIGN.md):
static token-shift mix ratios (Finch makes them data-dependent), and
a per-channel RMS norm on the time-mix output instead of per-head
GroupNorm.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

from .layers import rms_norm

_CHUNK = 16


def init_rwkv(key, cfg, dtype):
    d = cfg.d_model
    h, n = cfg.num_heads, cfg.rwkv_head_dim
    m = h * n
    rank = cfg.rwkv_lora_rank
    ks = jax.random.split(key, 8)
    s_d = 1.0 / math.sqrt(d)
    return {
        "mu": jax.random.uniform(ks[0], (5, d), dtype),  # r,k,v,w,g mix ratios
        "wr": jax.random.normal(ks[1], (d, m), dtype) * s_d,
        "wk": jax.random.normal(ks[2], (d, m), dtype) * s_d,
        "wv": jax.random.normal(ks[3], (d, m), dtype) * s_d,
        "wg": jax.random.normal(ks[4], (d, m), dtype) * s_d,
        "wo": jax.random.normal(ks[5], (m, d), dtype) * (1.0 / math.sqrt(m)),
        "w0": jnp.full((m,), -2.0, jnp.float32),         # base decay
        "wa": jax.random.normal(ks[6], (d, rank), dtype) * s_d,
        "wb": jax.random.normal(ks[7], (rank, m), dtype) * (1.0 / math.sqrt(rank)),
        "u": jnp.zeros((h, n), jnp.float32),             # first-token bonus
        "ln": jnp.ones((m,), dtype),
    }


def init_channel_mix(key, cfg, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    s_d, s_f = 1.0 / math.sqrt(d), 1.0 / math.sqrt(f)
    return {
        "mu": jax.random.uniform(ks[0], (2, d), dtype),  # k, r mix ratios
        "wk": jax.random.normal(ks[1], (d, f), dtype) * s_d,
        "wv": jax.random.normal(ks[2], (f, d), dtype) * s_f,
        "wr": jax.random.normal(jax.random.fold_in(key, 9), (d, d), dtype) * s_d,
    }


def _token_shift(x, last):
    """x: (B, S, d); last: (B, d) previous token (zeros at t=0)."""
    prev = jnp.concatenate([last[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _wkv_step(r, k, v, w, u, state):
    """Single decode step. r,k,v,w: (B, H, n); state: (B, H, n, n)."""
    kv = k[..., :, None] * v[..., None, :]
    o = jnp.einsum("bhi,bhij->bhj", r, state + u[..., None] * kv)
    new_state = w[..., :, None] * state + kv
    return o, new_state


def time_mix_apply(x, p, cfg, cache=None, ctx=None):
    """RWKV-6 time mixing. x: (B, S, d).

    cache: {'state': (B,H,n,n) f32, 'shift': (B,d)} or None (training).
    Returns (out (B, S, d), new_cache_or_None).
    """
    b, s, d = x.shape
    h, n = cfg.num_heads, cfg.rwkv_head_dim
    last = cache["shift"].astype(x.dtype) if cache is not None \
        else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)

    def mix(i):
        mu = p["mu"][i].astype(x.dtype)
        return x + mu * (prev - x)

    from .context import constrain
    pin = lambda t: constrain(t, ctx, "dp", None, "tp")
    xr, xk, xv, xw, xg = (mix(i) for i in range(5))
    r = pin((xr @ p["wr"]).astype(jnp.float32)).reshape(b, s, h, n)
    k = pin((xk @ p["wk"]).astype(jnp.float32)).reshape(b, s, h, n)
    v = pin((xv @ p["wv"]).astype(jnp.float32)).reshape(b, s, h, n)
    g = pin(jax.nn.silu(xg @ p["wg"]))
    # data-dependent decay (Finch): w = exp(-exp(w0 + tanh(xw A) B))
    lw = p["w0"].astype(jnp.float32) + \
        (jnp.tanh(xw.astype(jnp.float32) @ p["wa"].astype(jnp.float32))
         @ p["wb"].astype(jnp.float32))
    w = jnp.exp(-jnp.exp(jnp.clip(lw, -12.0, 4.0))).reshape(b, s, h, n)
    u = p["u"].astype(jnp.float32)

    if cache is not None and s == 1:
        o, new_state = _wkv_step(r[:, 0], k[:, 0], v[:, 0], w[:, 0], u,
                                 cache["state"])
        o = o[:, None]
    else:
        state0 = cache["state"] if cache is not None else \
            jnp.zeros((b, h, n, n), jnp.float32)
        o, new_state = _wkv_chunk_scan(r, k, v, w, u, state0)

    o = o.reshape(b, s, h * n).astype(x.dtype)
    o = rms_norm(o, p["ln"], cfg.norm_eps) * g
    out = o @ p["wo"]
    new_cache = None
    if cache is not None:
        new_cache = {"state": new_state, "shift": x[:, -1, :].astype(jnp.float32)}
    return out, new_cache


def _wkv_chunk_scan(r, k, v, w, u, state0):
    """Exact recurrence, scanned over time chunks of length _CHUNK.

    r,k,v,w: (B, S, H, n) f32 (w is the per-step decay in (0,1));
    u: (H, n); state0: (B, H, n, n).  Returns (o, final_state).
    """
    b, s, h, n = r.shape
    # prepend nothing; just run the scan but seed the carry
    pad = (-s) % _CHUNK
    if pad:
        zp = lambda a, cv=0.0: jnp.pad(
            a, ((0, 0), (0, pad), (0, 0), (0, 0)), constant_values=cv)
        r, k, v, w = zp(r), zp(k), zp(v), zp(w, 1.0)
    sc = r.shape[1] // _CHUNK
    resh = lambda a: a.reshape(b, sc, _CHUNK, h, n).transpose(1, 0, 2, 3, 4)
    rc, kc, vc, wc = resh(r), resh(k), resh(v), resh(w)

    def chunk_body(state, inp):
        rr, kk, vv, ww = inp
        outs = []
        for t in range(_CHUNK):
            kt, vt, rt, wt = kk[:, t], vv[:, t], rr[:, t], ww[:, t]
            kv = kt[..., :, None] * vt[..., None, :]
            o = jnp.einsum("bhi,bhij->bhj", rt, state + u[..., None] * kv)
            outs.append(o)
            state = wt[..., :, None] * state + kv
        return state, jnp.stack(outs, axis=1)

    state, o = lax.scan(chunk_body, state0.astype(jnp.float32), (rc, kc, vc, wc))
    o = o.transpose(1, 0, 2, 3, 4).reshape(b, -1, h, n)[:, :s]
    return o, state


def channel_mix_apply(x, p, cache=None, ctx=None):
    """RWKV channel mixing (the FFN). x: (B, S, d)."""
    from .context import constrain
    b, s, d = x.shape
    last = cache["shift"].astype(x.dtype) if cache is not None \
        else jnp.zeros((b, d), x.dtype)
    prev = _token_shift(x, last)
    xk = x + p["mu"][0].astype(x.dtype) * (prev - x)
    xr = x + p["mu"][1].astype(x.dtype) * (prev - x)
    kk = constrain(jnp.square(jax.nn.relu(xk @ p["wk"])), ctx, "dp", None, "tp")
    out = jax.nn.sigmoid(xr @ p["wr"]) * (kk @ p["wv"])
    new_cache = None
    if cache is not None:
        new_cache = {"shift": x[:, -1, :].astype(jnp.float32)}
    return out, new_cache


def init_rwkv_cache(cfg, batch: int):
    h, n, d = cfg.num_heads, cfg.rwkv_head_dim, cfg.d_model
    return {
        "tmix": {"state": jnp.zeros((batch, h, n, n), jnp.float32),
                 "shift": jnp.zeros((batch, d), jnp.float32)},
        "cmix": {"shift": jnp.zeros((batch, d), jnp.float32)},
    }
