"""Auto split-point selection (arXiv:1802.03931 via the accuracy sweep).

The edge wants to run as little of the network as possible; the paper's
constraint is that compression at the boundary must not cost task
accuracy.  :func:`select_split_point` sweeps every legal boundary tap of
a scenario through the same accuracy harness, prices each tap's *edge*
cost with the static HLO analyzer (``launch.hlo_analysis`` over the
jitted ``forward_head`` program -- measured FLOPs of the compiled
module, not a layer-count proxy), and returns the cheapest tap whose
worst-case degradation across the scenario's codec matrix stays within
the budget.

Everything is deterministic: the harness's token batches and parameter
init are seeded by the scenario, and HLO FLOPs are a property of the
compiled program, so repeated selection returns the same tap.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax

from .. import models
from ..launch.hlo_analysis import analyze
from .harness import ScenarioReport, run_scenario
from .scenarios import Scenario

__all__ = ["SplitCandidate", "SplitSelection", "head_flops",
           "select_split_point"]


@dataclasses.dataclass(frozen=True)
class SplitCandidate:
    split_after: int
    head_flops: float
    worst_degradation: float     # max over the scenario's codec matrix
    meets_budget: bool
    report: ScenarioReport

    def to_dict(self) -> dict[str, Any]:
        return {"split_after": self.split_after,
                "head_flops": self.head_flops,
                "worst_degradation": self.worst_degradation,
                "meets_budget": self.meets_budget}


@dataclasses.dataclass(frozen=True)
class SplitSelection:
    scenario: str
    budget: float
    chosen: SplitCandidate | None    # None: no tap meets the budget
    candidates: tuple[SplitCandidate, ...]

    def to_dict(self) -> dict[str, Any]:
        return {"scenario": self.scenario, "budget": self.budget,
                "chosen": (self.chosen.to_dict()
                           if self.chosen is not None else None),
                "candidates": [c.to_dict() for c in self.candidates]}


def head_flops(sc: Scenario, split_after: int) -> float:
    """Edge-side cost of one boundary tap: dot/conv FLOPs of the
    compiled ``forward_head`` program (the dryrun idiom: jit -> lower ->
    compile -> analyze the optimized HLO text)."""
    cfg = sc.model_config()
    params = models.init_params(cfg, jax.random.PRNGKey(sc.seed))
    tokens = jax.numpy.zeros((sc.batch, sc.seq_len), jax.numpy.int32)
    txt = (jax.jit(lambda p, t: models.forward_head(
        cfg, p, t, split_after=split_after))
        .lower(params, tokens).compile().as_text())
    return analyze(txt, 1).flops


def select_split_point(sc: Scenario, *, budget: float = 0.01,
                       backend: str | None = None) -> SplitSelection:
    """Cheapest boundary tap meeting the degradation budget.

    Sweeps ``split_after`` in 1..n_periods-1, runs the scenario's full
    codec matrix at each tap, and picks the tap with the lowest
    edge-side FLOPs among those whose *worst* case degradation is
    <= ``budget``.  Ties (identical FLOPs) break toward the shallower
    tap.  Returns every candidate so callers can inspect the frontier.
    """
    candidates = []
    for sa in sc.split_points:
        report = run_scenario(sc, split_after=sa, backend=backend)
        worst = max(c.degradation for c in report.cases)
        candidates.append(SplitCandidate(
            split_after=sa, head_flops=head_flops(sc, sa),
            worst_degradation=worst, meets_budget=worst <= budget,
            report=report))
    eligible = [c for c in candidates if c.meets_budget]
    chosen = (min(eligible, key=lambda c: (c.head_flops, c.split_after))
              if eligible else None)
    return SplitSelection(scenario=sc.name, budget=budget, chosen=chosen,
                          candidates=tuple(candidates))
