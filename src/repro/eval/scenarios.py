"""Declarative, schema-validated accuracy scenarios (ISSUE-10 tentpole).

A :class:`Scenario` names one end-to-end split-inference configuration:
which model family to cut, where to cut it, and the codec matrix
(rate rungs x clip modes x granularity) to sweep at that boundary.
Scenarios follow the dataclass-config-factory idiom (ludwig's schema
layer): every field is validated at construction, instances are frozen
and hashable, and each round-trips through JSON so a sweep is fully
described by one declarative blob -- no imperative setup hides in the
harness.

The named registry (:data:`SCENARIOS`) pins the default matrix used by
``launch/eval_accuracy.py``, ``benchmarks/bench_accuracy.py`` and the
tier-1 smoke: one scenario per activation family the paper's claim must
cover (transformer boundary, MoE expert outputs, rwkv6 / rglru
recurrent-state streams), plus tiled-granularity variants.
"""

from __future__ import annotations

import dataclasses
import json
from typing import Any

from ..configs.base import ModelConfig, reduced
from ..configs.registry import ARCHS, get_config

GRANULARITIES = ("tensor", "channel", "tile", "tile2d")
CLIP_MODES = ("model", "empirical", "aciq", "minmax")
TRANSPORTS = ("inproc", "loopback")


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One declarative accuracy-sweep configuration.

    The model is the registry arch shrunk to ``period * n_periods``
    layers at ``d_model`` width (``configs.base.reduced``), so every
    family keeps its real layer pattern -- an rglru scenario still
    interleaves rglru/attention periods -- while staying smoke-test
    sized.  ``split_after`` taps the boundary after that many full
    periods (None = the config's default mid-point).
    """

    name: str
    arch: str
    n_periods: int = 4
    split_after: int | None = None
    d_model: int = 64
    seq_len: int = 32
    batch: int = 2
    n_eval_batches: int = 2
    rungs: tuple[int, ...] = (256, 16, 4)
    clip_modes: tuple[str, ...] = ("minmax", "empirical")
    granularity: str = "tensor"
    channel_group_size: int = 1
    spatial_block_size: int = 0          # 'tile': elements per block
    spatial_block_hw: tuple[int, int] | None = None  # 'tile2d': (bh, bw)
    use_ecsq: bool = False
    calib_sample_cap: int = 0
    transport: str = "inproc"
    # task-metric stability: degradation is scored over tokens whose
    # reference top-2 logit margin exceeds this (near-tie argmax of a
    # smoke-scale random-init model flips under infinitesimal
    # perturbation -- sampling noise, not task signal; real codec
    # failures shift logits far past any such margin).  Raw agreement
    # over every token is reported alongside.
    decisive_margin: float = 0.05
    seed: int = 0

    def __post_init__(self):
        if not self.name:
            raise ValueError("scenario needs a name")
        cfg = get_config(self.arch)      # raises KeyError on unknown arch
        if cfg.input_mode != "tokens":
            raise ValueError(
                f"{self.name}: arch {self.arch!r} takes "
                f"{cfg.input_mode!r} input; accuracy scenarios need a "
                "token-in model (the embedding frontends are stubs)")
        if self.n_periods < 2:
            raise ValueError(
                f"{self.name}: n_periods={self.n_periods} < 2 -- the "
                "split boundary needs at least one period on each side")
        if self.split_after is not None \
                and not 1 <= self.split_after <= self.n_periods - 1:
            raise ValueError(
                f"{self.name}: split_after={self.split_after} out of "
                f"range for n_periods={self.n_periods}")
        if self.seq_len < 1 or self.batch < 1 or self.n_eval_batches < 1:
            raise ValueError(f"{self.name}: seq_len/batch/n_eval_batches "
                             "must be positive")
        if not self.rungs:
            raise ValueError(f"{self.name}: empty rung ladder")
        if any(r < 2 for r in self.rungs):
            raise ValueError(f"{self.name}: every rung needs >= 2 levels, "
                             f"got {self.rungs}")
        if len(set(self.rungs)) != len(self.rungs):
            raise ValueError(f"{self.name}: duplicate rungs {self.rungs}")
        if tuple(sorted(self.rungs, reverse=True)) != tuple(self.rungs):
            raise ValueError(
                f"{self.name}: rungs must be sorted high-to-low (the "
                f"monotone-degradation gate reads them as a ladder), "
                f"got {self.rungs}")
        if not self.clip_modes:
            raise ValueError(f"{self.name}: empty clip_modes")
        bad = set(self.clip_modes) - set(CLIP_MODES)
        if bad:
            raise ValueError(f"{self.name}: unknown clip modes {sorted(bad)}"
                             f"; allowed: {CLIP_MODES}")
        if self.granularity not in GRANULARITIES:
            raise ValueError(f"{self.name}: unknown granularity "
                             f"{self.granularity!r}; allowed: "
                             f"{GRANULARITIES}")
        if self.granularity == "tile2d" and self.spatial_block_hw is None:
            raise ValueError(f"{self.name}: tile2d granularity needs "
                             "spatial_block_hw=(bh, bw)")
        if self.granularity != "tile2d" and self.spatial_block_hw is not None:
            raise ValueError(f"{self.name}: spatial_block_hw is a tile2d "
                             "setting")
        if self.transport not in TRANSPORTS:
            raise ValueError(f"{self.name}: unknown transport "
                             f"{self.transport!r}; allowed: {TRANSPORTS}")
        if self.calib_sample_cap < 0:
            raise ValueError(f"{self.name}: calib_sample_cap must be >= 0")

    # -- derived ---------------------------------------------------------------

    def model_config(self) -> ModelConfig:
        """The shrunk :class:`ModelConfig` this scenario evaluates.

        ``layers = period * n_periods`` is explicit: ``reduced``'s
        default layer count gives only ONE full period for multi-period
        patterns (rglru's period-3 pattern), which has no interior split
        boundary at all.
        """
        base = get_config(self.arch)
        return reduced(base, layers=base.period * self.n_periods,
                       d_model=self.d_model, seq_len_cap=self.seq_len)

    @property
    def split_points(self) -> tuple[int, ...]:
        """Every legal boundary tap for this scenario's depth."""
        return tuple(range(1, self.n_periods))

    # -- serialization ---------------------------------------------------------

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)

    @classmethod
    def from_json(cls, blob: str | dict[str, Any]) -> "Scenario":
        d = json.loads(blob) if isinstance(blob, str) else dict(blob)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown scenario fields {sorted(unknown)}")
        for k in ("rungs", "clip_modes"):
            if k in d and d[k] is not None:
                d[k] = tuple(d[k])
        if d.get("spatial_block_hw") is not None:
            d["spatial_block_hw"] = tuple(d["spatial_block_hw"])
        return cls(**d)


# ---------------------------------------------------------------------------
# named registry
# ---------------------------------------------------------------------------

def _default_scenarios() -> dict[str, Scenario]:
    mk = Scenario
    return {s.name: s for s in [
        # the three activation families the paper's <1% claim must cover
        mk(name="transformer-tensor", arch="codeqwen1.5-7b"),
        mk(name="moe-expert", arch="dbrx-132b"),
        mk(name="rwkv-state", arch="rwkv6-3b"),
        # recurrentgemma interleaves 2x rglru + 1x attn per period: the
        # boundary tensor is a recurrent-state stream, not attention
        mk(name="rglru-state", arch="recurrentgemma-2b", n_periods=2),
        # granularity variants on the transformer boundary
        mk(name="transformer-channel", arch="codeqwen1.5-7b",
           granularity="channel", channel_group_size=8),
        mk(name="transformer-tile", arch="codeqwen1.5-7b",
           granularity="tile", channel_group_size=8,
           spatial_block_size=32),
        mk(name="transformer-tile2d", arch="codeqwen1.5-7b",
           granularity="tile2d", channel_group_size=8,
           spatial_block_hw=(2, 8)),
        # ACIQ baseline column (pins cmin = 0, the paper's comparison)
        mk(name="transformer-aciq", arch="codeqwen1.5-7b",
           clip_modes=("minmax", "aciq")),
        # the real-wire variant: every boundary tensor crosses a socket
        mk(name="transformer-loopback", arch="codeqwen1.5-7b",
           transport="loopback", n_eval_batches=1),
    ]}


SCENARIOS: dict[str, Scenario] = _default_scenarios()

#: the pinned CI mini-matrix: one scenario per family, small enough for
#: the accuracy_smoke job, broad enough for the >= 3 families x >= 3
#: rungs x >= 2 clip-modes acceptance bar
DEFAULT_MATRIX = ("transformer-tensor", "moe-expert", "rwkv-state",
                  "rglru-state")


def get_scenario(name: str) -> Scenario:
    if name not in SCENARIOS:
        raise KeyError(f"unknown scenario {name!r}; available: "
                       f"{sorted(SCENARIOS)}")
    return SCENARIOS[name]


def load_matrix(spec: str | None = None) -> list[Scenario]:
    """Resolve a CLI matrix spec: ``None``/"default" -> the pinned
    mini-matrix, "all" -> every registered scenario, a comma-separated
    name list -> those, a path ending in .json -> a JSON array of
    scenario dicts."""
    if spec is None or spec == "default":
        return [SCENARIOS[n] for n in DEFAULT_MATRIX]
    if spec == "all":
        return [SCENARIOS[n] for n in sorted(SCENARIOS)]
    if spec.endswith(".json"):
        with open(spec) as f:
            return [Scenario.from_json(d) for d in json.load(f)]
    return [get_scenario(n.strip()) for n in spec.split(",") if n.strip()]


__all__ = ["ARCHS", "CLIP_MODES", "DEFAULT_MATRIX", "GRANULARITIES",
           "SCENARIOS", "TRANSPORTS", "Scenario", "get_scenario",
           "load_matrix"]
