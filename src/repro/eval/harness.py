"""End-to-end accuracy harness: the paper's headline claim, measured.

For each :class:`~repro.eval.scenarios.Scenario` the harness runs *real*
split inference -- ``models.forward_head`` on the edge side, a
:class:`~repro.core.FeatureCodec` round trip at the boundary (optionally
through the loopback socket transport), ``models.forward_from_boundary``
on the cloud side -- and reports task-metric degradation against the
uncompressed split at the **measured** wire rate, not a nominal
log2(N):

* ``degradation``: 1 - top-1 next-token agreement with the uncompressed
  reference, scored over *decisive* tokens -- those whose reference
  top-2 logit margin exceeds ``Scenario.decisive_margin``.  On a
  smoke-scale random-init model a near-tie argmax flips under
  infinitesimal perturbation; excluding those ties makes the task
  metric stable (0.0 means task-indistinguishable) while any real
  codec failure still registers, because it moves logits far past the
  margin.  ``raw_degradation`` scores every token for reference.
* ``bits_per_elem``: coded stream bytes x 8 / boundary elements, from
  the actual ``encode_stream`` bytes (headers and all) or, in loopback
  mode, from the client's wire accounting (frames and all).
* ``logit_rmse``: a secondary, finer-grained signal for the monotone
  ladder gates (top-1 agreement saturates at small N on easy tokens).

One :func:`run_scenario` call sweeps the scenario's full
rungs x clip-modes matrix against a single calibration pass per clip
mode, re-using the jitted head/tail programs across every case.
"""

from __future__ import annotations

import dataclasses
import json
import time
from typing import Any

import jax
import numpy as np

from .. import models
from ..core import CodecConfig, calibrate
from ..core.codec import FeatureCodec
from .scenarios import Scenario

__all__ = ["CaseResult", "ScenarioReport", "codec_config_for",
           "run_matrix", "run_scenario"]


@dataclasses.dataclass(frozen=True)
class CaseResult:
    """One (rung, clip_mode) cell of a scenario's sweep."""

    scenario: str
    rung: int
    clip_mode: str
    bits_per_elem: float
    degradation: float           # 1 - top-1 agreement, decisive tokens
    agreement: float             # over decisive tokens
    raw_degradation: float       # 1 - top-1 agreement, every token
    raw_agreement: float
    n_decisive: int
    logit_rmse: float
    coded_bytes: int
    n_elems: int

    def to_dict(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


@dataclasses.dataclass(frozen=True)
class ScenarioReport:
    scenario: Scenario
    cases: tuple[CaseResult, ...]
    split_after: int             # the boundary actually evaluated
    n_tokens: int                # predictions scored per case
    elapsed_s: float

    def to_dict(self) -> dict[str, Any]:
        return {"scenario": json.loads(self.scenario.to_json()),
                "split_after": self.split_after,
                "n_tokens": self.n_tokens,
                "elapsed_s": self.elapsed_s,
                "cases": [c.to_dict() for c in self.cases]}

    def case(self, rung: int, clip_mode: str) -> CaseResult:
        for c in self.cases:
            if c.rung == rung and c.clip_mode == clip_mode:
                return c
        raise KeyError(f"no case (rung={rung}, clip_mode={clip_mode!r})")


def codec_config_for(sc: Scenario, rung: int, clip_mode: str,
                     backend: str | None = None) -> CodecConfig:
    """Map a scenario cell onto a :class:`CodecConfig`.

    Boundary activations are roughly symmetric (residual-stream, not
    post-ReLU), so cmin is never pinned to zero except by ACIQ itself,
    which is exactly the paper's point about that baseline.
    """
    kw: dict[str, Any] = dict(
        n_levels=rung, clip_mode=clip_mode, constrain_cmin_zero=False,
        use_ecsq=sc.use_ecsq, backend=backend,
        calib_sample_cap=sc.calib_sample_cap)
    if sc.granularity == "channel":
        kw.update(granularity="channel", channel_axis=-1,
                  channel_group_size=sc.channel_group_size)
    elif sc.granularity == "tile":
        kw.update(granularity="tile", channel_axis=-1,
                  channel_group_size=sc.channel_group_size,
                  spatial_block_size=sc.spatial_block_size)
    elif sc.granularity == "tile2d":
        kw.update(granularity="tile", channel_axis=-1,
                  channel_group_size=sc.channel_group_size,
                  spatial_block_hw=sc.spatial_block_hw)
    return CodecConfig(**kw)


def _token_batches(sc: Scenario, vocab: int) -> tuple[np.ndarray, np.ndarray]:
    """Deterministic eval + calibration token batches (same shape, so
    tile plans built on the calibration tensor match the eval tensors)."""
    rng = np.random.default_rng(sc.seed)
    ev = rng.integers(0, vocab, (sc.n_eval_batches, sc.batch, sc.seq_len),
                      dtype=np.int64).astype(np.int32)
    cal = rng.integers(0, vocab, (sc.batch, sc.seq_len),
                       dtype=np.int64).astype(np.int32)
    return ev, cal


def _roundtrip_inproc(codec: FeatureCodec, x: np.ndarray
                      ) -> tuple[np.ndarray, int]:
    """Encode/decode through the streaming path; returns (recon, bytes).
    The byte count sums every payload -- stream header, chunk headers
    and entropy bytes -- i.e. what would actually cross the wire."""
    payloads = list(codec.encode_stream(x))
    return (codec.decode_stream(payloads),
            sum(len(p) for p in payloads))


class _LoopbackLink:
    """A real CloudServer on a daemon-thread event loop plus a blocking
    edge client: boundary tensors cross an actual socket and the rate is
    the client's wire accounting."""

    def __init__(self, codec: FeatureCodec):
        import asyncio
        import threading

        from ..serving import TickConfig
        from ..transport import CloudServer, SyncEdgeClient

        self._loop = asyncio.new_event_loop()
        threading.Thread(target=self._loop.run_forever,
                         name="eval-cloud", daemon=True).start()
        self._server = CloudServer(echo_features=True,
                                   tick=TickConfig(max_wait_s=0.0))
        asyncio.run_coroutine_threadsafe(
            self._server.start(), self._loop).result()
        self._client = SyncEdgeClient("127.0.0.1", self._server.port,
                                      codec=codec)

    def roundtrip(self, x: np.ndarray) -> tuple[np.ndarray, int]:
        res = self._client.submit(x)
        return res.arrays[0], res.coded_bytes

    def close(self) -> None:
        import asyncio

        self._client.close()
        asyncio.run_coroutine_threadsafe(
            self._server.close(), self._loop).result()
        self._loop.call_soon_threadsafe(self._loop.stop)


def run_scenario(sc: Scenario, *, split_after: int | None = None,
                 backend: str | None = None) -> ScenarioReport:
    """Sweep one scenario's rungs x clip-modes matrix.

    ``split_after`` overrides the scenario's boundary (the split-point
    selector drives this); ``backend`` pins the quantizer backend
    (tests sweep jnp vs kernel_interpret).
    """
    t0 = time.perf_counter()
    cfg = sc.model_config()
    sa = split_after if split_after is not None else sc.split_after
    # resolve the default so the report names the evaluated boundary
    if sa is None:
        sa = min(max(1, cfg.n_full_periods // 4), cfg.n_full_periods - 1)
    params = models.init_params(cfg, jax.random.PRNGKey(sc.seed))
    ev_tokens, cal_tokens = _token_batches(sc, cfg.vocab_size)

    head = jax.jit(lambda p, t: models.forward_head(
        cfg, p, t, split_after=sa))
    tail = jax.jit(lambda p, x: models.forward_from_boundary(
        cfg, p, x, split_after=sa))

    boundaries = [np.asarray(head(params, t), np.float32)
                  for t in ev_tokens]
    cal_boundary = np.asarray(head(params, cal_tokens), np.float32)
    ref_logits = [np.asarray(tail(params, b), np.float64)
                  for b in boundaries]
    ref_top1 = [np.argmax(rl, axis=-1) for rl in ref_logits]
    # decisive mask: reference top-2 logit margin above the scenario
    # threshold -- near-tie argmax is chance, not task signal
    top2 = [np.partition(rl, -2, axis=-1)[..., -2:] for rl in ref_logits]
    decisive = [(t[..., 1] - t[..., 0]) > sc.decisive_margin for t in top2]
    n_tokens = int(sum(t.size for t in ref_top1))
    n_decisive = int(sum(d.sum() for d in decisive))
    if n_decisive == 0:
        raise ValueError(
            f"{sc.name}: no decisive tokens at margin "
            f"{sc.decisive_margin} -- widen the eval batches or lower "
            "decisive_margin")

    cases = []
    for clip_mode in sc.clip_modes:
        for rung in sc.rungs:
            codec = calibrate(
                codec_config_for(sc, rung, clip_mode, backend=backend),
                cal_boundary)
            link = (_LoopbackLink(codec) if sc.transport == "loopback"
                    else None)
            try:
                agree_dec = 0
                agree_all = 0
                sq = 0.0
                coded = 0
                elems = 0
                for b, rt, rl, dm in zip(boundaries, ref_top1,
                                         ref_logits, decisive):
                    if link is not None:
                        recon, nbytes = link.roundtrip(b)
                    else:
                        recon, nbytes = _roundtrip_inproc(codec, b)
                    recon = recon.reshape(b.shape)
                    logits = np.asarray(tail(params, recon), np.float64)
                    same = np.argmax(logits, axis=-1) == rt
                    agree_dec += int(same[dm].sum())
                    agree_all += int(same.sum())
                    sq += float(((logits - rl) ** 2).sum())
                    coded += nbytes
                    elems += b.size
            finally:
                if link is not None:
                    link.close()
            agreement = agree_dec / n_decisive
            raw_agreement = agree_all / n_tokens
            cases.append(CaseResult(
                scenario=sc.name, rung=rung, clip_mode=clip_mode,
                bits_per_elem=coded * 8.0 / elems,
                degradation=1.0 - agreement, agreement=agreement,
                raw_degradation=1.0 - raw_agreement,
                raw_agreement=raw_agreement, n_decisive=n_decisive,
                logit_rmse=(sq / sum(r.size for r in ref_logits)) ** 0.5,
                coded_bytes=coded, n_elems=elems))
    return ScenarioReport(scenario=sc, cases=tuple(cases), split_after=sa,
                          n_tokens=n_tokens,
                          elapsed_s=time.perf_counter() - t0)


def run_matrix(scenarios, *, backend: str | None = None
               ) -> dict[str, ScenarioReport]:
    """Run a list of scenarios; returns name -> report (insertion order)."""
    out: dict[str, ScenarioReport] = {}
    for sc in scenarios:
        out[sc.name] = run_scenario(sc, backend=backend)
    return out
