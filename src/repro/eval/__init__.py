"""Accuracy-grounded evaluation: scenarios, harness, split-point selector.

The subsystem that closes the paper's headline claim (<1% task-accuracy
loss at 0.6-0.8 bits/element) on real split inference instead of
synthetic-blob MSE.  See DESIGN.md, "Accuracy scenario matrix".
"""

from .harness import (CaseResult, ScenarioReport, codec_config_for,
                      run_matrix, run_scenario)
from .scenarios import (CLIP_MODES, DEFAULT_MATRIX, GRANULARITIES,
                        SCENARIOS, TRANSPORTS, Scenario, get_scenario,
                        load_matrix)
from .selector import (SplitCandidate, SplitSelection, head_flops,
                       select_split_point)

__all__ = ["CLIP_MODES", "CaseResult", "DEFAULT_MATRIX", "GRANULARITIES",
           "SCENARIOS", "Scenario", "ScenarioReport", "SplitCandidate",
           "SplitSelection", "TRANSPORTS", "codec_config_for",
           "get_scenario", "head_flops", "load_matrix", "run_matrix",
           "run_scenario", "select_split_point"]
