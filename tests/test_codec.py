"""End-to-end FeatureCodec tests: calibration, bitstream round trip, rates."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodecConfig, calibrate
from repro.core.distributions import resnet50_layer21_model


@pytest.fixture(scope="module")
def samples():
    return resnet50_layer21_model().sample(80_000, np.random.default_rng(0)) \
        .astype(np.float32)


class TestCalibration:
    def test_model_mode_matches_table1(self, samples):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model"),
                          sample_mean=1.1235656, sample_var=4.9280124)
        assert codec.cmax == pytest.approx(9.036, abs=2e-3)
        assert codec.cmin == 0.0

    def test_model_mode_from_samples(self, samples):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model"), samples=samples)
        assert codec.cmax == pytest.approx(9.036, rel=0.05)

    def test_unconstrained_range(self):
        codec = calibrate(CodecConfig(n_levels=2, clip_mode="model",
                                      constrain_cmin_zero=False),
                          sample_mean=1.1235656, sample_var=4.9280124)
        assert codec.cmin == pytest.approx(0.361, abs=5e-3)
        assert codec.cmax == pytest.approx(5.544, abs=5e-3)

    @pytest.mark.parametrize("mode", ["empirical", "aciq"])
    def test_other_modes(self, samples, mode):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode=mode), samples=samples)
        assert codec.cmax > 0


class TestBitstream:
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 8])
    def test_roundtrip_equals_fake_quant(self, samples, n_levels):
        x = samples[:20_000]
        codec = calibrate(CodecConfig(n_levels=n_levels, clip_mode="model"),
                          samples=x)
        data = codec.encode(x)
        decoded = codec.decode(data, shape=x.shape)
        fake = np.asarray(codec.apply(jnp.asarray(x)))
        assert np.allclose(decoded, fake, atol=1e-6)

    def test_paper_rate_claim(self, samples):
        """Paper abstract: 2-bit quantization + entropy coding lands well below
        2 bits/element.  (The 0.6-0.8 figure is for real, sparser feature maps;
        synthetic iid model samples carry more entropy -- ~1.1 bpe.)"""
        from repro.core.binarization import total_tu_bits
        from repro.core.uniform import quantize_np
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model"), samples=samples)
        x = samples[:40_000]
        bpe = codec.compressed_bits_per_element(x)
        assert 0.3 < bpe < 1.3
        raw_tu = total_tu_bits(quantize_np(x, codec.cmin, codec.cmax, 4), 4) / x.size
        assert bpe < raw_tu  # CABAC gains over raw binarization

    def test_rate_estimate_matches_actual(self, samples):
        x = samples[:30_000]
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model"), samples=x)
        est = float(codec.estimate_rate(jnp.asarray(x)))
        actual = codec.compressed_bits_per_element(x) - 16 * 8 / x.size
        assert est == pytest.approx(actual, rel=0.1)

    def test_ecsq_roundtrip(self, samples):
        x = samples[:15_000]
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model", use_ecsq=True,
                                      ecsq_lagrangian=0.05), samples=x)
        decoded = codec.decode(codec.encode(x), shape=x.shape)
        fake = np.asarray(codec.apply(jnp.asarray(x)))
        assert np.allclose(decoded, fake, atol=1e-6)
        assert codec.ecsq.levels[0] == codec.cmin
        assert codec.ecsq.levels[-1] == codec.cmax


class TestPacking:
    @pytest.mark.parametrize("n_levels,bits", [(2, 1), (3, 2), (4, 2), (8, 3),
                                               (16, 4)])
    def test_bits_per_index(self, n_levels, bits):
        codec = calibrate(CodecConfig(n_levels=n_levels, clip_mode="manual",
                                      manual_cmax=8.0))
        assert codec.bits_per_index() == bits

    @pytest.mark.parametrize("n_levels", [2, 4, 16])
    def test_pack_unpack_roundtrip(self, n_levels):
        rng = np.random.default_rng(0)
        idx = jnp.asarray(rng.integers(0, n_levels, size=4096).astype(np.int32))
        codec = calibrate(CodecConfig(n_levels=n_levels, clip_mode="manual",
                                      manual_cmax=1.0))
        packed = codec.pack(idx)
        assert packed.dtype == jnp.uint8
        bits = codec.bits_per_index()
        assert packed.size == 4096 * bits // 8
        back = codec.unpack(packed, 4096)
        assert (np.asarray(back) == np.asarray(idx)).all()
