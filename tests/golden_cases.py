"""Shared definitions of the frozen stream-conformance vectors.

Each case pins one wire format the codec has ever shipped (v1 seed
streams through the v4 2-D tile extension) with a deterministic input
tensor and codec construction, so ``tests/test_stream_conformance.py``
can assert *byte-exact* encode and *bit-exact* decode against the
committed files under ``tests/golden/`` -- the compatibility gate that
keeps refactors from silently breaking decode of deployed streams.

Regenerate the files with ``python tests/regen_golden.py`` (only when a
format change is intentional -- a diff in an existing ``.stream.bin`` is
a wire-compatibility break and must bump the format version instead).

Determinism notes: inputs come from ``np.random.default_rng`` (PCG64,
stable by specification); codecs use either explicit manual ranges /
quantizer tables or ``minmax`` calibration (exact elementwise float ops,
no accumulation-order dependence); entropy coder modes are pinned
(never "auto").  Quantizer indices are bit-identical across backends by
the QuantBackend contract, so these cases hold under the jnp, kernel
and kernel_interpret matrices alike.
"""

from __future__ import annotations

import struct

import numpy as np

from repro.core import CodecConfig, calibrate
from repro.core import cabac
from repro.core.codec import (FeatureCodec, _CHANNEL_EXT_FMT, _HEADER_FMT,
                              FLAG_CHANNEL, FLAG_V2)
from repro.core.ecsq import ECSQQuantizer

GOLDEN_SEED = 20260731


def _flat_input(n=3000, seed=GOLDEN_SEED):
    """Per-tensor calibration-friendly activations (ReLU-like)."""
    rng = np.random.default_rng(seed)
    return rng.exponential(1.5, n).astype(np.float32)


def _conv_input(shape=(1, 6, 11, 9), seed=GOLDEN_SEED + 1):
    """NCHW conv map with channel + row + column statistic drift."""
    rng = np.random.default_rng(seed)
    _, c, h, w = shape
    x = rng.exponential(1.0, shape).astype(np.float32)
    x += np.linspace(0.0, 5.0, c)[None, :, None, None]
    x += np.linspace(0.0, 3.0, h)[None, None, :, None]
    x += np.linspace(0.0, 2.0, w)[None, None, None, :]
    return x.astype(np.float32)


def build_v1_stream(x: np.ndarray, cmin: float, cmax: float,
                    n_levels: int) -> bytes:
    """A seed-format (v1) stream: 16-byte header with *no* flags and a
    bare serial-CABAC payload.  The current encoder always writes v2+
    headers, so v1 is decode-only -- this helper freezes the layout the
    seed encoder used."""
    from repro.core.backend import QuantSpec, get_backend
    idx = np.asarray(get_backend("jnp").quantize(x, QuantSpec(
        float(cmin), float(cmax), n_levels)))
    header = struct.pack(_HEADER_FMT, cmin, cmax, n_levels, 0, x.size)
    return header + cabac.encode_indices_serial(idx.ravel(), n_levels)


def build_v2_channel_stream(x: np.ndarray, lo: np.ndarray, hi: np.ndarray,
                            n_levels: int) -> bytes:
    """A legacy v2 per-channel stream (FLAG_CHANNEL ext, group size 1):
    produced by the PR-1 encoder, decode-only since PR 3 replaced it with
    the v3 tile ext.  ``x`` is (M, C) channel-minor."""
    from repro.core.backend import get_backend, spec_from_numpy
    spec = spec_from_numpy(lo, hi, n_levels, -1)
    idx = np.asarray(get_backend("jnp").quantize(x, spec))
    flags = FLAG_V2 | FLAG_CHANNEL
    header = struct.pack(_HEADER_FMT, float(lo.min()), float(hi.max()),
                         n_levels, flags, x.size)
    header += struct.pack(_CHANNEL_EXT_FMT, x.ndim, x.ndim - 1, 1,
                          lo.size)
    header += np.asarray(x.shape, "<u4").tobytes()
    header += np.stack([lo, hi], axis=-1).astype("<f4").tobytes()
    return header + cabac.encode_indices(idx.ravel(), n_levels,
                                         mode="rans")


def _v2_uniform_codec(n_levels=4):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="manual",
                                 manual_cmin=0.0, manual_cmax=9.0))


def _v2_ecsq_codec():
    """Per-tensor ECSQ with an explicit (non-designed) level table."""
    codec = _v2_uniform_codec()
    codec.ecsq = ECSQQuantizer.from_levels(
        np.array([0.0, 1.0, 2.5, 5.0], np.float32))
    return codec


def _v3_tile_codec(x):
    return calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="tile", channel_axis=1,
                                 channel_group_size=2,
                                 spatial_block_size=32), samples=x)


def _v4_tile2d_codec(x, use_ecsq=False, n_levels=4):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="tile", channel_axis=1,
                                 channel_group_size=2,
                                 spatial_block_hw=(4, 3),
                                 use_ecsq=use_ecsq), samples=x)


class Case:
    """One conformance vector: a deterministic (input, stream) pair.

    ``encode()`` returns the bytes the *current* encoder produces for the
    input (asserted byte-exact against the committed stream);
    ``decode(stream)`` dequantizes a stream (asserted bit-exact against
    the committed reconstruction).  Legacy formats the current encoder no
    longer writes set ``decode_only`` and freeze their byte layout
    through the manual ``build_*`` helpers instead.
    """

    def __init__(self, name: str, make_input, make_codec, *,
                 coder_mode: str = "rans", decode_only: bool = False,
                 builder=None, streamed: bool = False,
                 chunk_elems: int = 0):
        self.name = name
        self.make_input = make_input
        self.make_codec = make_codec
        self.coder_mode = coder_mode
        self.decode_only = decode_only
        self.builder = builder
        self.streamed = streamed
        self.chunk_elems = chunk_elems

    def encode(self, x: np.ndarray) -> bytes:
        if self.builder is not None:
            return self.builder(x)
        codec = self.make_codec(x)
        if self.streamed:
            return pack_payloads(list(codec.encode_stream(
                x, chunk_elems=self.chunk_elems,
                coder_mode=self.coder_mode)))
        return codec.encode(x, coder_mode=self.coder_mode)

    def decode(self, stream: bytes, x: np.ndarray) -> np.ndarray:
        codec = self.make_codec(x)
        if self.streamed:
            return codec.decode_stream(unpack_payloads(stream))
        return codec.decode(stream, shape=x.shape)


def pack_payloads(payloads: list[bytes]) -> bytes:
    """Serialize a payload sequence as u32-length-prefixed records (the
    golden-file form of an ``encode_stream`` run)."""
    return b"".join(struct.pack("<I", len(p)) + p for p in payloads)


def unpack_payloads(blob: bytes) -> list[bytes]:
    out, off = [], 0
    while off < len(blob):
        (n,) = struct.unpack_from("<I", blob, off)
        off += 4
        out.append(blob[off:off + n])
        off += n
    if off != len(blob):
        raise ValueError("trailing bytes in packed payload stream")
    return out


def _receiver(x):
    """A state-free receiver codec (self-describing formats need no
    calibration match)."""
    return _v2_uniform_codec()


CASES = [
    Case("v1_seed_uniform", _flat_input, _receiver, decode_only=True,
         builder=lambda x: build_v1_stream(x, 0.0, 9.0, 4)),
    Case("v2_uniform_serial", lambda: _flat_input(n=800),
         lambda x: _v2_uniform_codec(), coder_mode="serial"),
    Case("v2_uniform_rans", _flat_input, lambda x: _v2_uniform_codec()),
    Case("v2_uniform_n8", _flat_input,
         lambda x: _v2_uniform_codec(n_levels=8)),
    Case("v2_ecsq", _flat_input, lambda x: _v2_ecsq_codec()),
    Case("v2_channel_legacy",
         lambda: _flat_input(n=1024).reshape(128, 8) +
         np.linspace(0.0, 6.0, 8, dtype=np.float32)[None, :],
         _receiver, decode_only=True,
         builder=lambda x: build_v2_channel_stream(
             x, x.min(axis=0), x.max(axis=0), 4)),
    Case("v3_tile", _conv_input, _v3_tile_codec),
    Case("v3_tile_stream", _conv_input, _v3_tile_codec, streamed=True,
         chunk_elems=128),
    Case("v4_tile2d", _conv_input, _v4_tile2d_codec),
    Case("v4_tile2d_n8", _conv_input,
         lambda x: _v4_tile2d_codec(x, n_levels=8)),
    Case("v4_tile2d_ecsq", _conv_input,
         lambda x: _v4_tile2d_codec(x, use_ecsq=True)),
    Case("v4_tile2d_stream", _conv_input, _v4_tile2d_codec, streamed=True,
         chunk_elems=64),
]
