"""Table I reproduction + clipping/quantization error model tests."""

import numpy as np
import pytest

from repro.core import clipping
from repro.core.aciq import aciq_cmax, laplace_b_from_samples
from repro.core.distributions import resnet50_layer21_model, yolov3_layer12_model

RESNET_TABLE1_MODEL_CMAX = {2: 5.184, 3: 7.511, 4: 9.036, 5: 10.175,
                            6: 11.084, 7: 11.842, 8: 12.492}
YOLO_TABLE1_MODEL_CMAX = {2: 1.674, 3: 2.425, 4: 2.918, 5: 3.285,
                          6: 3.579, 7: 3.824, 8: 4.033}
RESNET_TABLE1_UNCONSTRAINED = {2: (0.361, 5.544), 4: (0.053, 9.089),
                               8: (-0.065, 12.427)}


@pytest.fixture(scope="module")
def resnet_model():
    return resnet50_layer21_model()


@pytest.fixture(scope="module")
def yolo_model():
    return yolov3_layer12_model()


class TestTable1:
    @pytest.mark.parametrize("n,expected", sorted(RESNET_TABLE1_MODEL_CMAX.items()))
    def test_resnet_model_cmax(self, resnet_model, n, expected):
        assert clipping.optimal_cmax(resnet_model, n) == pytest.approx(expected, abs=2e-3)

    @pytest.mark.parametrize("n,expected", sorted(YOLO_TABLE1_MODEL_CMAX.items()))
    def test_yolo_model_cmax(self, yolo_model, n, expected):
        assert clipping.optimal_cmax(yolo_model, n) == pytest.approx(expected, abs=2e-3)

    @pytest.mark.parametrize("n,expected", sorted(RESNET_TABLE1_UNCONSTRAINED.items()))
    def test_resnet_unconstrained_range(self, resnet_model, n, expected):
        lo, hi = clipping.optimal_range(resnet_model, n)
        assert lo == pytest.approx(expected[0], abs=5e-3)
        assert hi == pytest.approx(expected[1], abs=5e-3)

    def test_optimal_cmax_decreases_with_coarser_quantization(self, resnet_model):
        cs = [clipping.optimal_cmax(resnet_model, n) for n in range(2, 9)]
        assert all(a < b for a, b in zip(cs, cs[1:]))


class TestErrorModel:
    def test_eclip_monotone_decreasing_in_cmax(self, resnet_model):
        es = [clipping.e_clip(resnet_model, 0.0, c) for c in np.linspace(1, 20, 10)]
        assert all(a > b for a, b in zip(es, es[1:]))

    def test_eclip_independent_of_n(self, resnet_model):
        assert clipping.e_clip(resnet_model, 0.0, 5.0) == clipping.e_clip(resnet_model, 0.0, 5.0)

    def test_equant_increases_with_fewer_levels(self, resnet_model):
        e2 = clipping.e_quant(resnet_model, 0.0, 9.0, 2)
        e8 = clipping.e_quant(resnet_model, 0.0, 9.0, 8)
        assert e2 > e8

    def test_eq11_closed_form_n4(self, resnet_model):
        """Paper eq. (11): simplified closed form for N=4, c_min=0 (approximate)."""
        for c in (7.0, 9.036, 12.0):
            a = -0.3858 / 6 * c
            paper = 6.190 - 0.795 * c * (np.exp(a) + np.exp(3 * a) + np.exp(5 * a))
            exact = clipping.e_total(resnet_model, 0.0, c, 4)
            # the paper's printed form drops small terms; agree to ~2%
            assert exact == pytest.approx(paper, rel=0.02)

    def test_model_error_matches_measured_error(self, resnet_model):
        """Fig. 5(a): analytic e_tot tracks measured MSRE on model-true data."""
        s = resnet_model.sample(400_000, np.random.default_rng(11))
        for n in (2, 4, 8):
            for c in (4.0, 9.0, 14.0):
                analytic = clipping.e_total(resnet_model, 0.0, c, n)
                measured = clipping.empirical_e_total(s, 0.0, c, n)
                assert analytic == pytest.approx(measured, rel=0.05)

    def test_empirical_optimum_near_model_optimum_on_model_data(self, resnet_model):
        s = resnet_model.sample(300_000, np.random.default_rng(5))
        c_emp = clipping.empirical_optimal_cmax(s, 4)
        c_mod = clipping.optimal_cmax(resnet_model, 4)
        assert c_emp == pytest.approx(c_mod, rel=0.1)


class TestACIQ:
    def test_lambertw_formula(self):
        # internal consistency: W satisfies W e^W = 12 N^2
        for n in (2, 4, 8):
            c = aciq_cmax(1.0, n)
            assert c * np.exp(c) == pytest.approx(12 * n ** 2, rel=1e-9)

    def test_paper_aciq_column_consistent_with_eq13(self):
        """Table I ACIQ values imply a single data-estimated b (~2.02): check
        that eq. (13) reproduces the paper's ACIQ column with that b."""
        paper_vals = {2: 5.722, 3: 6.964, 4: 7.878, 5: 8.603, 8: 10.166}
        bs = {n: v / aciq_cmax(1.0, n) for n, v in paper_vals.items()}
        b = np.mean(list(bs.values()))
        assert np.std(list(bs.values())) < 0.01  # constant b across rows
        for n, v in paper_vals.items():
            assert aciq_cmax(b, n) == pytest.approx(v, abs=0.05)

    def test_aciq_cmax_grows_with_levels(self, resnet_model):
        s = resnet_model.sample(100_000, np.random.default_rng(2))
        b = laplace_b_from_samples(s)
        cs = [aciq_cmax(b, n) for n in range(2, 9)]
        assert all(a < c for a, c in zip(cs, cs[1:]))

    def test_b_estimator(self):
        rng = np.random.default_rng(0)
        lap = rng.laplace(loc=3.0, scale=1.7, size=500_000)
        assert laplace_b_from_samples(lap) == pytest.approx(1.7, rel=0.01)


class TestDegenerateCalibration:
    """Dead-channel / constant / empty tiles must never poison the clip
    range: b = 0 would give a zero step size, and the NaN from an empty
    estimate compares False against every guard."""

    def test_laplace_b_floored_on_dead_tile(self):
        b = laplace_b_from_samples(np.zeros(1024))
        assert b > 0.0
        c = aciq_cmax(b, 8)
        assert np.isfinite(c) and c > 0.0

    def test_laplace_b_floored_on_constant_tile(self):
        b = laplace_b_from_samples(np.full(512, 3.25))
        assert b > 0.0 and np.isfinite(aciq_cmax(b, 256))

    def test_laplace_b_empty_raises(self):
        with pytest.raises(ValueError, match="empty"):
            laplace_b_from_samples(np.empty(0))

    def test_aciq_cmax_rejects_nonfinite_scale(self):
        with pytest.raises(ValueError):
            aciq_cmax(float("nan"), 8)
        with pytest.raises(ValueError):
            aciq_cmax(-1.0, 8)

    def test_empirical_cmax_dead_tile_nondegenerate(self):
        c = clipping.empirical_optimal_cmax(np.zeros(256), 8)
        assert np.isfinite(c) and c > 0.0

    def test_empirical_calibrators_empty_raise(self):
        with pytest.raises(ValueError, match="empty"):
            clipping.empirical_optimal_cmax(np.empty(0), 8)
        with pytest.raises(ValueError, match="empty"):
            clipping.empirical_optimal_range(np.empty(0), 8)

    @pytest.mark.parametrize("clip_mode", ["aciq", "empirical", "minmax"])
    def test_per_channel_calibrate_with_dead_channel(self, clip_mode):
        """A dead channel inside a per-channel plan still yields a finite,
        ordered range table and an exact round trip for the live data."""
        import dataclasses

        from repro.core import CodecConfig, calibrate

        rng = np.random.default_rng(0)
        x = np.abs(rng.normal(size=(6, 32)).astype(np.float32))
        x[2] = 0.0  # dead channel
        cfg = CodecConfig(n_levels=8, clip_mode=clip_mode,
                          granularity="channel", channel_axis=0)
        codec = calibrate(cfg, x)
        lo = np.asarray(codec.cmin, np.float64).ravel()
        hi = np.asarray(codec.cmax, np.float64).ravel()
        assert np.isfinite(lo).all() and np.isfinite(hi).all()
        assert (hi > lo).all()
        dec = np.asarray(codec.decode(codec.encode(x)))
        assert np.isfinite(dec).all()
        np.testing.assert_allclose(dec[2], 0.0, atol=1e-5)

    def test_calibrate_nan_samples_fail_loudly(self):
        from repro.core import CodecConfig, calibrate

        bad = np.full(64, np.nan, dtype=np.float32)
        with pytest.raises(ValueError, match="non-finite|NaN"):
            calibrate(CodecConfig(n_levels=8, clip_mode="minmax"), bad)

    def test_calibrate_empty_samples_fail_loudly(self):
        from repro.core import CodecConfig, calibrate

        with pytest.raises(ValueError, match="empty"):
            calibrate(CodecConfig(n_levels=8, clip_mode="aciq"),
                      np.empty((0,), np.float32))
