"""Uniform quantizer (eq. 1), ECSQ (Alg. 1), binarization, CABAC, rate model."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import binarization, cabac, uniform
from repro.core.distributions import resnet50_layer21_model
from repro.core.ecsq import design_ecsq
from repro.core.rate_model import (estimated_bits_np,
                                   estimated_bits_per_element)


class TestUniformQuantizer:
    def test_round_half_away_from_zero(self):
        # with cmin=0, cmax=3, N=4: delta=1; x=0.5 is halfway -> rounds up to 1
        idx = uniform.quantize(jnp.array([0.5, 1.5, 2.5]), 0.0, 3.0, 4)
        assert list(np.asarray(idx)) == [1, 2, 3]

    def test_pinned_outer_bins(self):
        x = jnp.array([-5.0, 0.0, 0.2, 9.8, 10.0, 50.0])
        y = uniform.quantize_dequantize(x, 0.0, 10.0, 6)
        assert float(y[0]) == 0.0 and float(y[-1]) == 10.0
        # clipped values incur no further quant error
        assert float(y[-2]) == 10.0

    @pytest.mark.parametrize("n", [2, 3, 5, 6, 7])  # non-power-of-two allowed
    def test_n_levels_not_power_of_two(self, n):
        x = jnp.linspace(-1.0, 12.0, 1000)
        idx = np.asarray(uniform.quantize(x, 0.0, 10.0, n))
        assert idx.min() == 0 and idx.max() == n - 1

    def test_matches_numpy_reference(self):
        rng = np.random.default_rng(0)
        x = rng.normal(2, 3, size=10_000).astype(np.float32)
        j = np.asarray(uniform.quantize(jnp.asarray(x), 0.0, 9.0, 5))
        n = uniform.quantize_np(x, 0.0, 9.0, 5)
        assert (j == n).all()

    def test_idempotent(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(1, 2, 1000).astype(np.float32))
        y = uniform.quantize_dequantize(x, 0.0, 8.0, 4)
        z = uniform.quantize_dequantize(y, 0.0, 8.0, 4)
        assert np.allclose(np.asarray(y), np.asarray(z))

    def test_straight_through_gradient(self):
        import jax
        g = jax.grad(lambda x: uniform.straight_through_quant(x, 0.0, 4.0, 4).sum())
        gr = g(jnp.array([1.0, 2.5, -3.0, 7.0]))
        assert list(np.asarray(gr)) == [1.0, 1.0, 0.0, 0.0]


class TestECSQ:
    @pytest.fixture(scope="class")
    def samples(self):
        return resnet50_layer21_model().sample(60_000, np.random.default_rng(4))

    def test_pinned_boundaries(self, samples):
        q = design_ecsq(samples, 4, 0.05, 0.0, 9.0, pin_boundaries=True)
        assert q.levels[0] == 0.0 and q.levels[-1] == 9.0

    def test_conventional_shrinks_range(self, samples):
        q = design_ecsq(samples, 4, 0.05, 0.0, 9.0, pin_boundaries=False)
        assert q.levels[0] > 0.0 and q.levels[-1] < 9.0

    def test_levels_monotone_and_thresholds_interleave(self, samples):
        q = design_ecsq(samples, 5, 0.02, 0.0, 10.0)
        assert (np.diff(q.levels) >= 0).all()
        for i in range(len(q.thresholds)):
            assert q.levels[i] - 1e-9 <= q.thresholds[i] <= q.levels[i + 1] + 1e-9

    def test_beats_uniform_distortion_at_same_levels(self, samples):
        """Non-uniform design should reduce MSE vs uniform at lam -> 0."""
        q = design_ecsq(samples, 4, 1e-6, 0.0, 9.0)
        xc = np.clip(samples, 0.0, 9.0)
        mse_ecsq = np.mean((xc - q.dequantize_np(q.quantize_np(samples))) ** 2)
        u = uniform.quantize_np(samples, 0.0, 9.0, 4)
        mse_unif = np.mean((xc - uniform.dequantize_np(u, 0.0, 9.0, 4)) ** 2)
        assert mse_ecsq <= mse_unif * 1.001

    def test_larger_lagrangian_lowers_rate(self, samples):
        rates = []
        for lam in (1e-4, 0.2, 2.0):
            q = design_ecsq(samples, 4, lam, 0.0, 9.0)
            idx = q.quantize_np(samples)
            rates.append(estimated_bits_np(idx, 4) / idx.size)
        assert rates[0] >= rates[1] >= rates[2] - 1e-9


class TestBinarization:
    def test_tu_lengths(self):
        assert list(binarization.truncated_unary_lengths(4)) == [1, 2, 3, 3]
        assert list(binarization.truncated_unary_lengths(2)) == [1, 1]

    def test_codewords(self):
        assert [binarization.encode_index(i, 4) for i in range(4)] == \
            ["0", "10", "110", "111"]

    def test_plane_roundtrip(self):
        rng = np.random.default_rng(3)
        for n in (2, 3, 4, 8):
            idx = rng.integers(0, n, size=5000).astype(np.int32)
            planes = binarization.index_to_context_bits(idx, n)
            back = binarization.context_bits_to_index(planes, idx.size, n)
            assert (back == idx).all()

    def test_total_bits(self):
        idx = np.array([0, 1, 2, 3])
        assert binarization.total_tu_bits(idx, 4) == 1 + 2 + 3 + 3


class TestCABAC:
    @pytest.mark.parametrize("n,size,skew", [(2, 2000, 0.9), (4, 5000, 0.7),
                                             (8, 3000, 0.5), (3, 1, 0.5),
                                             (4, 0, 0.5)])
    def test_roundtrip_exact(self, n, size, skew):
        rng = np.random.default_rng(42)
        p = np.array([skew] + [(1 - skew) / (n - 1)] * (n - 1))
        idx = rng.choice(n, size=size, p=p).astype(np.int32)
        data = cabac.encode_indices(idx, n)
        back = cabac.decode_indices(data, size, n)
        assert (back == idx).all()

    def test_compresses_skewed_data(self):
        rng = np.random.default_rng(0)
        idx = (rng.random(20_000) > 0.95).astype(np.int32) * 3  # mostly zeros
        data = cabac.encode_indices(idx, 4)
        raw_bits = binarization.total_tu_bits(idx, 4)
        assert len(data) * 8 < raw_bits * 0.6

    def test_rate_close_to_entropy_estimate(self):
        m = resnet50_layer21_model()
        s = m.sample(30_000, np.random.default_rng(9))
        idx = uniform.quantize_np(s, 0.0, 9.036, 4)
        est = estimated_bits_np(idx, 4)
        actual = len(cabac.encode_indices(idx, 4)) * 8
        assert actual == pytest.approx(est, rel=0.08)


class TestRateModel:
    def test_jnp_matches_np(self):
        rng = np.random.default_rng(17)
        idx = rng.integers(0, 4, size=9000).astype(np.int32)
        j = float(estimated_bits_per_element(jnp.asarray(idx), 4)) * idx.size
        n = estimated_bits_np(idx, 4)
        assert j == pytest.approx(n, rel=1e-4)

    def test_uniform_indices_cost_tu_average(self):
        # all four indices equally likely: planes are all ~balanced
        idx = np.tile(np.arange(4, dtype=np.int32), 1000)
        bits = estimated_bits_np(idx, 4) / idx.size
        # entropy bound <= average TU length (1+2+3+3)/4
        assert bits <= 2.25 + 1e-6
