"""TilePlan codec tests: v3 self-describing tiled streams, per-tile ECSQ,
backend bit-exactness, streamed-vs-one-shot parity, tile-aware rate
control, batched chunk entropy coding, and the in-graph pack kernel."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodecConfig, TilePlan, calibrate
from repro.core import cabac
from repro.core.backend import JnpBackend, QuantSpec, get_backend
from repro.core.codec import FLAG_TILE, parse_header
from repro.core.rans import encode_planes, encode_planes_batch

try:  # hypothesis is optional: only the property sweeps need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _features(shape, axis, seed=0, constant_tiles=False):
    """Channel-biased + spatially drifting features; optionally with the
    leading channel held constant (degenerate tiles)."""
    rng = np.random.default_rng(seed)
    axis = axis % len(shape)
    c = shape[axis]
    rest = tuple(s for d, s in enumerate(shape) if d != axis)
    mu = np.linspace(0.0, 8.0, c).astype(np.float32)
    x = rng.exponential(1.0, shape).astype(np.float32)
    x += np.moveaxis(np.broadcast_to(
        mu[:, None], (c, x.size // c)).reshape((c,) + rest), 0, axis)
    if constant_tiles:
        xm = np.moveaxis(x, axis, 0)
        xm[0] = 3.25                    # whole channel constant
        x = np.moveaxis(xm, 0, axis)
    return np.ascontiguousarray(x)


def _tiled_codec(x, axis, gc, bs, n_levels=4, use_ecsq=False):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="tile", channel_axis=axis,
                                 channel_group_size=gc,
                                 spatial_block_size=bs,
                                 use_ecsq=use_ecsq),
                     samples=x)


GEOMETRIES = [
    # (shape, axis, channel_group, spatial_block): non-multiples on purpose
    ((300, 12), -1, 1, 64),
    ((300, 12), -1, 5, 100),
    ((7, 33, 10), 1, 4, 17),
    ((12, 250), 0, 3, 0),           # pure channel grouping, channel-major
    ((1, 130, 6), -1, 6, 130),      # single channel group, single block
]


class TestTilePlanGeometry:
    def test_counts_and_ids(self):
        plan = TilePlan(channel_axis=-1, channel_group_size=5,
                        spatial_block_size=100, n_channels=12,
                        spatial_extent=300)
        assert (plan.n_cgroups, plan.n_sblocks, plan.n_tiles) == (3, 3, 9)
        tid = plan.tile_ids((300, 12))
        assert tid.shape == (300, 12)
        assert tid.min() == 0 and tid.max() == 8
        # element (row 299, channel 11) -> cgroup 2, sblock 2
        assert tid[299, 11] == 8

    def test_coded_order_roundtrip(self):
        plan = TilePlan(channel_axis=1, channel_group_size=2,
                        spatial_block_size=7, n_channels=6,
                        spatial_extent=40)
        x = np.arange(240).reshape(8, 6, 5)
        back = plan.from_coded_order(plan.to_coded_order(x), x.shape)
        np.testing.assert_array_equal(back, x)

    def test_align_chunk_elems(self):
        plan = TilePlan(channel_axis=-1, channel_group_size=1,
                        spatial_block_size=64, n_channels=4,
                        spatial_extent=256)
        # M % bs == 0: align to the spatial block
        assert plan.align_chunk_elems(100, (256, 4)) == 128
        ragged = TilePlan(channel_axis=-1, channel_group_size=1,
                          spatial_block_size=100, n_channels=4,
                          spatial_extent=250)
        # ragged rows: align to whole channel rows
        assert ragged.align_chunk_elems(100, (250, 4)) == 250

    def test_mismatched_shape_rejected(self):
        x = _features((300, 12), -1)
        codec = _tiled_codec(x, -1, 4, 64)
        with pytest.raises(ValueError):
            codec.encode(x[:200])      # different spatial extent
        with pytest.raises(ValueError):
            codec.encode(x[:, :8])     # different channel count


class TestTiledRoundTrip:
    @pytest.mark.parametrize("shape,axis,gc,bs", GEOMETRIES)
    def test_fresh_receiver_and_streamed_decode(self, shape, axis, gc, bs):
        x = _features(shape, axis)
        codec = _tiled_codec(x, axis, gc, bs)
        blob = codec.encode(x)
        hdr = parse_header(blob)
        assert hdr.flags & FLAG_TILE and hdr.plan is not None

        receiver = calibrate(CodecConfig(n_levels=2, clip_mode="manual"))
        one_shot = receiver.decode(blob)
        fake = np.asarray(codec.apply(jnp.asarray(x)))
        assert one_shot.shape == x.shape
        np.testing.assert_allclose(one_shot, fake, atol=1e-5)

        streamed = receiver.decode_stream(
            list(codec.encode_stream(x, chunk_elems=97)))
        np.testing.assert_array_equal(streamed, one_shot)

    @pytest.mark.parametrize("shape,axis,gc,bs", GEOMETRIES[:3])
    def test_out_of_order_chunks(self, shape, axis, gc, bs):
        from repro.core import ChunkStreamDecoder
        x = _features(shape, axis, seed=3)
        codec = _tiled_codec(x, axis, gc, bs)
        payloads = list(codec.encode_stream(x, chunk_elems=64))
        dec = ChunkStreamDecoder(payloads[0])
        for p in reversed(payloads[1:]):
            dec.add_chunk(p)
        np.testing.assert_array_equal(dec.finish(),
                                      codec.decode(codec.encode(x)))

    def test_degenerate_constant_tiles(self):
        x = _features((120, 8), -1, constant_tiles=True)
        codec = _tiled_codec(x, -1, 1, 30)
        recon = codec.decode(codec.encode(x))
        # the constant channel reconstructs to (nearly) its constant value
        np.testing.assert_allclose(recon[:, 0], x[:, 0], atol=1e-5)
        streamed = codec.decode_stream(
            list(codec.encode_stream(x, chunk_elems=50)))
        np.testing.assert_array_equal(streamed, recon)

    def test_per_tile_ecsq_roundtrip(self):
        x = _features((400, 6), -1, seed=5)
        codec = _tiled_codec(x, -1, 2, 128, use_ecsq=True)
        assert codec.tile_ecsq is not None
        assert codec.tile_ecsq.levels.shape == (3 * 4, 4)
        receiver = calibrate(CodecConfig(n_levels=2, clip_mode="manual"))
        blob = codec.encode(x)
        decoded = receiver.decode(blob)
        fake = np.asarray(codec.apply(jnp.asarray(x)))
        np.testing.assert_allclose(decoded, fake, atol=1e-5)
        streamed = receiver.decode_stream(
            list(codec.encode_stream(x, chunk_elems=77)))
        np.testing.assert_array_equal(streamed, decoded)

    def test_tiled_beats_tensor_on_biased_channels(self):
        rng = np.random.default_rng(11)
        mu = np.linspace(0.0, 10.0, 16).astype(np.float32)
        x = (mu[None, :]
             + rng.exponential(1.0, (4096, 16))).astype(np.float32)
        common = dict(n_levels=4, clip_mode="minmax",
                      constrain_cmin_zero=False)
        tn = calibrate(CodecConfig(**common), samples=x)
        tl = calibrate(CodecConfig(granularity="tile", channel_axis=-1,
                                   channel_group_size=2,
                                   spatial_block_size=512, **common),
                       samples=x)
        xj = jnp.asarray(x)
        mse_tl = float(np.mean((np.asarray(tl.apply(xj)) - x) ** 2))
        mse_tn = float(np.mean((np.asarray(tn.apply(xj)) - x) ** 2))
        assert mse_tl < mse_tn
        assert tl.compressed_bits_per_element(x) <= \
            tn.compressed_bits_per_element(x)


class TestBackendBitExact:
    @pytest.mark.parametrize("shape,axis,gc,bs", GEOMETRIES)
    def test_jnp_vs_kernel_interpret(self, shape, axis, gc, bs):
        x = _features(shape, axis, seed=7)
        codec = _tiled_codec(x, axis, gc, bs)
        spec = codec.spec()
        xj = jnp.asarray(x)
        ji, jd = JnpBackend().quantize_dequantize(xj, spec)
        ki, kd = get_backend("kernel_interpret").quantize_dequantize(xj, spec)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ji))
        np.testing.assert_allclose(np.asarray(kd), np.asarray(jd),
                                   atol=1e-6)

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(st.integers(2, 20), st.integers(2, 40), st.integers(1, 6),
               st.integers(0, 50), st.integers(2, 8), st.integers(0, 2 ** 31))
        def test_random_geometry_property(self, c, m, gc, bs, n_levels,
                                          seed):
            rng = np.random.default_rng(seed)
            x = rng.normal(2.0, 3.0, size=(m, c)).astype(np.float32)
            n_sb = 1 if bs == 0 else -(-m // bs)
            lo = rng.uniform(-2, 0, (-(-c // gc), n_sb)).astype(np.float32)
            hi = lo + rng.uniform(0.0, 4.0, lo.shape).astype(np.float32)
            plan = TilePlan(channel_axis=-1, channel_group_size=gc,
                            spatial_block_size=bs, n_channels=c,
                            spatial_extent=m if bs else None)
            spec = QuantSpec(lo, hi, n_levels, -1, None, plan)
            xj = jnp.asarray(x)
            ji = JnpBackend().quantize(xj, spec)
            ki = get_backend("kernel_interpret").quantize(xj, spec)
            np.testing.assert_array_equal(np.asarray(ki), np.asarray(ji))


class TestPackKernel:
    @pytest.mark.parametrize("n_levels", [2, 4, 16])
    @pytest.mark.parametrize("n", [1, 7, 255, 1001, 5000])
    def test_kernel_matches_host_layout(self, n_levels, n):
        from repro.kernels import ops
        rng = np.random.default_rng(n)
        idx = jnp.asarray(rng.integers(0, n_levels, n).astype(np.int32))
        codec = calibrate(CodecConfig(n_levels=n_levels, clip_mode="manual",
                                      manual_cmax=1.0))
        host = JnpBackend().pack_indices(idx, codec.bits_per_index())
        dev = ops.pack_indices(idx, bits=codec.bits_per_index(),
                               interpret=True)
        np.testing.assert_array_equal(np.asarray(dev), np.asarray(host))
        back = codec.unpack(jnp.asarray(np.asarray(dev)), n)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(idx))

    def test_codec_pack_backend_dispatch(self):
        idx = jnp.asarray(np.arange(100, dtype=np.int32) % 4)
        jc = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                   manual_cmax=1.0, backend="jnp"))
        kc = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                   manual_cmax=1.0,
                                   backend="kernel_interpret"))
        np.testing.assert_array_equal(np.asarray(jc.pack(idx)),
                                      np.asarray(kc.pack(idx)))


class TestBatchedChunkCoding:
    def test_batch_byte_identical_to_serial(self):
        rng = np.random.default_rng(1)
        segs = [rng.choice(4, size=n, p=[.5, .25, .15, .1]).astype(np.int32)
                for n in (70_000, 70_000, 70_000, 200, 0, 40_000)]
        one_by_one = [cabac.encode_indices(s, 4, mode="auto") for s in segs]
        batched = cabac.encode_indices_batch(segs, 4, mode="auto")
        assert one_by_one == batched
        for s, blob in zip(segs, batched):
            np.testing.assert_array_equal(
                cabac.decode_indices(blob, s.size, 4), s)

    def test_planes_batch_identical(self):
        rng = np.random.default_rng(2)
        streams = [[rng.integers(0, 2, n).astype(np.uint8)
                    for n in (5000, 3000)] for _ in range(5)]
        ref = [encode_planes(p) for p in streams]
        assert encode_planes_batch(streams) == ref

    def test_stream_chunk_batching_matches_unbatched(self):
        x = _features((600, 8), -1, seed=9)
        codec = _tiled_codec(x, -1, 2, 100)
        batched = list(codec.encode_stream(x, chunk_elems=50))
        serial = list(codec.encode_stream(x, chunk_elems=50,
                                          chunk_batch=1))
        assert batched == serial


class TestTileAwareRateControl:
    def test_mixed_granularity_ladder(self):
        from repro.transport import (CodecBank, RateControlConfig,
                                     RateController, Rung)
        x = _features((1024, 16), -1, seed=13)
        ladder = (Rung(2, "tensor"), Rung(4, "tensor"),
                  Rung(4, "channel"), Rung(8, "tensor"),
                  Rung(8, "tile", 4, 256))
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False,
                                     channel_axis=-1), x, ladder=ladder)
        for rung in ladder:
            codec = bank.get(rung)
            assert codec.config.n_levels == rung.n_levels
            assert codec.config.granularity == rung.granularity
            blob = codec.encode(x)
            np.testing.assert_allclose(
                codec.decode(blob, shape=x.shape),
                np.asarray(codec.apply(jnp.asarray(x))), atol=1e-5)
        rc = RateController(RateControlConfig(target_bpe=2.0,
                                              ladder=ladder))
        bits = elems = 0
        rng = np.random.default_rng(0)
        for _ in range(25):
            xt = x + rng.normal(0, 0.01, x.shape).astype(np.float32)
            rung = rc.next_rung()
            blob = bank.get(rung).encode(xt)
            rc.on_tensor(rung, len(blob), xt.size)
            bits += 8 * len(blob)
            elems += xt.size
        assert abs(bits / elems - 2.0) <= 0.4
        # granularity rungs actually got exercised by the controller
        assert len({h["rung"] for h in rc.history}) >= 2

    def test_per_tensor_ecsq_with_plan_rejected(self):
        """plan + per-tensor ECSQQuantizer would be silently ignored;
        backends reject the combination instead."""
        from repro.core.ecsq import design_ecsq
        rng = np.random.default_rng(1)
        xs = rng.exponential(1.0, 5000).astype(np.float32)
        q = design_ecsq(xs, 4, 0.05, 0.0, 6.0)
        plan = TilePlan(channel_axis=-1, channel_group_size=1,
                        spatial_block_size=0, n_channels=4)
        spec = QuantSpec(np.zeros((4, 1), np.float32),
                         np.ones((4, 1), np.float32), 4, -1, q, plan)
        with pytest.raises(ValueError):
            JnpBackend().quantize(jnp.zeros((8, 4)), spec)

    def test_legacy_int_flow_consistent_on_mixed_ladder(self):
        """next_levels() -> bank.get(n) -> on_tensor(n) attributes the
        measurement to the rung whose codec the bank handed out."""
        from repro.transport import (CodecBank, RateControlConfig,
                                     RateController, Rung)
        x = _features((512, 8), -1)
        ladder = (Rung(4, "channel"), Rung(4, "tensor"))
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False,
                                     channel_axis=-1), x, ladder=ladder)
        rc = RateController(RateControlConfig(target_bpe=2.0,
                                              ladder=ladder))
        n = rc.next_levels()
        codec = bank.get(n)
        rc.on_tensor(n, 1000, 4000)
        recorded = rc.history[-1]["rung"]
        assert recorded == str(Rung(4, "tensor"))
        assert codec.config.granularity == "tensor"

    def test_int_lookup_prefers_plain_rung_on_mixed_ladder(self):
        from repro.transport import CodecBank, Rung
        x = _features((256, 8), -1)
        ladder = (Rung(4, "channel"), Rung(4, "tensor"))
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False,
                                     channel_axis=-1), x, ladder=ladder)
        assert bank.get(4).config.granularity == "tensor"

    def test_int_ladder_inherits_base_granularity(self):
        """Legacy int ladders keep pre-Rung semantics: only n_levels is
        overridden, the bank's base granularity is preserved."""
        from repro.transport import CodecBank, rung_of_codec
        x = _features((256, 8), -1)
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False,
                                     granularity="channel",
                                     channel_axis=-1), x, ladder=(2, 4))
        codec = bank.get(4)
        assert codec.config.granularity == "channel"
        assert codec.config.n_levels == 4
        assert rung_of_codec(codec).granularity == "channel"

    def test_int_ladder_still_works(self):
        from repro.transport import (CodecBank, RateControlConfig,
                                     RateController)
        x = _features((256, 8), -1).ravel()
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax"),
                         x, ladder=(2, 4))
        assert bank.get(4) is bank.get(4)
        rc = RateController(RateControlConfig(target_bpe=1.5,
                                              ladder=(2, 4)))
        n = rc.next_levels()
        assert n in (2, 4)
        rc.on_tensor(n, 1000, 4000)
        assert rc.next_levels() in (2, 4)
