"""2-D (row x column) TilePlan tests: v4 self-describing streams,
coded-order permutation invariants, backend bit-exactness, fused-vs-
unfused parity, streamed-vs-one-shot parity, and hypothesis sweeps over
random (C, H, W, channel-group, bh, bw) geometries including
non-multiple tile sizes and degenerate 1x1 / full-extent tiles."""

import numpy as np
import pytest
import jax.numpy as jnp

from repro.core import CodecConfig, TilePlan, calibrate
from repro.core.backend import get_backend
from repro.core.codec import FLAG_TILE2D, parse_header
from repro.core.tiling import spatial_grid

try:  # hypothesis is optional: only the property sweeps need it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


def _conv_features(shape, axis, seed=0):
    """Conv-map-like features whose statistics drift along channels AND
    both spatial axes (the case 2-D tiles exist for)."""
    rng = np.random.default_rng(seed)
    axis = axis % len(shape)
    h, w = spatial_grid(shape, axis)
    c = shape[axis]
    x = rng.exponential(1.0, (c, h, w)).astype(np.float32)
    x += np.linspace(0.0, 5.0, c)[:, None, None]
    x += np.linspace(0.0, 4.0, h)[None, :, None]
    x += np.linspace(0.0, 3.0, w)[None, None, :]
    moved = [shape[axis]] + [s for d, s in enumerate(shape) if d != axis]
    return np.ascontiguousarray(
        np.moveaxis(x.reshape(moved), 0, axis)).astype(np.float32)


def _codec2d(x, axis, gc, bh, bw, n_levels=4, use_ecsq=False):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="tile", channel_axis=axis,
                                 channel_group_size=gc,
                                 spatial_block_hw=(bh, bw),
                                 use_ecsq=use_ecsq),
                     samples=x)


# (shape, channel_axis, channel_group, bh, bw): non-multiples on purpose
GEOMETRIES_2D = [
    ((1, 5, 13, 11), 1, 2, 4, 3),     # NCHW, ragged rows + cols
    ((6, 9, 4), -1, 1, 3, 3),         # NHWC-ish, rows tile exactly
    ((3, 10, 10), 0, 3, 1, 1),        # degenerate 1x1 blocks
    ((2, 7, 9), 0, 2, 7, 9),          # full-extent single tile per group
    ((4, 6, 5), 0, 4, 100, 100),      # blocks larger than the grid
    ((2, 3, 8, 7), -1, 7, 5, 2),      # batch folded into rows, gc > C
]


class TestTilePlan2DGeometry:
    def test_sblock_ids_band_sizes_consistent(self):
        plan = TilePlan(channel_axis=0, channel_group_size=1,
                        spatial_block_size=0, n_channels=2,
                        spatial_extent=13 * 11, spatial_hw=(13, 11),
                        spatial_block_hw=(4, 3))
        ids = plan.sblock_ids(13 * 11)
        sizes = plan.band_sizes(13 * 11)
        np.testing.assert_array_equal(
            np.bincount(ids, minlength=plan.n_sblocks), sizes)
        assert sizes.sum() == 13 * 11
        assert plan.n_sblocks == 4 * 4 and plan.n_rblocks == 4

    def test_coded_order_roundtrip(self):
        for shape, axis, gc, bh, bw in GEOMETRIES_2D:
            x = _conv_features(shape, axis, seed=3)
            codec = _codec2d(x, axis, gc, bh, bw)
            coded = codec.plan.to_coded_order(x)
            back = codec.plan.from_coded_order(coded, x.shape)
            np.testing.assert_array_equal(back, x)

    def test_coded_order_tiles_contiguous(self):
        """Every tile's elements form one contiguous run per coded row."""
        shape, axis, gc, bh, bw = GEOMETRIES_2D[0]
        x = _conv_features(shape, axis)
        codec = _codec2d(x, axis, gc, bh, bw)
        plan = codec.plan
        m = plan.spatial_extent
        tid_coded = plan.sblock_ids(m)[plan.spatial_perm(m)]
        bounds = plan.coded_band_bounds(m)
        for b in range(plan.n_sblocks):
            seg = tid_coded[bounds[b]:bounds[b + 1]]
            assert (seg == b).all()

    def test_align_chunk_elems(self):
        x = _conv_features((1, 4, 12, 8), 1)
        codec = _codec2d(x, 1, 2, 4, 4)      # exact tiling: run = 16
        assert codec.plan.align_chunk_elems(10, x.shape) == 16
        assert codec.plan.align_chunk_elems(17, x.shape) == 32
        ragged = _codec2d(x, 1, 2, 5, 3)     # ragged: run = whole row
        assert ragged.plan.align_chunk_elems(10, x.shape) == 96

    def test_spatial_grid_rule(self):
        assert spatial_grid((1, 64, 56, 56), 1) == (56, 56)     # NCHW
        assert spatial_grid((2, 56, 57, 64), -1) == (2 * 56, 57)  # NHWC
        assert spatial_grid((64, 7), 1) == (1, 64)
        assert spatial_grid((64,), 0) == (1, 1)

    def test_invalid_plans_rejected(self):
        with pytest.raises(ValueError):
            TilePlan(channel_axis=0, channel_group_size=1,
                     spatial_block_size=0, n_channels=2, spatial_extent=12,
                     spatial_hw=(3, 5), spatial_block_hw=(2, 2))
        with pytest.raises(ValueError):
            TilePlan(channel_axis=0, channel_group_size=1,
                     spatial_block_size=4, n_channels=2, spatial_extent=12,
                     spatial_hw=(3, 4), spatial_block_hw=(2, 2))
        with pytest.raises(ValueError):
            TilePlan(channel_axis=0, channel_group_size=1,
                     spatial_block_size=0, n_channels=2, spatial_extent=12,
                     spatial_hw=(3, 4), spatial_block_hw=None)
        with pytest.raises(ValueError):
            calibrate(CodecConfig(granularity="tile", spatial_block_size=8,
                                  spatial_block_hw=(2, 2)),
                      samples=np.zeros((4, 6, 6), np.float32))


class TestTile2DCodec:
    @pytest.mark.parametrize("geom", GEOMETRIES_2D,
                             ids=[str(g) for g in GEOMETRIES_2D])
    def test_roundtrip_and_header(self, geom):
        shape, axis, gc, bh, bw = geom
        x = _conv_features(shape, axis)
        codec = _codec2d(x, axis, gc, bh, bw)
        blob = codec.encode(x)
        hdr = parse_header(blob)
        assert hdr.flags & FLAG_TILE2D
        assert hdr.plan.spatial_block_hw == (bh, bw)
        assert hdr.plan.spatial_hw == spatial_grid(shape, axis)
        assert hdr.plan.n_tiles == codec.plan.n_tiles
        y = codec.decode(blob)
        ref = np.asarray(codec.dequantize(codec.quantize(jnp.asarray(x))))
        np.testing.assert_array_equal(y, ref)
        # every element obeys its own tile's clip range after dequant
        lo, hi = codec.tile_tables()
        tid = codec.plan.tile_ids(x.shape)
        assert (y >= lo.reshape(-1)[tid] - 1e-5).all()
        assert (y <= hi.reshape(-1)[tid] + 1e-5).all()

    @pytest.mark.parametrize("geom", GEOMETRIES_2D,
                             ids=[str(g) for g in GEOMETRIES_2D])
    def test_fused_equals_unfused(self, geom):
        shape, axis, gc, bh, bw = geom
        x = _conv_features(shape, axis, seed=1)
        codec = _codec2d(x, axis, gc, bh, bw)
        assert codec.encode(x) == codec.encode(x, fused=False)

    @pytest.mark.parametrize("geom", GEOMETRIES_2D,
                             ids=[str(g) for g in GEOMETRIES_2D])
    def test_jnp_kernel_bit_identical(self, geom):
        shape, axis, gc, bh, bw = geom
        x = _conv_features(shape, axis, seed=2)
        codec = _codec2d(x, axis, gc, bh, bw)
        spec = codec.spec()
        jb, kb = get_backend("jnp"), get_backend("kernel_interpret")
        xj = jnp.asarray(x)
        np.testing.assert_array_equal(np.asarray(jb.quantize(xj, spec)),
                                      np.asarray(kb.quantize(xj, spec)))
        cj, hj = jb.encode_fused(xj, spec, codec.bits_per_index(),
                                 want_hist=True)
        ck, hk = kb.encode_fused(xj, spec, codec.bits_per_index(),
                                 want_hist=True)
        np.testing.assert_array_equal(cj, ck)
        np.testing.assert_array_equal(hj, hk)
        assert int(np.sum(hj)) == x.size
        idx = jb.quantize(xj, spec)
        np.testing.assert_array_equal(
            np.asarray(jb.tile_histogram(idx, spec)),
            np.asarray(kb.tile_histogram(idx, spec)))

    def test_streamed_equals_one_shot(self):
        shape, axis, gc, bh, bw = GEOMETRIES_2D[0]
        x = _conv_features(shape, axis, seed=4)
        codec = _codec2d(x, axis, gc, bh, bw)
        one_shot = codec.decode(codec.encode(x))
        for chunk in (1, 37, 1 << 18):
            payloads = list(codec.encode_stream(x, chunk_elems=chunk))
            np.testing.assert_array_equal(codec.decode_stream(payloads),
                                          one_shot)
        # out-of-order chunk arrival
        payloads = list(codec.encode_stream(x, chunk_elems=37))
        shuffled = [payloads[0]] + payloads[:0:-1]
        np.testing.assert_array_equal(codec.decode_stream(shuffled),
                                      one_shot)

    def test_ecsq_2d(self):
        shape, axis, gc, bh, bw = GEOMETRIES_2D[1]
        x = _conv_features(shape, axis, seed=5)
        codec = _codec2d(x, axis, gc, bh, bw, use_ecsq=True)
        assert codec.tile_ecsq is not None
        assert codec.tile_ecsq.levels.shape == (codec.plan.n_tiles, 4)
        blob = codec.encode(x)
        hdr = parse_header(blob)
        assert hdr.tile_levels is not None
        ref = np.asarray(codec.dequantize(codec.quantize(jnp.asarray(x))))
        np.testing.assert_array_equal(codec.decode(blob), ref)
        spec = codec.spec()
        jb, kb = get_backend("jnp"), get_backend("kernel_interpret")
        np.testing.assert_array_equal(
            np.asarray(jb.quantize(jnp.asarray(x), spec)),
            np.asarray(kb.quantize(jnp.asarray(x), spec)))

    def test_receiver_needs_no_state(self):
        shape, axis, gc, bh, bw = GEOMETRIES_2D[0]
        x = _conv_features(shape, axis, seed=6)
        sender = _codec2d(x, axis, gc, bh, bw)
        receiver = calibrate(CodecConfig(n_levels=8, clip_mode="manual",
                                         manual_cmin=-1.0, manual_cmax=1.0))
        np.testing.assert_array_equal(receiver.decode(sender.encode(x)),
                                      sender.decode(sender.encode(x)))

    def test_rate_estimate_matches_tile_hists(self):
        shape, axis, gc, bh, bw = GEOMETRIES_2D[0]
        x = _conv_features(shape, axis, seed=7)
        codec = _codec2d(x, axis, gc, bh, bw)
        rate = float(codec.estimate_rate(jnp.asarray(x)))
        tile_bits = np.asarray(codec.tile_rate_bits(jnp.asarray(x)))
        assert tile_bits.shape == (codec.plan.n_cgroups,
                                   codec.plan.n_sblocks)
        assert rate == pytest.approx(tile_bits.sum() / x.size, rel=1e-5)

    def test_wrong_extent_rejected(self):
        x = _conv_features((1, 4, 8, 8), 1)
        codec = _codec2d(x, 1, 2, 4, 4)
        with pytest.raises(ValueError):
            codec.encode(_conv_features((1, 4, 8, 9), 1))

    def test_wrong_grid_same_extent_rejected(self):
        """Same flattened extent but a different (H, W) grid must be
        rejected -- the 2-D tile map is positional in both axes, so a
        reshaped tensor would silently mis-tile every block."""
        x = _conv_features((1, 4, 8, 8), 1)
        codec = _codec2d(x, 1, 2, 4, 4)
        with pytest.raises(ValueError, match="grid"):
            codec.encode(x.reshape(1, 4, 4, 16))
        with pytest.raises(ValueError, match="grid"):
            codec.quantize(jnp.asarray(x).reshape(1, 4, 16, 4))

    def test_spatial_block_hw_needs_tile_granularity(self):
        x = _conv_features((1, 4, 8, 8), 1)
        for grain in ("tensor", "channel"):
            with pytest.raises(ValueError, match="tile"):
                calibrate(CodecConfig(granularity=grain, channel_axis=1,
                                      spatial_block_hw=(4, 4)), samples=x)


if HAVE_HYPOTHESIS:
    class TestTile2DProperties:
        @settings(max_examples=25, deadline=None)
        @given(st.integers(1, 6), st.integers(1, 9), st.integers(1, 9),
               st.integers(1, 7), st.integers(1, 10), st.integers(1, 10),
               st.integers(2, 5))
        def test_random_geometry_roundtrip(self, c, h, w, gc, bh, bw,
                                           n_levels):
            x = _conv_features((c, h, w), 0, seed=c * 1000 + h * 100 + w)
            codec = _codec2d(x, 0, gc, bh, bw, n_levels=n_levels)
            blob = codec.encode(x)
            assert blob == codec.encode(x, fused=False)
            ref = np.asarray(codec.dequantize(
                codec.quantize(jnp.asarray(x))))
            np.testing.assert_array_equal(codec.decode(blob), ref)
            payloads = list(codec.encode_stream(
                x, chunk_elems=max(1, h * w // 3)))
            np.testing.assert_array_equal(codec.decode_stream(payloads),
                                          ref)

        @settings(max_examples=15, deadline=None)
        @given(st.integers(1, 5), st.integers(1, 8), st.integers(1, 8),
               st.integers(1, 6), st.integers(1, 9), st.integers(1, 9))
        def test_random_geometry_backend_parity(self, c, h, w, gc, bh, bw):
            x = _conv_features((c, h, w), 0, seed=c * 97 + h * 13 + w)
            codec = _codec2d(x, 0, gc, bh, bw)
            spec = codec.spec()
            jb, kb = get_backend("jnp"), get_backend("kernel_interpret")
            xj = jnp.asarray(x)
            np.testing.assert_array_equal(np.asarray(jb.quantize(xj, spec)),
                                          np.asarray(kb.quantize(xj, spec)))
            cj, hj = jb.encode_fused(xj, spec, codec.bits_per_index(),
                                     want_hist=True)
            ck, hk = kb.encode_fused(xj, spec, codec.bits_per_index(),
                                     want_hist=True)
            np.testing.assert_array_equal(cj, ck)
            np.testing.assert_array_equal(hj, hk)
