"""Tier-1 smoke for the accuracy scenario matrix (ISSUE-10 tentpole).

Everything the harness touches is deterministic -- seeded params, seeded
token batches, deterministic codec, bit-exact backends -- so these
assertions are exact, not statistical:

* the three continuous-tail families (transformer / rwkv / rglru) show
  ZERO decisive-token degradation at the top rung under BOTH quantizer
  backends;
* the MoE family stays bounded (router top-k is discontinuous under
  half-step boundary noise, so exact zero is unachievable by design);
* the rung ladder is monotone in logit RMSE (the fine-grained signal;
  top-1 agreement saturates);
* jnp and kernel_interpret backends produce byte-identical streams and
  identical task metrics;
* the loopback-socket transport reproduces the in-process degradation
  exactly, at a strictly higher wire rate (framing bytes are real);
* the split-point selector is deterministic and picks the cheapest
  (HLO-measured head FLOPs) tap meeting the budget.
"""

import dataclasses
import functools

import numpy as np
import pytest

from repro.core import CodecConfig, calibrate
from repro.eval import (DEFAULT_MATRIX, SCENARIOS, Scenario,
                        codec_config_for, get_scenario, load_matrix,
                        run_scenario, select_split_point)

ZERO_FAMILIES = ("transformer-tensor", "rwkv-state", "rglru-state")


@functools.lru_cache(maxsize=None)
def _report(name: str, backend: str = "jnp"):
    return run_scenario(get_scenario(name), backend=backend)


# ---------------------------------------------------------------------------
# schema validation
# ---------------------------------------------------------------------------

class TestScenarioSchema:
    @pytest.mark.parametrize("kw, match", [
        (dict(rungs=(4, 16, 256)), "high-to-low"),
        (dict(rungs=(256, 256)), "duplicate"),
        (dict(rungs=(256, 1)), ">= 2 levels"),
        (dict(clip_modes=("nope",)), "unknown clip modes"),
        (dict(granularity="voxel"), "unknown granularity"),
        (dict(granularity="tile2d"), "spatial_block_hw"),
        (dict(spatial_block_hw=(2, 8)), "tile2d setting"),
        (dict(transport="carrier-pigeon"), "unknown transport"),
        (dict(n_periods=1), "at least one period"),
        (dict(split_after=7), "out of range"),
        (dict(seq_len=0), "positive"),
    ])
    def test_rejects(self, kw, match):
        base = dict(name="t", arch="codeqwen1.5-7b")
        with pytest.raises(ValueError, match=match):
            Scenario(**{**base, **kw})

    def test_rejects_embedding_frontend_archs(self):
        with pytest.raises(ValueError, match="token-in"):
            Scenario(name="t", arch="musicgen-large")

    def test_json_roundtrip(self):
        sc = get_scenario("transformer-tile2d")
        assert Scenario.from_json(sc.to_json()) == sc

    def test_json_rejects_unknown_fields(self):
        with pytest.raises(ValueError, match="unknown scenario fields"):
            Scenario.from_json({"name": "t", "arch": "codeqwen1.5-7b",
                                "bitrate": 8})

    def test_default_matrix_meets_acceptance_bar(self):
        # the ISSUE-10 bar: >= 3 families x >= 3 rungs x >= 2 clip modes
        # from one declarative config
        matrix = load_matrix("default")
        assert len({sc.arch for sc in matrix}) >= 3
        for sc in matrix:
            assert len(sc.rungs) >= 3
            assert len(sc.clip_modes) >= 2

    def test_codec_config_mapping(self):
        sc = get_scenario("transformer-tile2d")
        cfg = codec_config_for(sc, 16, "aciq", backend="jnp")
        assert (cfg.n_levels, cfg.clip_mode) == (16, "aciq")
        assert cfg.granularity == "tile"
        assert cfg.spatial_block_hw == (2, 8)
        assert cfg.backend == "jnp"
        assert not cfg.constrain_cmin_zero


# ---------------------------------------------------------------------------
# the paper's claim, end to end
# ---------------------------------------------------------------------------

class TestAccuracyMatrix:
    @pytest.mark.parametrize("backend", ["jnp", "kernel_interpret"])
    @pytest.mark.parametrize("name", ZERO_FAMILIES)
    def test_top_rung_degradation_is_zero(self, name, backend):
        rep = _report(name, backend)
        top = rep.scenario.rungs[0]
        for mode in rep.scenario.clip_modes:
            c = rep.case(top, mode)
            assert c.degradation == 0.0, (name, backend, mode)
            assert c.n_decisive > 0

    def test_moe_top_rung_bounded(self):
        # MoE tails route top-k discretely: half-step boundary noise can
        # flip expert choice, so the gate bounds degradation instead of
        # requiring zero
        rep = _report("moe-expert")
        for mode in rep.scenario.clip_modes:
            assert rep.case(rep.scenario.rungs[0], mode).degradation <= 0.05

    @pytest.mark.parametrize("name", DEFAULT_MATRIX)
    def test_rmse_ladder_monotone(self, name):
        rep = _report(name)
        for mode in rep.scenario.clip_modes:
            ladder = [rep.case(r, mode) for r in rep.scenario.rungs]
            rmses = [c.logit_rmse for c in ladder]
            assert rmses == sorted(rmses), (name, mode, rmses)
            # coarser rungs also cost no less task accuracy at the ends
            assert ladder[0].degradation <= ladder[-1].degradation

    @pytest.mark.parametrize("name", DEFAULT_MATRIX)
    def test_measured_rate_not_nominal(self, name):
        # bits_per_elem comes from actual stream bytes (headers and
        # all), so it can never be the bare log2(N) and must shrink as
        # the rung drops
        rep = _report(name)
        for mode in rep.scenario.clip_modes:
            bpes = [rep.case(r, mode).bits_per_elem
                    for r in rep.scenario.rungs]
            assert all(b > 0 for b in bpes)
            assert bpes == sorted(bpes, reverse=True), (name, mode, bpes)
            total = sum(rep.case(r, mode).coded_bytes
                        for r in rep.scenario.rungs)
            assert total > 0

    def test_backends_bit_identical(self):
        a = _report("transformer-tensor", "jnp")
        b = _report("transformer-tensor", "kernel_interpret")
        for ca, cb in zip(a.cases, b.cases):
            assert ca.coded_bytes == cb.coded_bytes
            assert ca.degradation == cb.degradation
            assert ca.logit_rmse == pytest.approx(cb.logit_rmse)

    def test_report_serializes(self):
        d = _report("transformer-tensor").to_dict()
        assert d["split_after"] == 1
        assert {c["rung"] for c in d["cases"]} == {256, 16, 4}


class TestTransportParity:
    def test_loopback_matches_inproc(self):
        lb = run_scenario(get_scenario("transformer-loopback"))
        inp = run_scenario(dataclasses.replace(
            get_scenario("transformer-loopback"), transport="inproc"))
        for cl, ci in zip(lb.cases, inp.cases):
            assert cl.degradation == ci.degradation
            assert cl.logit_rmse == pytest.approx(ci.logit_rmse)
            # the socket path counts frame headers too, so its measured
            # rate is strictly higher than the bare stream bytes
            assert cl.coded_bytes > ci.coded_bytes


class TestSplitSelector:
    OPERATING_POINT = dataclasses.replace(
        SCENARIOS["transformer-tensor"], rungs=(256,),
        clip_modes=("minmax",), n_eval_batches=1)

    def test_deterministic_and_cheapest(self):
        first = select_split_point(self.OPERATING_POINT, budget=0.01)
        again = select_split_point(self.OPERATING_POINT, budget=0.01)
        assert first.chosen is not None
        assert first.chosen.split_after == again.chosen.split_after
        assert first.chosen.head_flops == again.chosen.head_flops
        eligible = [c for c in first.candidates if c.meets_budget]
        assert first.chosen.head_flops == min(c.head_flops for c in eligible)
        # head cost grows with depth, so the cheapest eligible tap is
        # the shallowest
        flops = [c.head_flops for c in first.candidates]
        assert flops == sorted(flops)
        assert first.chosen.split_after == eligible[0].split_after

    def test_unmeetable_budget_returns_none(self):
        sel = select_split_point(self.OPERATING_POINT, budget=-1.0)
        assert sel.chosen is None
        assert all(not c.meets_budget for c in sel.candidates)
        assert sel.to_dict()["chosen"] is None


class TestCalibSampleCap:
    def test_capped_calibration_is_deterministic_and_close(self):
        rng = np.random.default_rng(0)
        x = rng.normal(size=(64, 256)).astype(np.float32)
        full = calibrate(CodecConfig(n_levels=16, clip_mode="minmax",
                                     constrain_cmin_zero=False), x)
        cap_a = calibrate(CodecConfig(n_levels=16, clip_mode="minmax",
                                      constrain_cmin_zero=False,
                                      calib_sample_cap=1024), x)
        cap_b = calibrate(CodecConfig(n_levels=16, clip_mode="minmax",
                                      constrain_cmin_zero=False,
                                      calib_sample_cap=1024), x)
        assert cap_a.cmin == cap_b.cmin and cap_a.cmax == cap_b.cmax
        # the even-stride subsample must still bracket most of the range
        assert cap_a.cmin >= full.cmin and cap_a.cmax <= full.cmax
        assert cap_a.cmax - cap_a.cmin > 0.5 * (full.cmax - full.cmin)
