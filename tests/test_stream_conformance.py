"""Stream-conformance suite: frozen golden bitstreams for every wire
format the codec has shipped (v1 seed, v2 per-tensor/ECSQ/legacy-channel,
v3 1-D tile, v4 2-D tile; one-shot and chunked-stream forms).

Asserts *byte-exact* encode and *bit-exact* decode against the committed
vectors under ``tests/golden/``, so a refactor of the quantizer, entropy
stage, header layout or coded order cannot silently break compatibility
with streams already on the wire.  Regenerate (only for intentional
format changes) with ``python tests/regen_golden.py``; diffs in existing
``.stream.bin`` files are wire-compatibility breaks and need a new
header version instead.
"""

import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from golden_cases import CASES, unpack_payloads  # noqa: E402
from repro.core.codec import (FLAG_CHANNEL, FLAG_ECSQ, FLAG_TILE,
                              FLAG_TILE2D, FLAG_V2,
                              parse_header)  # noqa: E402

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"
_IDS = [c.name for c in CASES]


def _load(case):
    stream = (GOLDEN_DIR / f"{case.name}.stream.bin").read_bytes()
    x = np.load(GOLDEN_DIR / f"{case.name}.input.npy")
    decoded = np.load(GOLDEN_DIR / f"{case.name}.decoded.npy")
    return x, stream, decoded


@pytest.mark.parametrize("case", CASES, ids=_IDS)
class TestGoldenStreams:
    def test_input_deterministic(self, case):
        """The case's input construction still reproduces the committed
        tensor (separates "rng/input drifted" from "format broke")."""
        np.testing.assert_array_equal(case.make_input(), _load(case)[0])

    def test_encode_byte_exact(self, case):
        """Encoding the frozen input reproduces the frozen bytes --
        header layout, coded order and entropy payload all unchanged.
        Decode-only legacy formats freeze their layout through the
        manual builder the seed/PR-1 encoders used."""
        x, stream, _ = _load(case)
        assert case.encode(x) == stream, (
            f"{case.name}: encoder output differs from the committed "
            "golden stream -- this is a wire-format change")

    def test_decode_bit_exact(self, case):
        x, stream, decoded = _load(case)
        got = np.asarray(case.decode(stream, x), np.float32)
        assert got.dtype == decoded.dtype and got.shape == decoded.shape
        np.testing.assert_array_equal(got, decoded)


class TestGoldenCoverage:
    """The committed vectors actually span the formats they claim to."""

    def _flags(self, name):
        stream = (GOLDEN_DIR / f"{name}.stream.bin").read_bytes()
        return parse_header(stream).flags

    def test_v1_has_no_flags(self):
        assert self._flags("v1_seed_uniform") == 0

    def test_v2_flags(self):
        assert self._flags("v2_uniform_rans") == FLAG_V2
        assert self._flags("v2_ecsq") == FLAG_V2 | FLAG_ECSQ
        assert self._flags("v2_channel_legacy") == FLAG_V2 | FLAG_CHANNEL

    def test_v3_v4_flags(self):
        assert self._flags("v3_tile") == FLAG_V2 | FLAG_TILE
        assert self._flags("v4_tile2d") == FLAG_V2 | FLAG_TILE2D
        assert self._flags("v4_tile2d_ecsq") == FLAG_V2 | FLAG_TILE2D

    def test_v4_header_carries_2d_geometry(self):
        stream = (GOLDEN_DIR / "v4_tile2d.stream.bin").read_bytes()
        hdr = parse_header(stream)
        assert hdr.plan is not None and hdr.plan.is_2d
        assert hdr.plan.spatial_block_hw == (4, 3)
        assert hdr.plan.spatial_hw == (11, 9)
        ecsq = parse_header(
            (GOLDEN_DIR / "v4_tile2d_ecsq.stream.bin").read_bytes())
        assert ecsq.tile_levels is not None
        assert ecsq.tile_levels.shape == (ecsq.plan.n_tiles, 4)

    def test_streamed_chunks_align_to_tiles(self):
        """The committed streamed vectors chunk on tile-aligned element
        boundaries (the v3/v4 chunk-alignment rule): the stream-meta
        chunk size must be a whole multiple of the plan's tile run
        length in coded order."""
        from repro.core.codec import ChunkStreamDecoder
        for name in ("v3_tile_stream", "v4_tile2d_stream"):
            payloads = unpack_payloads(
                (GOLDEN_DIR / f"{name}.stream.bin").read_bytes())
            assert len(payloads) > 2, "streamed vector must be chunked"
            dec = ChunkStreamDecoder(payloads[0])
            plan = dec.header.plan
            assert plan is not None
            m = dec.header.n_elems // plan.n_channels
            sizes = plan.band_sizes(m)
            run = int(sizes[0]) if (sizes == sizes[0]).all() else m
            assert dec.chunk_elems % run == 0, (
                f"{name}: chunk size {dec.chunk_elems} splits the "
                f"{run}-element tile run")
            assert dec.n_chunks == -(-dec.header.n_elems
                                     // dec.chunk_elems)

    def test_coder_ids(self):
        """Payload coder-id bytes stay stable (1-byte id after header)."""
        serial = (GOLDEN_DIR / "v2_uniform_serial.stream.bin").read_bytes()
        hdr = parse_header(serial)
        assert serial[hdr.payload_off] == 0          # serial CABAC
        rans = (GOLDEN_DIR / "v2_uniform_rans.stream.bin").read_bytes()
        hdr = parse_header(rans)
        assert rans[hdr.payload_off] == 1            # vectorized rANS


_ENCODABLE = [c for c in CASES if not c.decode_only]
_BACKENDS = ["jnp", "kernel_interpret"]


def _with_backend(codec, backend):
    import dataclasses
    codec.config = dataclasses.replace(codec.config, backend=backend)
    return codec


def _host_single_shard(codec, x):
    """The host reference for coder 4: coder-2 layout with exactly one
    shard over the same coded-order indices."""
    import jax.numpy as jnp

    from repro.core import cabac

    coded = np.asarray(codec.backend.coded_indices_device(
        jnp.asarray(x), codec.spec(), codec.bits_per_index()))
    return cabac._encode_rans_sharded(coded, codec.config.n_levels, 1)


@pytest.mark.parametrize("backend", _BACKENDS)
class TestDeviceEntropyConformance:
    """Coder id 4 (device-resident interleaved rANS): the device stream
    must be byte-identical to the host coder-2 single-shard stream past
    the coder-id byte, and decode bit-exact to the committed golden
    reconstructions -- the fused encode path may emit wire bytes on
    device only because these hold on every shipped format."""

    @pytest.mark.parametrize("case", _ENCODABLE,
                             ids=[c.name for c in _ENCODABLE])
    def test_payload_byte_identity_vs_host_coder2(self, case, backend):
        import jax.numpy as jnp

        x = _load(case)[0]
        codec = _with_backend(case.make_codec(x), backend)
        host2 = _host_single_shard(codec, x)
        dev, hist = codec.backend.encode_fused(
            jnp.asarray(x), codec.spec(), codec.bits_per_index(),
            emit_wire=True)
        assert hist is None
        assert dev[0] == 4 and host2[0] == 2
        assert dev[1:] == host2[1:], (
            f"{case.name}: device rANS payload diverged from the host "
            "single-shard reference")

    @pytest.mark.parametrize("case", _ENCODABLE,
                             ids=[c.name for c in _ENCODABLE])
    def test_device_stream_decodes_to_golden(self, case, backend):
        """encode(device_entropy=True) decodes bit-exact to the same
        committed reconstruction the host stream decodes to."""
        from golden_cases import pack_payloads

        x, _, decoded = _load(case)
        codec = _with_backend(case.make_codec(x), backend)
        if case.streamed:
            stream = pack_payloads(list(codec.encode_stream(
                x, chunk_elems=case.chunk_elems,
                coder_mode=case.coder_mode, device_entropy=True)))
            got = codec.decode_stream(unpack_payloads(stream))
        else:
            stream = codec.encode(x, coder_mode=case.coder_mode,
                                  device_entropy=True)
            got = codec.decode(stream, shape=x.shape)
        np.testing.assert_array_equal(np.asarray(got, np.float32), decoded)

    def test_random_tile_plans_byte_identity(self, backend):
        """Fresh (non-golden) TilePlan geometries: device payload stays
        byte-identical to the host reference on randomly drawn 1-D and
        2-D tilings."""
        import jax.numpy as jnp

        from repro.core import CodecConfig, calibrate

        rng = np.random.default_rng(20260808)
        for trial in range(4):
            c = 2 * int(rng.integers(1, 4))
            h = int(rng.integers(4, 13))
            w = int(rng.integers(4, 13))
            x = rng.normal(0.0, 2.0, (1, c, h, w)).astype(np.float32)
            if trial % 2 == 0:
                tiling = dict(spatial_block_size=int(rng.integers(2, 5)))
            else:
                tiling = dict(spatial_block_hw=(
                    int(rng.integers(2, min(5, h + 1))),
                    int(rng.integers(2, min(5, w + 1)))))
            codec = calibrate(
                CodecConfig(n_levels=int(rng.choice([2, 4, 8])),
                            clip_mode="minmax",
                            constrain_cmin_zero=False,
                            granularity="tile", channel_axis=1,
                            channel_group_size=2, backend=backend,
                            **tiling), samples=x)
            host2 = _host_single_shard(codec, x)
            dev, _ = codec.backend.encode_fused(
                jnp.asarray(x), codec.spec(), codec.bits_per_index(),
                emit_wire=True)
            assert dev[0] == 4 and dev[1:] == host2[1:], (
                f"trial {trial}: tiling {tiling} diverged")

    def test_unsupported_levels_fall_back_to_host_same_container(
            self, backend):
        """n_levels above the device coder's lane budget host-codes the
        planes but ships the identical coder-4 container bytes."""
        from repro.core import CodecConfig, calibrate
        from repro.kernels.rans_coder import MAX_DEVICE_LEVELS, \
            device_supported

        n_levels = MAX_DEVICE_LEVELS + 1
        rng = np.random.default_rng(7)
        x = rng.exponential(1.0, 513).astype(np.float32)
        assert not device_supported(x.size, n_levels)
        codec = calibrate(CodecConfig(n_levels=n_levels,
                                      clip_mode="minmax",
                                      constrain_cmin_zero=False,
                                      backend=backend), samples=x)
        host2 = _host_single_shard(codec, x)
        stream = codec.encode(x, device_entropy=True)
        hdr = parse_header(stream)
        payload = stream[hdr.payload_off:]
        assert payload[0] == 4 and payload[1:] == host2[1:]
        np.testing.assert_array_equal(
            np.asarray(codec.decode(stream, shape=x.shape), np.float32),
            np.asarray(codec.decode(
                stream[:hdr.payload_off] + host2, shape=x.shape),
                np.float32))
