"""Stream-conformance suite: frozen golden bitstreams for every wire
format the codec has shipped (v1 seed, v2 per-tensor/ECSQ/legacy-channel,
v3 1-D tile, v4 2-D tile; one-shot and chunked-stream forms).

Asserts *byte-exact* encode and *bit-exact* decode against the committed
vectors under ``tests/golden/``, so a refactor of the quantizer, entropy
stage, header layout or coded order cannot silently break compatibility
with streams already on the wire.  Regenerate (only for intentional
format changes) with ``python tests/regen_golden.py``; diffs in existing
``.stream.bin`` files are wire-compatibility breaks and need a new
header version instead.
"""

import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from golden_cases import CASES, unpack_payloads  # noqa: E402
from repro.core.codec import (FLAG_CHANNEL, FLAG_ECSQ, FLAG_TILE,
                              FLAG_TILE2D, FLAG_V2,
                              parse_header)  # noqa: E402

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"
_IDS = [c.name for c in CASES]


def _load(case):
    stream = (GOLDEN_DIR / f"{case.name}.stream.bin").read_bytes()
    x = np.load(GOLDEN_DIR / f"{case.name}.input.npy")
    decoded = np.load(GOLDEN_DIR / f"{case.name}.decoded.npy")
    return x, stream, decoded


@pytest.mark.parametrize("case", CASES, ids=_IDS)
class TestGoldenStreams:
    def test_input_deterministic(self, case):
        """The case's input construction still reproduces the committed
        tensor (separates "rng/input drifted" from "format broke")."""
        np.testing.assert_array_equal(case.make_input(), _load(case)[0])

    def test_encode_byte_exact(self, case):
        """Encoding the frozen input reproduces the frozen bytes --
        header layout, coded order and entropy payload all unchanged.
        Decode-only legacy formats freeze their layout through the
        manual builder the seed/PR-1 encoders used."""
        x, stream, _ = _load(case)
        assert case.encode(x) == stream, (
            f"{case.name}: encoder output differs from the committed "
            "golden stream -- this is a wire-format change")

    def test_decode_bit_exact(self, case):
        x, stream, decoded = _load(case)
        got = np.asarray(case.decode(stream, x), np.float32)
        assert got.dtype == decoded.dtype and got.shape == decoded.shape
        np.testing.assert_array_equal(got, decoded)


class TestGoldenCoverage:
    """The committed vectors actually span the formats they claim to."""

    def _flags(self, name):
        stream = (GOLDEN_DIR / f"{name}.stream.bin").read_bytes()
        return parse_header(stream).flags

    def test_v1_has_no_flags(self):
        assert self._flags("v1_seed_uniform") == 0

    def test_v2_flags(self):
        assert self._flags("v2_uniform_rans") == FLAG_V2
        assert self._flags("v2_ecsq") == FLAG_V2 | FLAG_ECSQ
        assert self._flags("v2_channel_legacy") == FLAG_V2 | FLAG_CHANNEL

    def test_v3_v4_flags(self):
        assert self._flags("v3_tile") == FLAG_V2 | FLAG_TILE
        assert self._flags("v4_tile2d") == FLAG_V2 | FLAG_TILE2D
        assert self._flags("v4_tile2d_ecsq") == FLAG_V2 | FLAG_TILE2D

    def test_v4_header_carries_2d_geometry(self):
        stream = (GOLDEN_DIR / "v4_tile2d.stream.bin").read_bytes()
        hdr = parse_header(stream)
        assert hdr.plan is not None and hdr.plan.is_2d
        assert hdr.plan.spatial_block_hw == (4, 3)
        assert hdr.plan.spatial_hw == (11, 9)
        ecsq = parse_header(
            (GOLDEN_DIR / "v4_tile2d_ecsq.stream.bin").read_bytes())
        assert ecsq.tile_levels is not None
        assert ecsq.tile_levels.shape == (ecsq.plan.n_tiles, 4)

    def test_streamed_chunks_align_to_tiles(self):
        """The committed streamed vectors chunk on tile-aligned element
        boundaries (the v3/v4 chunk-alignment rule): the stream-meta
        chunk size must be a whole multiple of the plan's tile run
        length in coded order."""
        from repro.core.codec import ChunkStreamDecoder
        for name in ("v3_tile_stream", "v4_tile2d_stream"):
            payloads = unpack_payloads(
                (GOLDEN_DIR / f"{name}.stream.bin").read_bytes())
            assert len(payloads) > 2, "streamed vector must be chunked"
            dec = ChunkStreamDecoder(payloads[0])
            plan = dec.header.plan
            assert plan is not None
            m = dec.header.n_elems // plan.n_channels
            sizes = plan.band_sizes(m)
            run = int(sizes[0]) if (sizes == sizes[0]).all() else m
            assert dec.chunk_elems % run == 0, (
                f"{name}: chunk size {dec.chunk_elems} splits the "
                f"{run}-element tile run")
            assert dec.n_chunks == -(-dec.header.n_elems
                                     // dec.chunk_elems)

    def test_coder_ids(self):
        """Payload coder-id bytes stay stable (1-byte id after header)."""
        serial = (GOLDEN_DIR / "v2_uniform_serial.stream.bin").read_bytes()
        hdr = parse_header(serial)
        assert serial[hdr.payload_off] == 0          # serial CABAC
        rans = (GOLDEN_DIR / "v2_uniform_rans.stream.bin").read_bytes()
        hdr = parse_header(rans)
        assert rans[hdr.payload_off] == 1            # vectorized rANS
