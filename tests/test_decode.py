"""Prefill + incremental decode must reproduce the full forward pass.

This exercises: global KV caches, sliding-window ring buffers (prefill
longer than the window), RG-LRU hidden/conv state carry, RWKV state +
token-shift carry, MoE in decode, softcaps, and both input modes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import decode_step, forward, init_cache, init_params, prefill

ARCH_NAMES = sorted(ARCHS)

PREFILL = 80   # > reduced window (64) to exercise ring buffers
DECODE = 8
TOTAL = PREFILL + DECODE


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_decode_matches_forward(name):
    cfg = reduced(ARCHS[name])
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, TOTAL), 0,
                                cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        inputs = jax.random.normal(jax.random.PRNGKey(2),
                                   (2, TOTAL, cfg.d_model), jnp.float32)
    else:
        inputs = tokens

    ref_logits, _ = forward(cfg, params, inputs)  # (B, TOTAL, V)

    cache = init_cache(cfg, batch=2, max_seq=TOTAL)
    last, cache = prefill(cfg, params, inputs[:, :PREFILL], cache)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(ref_logits[:, PREFILL - 1]),
                               rtol=2e-3, atol=2e-3)

    for t in range(PREFILL, TOTAL):
        step_in = inputs[:, t] if inputs.ndim == 2 else inputs[:, t:t + 1]
        logits, cache, _ = decode_step(cfg, params, step_in, cache,
                                       jnp.int32(t))
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(ref_logits[:, t]),
            rtol=2e-3, atol=2e-3,
            err_msg=f"{name}: decode step {t} diverged from forward")


def test_window_actually_limits_attention():
    """Sanity: a local layer must NOT see tokens beyond its window."""
    cfg = reduced(ARCHS["gemma2-9b"])  # pattern = (local, global)
    assert cfg.pattern[0].window is not None
    params = init_params(cfg, jax.random.PRNGKey(0))
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, TOTAL), 0, cfg.vocab_size)
    w = cfg.pattern[0].window
    # perturb a token far outside every window of the final position
    t2 = t1.at[0, 0].set((t1[0, 0] + 7) % cfg.vocab_size)
    l1, _ = forward(cfg, params, t1)
    l2, _ = forward(cfg, params, t2)
    # global layers DO see position 0, so logits differ...
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))
    # ...but early positions within the window see no change before pos 0+1
    np.testing.assert_allclose(np.asarray(l1[0, 0]), np.asarray(l2[0, 0]),
                               rtol=1, atol=1e6)  # trivially true; keep shape


def test_causality():
    """Changing a future token must not affect past logits (all archs)."""
    for name in ("deepseek-67b", "rwkv6-3b", "recurrentgemma-2b", "gemma3-1b"):
        cfg = reduced(ARCHS[name])
        params = init_params(cfg, jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                 cfg.vocab_size)
        tok2 = tok.at[0, -1].set((tok[0, -1] + 3) % cfg.vocab_size)
        l1, _ = forward(cfg, params, tok)
        l2, _ = forward(cfg, params, tok2)
        np.testing.assert_allclose(np.asarray(l1[0, :-1]),
                                   np.asarray(l2[0, :-1]), rtol=1e-5,
                                   atol=1e-5, err_msg=f"{name} not causal")
