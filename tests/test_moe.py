"""MoE dispatch correctness: local capacity path vs dense oracle, dropping,
and routing invariants.  (Expert-parallel shard_map paths are exercised in
tests/test_distributed.py via a multi-device subprocess.)"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import moe as MOE


@pytest.fixture(scope="module")
def cfg():
    base = reduced(ARCHS["qwen3-moe-235b-a22b"])
    return dataclasses.replace(base, num_experts=8, experts_per_token=2)


@pytest.fixture(scope="module")
def params(cfg):
    return MOE.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)


def test_local_matches_dense_oracle_when_capacity_ample(cfg, params):
    x = jax.random.normal(jax.random.PRNGKey(1), (64, cfg.d_model))
    dense = MOE._moe_dense_ref(x, params, cfg)
    local = MOE.moe_local(x, params, cfg, cap=64 * cfg.experts_per_token)
    np.testing.assert_allclose(np.asarray(local), np.asarray(dense),
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_reduce_output_norm(cfg, params):
    x = jax.random.normal(jax.random.PRNGKey(2), (128, cfg.d_model))
    full = MOE.moe_local(x, params, cfg, cap=256)
    tight = MOE.moe_local(x, params, cfg, cap=2)  # heavy dropping
    # dropped tokens get zero contribution from dropped experts
    assert float(jnp.linalg.norm(tight)) < float(jnp.linalg.norm(full))


def test_router_weights_normalized(cfg, params):
    x = jax.random.normal(jax.random.PRNGKey(3), (32, cfg.d_model))
    w, i = MOE._route(x, params["router"], cfg.experts_per_token)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert int(i.max()) < cfg.num_experts and int(i.min()) >= 0


def test_dispatch_indices_slot_uniqueness(cfg):
    rng = np.random.default_rng(0)
    top_i = jnp.asarray(rng.integers(0, 8, size=(50, 2)).astype(np.int32))
    slot, keep, tok, order = MOE._dispatch_indices(top_i, 2, 8, cap=16)
    kept = np.asarray(slot)[np.asarray(keep)]
    assert len(set(kept.tolist())) == len(kept), "slot collision"
    assert kept.max() < 8 * 16


def test_grad_flows_through_moe(cfg, params):
    x = jax.random.normal(jax.random.PRNGKey(4), (32, cfg.d_model))

    def f(p):
        return jnp.sum(MOE.moe_local(x, p, cfg) ** 2)

    g = jax.grad(f)(params)
    assert float(jnp.abs(g["w1"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0
