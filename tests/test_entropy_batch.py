"""Batched entropy decode + process-pool shard coder tests.

``decode_indices_batch`` must be result-identical to per-payload
``decode_indices`` for any mix of coders; the process-pool coder
(id 3) must round-trip, share the thread-sharded byte layout, and fall
back in-process -- byte-identically -- when the pool breaks.
"""

import os

import numpy as np
import pytest

from repro.core import cabac, rans
from repro.core import CodecConfig, calibrate
from repro.core.binarization import index_to_context_bits


@pytest.fixture(autouse=True)
def _clean_pools():
    # restore (not just pop) the pool env: the CI entropy_coders matrix
    # exports these for the whole pytest run, and later test files must
    # still see them -- only values set *by a test here* are undone
    before = {k: os.environ.get(k)
              for k in ("REPRO_RANS_PROCS", "REPRO_RANS_THREADS")}
    yield
    for k, v in before.items():
        if v is None:
            os.environ.pop(k, None)
        else:
            os.environ[k] = v
    rans._shutdown_proc_pool()


class TestBatchPlaneDecoder:
    @pytest.mark.parametrize("seed", range(4))
    def test_matches_serial_decoder(self, seed):
        rng = np.random.default_rng(seed)
        streams = []
        for _ in range(int(rng.integers(2, 6))):
            # totals pinned inside one lane bucket so the streams are
            # batchable (callers group by the blob's lane count), while
            # plane counts/sizes and probabilities still vary per stream
            n_planes = int(rng.integers(1, 4))
            sizes = rng.multinomial(
                int(rng.integers(34000, 40000)),
                np.ones(n_planes) / n_planes) + 1
            planes = [(rng.random(int(s)) < rng.random()).astype(np.uint8)
                      for s in sizes]
            streams.append(planes)
        blobs = [rans.encode_planes(p) for p in streams]
        lanes = {rans.PlaneStreamDecoder(b).lanes for b in blobs}
        assert len(lanes) == 1, "test construction: one lane bucket"
        batch = rans.BatchPlaneDecoder(blobs)
        serial = [rans.PlaneStreamDecoder(b) for b in blobs]
        n_planes = max(len(p) for p in streams)
        for j in range(n_planes):
            sizes = [s[j].size if j < len(s) else 0 for s in streams]
            got = batch.next_planes(sizes)
            for s, (size, out) in enumerate(zip(sizes, got)):
                want = serial[s].next_plane(size)
                np.testing.assert_array_equal(out, want)
                if size:
                    np.testing.assert_array_equal(out, streams[s][j])

    def test_rejects_mixed_lanes_and_empty(self):
        a = rans.encode_planes([np.ones(40000, np.uint8)])
        b = rans.encode_planes([np.zeros(10, np.uint8)])
        with pytest.raises(ValueError):
            rans.BatchPlaneDecoder([a, b])
        import struct
        empty = struct.pack("<HI", 0, 0)
        with pytest.raises(ValueError):
            rans.BatchPlaneDecoder([a, empty])


class TestDecodeIndicesBatch:
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 8, 17])
    def test_identical_to_per_payload(self, n_levels):
        rng = np.random.default_rng(n_levels)
        segs = [rng.choice(n_levels, size=int(s)).astype(np.int32)
                for s in (1, 500, 65536, 66000, 150000, 150001)]
        blobs = [cabac.encode_indices(s, n_levels) for s in segs]
        batch = cabac.decode_indices_batch(blobs,
                                           [s.size for s in segs], n_levels)
        for s, blob, out in zip(segs, blobs, batch):
            np.testing.assert_array_equal(
                out, cabac.decode_indices(blob, s.size, n_levels))
            np.testing.assert_array_equal(out, s)

    def test_mixed_coders_and_degenerate(self):
        rng = np.random.default_rng(0)
        segs = [np.zeros(5, np.int32),
                np.full(130000, 7, np.int32),
                rng.choice(8, size=200000).astype(np.int32),
                rng.choice(2, size=70000).astype(np.int32)]
        blobs = [cabac.encode_indices(s, 8) for s in segs]
        os.environ["REPRO_RANS_THREADS"] = "2"
        blobs.append(cabac.encode_indices(segs[2], 8, mode="rans_sharded"))
        segs.append(segs[2])
        out = cabac.decode_indices_batch(blobs, [s.size for s in segs], 8)
        for s, o in zip(segs, out):
            np.testing.assert_array_equal(o, s)

    def test_single_member_group(self):
        rng = np.random.default_rng(1)
        seg = rng.choice(4, size=90000).astype(np.int32)
        blob = cabac.encode_indices(seg, 4, mode="rans")
        (out,) = cabac.decode_indices_batch([blob], [seg.size], 4)
        np.testing.assert_array_equal(out, seg)


class TestStreamBatchedDecode:
    def test_chunk_batching_bit_exact_any_order(self):
        from repro.core import ChunkStreamDecoder
        rng = np.random.default_rng(3)
        x = rng.exponential(1.0, (64, 1024)).astype(np.float32)
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      constrain_cmin_zero=False), samples=x)
        payloads = list(codec.encode_stream(x, chunk_elems=3000))
        one_shot = codec.decode(codec.encode(x), shape=x.shape)
        for batch in (1, 3, len(payloads)):
            dec = ChunkStreamDecoder(payloads[0], chunk_batch=batch)
            order = rng.permutation(len(payloads) - 1)
            for k in order:
                dec.add_chunk(payloads[1 + k])
            np.testing.assert_array_equal(dec.finish(), one_shot)

    def test_corrupt_chunk_does_not_poison_stream(self):
        from repro.core import ChunkStreamDecoder
        rng = np.random.default_rng(6)
        x = rng.exponential(1.0, (8192,)).astype(np.float32)
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      constrain_cmin_zero=False), samples=x)
        payloads = list(codec.encode_stream(x, chunk_elems=1000))
        dec = ChunkStreamDecoder(payloads[0], chunk_batch=1)
        bad = payloads[1][:4] + bytes([255]) + payloads[1][5:]  # coder id
        with pytest.raises(ValueError):
            dec.add_chunk(bad)
        # the failed chunk is re-requestable -- not a duplicate
        for p in payloads[1:]:
            dec.add_chunk(p)
        np.testing.assert_array_equal(
            dec.finish(), codec.decode(codec.encode(x), shape=x.shape))

    def test_truncated_member_raises_in_batch(self):
        rng = np.random.default_rng(7)
        segs = [rng.choice(4, size=90000).astype(np.int32) for _ in range(3)]
        blobs = [cabac.encode_indices(s, 4, mode="rans") for s in segs]
        cut = blobs[1][:len(blobs[1]) - 40]     # drop trailing words
        with pytest.raises(ValueError):
            cabac.decode_indices_batch([blobs[0], cut, blobs[2]],
                                       [s.size for s in segs], 4)

    def test_duplicate_rejected_before_batch_flush(self):
        from repro.core import ChunkStreamDecoder
        rng = np.random.default_rng(4)
        x = rng.exponential(1.0, (4096,)).astype(np.float32)
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      constrain_cmin_zero=False), samples=x)
        payloads = list(codec.encode_stream(x, chunk_elems=500))
        dec = ChunkStreamDecoder(payloads[0], chunk_batch=64)
        dec.add_chunk(payloads[1])
        with pytest.raises(ValueError, match="duplicate"):
            dec.add_chunk(payloads[1])
        with pytest.raises(ValueError, match="incomplete"):
            dec.finish()


class TestProcessPoolCoder:
    def test_round_trip_and_auto_selection(self):
        os.environ["REPRO_RANS_PROCS"] = "2"
        rng = np.random.default_rng(0)
        idx = rng.choice(4, size=1 << 21).astype(np.int32)
        blob = cabac.encode_indices(idx, 4, mode="auto")
        assert blob[0] == cabac._CODER_RANS_PROC
        np.testing.assert_array_equal(
            cabac.decode_indices(blob, idx.size, 4), idx)

    def test_decodes_without_pool_configured(self):
        os.environ["REPRO_RANS_PROCS"] = "2"
        rng = np.random.default_rng(1)
        idx = rng.choice(4, size=300000).astype(np.int32)
        blob = cabac.encode_indices(idx, 4, mode="rans_proc")
        del os.environ["REPRO_RANS_PROCS"]
        rans._shutdown_proc_pool()
        np.testing.assert_array_equal(
            cabac.decode_indices(blob, idx.size, 4), idx)

    def test_shard_bytes_match_thread_coder(self):
        os.environ["REPRO_RANS_PROCS"] = "2"
        os.environ["REPRO_RANS_THREADS"] = "2"
        rng = np.random.default_rng(2)
        idx = rng.choice(4, size=400000).astype(np.int32)
        proc = cabac.encode_indices(idx, 4, mode="rans_proc")
        thread = cabac.encode_indices(idx, 4, mode="rans_sharded")
        assert proc[0] == 3 and thread[0] == 2
        assert proc[1:] == thread[1:]

    def test_worker_crash_falls_back_byte_identical(self):
        os.environ["REPRO_RANS_PROCS"] = "2"
        rng = np.random.default_rng(3)
        idx = rng.choice(4, size=300000).astype(np.int32)
        good = cabac.encode_indices(idx, 4, mode="rans_proc")

        class BrokenPool:
            def map(self, *a, **k):
                raise RuntimeError("worker died")

            def shutdown(self, wait=False):
                pass

        rans._PROC_POOL = BrokenPool()
        rans._PROC_SIZE = 99
        fallback = cabac.encode_indices(idx, 4, mode="rans_proc")
        assert fallback == good          # serial fallback, same bytes
        assert rans._PROC_POOL is None   # broken pool was torn down
        rans._PROC_POOL = BrokenPool()
        rans._PROC_SIZE = 99
        np.testing.assert_array_equal(
            cabac.decode_indices(good, idx.size, 4), idx)
        assert rans._PROC_POOL is None


class TestEncoderCompaction:
    """The compacted TU plane builder must match the straightforward
    definition (plane j = bits of elements with idx >= j)."""

    @pytest.mark.parametrize("n_levels", [2, 4, 9])
    def test_planes_match_definition(self, n_levels):
        rng = np.random.default_rng(n_levels)
        idx = rng.choice(n_levels, size=5000).astype(np.int32)
        planes = index_to_context_bits(idx, n_levels)
        assert len(planes) == n_levels - 1
        for j, plane in enumerate(planes):
            alive = idx >= j
            np.testing.assert_array_equal(plane,
                                          (idx[alive] > j).astype(np.uint8))
