"""Tiled codec core tests: QuantBackend dispatch, per-channel granularity,
self-describing headers, packed-transport edge sizes, vectorized coder."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import CodecConfig, calibrate
from repro.core.backend import JnpBackend, QuantSpec, get_backend
from repro.core import cabac


@pytest.fixture(scope="module")
def channel_samples():
    """NHWC-style channel-minor features with per-channel bias (BN-like)."""
    rng = np.random.default_rng(0)
    mu = np.linspace(0.0, 10.0, 12).astype(np.float32)
    return (mu[None, :] + rng.exponential(1.0, (3000, 12))).astype(np.float32)


class TestBackendDispatch:
    def test_kernel_matches_jnp_per_tensor(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(2, 4, size=(513,)).astype(np.float32))
        spec = QuantSpec(0.0, 9.036, 4)
        ji, jd = JnpBackend().quantize_dequantize(x, spec)
        ki, kd = get_backend("kernel_interpret").quantize_dequantize(x, spec)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ji))
        np.testing.assert_allclose(np.asarray(kd), np.asarray(jd), atol=1e-6)

    @pytest.mark.parametrize("shape,axis", [((7, 5), -1), ((4, 6, 9), 1),
                                            ((130, 300), 0)])
    def test_kernel_matches_jnp_per_channel(self, shape, axis):
        rng = np.random.default_rng(2)
        C = shape[axis]
        x = jnp.asarray(rng.normal(2, 3, size=shape).astype(np.float32))
        spec = QuantSpec(rng.uniform(-1, 0, C).astype(np.float32),
                         rng.uniform(1, 5, C).astype(np.float32), 4, axis)
        ji, jd = JnpBackend().quantize_dequantize(x, spec)
        ki, kd = get_backend("kernel_interpret").quantize_dequantize(x, spec)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ji))
        np.testing.assert_allclose(np.asarray(kd), np.asarray(jd), atol=1e-6)

    def test_codec_backend_override(self):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                      manual_cmax=8.0,
                                      backend="kernel_interpret"))
        assert codec.backend.name == "kernel"
        ref = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                    manual_cmax=8.0, backend="jnp"))
        x = jnp.asarray(np.random.default_rng(3)
                        .normal(3, 3, 1000).astype(np.float32))
        np.testing.assert_array_equal(np.asarray(codec.quantize(x)),
                                      np.asarray(ref.quantize(x)))

    def test_histogram_unified(self):
        idx = jnp.asarray(np.random.default_rng(4).integers(0, 4, 5000)
                          .astype(np.int32))
        h1 = JnpBackend().histogram(idx, 4)
        h2 = get_backend("kernel_interpret").histogram(idx, 4)
        np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))


class TestChannelGranularity:
    def test_calibrate_produces_group_vectors(self, channel_samples):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      granularity="channel", channel_axis=-1,
                                      constrain_cmin_zero=False),
                          samples=channel_samples)
        assert codec.per_channel and codec.n_channels == 12
        assert codec.cmin.shape == (12,) and codec.cmax.shape == (12,)
        assert (np.diff(codec.cmin) > 0).all()  # tracks the channel bias

    def test_channel_groups(self, channel_samples):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      granularity="channel", channel_axis=-1,
                                      channel_group_size=4,
                                      constrain_cmin_zero=False),
                          samples=channel_samples)
        assert codec.cmin.shape == (3,)
        lo, hi = codec.channel_ranges()
        assert lo.shape == (12,) and (lo[:4] == lo[0]).all()

    def test_header_roundtrip_fresh_receiver(self, channel_samples):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      granularity="channel", channel_axis=-1,
                                      constrain_cmin_zero=False),
                          samples=channel_samples)
        x = channel_samples[:512]
        blob = codec.encode(x)
        receiver = calibrate(CodecConfig(n_levels=2, clip_mode="manual"))
        decoded = receiver.decode(blob)
        fake = np.asarray(codec.apply(jnp.asarray(x)))
        assert decoded.shape == x.shape
        np.testing.assert_allclose(decoded, fake, atol=1e-5)

    def test_channel_rate_beats_tensor_on_biased_channels(self,
                                                          channel_samples):
        x = channel_samples
        common = dict(n_levels=4, clip_mode="minmax",
                      constrain_cmin_zero=False)
        ch = calibrate(CodecConfig(granularity="channel", channel_axis=-1,
                                   **common), samples=x)
        tn = calibrate(CodecConfig(**common), samples=x)
        assert ch.compressed_bits_per_element(x) <= \
            tn.compressed_bits_per_element(x)

    def test_channel_accuracy_beats_tensor(self, channel_samples):
        x = channel_samples
        common = dict(n_levels=4, clip_mode="minmax",
                      constrain_cmin_zero=False)
        ch = calibrate(CodecConfig(granularity="channel", channel_axis=-1,
                                   **common), samples=x)
        tn = calibrate(CodecConfig(**common), samples=x)
        xj = jnp.asarray(x)
        mse_ch = float(np.mean((np.asarray(ch.apply(xj)) - x) ** 2))
        mse_tn = float(np.mean((np.asarray(tn.apply(xj)) - x) ** 2))
        assert mse_ch < mse_tn

    def test_ecsq_channel_designs_per_tile(self, channel_samples):
        """Per-channel ECSQ (one designed quantizer per channel group)
        round-trips through a fresh receiver via the v3 level tables."""
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="minmax",
                                      granularity="channel", channel_axis=-1,
                                      constrain_cmin_zero=False,
                                      use_ecsq=True),
                          samples=channel_samples)
        assert codec.tile_ecsq is not None
        assert codec.tile_ecsq.levels.shape == (12, 4)
        x = channel_samples[:512]
        receiver = calibrate(CodecConfig(n_levels=2, clip_mode="manual"))
        decoded = receiver.decode(codec.encode(x))
        np.testing.assert_allclose(
            decoded, np.asarray(codec.apply(jnp.asarray(x))), atol=1e-5)


class TestHeaderHonored:
    def test_receiver_with_mismatched_config(self):
        rng = np.random.default_rng(5)
        x = rng.exponential(1.0, 6000).astype(np.float32)
        sender = calibrate(CodecConfig(n_levels=4, clip_mode="model"),
                           samples=x)
        blob = sender.encode(x)
        receiver = calibrate(CodecConfig(n_levels=8, clip_mode="manual",
                                         manual_cmax=99.0))
        decoded = receiver.decode(blob, shape=x.shape)
        np.testing.assert_allclose(
            decoded, np.asarray(sender.apply(jnp.asarray(x))), atol=1e-5)

    def test_ecsq_receiver_can_reencode_from_levels(self):
        """Header levels + from_levels rebuild a working quantizer."""
        from repro.core.ecsq import ECSQQuantizer, design_ecsq
        rng = np.random.default_rng(10)
        x = rng.exponential(1.0, 20000).astype(np.float32)
        q = design_ecsq(x, 4, 0.05, 0.0, 6.0)
        rebuilt = ECSQQuantizer.from_levels(q.levels, q.lagrangian)
        np.testing.assert_allclose(rebuilt.thresholds, q.thresholds,
                                   atol=1e-9)
        np.testing.assert_array_equal(rebuilt.quantize_np(x),
                                      q.quantize_np(x))

    def test_ecsq_levels_travel_in_header(self):
        rng = np.random.default_rng(6)
        x = rng.exponential(1.0, 15000).astype(np.float32)
        sender = calibrate(CodecConfig(n_levels=4, clip_mode="model",
                                       use_ecsq=True), samples=x)
        receiver = calibrate(CodecConfig(n_levels=3, clip_mode="manual"))
        decoded = receiver.decode(sender.encode(x), shape=x.shape)
        np.testing.assert_allclose(
            decoded, np.asarray(sender.apply(jnp.asarray(x))), atol=1e-6)


class TestPackingEdgeSizes:
    @pytest.mark.parametrize("n", [1, 3, 7, 13, 255, 1001, 4097])
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 8, 17])
    def test_pack_unpack_awkward_sizes(self, n, n_levels):
        rng = np.random.default_rng(n)
        idx = jnp.asarray(rng.integers(0, n_levels, size=n).astype(np.int32))
        codec = calibrate(CodecConfig(n_levels=n_levels, clip_mode="manual",
                                      manual_cmax=1.0))
        back = codec.unpack(codec.pack(idx), n)
        np.testing.assert_array_equal(np.asarray(back),
                                      np.asarray(idx).reshape(-1))

    def test_packed_byte_count_rounds_up(self):
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                      manual_cmax=1.0))
        idx = jnp.ones((13,), jnp.int32)
        assert codec.pack(idx).size == 4  # ceil(13 / 4) lanes of 2 bits


class TestVectorizedCoder:
    @pytest.mark.parametrize("n", [0, 1, 100, 5000, 70_001])
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 8])
    def test_rans_roundtrip(self, n, n_levels):
        rng = np.random.default_rng(n + n_levels)
        idx = rng.integers(0, n_levels, size=n).astype(np.int32)
        blob = cabac.encode_indices(idx, n_levels, mode="rans")
        np.testing.assert_array_equal(
            cabac.decode_indices(blob, n, n_levels), idx)

    def test_serial_roundtrip_and_auto_dispatch(self):
        rng = np.random.default_rng(7)
        small = rng.integers(0, 4, size=500).astype(np.int32)
        large = rng.integers(0, 4, size=80_000).astype(np.int32)
        assert cabac.encode_indices(small, 4)[0] == cabac._CODER_SERIAL
        assert cabac.encode_indices(large, 4)[0] == cabac._CODER_RANS
        for idx in (small, large):
            blob = cabac.encode_indices(idx, 4)
            np.testing.assert_array_equal(
                cabac.decode_indices(blob, idx.size, 4), idx)

    def test_seed_stream_still_decodes(self):
        """Legacy (headerless-payload) serial streams remain readable."""
        rng = np.random.default_rng(8)
        idx = rng.integers(0, 4, size=2000).astype(np.int32)
        legacy = cabac.encode_indices_serial(idx, 4)
        np.testing.assert_array_equal(
            cabac.decode_indices_serial(legacy, idx.size, 4), idx)

    def test_rans_rate_near_entropy(self):
        from repro.core.rate_model import estimated_bits_np
        rng = np.random.default_rng(9)
        idx = rng.choice(4, size=300_000,
                         p=[0.55, 0.25, 0.13, 0.07]).astype(np.int32)
        blob = cabac.encode_indices(idx, 4, mode="rans")
        est = estimated_bits_np(idx, 4)
        # within 10% of the adaptive bound: the speed-tuned lane count
        # (rans.lane_count) spends ~5-8% on per-lane state flushes in
        # exchange for the >=20 Melem/s hot path (see BENCH_codec.json)
        assert 8 * len(blob) <= est * 1.10
