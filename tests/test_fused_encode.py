"""Fused encode megakernel parity suite.

The single-pass encode contract: one device pass (clip -> quantize ->
bit-pack -> per-tile histogram) whose packed bytes + histograms are the
only device->host transfer, with coded-order indices bit-identical to the
unfused quantize path on *every* backend -- which is what keeps the
entropy payload byte-identical.  Kernels run in interpret mode on CPU;
the jnp backend fulfils the same contract with its reference formulas.
"""

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CodecConfig, calibrate
from repro.core.backend import QuantSpec, _coded_order, get_backend
from repro.core.tiling import TileECSQ, TilePlan
from repro.kernels import ops


def _bits(n_levels: int) -> int:
    return max(1, int(np.ceil(np.log2(n_levels))))


@pytest.fixture(scope="module")
def backends():
    return get_backend("jnp"), get_backend("kernel_interpret")


class TestFusedPerTensor:
    @pytest.mark.parametrize("n", [1, 513, 1000, 4096, 1 << 16])
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 5, 8, 17, 64])
    def test_fused_equals_unfused(self, backends, n, n_levels):
        rng = np.random.default_rng(n + n_levels)
        x = jnp.asarray(rng.normal(2, 3, (n,)).astype(np.float32))
        spec = QuantSpec(0.0, 7.5, n_levels)
        for be in backends:
            coded, hists = be.encode_fused(x, spec, _bits(n_levels),
                                           want_hist=True)
            unfused = _coded_order(np.asarray(be.quantize(x, spec)), spec)
            np.testing.assert_array_equal(coded, unfused)
            assert hists.shape == (1, 1, n_levels)
            np.testing.assert_array_equal(
                hists.ravel(), np.bincount(unfused, minlength=n_levels))

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes_fused_matches_own_backend(self, backends, dtype):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(1, 2, (3, 7, 41)), dtype)
        spec = QuantSpec(-1.0, 5.0, 8)
        for be in backends:
            coded, _ = be.encode_fused(x, spec, 3)
            np.testing.assert_array_equal(
                coded, np.asarray(be.quantize(x, spec)).ravel())

    def test_backends_agree_f32(self, backends):
        jb, kb = backends
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 4, (2000,)).astype(np.float32))
        spec = QuantSpec(-3.0, 3.0, 4)
        cj, hj = jb.encode_fused(x, spec, 2, want_hist=True)
        ck, hk = kb.encode_fused(x, spec, 2, want_hist=True)
        np.testing.assert_array_equal(cj, ck)
        np.testing.assert_array_equal(hj, hk)


class TestFusedTiled:
    @pytest.mark.parametrize("geom", [
        # (shape, group, spatial_block): non-multiple channel counts,
        # non-multiple spatial blocks, one-spatial-block (per-channel)
        ((7, 11, 17), 4, 30),
        ((5, 64), 1, 0),
        ((16, 16, 3), 2, 7),
        ((33, 129), 5, 100),
    ])
    @pytest.mark.parametrize("n_levels", [2, 4, 6, 16, 33])
    def test_fused_equals_unfused(self, backends, geom, n_levels):
        shape, gs, bs = geom
        rng = np.random.default_rng(n_levels)
        x = rng.normal(1, 2, shape).astype(np.float32)
        c = shape[-1]
        m = int(np.prod(shape)) // c
        plan = TilePlan(channel_axis=-1, channel_group_size=gs,
                        spatial_block_size=bs, n_channels=c,
                        spatial_extent=m if bs else None)
        lo = rng.normal(-3, 0.1,
                        (plan.n_cgroups, plan.n_sblocks)).astype(np.float32)
        hi = lo + rng.uniform(1, 5, lo.shape).astype(np.float32)
        spec = QuantSpec(lo, hi, n_levels, -1, None, plan)
        xj = jnp.asarray(x)
        results = []
        for be in backends:
            coded, hists = be.encode_fused(xj, spec, _bits(n_levels),
                                           want_hist=True)
            unfused = plan.to_coded_order(np.asarray(be.quantize(xj, spec)))
            np.testing.assert_array_equal(coded, unfused)
            assert int(hists.sum()) == x.size
            # per-tile counts match host bincounts over the tile map
            tid = plan.tile_ids(x.shape)
            flat = hists.reshape(plan.n_tiles, n_levels)
            idx_full = plan.from_coded_order(coded, x.shape)
            for t in range(plan.n_tiles):
                np.testing.assert_array_equal(
                    flat[t], np.bincount(idx_full[tid == t],
                                         minlength=n_levels))
            results.append((coded, hists))
        np.testing.assert_array_equal(results[0][0], results[1][0])
        np.testing.assert_array_equal(results[0][1], results[1][1])

    def test_tile_histogram_matches_fused(self, backends):
        rng = np.random.default_rng(5)
        x = rng.normal(1, 2, (7, 11, 17)).astype(np.float32)
        plan = TilePlan(channel_axis=-1, channel_group_size=4,
                        spatial_block_size=30, n_channels=17,
                        spatial_extent=77)
        lo = np.full((plan.n_cgroups, plan.n_sblocks), -2.0, np.float32)
        hi = np.full_like(lo, 4.0)
        spec = QuantSpec(lo, hi, 6, -1, None, plan)
        for be in backends:
            idx = be.quantize(jnp.asarray(x), spec)
            th = np.asarray(be.tile_histogram(idx, spec))
            _, fused = be.encode_fused(jnp.asarray(x), spec, 3,
                                       want_hist=True)
            np.testing.assert_array_equal(th, fused)


class TestFusedCodecStreams:
    @pytest.mark.parametrize("granularity,kw", [
        ("tensor", {}),
        ("channel", {}),
        ("tile", {"spatial_block_size": 1000}),
    ])
    @pytest.mark.parametrize("coder_mode", ["serial", "rans"])
    def test_encode_byte_identical(self, granularity, kw, coder_mode):
        rng = np.random.default_rng(7)
        mu = np.linspace(0.0, 6.0, 16).astype(np.float32)
        x = (mu[None] + rng.exponential(1.0, (256, 16))).astype(np.float32)
        cfg = CodecConfig(n_levels=4, clip_mode="minmax",
                          constrain_cmin_zero=False,
                          granularity=granularity, channel_axis=-1,
                          channel_group_size=3, **kw)
        codec = calibrate(cfg, samples=x)
        fused = codec.encode(x, coder_mode=coder_mode)
        unfused = codec.encode(x, coder_mode=coder_mode, fused=False)
        assert fused == unfused
        np.testing.assert_array_equal(codec.decode(fused, shape=x.shape),
                                      codec.decode(unfused, shape=x.shape))

    def test_ecsq_falls_back_bit_exact(self):
        rng = np.random.default_rng(11)
        x = rng.exponential(1.0, (4096,)).astype(np.float32)
        codec = calibrate(CodecConfig(n_levels=4, use_ecsq=True,
                                      clip_mode="minmax",
                                      constrain_cmin_zero=False),
                          samples=x)
        assert codec.encode(x) == codec.encode(x, fused=False)


class TestUnpackBytes:
    @pytest.mark.parametrize("bits", [1, 2, 4, 3, 6, 8])
    def test_pack_unpack_roundtrip_layout(self, bits):
        rng = np.random.default_rng(bits)
        per = 8 // bits if bits in (1, 2, 4) else 1
        vals = rng.integers(0, 1 << min(bits, 8),
                            size=(4, 16 * per)).astype(np.int32)
        if per == 1:
            packed = vals.astype(np.uint8)
        else:
            shifts = np.arange(per, dtype=np.uint8) * bits
            packed = np.sum(
                vals.reshape(4, -1, per).astype(np.uint8) << shifts,
                axis=-1).astype(np.uint8)
        np.testing.assert_array_equal(ops.unpack_bytes(packed, bits), vals)


class TestTiledECSQKernel:
    @pytest.mark.parametrize("n_levels", [4, 17, 33, 64])
    def test_parity_with_jnp(self, backends, n_levels):
        jb, kb = backends
        rng = np.random.default_rng(n_levels)
        x = rng.normal(1, 2, (7, 11, 17)).astype(np.float32)
        plan = TilePlan(channel_axis=-1, channel_group_size=4,
                        spatial_block_size=30, n_channels=17,
                        spatial_extent=77)
        lo = rng.normal(-3, 0.1,
                        (plan.n_cgroups, plan.n_sblocks)).astype(np.float32)
        hi = lo + rng.uniform(1, 5, lo.shape).astype(np.float32)
        lv = np.sort(rng.normal(0, 2, (plan.n_tiles, n_levels))
                     .astype(np.float32), axis=1)
        te = TileECSQ(levels=lv, thresholds=(lv[:, :-1] + lv[:, 1:]) / 2)
        spec = QuantSpec(lo, hi, n_levels, -1, te, plan)
        xj = jnp.asarray(x)
        ij, dj = (np.asarray(a) for a in jb.quantize_dequantize(xj, spec))
        ik, dk = (np.asarray(a) for a in kb.quantize_dequantize(xj, spec))
        np.testing.assert_array_equal(ij, ik)
        np.testing.assert_array_equal(dj, dk)

    def test_designed_tile_ecsq_through_kernel_codec(self):
        """End-to-end: per-tile ECSQ designed by calibrate, quantized via
        the kernel backend, stream round trip bit-exact."""
        rng = np.random.default_rng(2)
        mu = np.linspace(0.0, 5.0, 8).astype(np.float32)
        x = (mu[None] + rng.exponential(1.0, (512, 8))).astype(np.float32)
        cfg = CodecConfig(n_levels=4, use_ecsq=True, clip_mode="minmax",
                          constrain_cmin_zero=False, granularity="channel",
                          channel_axis=-1, backend="kernel_interpret")
        codec = calibrate(cfg, samples=x)
        out = codec.decode(codec.encode(x), shape=x.shape)
        ref_cfg = CodecConfig(n_levels=4, use_ecsq=True, clip_mode="minmax",
                              constrain_cmin_zero=False,
                              granularity="channel", channel_axis=-1,
                              backend="jnp")
        ref = calibrate(ref_cfg, samples=x)
        np.testing.assert_array_equal(out,
                                      ref.decode(ref.encode(x),
                                                 shape=x.shape))
