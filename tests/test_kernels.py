"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis.

Kernels run in interpret mode on CPU (the TPU lowering is exercised by the
pallas_call construction itself; numerical behaviour is identical).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:  # hypothesis is optional: only the property sweep needs it
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.distributions import resnet50_layer21_model
from repro.core.ecsq import design_ecsq
from repro.core.rate_model import estimated_bits_np
from repro.kernels import ops, ref

SHAPES = [(128,), (1000,), (32, 32), (8, 128), (17, 93), (4, 4, 64),
          (2, 3, 5, 7)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.fixture(scope="module")
def samples():
    m = resnet50_layer21_model()
    return m.sample(200_000, np.random.default_rng(0)).astype(np.float32)


class TestClipQuant:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("dtype", DTYPES)
    @pytest.mark.parametrize("n_levels", [2, 4, 5, 8])
    def test_matches_ref(self, shape, dtype, n_levels):
        rng = np.random.default_rng(42)
        x = jnp.asarray(rng.normal(2, 4, size=shape), dtype)
        ki, kd = ops.clip_quantize(x, cmin=0.0, cmax=9.0, n_levels=n_levels)
        ri, rd = ref.clip_quant_ref(x, 0.0, 9.0, n_levels)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(kd, np.float32),
                                   np.asarray(rd, np.float32), atol=1e-6)

    def test_matches_core_uniform(self, samples):
        from repro.core import uniform
        x = jnp.asarray(samples[:8192])
        ki, kd = ops.clip_quantize(x, cmin=0.0, cmax=9.036, n_levels=4)
        ci = uniform.quantize(x, 0.0, 9.036, 4)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ci))

    if HAVE_HYPOTHESIS:
        @settings(max_examples=25, deadline=None)
        @given(n=st.integers(1, 3000), lv=st.integers(2, 16),
               cmax=st.floats(0.5, 50.0))
        def test_hypothesis_idx_range_and_idempotence(self, n, lv, cmax):
            rng = np.random.default_rng(n)
            x = jnp.asarray(rng.normal(0, 5, size=(n,)).astype(np.float32))
            idx, deq = ops.clip_quantize(x, cmin=0.0, cmax=float(cmax),
                                         n_levels=lv)
            assert int(idx.min()) >= 0 and int(idx.max()) <= lv - 1
            idx2, deq2 = ops.clip_quantize(deq, cmin=0.0, cmax=float(cmax),
                                           n_levels=lv)
            np.testing.assert_array_equal(np.asarray(idx), np.asarray(idx2))
    else:
        def test_hypothesis_idx_range_and_idempotence(self):
            pytest.skip("hypothesis not installed")


class TestECSQAssign:
    @pytest.mark.parametrize("n_levels", [2, 3, 4, 8, 16, 24, 48, 64])
    def test_matches_ref(self, samples, n_levels):
        q = design_ecsq(samples[:20000], n_levels, 0.05, 0.0, 9.0)
        x = jnp.asarray(samples[:4096])
        thr = jnp.asarray(q.thresholds)
        lvl = jnp.asarray(q.levels)
        ki, kd = ops.ecsq_quantize(x, thr, lvl, cmin=0.0, cmax=9.0)
        ri, rd = ref.ecsq_assign_ref(x, thr, lvl, 0.0, 9.0)
        np.testing.assert_array_equal(np.asarray(ki), np.asarray(ri))
        np.testing.assert_allclose(np.asarray(kd), np.asarray(rd), atol=1e-6)

    def test_matches_host_ecsq(self, samples):
        q = design_ecsq(samples[:20000], 4, 0.05, 0.0, 9.0)
        x = samples[:2048]
        ki, _ = ops.ecsq_quantize(jnp.asarray(x), jnp.asarray(q.thresholds),
                                  jnp.asarray(q.levels), cmin=0.0, cmax=9.0)
        np.testing.assert_array_equal(np.asarray(ki), q.quantize_np(x))


class TestRateHist:
    @pytest.mark.parametrize("shape", SHAPES)
    @pytest.mark.parametrize("n_levels", [2, 4, 8, 64])
    def test_matches_ref(self, shape, n_levels):
        rng = np.random.default_rng(7)
        idx = jnp.asarray(rng.integers(0, n_levels, size=shape).astype(np.int32))
        kh = ops.index_histogram(idx, n_levels=n_levels)
        rh = ref.index_histogram_ref(idx, n_levels)
        np.testing.assert_array_equal(np.asarray(kh), np.asarray(rh))
        assert int(kh.sum()) == idx.size

    @pytest.mark.parametrize("n_levels", [17, 33, 64])
    def test_past_legacy_16_cap(self, n_levels):
        """The lifted fori_loop kernels agree with numpy above N=16."""
        rng = np.random.default_rng(n_levels)
        idx = rng.integers(0, n_levels, size=40_000).astype(np.int32)
        kh = ops.index_histogram(jnp.asarray(idx), n_levels=n_levels,
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(kh),
                                      np.bincount(idx, minlength=n_levels))

    def test_rate_estimate_matches_host(self, samples):
        idx, _ = ops.clip_quantize(jnp.asarray(samples[:32768]), cmin=0.0,
                                   cmax=9.036, n_levels=4)
        kernel_rate = float(ops.estimate_rate_bits(idx, 4))
        host_rate = estimated_bits_np(np.asarray(idx), 4) / idx.size
        assert kernel_rate == pytest.approx(host_rate, rel=1e-5)


class TestEndToEnd:
    def test_kernel_codec_path_equals_core_codec(self, samples):
        """kernel clip-quant + CABAC == FeatureCodec.encode/decode."""
        from repro.core import CodecConfig, calibrate
        from repro.core.cabac import decode_indices, encode_indices
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="model"),
                          samples=samples)
        x = jnp.asarray(samples[:8192])
        idx, deq = ops.clip_quantize(x, cmin=codec.cmin, cmax=codec.cmax,
                                     n_levels=4)
        blob = encode_indices(np.asarray(idx), 4)
        back = decode_indices(blob, idx.size, 4)
        np.testing.assert_array_equal(back, np.asarray(idx))
        np.testing.assert_allclose(np.asarray(deq),
                                   np.asarray(codec.apply(x)), atol=1e-6)
