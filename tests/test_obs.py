"""Observability layer tests: metrics registry semantics, span tracing,
the Prometheus exposition endpoint, the FT_METRICS in-band snapshot, and
the counter-migration invariants (legacy dict shapes, per-session label
series lifecycle across completion / eviction / disconnect)."""

import asyncio
import dataclasses
import json
import pathlib
import sys
import time
import urllib.request

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from repro.core import CodecConfig, calibrate
from repro.obs import (BPE_BUCKETS, LATENCY_BUCKETS, MetricsExposition,
                       MetricsRegistry, configure_tracing,
                       parse_prometheus_text, tracer)
from repro.obs.tracing import _NULL_SPAN, span
from repro.serving import TickConfig
from repro.transport import (CloudServer, EdgeClient, encode_frame,
                             tensor_to_frames)
from repro.transport.framing import FT_METRICS


@pytest.fixture(scope="module")
def features():
    rng = np.random.default_rng(11)
    mu = np.linspace(0.0, 6.0, 16).astype(np.float32)
    return (mu[None, :] + rng.exponential(1.0, (512, 16))).astype(np.float32)


def _live_codec(features, n_levels=8):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="channel", channel_axis=-1,
                                 channel_group_size=4), samples=features)


def _series(snap: dict, name: str) -> dict:
    """The single label series of ``name`` in a registry snapshot."""
    series = snap[name]["series"]
    assert len(series) == 1, (name, series)
    return series[0]


class TestRegistry:
    def test_counter_gauge_histogram(self):
        reg = MetricsRegistry()
        c = reg.counter("repro_t_things_total", "things")
        c.inc()
        c.inc(4)
        assert c.value() == 5
        g = reg.gauge("repro_t_depth_count", "depth")
        g.set(3)
        g.dec()
        assert g.value() == 2
        h = reg.histogram("repro_t_lat_seconds", "lat")
        for v in (0.001, 0.01, 0.01, 5.0):
            h.observe(v)
        assert h.count() == 4
        assert h.sum() == pytest.approx(5.021)

    def test_labels_and_removal(self):
        reg = MetricsRegistry()
        g = reg.gauge("repro_t_pending_count", "pending",
                      labelnames=("session",))
        g.set(2, session="1:0")
        g.set(7, session="1:1")
        assert g.value(session="1:1") == 7
        assert len(g.series()) == 2
        g.remove(session="1:0")
        assert len(g.series()) == 1
        g.remove(session="no-such")          # idempotent
        assert len(g.series()) == 1

    def test_get_or_create_conflicts(self):
        reg = MetricsRegistry()
        reg.counter("repro_t_x_total", "x")
        # same name, same kind -> same instrument
        assert reg.counter("repro_t_x_total", "x") is \
            reg.counter("repro_t_x_total", "x")
        with pytest.raises(ValueError):
            reg.gauge("repro_t_x_total", "x")
        with pytest.raises(ValueError):
            reg.counter("repro_t_x_total", "x", labelnames=("a",))

    def test_render_parse_roundtrip(self):
        reg = MetricsRegistry()
        reg.counter("repro_t_events_total", "evts",
                    labelnames=("kind",)).inc(3, kind='a"b\\c')
        reg.gauge("repro_t_level_count", "lvl").set(1.5)
        h = reg.histogram("repro_t_bpe", "bpe", buckets=BPE_BUCKETS)
        h.observe(2.0)
        fams = parse_prometheus_text(reg.render())
        assert fams["repro_t_events_total"]["type"] == "counter"
        assert fams["repro_t_level_count"]["type"] == "gauge"
        assert fams["repro_t_bpe"]["type"] == "histogram"
        # cumulative buckets + +Inf
        buckets = [(k, v) for (k, labels), v
                   in fams["repro_t_bpe"]["samples"].items()
                   if k == "repro_t_bpe_bucket"]
        assert len(buckets) == len(BPE_BUCKETS) + 1
        assert all(v <= 1.0 for _, v in buckets)

    def test_quantile(self):
        reg = MetricsRegistry()
        h = reg.histogram("repro_t_q_seconds", "q", buckets=LATENCY_BUCKETS)
        for _ in range(99):
            h.observe(0.002)
        h.observe(9.0)
        assert h.quantile(0.5) <= 0.01
        assert h.quantile(0.995) > 1.0


class TestTracing:
    def test_disabled_is_shared_noop(self):
        configure_tracing(enabled=False)
        assert span("anything") is _NULL_SPAN
        assert span("other", k=1) is _NULL_SPAN

    def test_events_nest_and_feed_histogram(self):
        configure_tracing(enabled=True)
        try:
            tracer().reset()
            with span("tick_drain", sessions=2):
                with span("entropy_decode", chunks=3):
                    time.sleep(0.001)
            events = tracer().snapshot_events()
        finally:
            configure_tracing(enabled=False)
        assert [e["stage"] for e in events] == ["entropy_decode",
                                                "tick_drain"]
        child, parent = events
        assert child["parent_id"] == parent["span_id"]
        assert parent["parent_id"] is None
        assert child["chunks"] == 3
        assert child["dur_s"] > 0
        totals = tracer().stage_totals(stages={"entropy_decode"})
        assert totals["entropy_decode"] >= child["dur_s"]
        from repro.obs import default_registry
        hist = default_registry().get(
            "repro_pipeline_stage_latency_seconds")
        assert hist.count(stage="entropy_decode") >= 1

    def test_error_annotation_and_dump(self, tmp_path):
        configure_tracing(enabled=True)
        try:
            tracer().reset()
            with pytest.raises(RuntimeError):
                with span("tail"):
                    raise RuntimeError("boom")
            path = tmp_path / "events.json"
            n = tracer().dump_events(str(path))
        finally:
            configure_tracing(enabled=False)
        assert n == 1
        events = json.loads(path.read_text())["events"]
        assert events[0]["error"] == "RuntimeError"


class TestExposition:
    def test_scrape_routes(self):
        reg = MetricsRegistry()
        reg.counter("repro_t_hits_total", "hits").inc(2)
        pulled = []

        async def run():
            exp = MetricsExposition([reg],
                                    collectors=[lambda: pulled.append(1)])
            await exp.start()
            url = f"http://127.0.0.1:{exp.port}"
            try:
                def get(path):
                    with urllib.request.urlopen(url + path,
                                                timeout=5) as r:
                        return r.status, r.read().decode()
                out = {p: await asyncio.to_thread(get, p)
                       for p in ("/metrics", "/events", "/healthz")}
                with pytest.raises(urllib.error.HTTPError):
                    await asyncio.to_thread(get, "/nope")
            finally:
                await exp.close()
            return out

        out = asyncio.run(run())
        fams = parse_prometheus_text(out["/metrics"][1])
        assert fams["repro_t_hits_total"]["samples"][
            ("repro_t_hits_total", frozenset())] == 2.0
        assert pulled                       # collector ran before render
        assert "events" in json.loads(out["/events"][1])
        assert out["/healthz"] == (200, "ok\n")


class TestServerTelemetry:
    def test_metrics_port_scrape_and_ft_metrics(self, features):
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.01)

        async def run():
            async with CloudServer(echo_features=True, tick=tick,
                                   metrics_port=0) as srv:
                async with EdgeClient("127.0.0.1", srv.port,
                                      codec=codec) as client:
                    await client.submit(features)
                    snap = await client.fetch_cloud_metrics()
                url = f"http://127.0.0.1:{srv.metrics_port}/metrics"
                text = await asyncio.to_thread(
                    lambda: urllib.request.urlopen(url, timeout=5)
                    .read().decode())
            return snap, text

        snap, text = asyncio.run(run())
        assert snap["counters"]["sessions_served"] == 1
        assert _series(snap["metrics"],
                       "repro_server_ticks_total")["value"] >= 1
        fams = parse_prometheus_text(text)
        for name in ("repro_server_sessions_served_total",
                     "repro_server_ticks_total",
                     "repro_server_coded_bytes_total",
                     "repro_server_measured_bpe",
                     "repro_server_header_cache_hits_count",
                     "repro_decode_entropy_calls_total",
                     "repro_bank_cache_hits_total"):
            assert name in fams, name
        served = fams["repro_server_sessions_served_total"]["samples"]
        assert served[("repro_server_sessions_served_total",
                       frozenset())] == 1.0

    def test_legacy_tick_none_registry_counts_errors(self, features):
        codec = _live_codec(features)

        async def run():
            async with CloudServer(echo_features=True, tick=None) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    await client.submit(features)
                # a second connection sends garbage: CHUNK before HEADER
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", srv.port)
                from repro.transport.framing import FT_CHUNK
                writer.write(encode_frame(FT_CHUNK, 9, 0, b"\x00\x01"))
                await writer.drain()
                await reader.read()         # server replies ERROR+closes
                writer.close()
                for _ in range(50):         # until the close is observed
                    if srv.open_connections == 0:
                        break
                    await asyncio.sleep(0.05)
                return srv.counters, srv.metrics.snapshot()

        counters, snap = asyncio.run(run())
        # the legacy dict shape is pinned: registry-only telemetry must
        # not leak new keys into it
        assert set(counters) == {"sessions_served", "open_connections"}
        assert counters["sessions_served"] == 1
        assert _series(snap,
                       "repro_server_sessions_served_total")["value"] == 1
        assert _series(snap,
                       "repro_server_decode_errors_total")["value"] == 1

    def test_eviction_clears_per_session_series(self, features):
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.05, max_chunks=1 << 30)

        async def run():
            async with CloudServer(echo_features=True, tick=tick) as srv:
                # half a stream, then vanish mid-tick
                frames = list(tensor_to_frames(codec, features, session=0,
                                               chunk_elems=600))
                _, writer = await asyncio.open_connection(
                    "127.0.0.1", srv.port)
                for fb in frames[:max(2, len(frames) // 2)]:
                    writer.write(fb)
                await writer.drain()
                await asyncio.sleep(0.01)
                pending_mid = len(srv.metrics.get(
                    "repro_server_session_pending_chunks_count").series())
                writer.close()
                await writer.wait_closed()
                # a healthy session completes alongside
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    await client.submit(0.5 * features)
                await asyncio.sleep(0.2)
                srv._sync_gauges()
                return pending_mid, srv.metrics.snapshot()

        pending_mid, snap = asyncio.run(run())
        assert pending_mid == 1             # tracked while in flight
        # disconnect + completion both drop their label series: nothing
        # leaks across sessions
        assert snap["repro_server_session_pending_chunks_count"][
            "series"] == []
        assert _series(snap,
                       "repro_server_queue_depth_count")["value"] == 0
        assert _series(snap,
                       "repro_server_sessions_served_total")["value"] == 1

    def test_kill_paths_clear_per_session_series(self, features):
        """PR-9 kill paths: a mid-stream disconnect of a *resumable*
        (token'd) connection parks its sessions -- and the parked TTL
        expiry must release the pending-chunks series and the inflight
        accounting exactly like a plain disconnect does."""
        import json

        from repro.transport.framing import FT_HELLO
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.05, max_chunks=1 << 30)

        async def run():
            async with CloudServer(echo_features=True, tick=tick,
                                   resume_ttl_s=0.1) as srv:
                frames = list(tensor_to_frames(codec, features, session=1,
                                               chunk_elems=600))
                _, writer = await asyncio.open_connection(
                    "127.0.0.1", srv.port)
                writer.write(encode_frame(
                    FT_HELLO, 0, 0,
                    json.dumps({"token": "obs-kill"}).encode()))
                for fb in frames[:max(2, len(frames) // 2)]:
                    writer.write(fb)
                await writer.drain()
                await asyncio.sleep(0.02)
                pending_mid = len(srv.metrics.get(
                    "repro_server_session_pending_chunks_count").series())
                writer.close()                 # vanish mid-tick
                await writer.wait_closed()
                await asyncio.sleep(0.05)
                srv._sync_gauges()
                parked_mid = srv.metrics.get(
                    "repro_server_parked_sessions_count").value()
                await asyncio.sleep(0.25)      # resume TTL expires
                srv._sync_gauges()
                return pending_mid, parked_mid, srv.metrics.snapshot(), \
                    srv.load

        pending_mid, parked_mid, snap, load = asyncio.run(run())
        assert pending_mid == 1
        assert parked_mid == 1
        assert snap["repro_server_session_pending_chunks_count"][
            "series"] == []
        assert _series(snap,
                       "repro_server_parked_sessions_count")["value"] == 0
        assert _series(snap,
                       "repro_server_queue_depth_count")["value"] == 0
        assert load == 0

    def test_ft_metrics_frame_raw(self, features):
        # protocol level: an empty METRICS frame gets a JSON METRICS
        # frame back, no client machinery required
        codec = _live_codec(features)

        async def run():
            async with CloudServer(echo_features=True, tick=None) as srv:
                reader, writer = await asyncio.open_connection(
                    "127.0.0.1", srv.port)
                writer.write(encode_frame(FT_METRICS, 0, 0, b""))
                await writer.drain()
                from repro.transport import FrameReader
                frames = FrameReader()
                while True:
                    data = await asyncio.wait_for(reader.read(1 << 16),
                                                  timeout=10)
                    frames.feed(data)
                    for frame in frames:
                        writer.close()
                        return frame

        frame = asyncio.run(run())
        assert frame.ftype == FT_METRICS
        snap = json.loads(frame.payload.decode())
        assert "counters" in snap and "metrics" in snap


class TestClientTelemetry:
    def test_encode_counters_backed_by_registry(self, features):
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.01, max_batch=8)

        async def run():
            async with CloudServer(echo_features=True) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600,
                                      tick=tick) as client:
                    await asyncio.gather(*[
                        client.submit(t)
                        for t in (features, 0.5 * features)])
                    return dict(client.encode_counters), \
                        client.metrics.snapshot()

        counters, snap = asyncio.run(run())
        assert set(counters) == {"ticks", "sessions", "stacked_sessions",
                                 "fused_launches", "entropy_calls",
                                 "elems", "coded_bytes", "encode_s"}
        assert counters["sessions"] == 2
        assert _series(snap, "repro_client_sessions_total")["value"] == 2
        assert _series(snap,
                       "repro_client_submit_latency_seconds")["count"] == 2


class TestEngineTelemetry:
    def test_latency_ring_and_percentiles(self):
        import jax

        from repro.configs import ARCHS, reduced
        from repro.models import init_params
        from repro.serving import Request, ServeEngine
        cfg = dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                                  vocab_size=128, d_model=32, d_ff=64,
                                  num_heads=2, num_kv_heads=2, head_dim=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, slots=2, max_seq=64,
                          latency_log_size=3)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 128, size=4)
                        .astype(np.int32), max_new_tokens=2)
                for _ in range(5)]
        eng.generate(reqs)
        assert all(r.done for r in reqs)
        # ring buffer: bounded at latency_log_size, not len(reqs)
        assert len(eng.latency_log) == 3
        c = eng.counters
        assert c["requests_done"] == 5
        assert c["request_latency_p99_s"] >= c["request_latency_p50_s"] > 0
        snap = eng.metrics.snapshot()
        assert _series(snap, "repro_engine_requests_total")["value"] == 5
        assert _series(
            snap, "repro_engine_request_latency_seconds")["count"] == 5
