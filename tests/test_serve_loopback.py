"""Loopback serving regression: the split-boundary socket round-trip must
complete on a single-CPU host.

The seed's ``--transport loopback`` hookup ran the round-trip inside the
jitted step via an ordered ``io_callback``; the client's own jax encode
then deadlocked on 1-CPU hosts (the callback holds XLA's only dispatch
thread while the nested encode waits for it).  The engine now splits each
stage into two jitted halves at the boundary (``codec_host_fn``) and runs
the round-trip eagerly in between -- these tests pin both the numerics of
the split halves and, via a subprocess wall-clock timeout, the absence of
the deadlock itself.
"""

import dataclasses
import subprocess
import sys

import jax
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import init_params


@pytest.fixture()
def tiny_cfg():
    return dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                               vocab_size=128, d_model=32, d_ff=64,
                               num_heads=2, num_kv_heads=2, head_dim=16)


class TestSplitHalves:
    def test_host_fn_engine_matches_inline_codec_fn(self, tiny_cfg):
        """The two-jitted-halves engine (codec_host_fn) generates the
        same tokens as the single-program engine with an equivalent
        in-graph codec_fn."""
        from repro.core import CodecConfig, calibrate
        from repro.serving import Request, ServeEngine

        codec = calibrate(CodecConfig(n_levels=8, clip_mode="manual",
                                      manual_cmin=-6.0, manual_cmax=6.0))
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))

        def host_fn(x):
            # host fake-quant round-trip: same reconstruction the
            # in-graph codec_fn computes
            return np.asarray(codec.apply(x), np.float32), 1.0

        def mk_reqs():
            rng = np.random.default_rng(0)
            return [Request(prompt=rng.integers(0, 128, 5).astype(np.int32),
                            max_new_tokens=m) for m in (3, 2, 4)]

        eng_a = ServeEngine(tiny_cfg, params, slots=2, max_seq=64,
                            codec_fn=lambda x: (codec.apply(x), 1.0))
        eng_b = ServeEngine(tiny_cfg, params, slots=2, max_seq=64,
                            codec_host_fn=host_fn)
        out_a = eng_a.generate(mk_reqs())
        out_b = eng_b.generate(mk_reqs())
        for ra, rb in zip(out_a, out_b):
            assert ra.out_tokens == rb.out_tokens
        assert len(eng_b.rate_log) > 0

    def test_host_fn_refill_path(self, tiny_cfg):
        """Mid-epoch refills go through the split prefill halves too."""
        from repro.serving import Request, ServeEngine

        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=2, max_seq=64,
                          codec_host_fn=lambda x: (x, 0.5))
        rng = np.random.default_rng(1)
        reqs = [Request(prompt=rng.integers(0, 128, 5).astype(np.int32),
                        max_new_tokens=m) for m in (2, 7, 3, 1)]
        eng.generate(reqs)
        for r in reqs:
            assert r.done and len(r.out_tokens) == r.max_new_tokens
        assert eng.counters["refills"] >= 1


_LOOPBACK_SCRIPT = """
import dataclasses
import jax
import numpy as np
from repro.configs import ARCHS, reduced
from repro.core import CodecConfig, calibrate
from repro.launch.serve import _loopback_codec_fn
from repro.models import init_params
from repro.serving import Request, ServeEngine

cfg = dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                          vocab_size=128, d_model=32, d_ff=64,
                          num_heads=2, num_kv_heads=2, head_dim=16)
params = init_params(cfg, jax.random.PRNGKey(0))
codec = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                              manual_cmin=-6.0, manual_cmax=6.0))
host_fn, cleanup = _loopback_codec_fn(codec, chunk_elems=1 << 12)
eng = ServeEngine(cfg, params, slots=2, max_seq=32,
                  codec_host_fn=host_fn)
rng = np.random.default_rng(0)
reqs = [Request(prompt=rng.integers(0, 128, 6).astype(np.int32),
                max_new_tokens=3) for _ in range(2)]
eng.generate(reqs)
assert all(r.done and len(r.out_tokens) == 3 for r in reqs)
assert len(eng.rate_log) > 0 and all(r > 0 for r in eng.rate_log)
cleanup()
print("LOOPBACK_OK")
"""


class TestLoopbackNoDeadlock:
    def test_loopback_roundtrip_completes_on_one_cpu(self):
        """Full socket loopback under a hard wall-clock budget, pinned to
        one CPU: every boundary tensor streams through the framed client/
        server stack (the client runs its own jax encode) and the run
        must finish -- the seed hookup deadlocked here indefinitely."""
        env = {"JAX_PLATFORMS": "cpu",
               "XLA_FLAGS": "--xla_force_host_platform_device_count=1"}
        import os
        env = {**os.environ, **env}
        proc = subprocess.run(
            [sys.executable, "-c", _LOOPBACK_SCRIPT],
            capture_output=True, text=True, timeout=300, env=env)
        assert proc.returncode == 0, proc.stderr[-2000:]
        assert "LOOPBACK_OK" in proc.stdout
