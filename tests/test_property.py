"""System-level property tests (hypothesis) for codec invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import binarization, cabac, uniform
from repro.core.rate_model import estimated_bits_np


@settings(max_examples=30, deadline=None)
@given(data=st.lists(st.integers(0, 7), min_size=0, max_size=800),
       n_levels=st.integers(2, 8))
def test_cabac_roundtrip_any_sequence(data, n_levels):
    idx = np.asarray([d % n_levels for d in data], dtype=np.int32)
    blob = cabac.encode_indices(idx, n_levels)
    back = cabac.decode_indices(blob, idx.size, n_levels)
    assert (back == idx).all()


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**16), n_levels=st.integers(2, 16),
       cmax=st.floats(0.5, 30.0))
def test_quantizer_error_bounded_by_half_bin(seed, n_levels, cmax):
    """Inside the clip range, |x - deq(q(x))| <= delta/2 (pinned bins)."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0.0, cmax, size=500)
    q = uniform.quantize_np(x, 0.0, cmax, n_levels)
    deq = uniform.dequantize_np(q, 0.0, cmax, n_levels)
    delta = cmax / (n_levels - 1)
    assert np.max(np.abs(x - deq)) <= delta / 2 + 1e-9


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16))
def test_rate_monotone_in_levels(seed):
    """More quantizer levels never decreases the entropy-coded rate."""
    rng = np.random.default_rng(seed)
    x = rng.gamma(2.0, 2.0, size=20_000)
    rates = []
    for n in (2, 3, 4, 6, 8):
        idx = uniform.quantize_np(x, 0.0, 10.0, n)
        rates.append(estimated_bits_np(idx, n) / idx.size)
    assert all(a <= b + 1e-6 for a, b in zip(rates, rates[1:]))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n_levels=st.integers(2, 8))
def test_tu_bits_upper_bound_entropy_estimate(seed, n_levels):
    """Entropy-coded estimate never exceeds raw TU bits."""
    rng = np.random.default_rng(seed)
    idx = rng.integers(0, n_levels, size=5000).astype(np.int32)
    est = estimated_bits_np(idx, n_levels)
    raw = binarization.total_tu_bits(idx, n_levels)
    assert est <= raw + 1e-6


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**16), n_levels=st.integers(2, 8),
       skew=st.floats(0.05, 0.95))
def test_cabac_beats_or_matches_fixed_width(seed, n_levels, skew):
    """Compressed size is below ceil(log2 N) fixed-width for skewed data
    (plus bounded coder overhead for tiny payloads)."""
    rng = np.random.default_rng(seed)
    p = np.full(n_levels, (1 - skew) / max(n_levels - 1, 1))
    p[0] = skew
    idx = rng.choice(n_levels, size=8000, p=p).astype(np.int32)
    blob = cabac.encode_indices(idx, n_levels)
    fixed_bits = idx.size * int(np.ceil(np.log2(n_levels)))
    assert len(blob) * 8 <= fixed_bits + 512
