"""Multi-device tests (subprocess: the main test process owns 1 CPU device).

Exercises on an 8-device (pod=2, data=2, model=2) host mesh:
  * the split runtime: edge/cloud pod split with packed uint8 transport —
    logits must match the unsplit model (up to codec quantization);
  * expert-parallel MoE (shard_map all_to_all path) vs the local oracle.
"""

import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp

    from repro.configs import ARCHS, reduced
    from repro.core import CodecConfig, calibrate
    from repro.models import init_params, init_cache, decode_step
    from repro.models.context import DistContext
    from repro.compression import split_runtime as SR

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    cfg = dataclasses.replace(
        reduced(ARCHS["codeqwen1.5-7b"]), num_layers=4, vocab_size=64)
    params = init_params(cfg, jax.random.PRNGKey(0))

    # ---- reference: unsplit decode on one device ----
    cache = init_cache(cfg, batch=4, max_seq=16)
    tok = jnp.arange(4, dtype=jnp.int32)
    ref_logits, _, _ = decode_step(cfg, params, tok, cache, jnp.int32(0))

    # ---- split runtime across the pod axis ----
    sp = SR.init_split_params(cfg, jax.random.PRNGKey(0))
    codec = calibrate(CodecConfig(n_levels=256, clip_mode="manual",
                                  manual_cmin=-8.0, manual_cmax=8.0))
    step = SR.make_split_decode_step(cfg, mesh, codec, transport="packed")
    caches = SR.init_split_cache(cfg, batch=4, max_seq=16)
    logits, caches, rate = jax.jit(step)(sp, tok, caches, jnp.int32(0))
    err = float(jnp.max(jnp.abs(logits - ref_logits)))
    print("SPLIT_MAX_ERR", err)
    assert err < 0.2, f"split logits diverged: {err}"

    # ---- EP MoE vs local oracle ----
    from repro.models import moe as MOE
    mcfg = dataclasses.replace(reduced(ARCHS["qwen3-moe-235b-a22b"]),
                               num_experts=8, experts_per_token=2)
    mp = MOE.init_moe(jax.random.PRNGKey(1), mcfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 8, mcfg.d_model))
    ctx = DistContext(mesh, ("pod", "data"))
    ep = MOE.moe_apply(x, mp, mcfg, ctx)
    local = MOE.moe_local(x.reshape(32, -1), mp, mcfg,
                          cap=64).reshape(x.shape)
    d = float(jnp.max(jnp.abs(ep - local)))
    print("MOE_EP_MAX_ERR", d)
    assert d < 0.05, f"EP MoE diverged from oracle: {d}"
    print("DISTRIBUTED_OK")
""")


@pytest.mark.timeout(600)
def test_split_runtime_and_ep_moe_multidevice():
    import os
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
           # force the host platform: without this, containers that ship
           # libtpu spend 60s+ probing for TPU metadata before falling back
           "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}
    res = subprocess.run([sys.executable, "-c", _SCRIPT],
                         capture_output=True, text=True, timeout=600,
                         env=env, cwd="/root/repo")
    assert "DISTRIBUTED_OK" in res.stdout, \
        f"stdout:\n{res.stdout[-2000:]}\nstderr:\n{res.stderr[-3000:]}"
