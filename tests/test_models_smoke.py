"""Per-architecture smoke tests: reduced configs, one forward/train step on
CPU, asserting output shapes and finiteness.  Full configs are exercised
only via the dry-run (eval_shape, no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, reduced
from repro.models import forward, init_params, loss_fn

ARCH_NAMES = sorted(ARCHS)


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


def _make_inputs(cfg, batch=2, seq=32):
    key = jax.random.PRNGKey(1)
    tokens = jax.random.randint(key, (batch, seq), 0, cfg.vocab_size)
    if cfg.input_mode == "embeddings":
        emb = jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
        return tokens, emb
    return tokens, None


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name, rng):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, rng)
    tokens, emb = _make_inputs(cfg)
    logits, aux = forward(cfg, params, emb if emb is not None else tokens)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name} produced non-finite logits"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_train_step_no_nans(name, rng):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, rng)
    tokens, emb = _make_inputs(cfg)

    def loss(p):
        l, _ = loss_fn(cfg, p, tokens, inputs=emb, remat=False)
        return l

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    # a sane CE magnitude for random init: close to log(vocab)
    assert 0.2 * np.log(cfg.vocab_size) < float(val) < 3 * np.log(cfg.vocab_size)
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.isfinite(g).all()) for g in leaves)
    assert any(float(jnp.abs(g).max()) > 0 for g in leaves)


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_count_formula_matches_init(name, rng):
    cfg = reduced(ARCHS[name])
    params = init_params(cfg, rng)
    actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    assert actual == cfg.param_count(), \
        f"{name}: param_count()={cfg.param_count()} vs actual {actual}"


def test_full_config_param_counts_sane():
    """Full-size param counts should be in the ballpark the names imply."""
    expect = {"dbrx-132b": (110e9, 150e9), "deepseek-67b": (60e9, 72e9),
              "qwen3-moe-235b-a22b": (200e9, 260e9), "gemma2-9b": (8e9, 12e9),
              "gemma3-1b": (0.7e9, 1.6e9), "codeqwen1.5-7b": (6e9, 9e9),
              "rwkv6-3b": (2e9, 4.5e9), "recurrentgemma-2b": (2e9, 3.6e9),
              "qwen2-vl-2b": (1.2e9, 2.4e9), "musicgen-large": (1.5e9, 2.6e9)}
    for name, (lo, hi) in expect.items():
        n = ARCHS[name].param_count()
        assert lo < n < hi, f"{name}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_less_than_total():
    cfg = ARCHS["qwen3-moe-235b-a22b"]
    assert cfg.active_param_count() < 0.2 * cfg.param_count()
