"""Regenerate the frozen stream-conformance vectors under tests/golden/.

    PYTHONPATH=src python tests/regen_golden.py [--check]

Writes, for every case in ``tests/golden_cases.py``:

    <name>.input.npy     the deterministic input tensor
    <name>.stream.bin    the encoded bitstream (or length-prefixed
                         payload sequence for streamed cases)
    <name>.decoded.npy   the bit-exact reconstruction of the stream

``--check`` regenerates in memory and reports diffs without writing --
the same comparison ``tests/test_stream_conformance.py`` gates in CI.

Only regenerate when a format change is *intentional*: a diff in an
existing ``.stream.bin`` means previously written streams no longer
decode (or re-encode differently), which is a wire-compatibility break
-- new formats must add a header version instead of mutating an old one.
"""

from __future__ import annotations

import pathlib
import sys

import numpy as np

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from golden_cases import CASES  # noqa: E402

GOLDEN_DIR = pathlib.Path(__file__).resolve().parent / "golden"


def generate(case):
    x = case.make_input()
    stream = case.encode(x)
    decoded = case.decode(stream, x)
    return x, stream, np.asarray(decoded, np.float32)


def main(argv) -> int:
    check = "--check" in argv
    GOLDEN_DIR.mkdir(exist_ok=True)
    n_diff = 0
    for case in CASES:
        x, stream, decoded = generate(case)
        paths = {
            "input": GOLDEN_DIR / f"{case.name}.input.npy",
            "stream": GOLDEN_DIR / f"{case.name}.stream.bin",
            "decoded": GOLDEN_DIR / f"{case.name}.decoded.npy",
        }
        if check:
            ok = (paths["stream"].exists()
                  and paths["stream"].read_bytes() == stream
                  and np.array_equal(np.load(paths["input"]), x)
                  and np.array_equal(np.load(paths["decoded"]), decoded))
            print(f"{case.name}: {'ok' if ok else 'DIFFERS'} "
                  f"({len(stream)} stream bytes)")
            n_diff += not ok
            continue
        np.save(paths["input"], x)
        paths["stream"].write_bytes(stream)
        np.save(paths["decoded"], decoded)
        print(f"wrote {case.name}: {x.size} elems -> "
              f"{len(stream)} stream bytes")
    return 1 if n_diff else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
