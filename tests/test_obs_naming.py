"""Instrument naming-convention gate.

Every metric any component registers must follow
``repro_<subsystem>_<name>_<unit>`` (lowercase underscore tokens, a
recognized unit suffix, counters ending ``_total``), so scrape names --
the public telemetry API -- stay stable across PRs.  The live checks
instantiate each instrumented component and validate every instrument it
actually registered; a renamed or malformed instrument fails here before
it ever reaches a dashboard.
"""

import dataclasses
import pathlib
import sys

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from repro.core import CodecConfig, calibrate
from repro.obs import configure_tracing, default_registry
from repro.obs.metrics import ALLOWED_UNITS, validate_name


class TestValidateName:
    @pytest.mark.parametrize("name,kind", [
        ("repro_server_ticks_total", "counter"),
        ("repro_client_coded_bytes_total", "counter"),
        ("repro_engine_request_latency_seconds", "histogram"),
        ("repro_rate_target_bpe", "gauge"),
        ("repro_server_queue_depth_count", "gauge"),
        ("repro_pipeline_stage_latency_seconds", "histogram"),
    ])
    def test_accepts(self, name, kind):
        validate_name(name, kind)

    @pytest.mark.parametrize("name,kind", [
        ("server_ticks_total", "counter"),          # missing prefix
        ("repro_Server_ticks_total", "counter"),    # uppercase
        ("repro_ticks", "counter"),                 # too few tokens
        ("repro_server_speed_furlongs", "gauge"),   # unknown unit
        ("repro_server_ticks_count", "counter"),    # counter not _total
        ("repro_server_depth_total", "gauge"),      # _total on non-counter
        ("repro_server__ticks_total", "counter"),   # empty token
    ])
    def test_rejects(self, name, kind):
        with pytest.raises(ValueError):
            validate_name(name, kind)

    def test_units_are_closed_set(self):
        # adding a unit is an API decision: update this list consciously
        assert ALLOWED_UNITS == {"total", "seconds", "bytes", "bits",
                                 "elements", "chunks", "count", "bpe",
                                 "ratio", "info"}


def _assert_conforms(registry, expect_prefixes):
    instruments = registry.instruments()
    assert instruments, "component registered no instruments"
    for inst in instruments:
        validate_name(inst.name, inst.kind)     # raises on violation
        for ln in inst.labelnames:
            assert ln.islower(), (inst.name, ln)
    names = {i.name for i in instruments}
    for prefix in expect_prefixes:
        assert any(n.startswith(prefix) for n in names), \
            f"no {prefix}* instrument in {sorted(names)}"


class TestLiveInstruments:
    def test_server_and_batcher(self):
        from repro.transport import CloudServer
        srv = CloudServer()
        _assert_conforms(srv.metrics, ["repro_server_", "repro_decode_"])

    def test_client_and_rate_controller(self):
        from repro.transport import (CodecBank, RateControlConfig,
                                     RateController)
        from repro.transport.client import EdgeClient
        rng = np.random.default_rng(0)
        samples = rng.exponential(1.0, 4096).astype(np.float32)
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False), samples)
        rc = RateController(RateControlConfig(target_bpe=2.0))
        client = EdgeClient("127.0.0.1", 1, codec_bank=bank,
                            rate_controller=rc)
        _assert_conforms(client.metrics, ["repro_client_", "repro_rate_"])

    def test_engine(self):
        import jax

        from repro.configs import ARCHS, reduced
        from repro.models import init_params
        from repro.serving import ServeEngine
        cfg = dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                                  vocab_size=128, d_model=32, d_ff=64,
                                  num_heads=2, num_kv_heads=2, head_dim=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(cfg, params, slots=2, max_seq=64)
        _assert_conforms(eng.metrics, ["repro_engine_"])

    def test_default_registry(self):
        # importing rate_control registers the bank-cache instruments;
        # enabling tracing registers the stage-latency histogram
        import repro.transport.rate_control  # noqa: F401
        configure_tracing(enabled=True)
        configure_tracing(enabled=False)
        _assert_conforms(default_registry(),
                         ["repro_bank_cache_", "repro_pipeline_"])
