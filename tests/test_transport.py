"""Streaming transport tests: chunked codec round trips, wire framing
(torn delivery included), sharded rANS, rate control, and the asyncio
edge<->cloud session layer."""

import asyncio
import struct

import numpy as np
import pytest

from repro.core import CodecConfig, calibrate
from repro.core import cabac, rans
from repro.transport import (CloudServer, CodecBank, EdgeClient, Frame,
                             FrameReader, FramingError, RateControlConfig,
                             RateController, TensorAssembler, encode_frame,
                             framing, pack_arrays, tensor_to_frames,
                             unpack_arrays)


@pytest.fixture(scope="module")
def features():
    rng = np.random.default_rng(0)
    mu = np.linspace(0.0, 6.0, 16).astype(np.float32)
    return (mu[None, :] + rng.exponential(1.0, (512, 16))).astype(np.float32)


def _codec(features, granularity="tensor", n_levels=4, **kw):
    cfg = CodecConfig(n_levels=n_levels, clip_mode="minmax",
                      constrain_cmin_zero=False, granularity=granularity,
                      channel_axis=-1, channel_group_size=4, **kw)
    return calibrate(cfg, samples=features)


class TestChunkedStream:
    @pytest.mark.parametrize("granularity", ["tensor", "channel"])
    @pytest.mark.parametrize("mode", ["serial", "rans", "rans_sharded"])
    def test_bit_exact_with_one_shot(self, features, granularity, mode):
        codec = _codec(features, granularity)
        one = codec.decode(codec.encode(features, coder_mode=mode),
                           shape=features.shape)
        st = codec.decode_stream(
            codec.encode_stream(features, chunk_elems=777, coder_mode=mode))
        assert st.shape == features.shape
        np.testing.assert_array_equal(st, one)

    def test_bit_exact_ecsq(self, features):
        codec = calibrate(CodecConfig(n_levels=4, use_ecsq=True,
                                      clip_mode="minmax",
                                      constrain_cmin_zero=False),
                          samples=features)
        one = codec.decode(codec.encode(features), shape=features.shape)
        st = codec.decode_stream(codec.encode_stream(features,
                                                     chunk_elems=500))
        np.testing.assert_array_equal(st, one)

    def test_single_chunk_and_odd_sizes(self, features):
        codec = _codec(features)
        for chunk in (1, 13, features.size, 10 * features.size):
            st = codec.decode_stream(
                codec.encode_stream(features, chunk_elems=chunk))
            np.testing.assert_array_equal(
                st, codec.decode(codec.encode(features),
                                 shape=features.shape))

    def test_out_of_order_chunks(self, features):
        from repro.core import ChunkStreamDecoder
        codec = _codec(features)
        payloads = list(codec.encode_stream(features, chunk_elems=1000))
        dec = ChunkStreamDecoder(payloads[0])
        for p in reversed(payloads[1:]):
            dec.add_chunk(p)
        np.testing.assert_array_equal(
            dec.finish(), codec.decode(codec.encode(features),
                                       shape=features.shape))

    def test_incomplete_and_duplicate_chunks(self, features):
        from repro.core import ChunkStreamDecoder
        codec = _codec(features)
        payloads = list(codec.encode_stream(features, chunk_elems=1000))
        dec = ChunkStreamDecoder(payloads[0])
        dec.add_chunk(payloads[1])
        with pytest.raises(ValueError, match="incomplete"):
            dec.finish()
        with pytest.raises(ValueError, match="duplicate"):
            dec.add_chunk(payloads[1])


class TestFraming:
    def test_roundtrip_and_torn_delivery(self, features):
        codec = _codec(features)
        wire = b"".join(tensor_to_frames(codec, features, session=3,
                                         chunk_elems=900))
        ref = codec.decode(codec.encode(features), shape=features.shape)
        # byte-at-a-time delivery
        reader = FrameReader()
        asm = TensorAssembler()
        out = None
        for i in range(len(wire)):
            reader.feed(wire[i:i + 1])
            for frame in reader:
                assert frame.session == 3
                r = asm.feed(frame)
                if r is not None:
                    out = r
        assert out is not None and reader.pending_bytes == 0
        np.testing.assert_array_equal(out, ref)

    def test_interleaved_sessions(self, features):
        codec = _codec(features)
        a = list(tensor_to_frames(codec, features, session=1,
                                  chunk_elems=1500))
        b = list(tensor_to_frames(codec, 2.0 * features, session=2,
                                  chunk_elems=700))
        wire = bytearray()
        for i in range(max(len(a), len(b))):  # interleave frame-wise
            if i < len(a):
                wire += a[i]
            if i < len(b):
                wire += b[i]
        reader = FrameReader()
        reader.feed(bytes(wire))
        asms = {1: TensorAssembler(), 2: TensorAssembler()}
        outs = {}
        for frame in reader:
            r = asms[frame.session].feed(frame)
            if r is not None:
                outs[frame.session] = r
        np.testing.assert_array_equal(
            outs[1], codec.decode(codec.encode(features),
                                  shape=features.shape))
        np.testing.assert_array_equal(
            outs[2], codec.decode(codec.encode(2.0 * features),
                                  shape=features.shape))

    def test_crc_corruption_detected(self):
        frame = encode_frame(framing.FT_CHUNK, 0, 0, b"payload-bytes")
        corrupted = bytearray(frame)
        corrupted[-3] ^= 0xFF  # flip a payload byte
        reader = FrameReader()
        reader.feed(bytes(corrupted))
        with pytest.raises(FramingError, match="CRC"):
            list(reader)

    def test_bad_magic_detected(self):
        reader = FrameReader()
        reader.feed(b"\x00" * 32)
        with pytest.raises(FramingError, match="magic"):
            list(reader)

    def test_pack_unpack_arrays(self):
        arrays = [np.arange(6, dtype=np.float32).reshape(2, 3),
                  np.arange(4, dtype=np.int32),
                  np.zeros((2, 2, 2), np.uint8)]
        back = unpack_arrays(pack_arrays(arrays))
        assert len(back) == 3
        for a, b in zip(arrays, back):
            assert a.dtype == b.dtype and a.shape == b.shape
            np.testing.assert_array_equal(a, b)


class TestShardedRans:
    @pytest.mark.parametrize("n", [0, 1, 7, 4096, 100_001])
    def test_round_trip(self, n):
        rng = np.random.default_rng(n)
        idx = rng.integers(0, 4, n).astype(np.int32)
        blob = cabac.encode_indices(idx, 4, mode="rans_sharded")
        np.testing.assert_array_equal(
            cabac.decode_indices(blob, n, 4), idx)

    def test_thread_override(self, monkeypatch):
        rng = np.random.default_rng(0)
        idx = rng.integers(0, 8, 50_000).astype(np.int32)
        monkeypatch.setenv("REPRO_RANS_THREADS", "3")
        assert rans.rans_threads() == 3
        blob3 = cabac.encode_indices(idx, 8, mode="rans_sharded")
        # streams decode under any thread configuration
        monkeypatch.setenv("REPRO_RANS_THREADS", "1")
        np.testing.assert_array_equal(
            cabac.decode_indices(blob3, idx.size, 8), idx)
        blob1 = cabac.encode_indices(idx, 8, mode="rans_sharded")
        monkeypatch.setenv("REPRO_RANS_THREADS", "4")
        np.testing.assert_array_equal(
            cabac.decode_indices(blob1, idx.size, 8), idx)

    def test_auto_mode_selects_sharded_only_with_threads(self, monkeypatch):
        rng = np.random.default_rng(1)
        idx = rng.integers(0, 4, 2_000_000).astype(np.int32)
        # the proc pool outranks the thread pool in auto mode: pin this
        # test's coder choice regardless of the CI coder-matrix env
        monkeypatch.delenv("REPRO_RANS_PROCS", raising=False)
        monkeypatch.setenv("REPRO_RANS_THREADS", "1")
        assert cabac.encode_indices(idx, 4, mode="auto")[0] \
            == cabac._CODER_RANS
        monkeypatch.setenv("REPRO_RANS_THREADS", "2")
        blob = cabac.encode_indices(idx, 4, mode="auto")
        assert blob[0] == cabac._CODER_RANS_SHARDED
        np.testing.assert_array_equal(
            cabac.decode_indices(blob, idx.size, 4), idx)


class TestRateControl:
    def test_tracks_budget(self, features):
        rng = np.random.default_rng(2)
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False), features)
        rc = RateController(RateControlConfig(target_bpe=2.0))
        bits = elems = 0
        for _ in range(30):
            x = (rng.exponential(1.0, (256, 16))
                 + np.linspace(0, 6, 16)[None, :]).astype(np.float32)
            n = rc.next_levels()
            blob = bank.get(n).encode(x)
            rc.on_tensor(n, len(blob), x.size, send_seconds=0.01)
            bits += 8 * len(blob)
            elems += x.size
        assert abs(bits / elems - 2.0) <= 0.2  # within 10% of budget

    def test_backpressure_steps_down(self, features):
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                     constrain_cmin_zero=False), features)
        rc = RateController(RateControlConfig(target_bpe=3.0, queue_high=4))
        first = rc.next_levels()
        rc.on_tensor(first, 1000, 4000)
        rc.on_queue_depth(10)             # sustained pressure
        assert rc.next_levels() < first

    def test_bank_caches_and_validates(self, features):
        bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax"),
                         features, ladder=(2, 4))
        assert bank.get(4) is bank.get(4)
        with pytest.raises(KeyError):
            bank.get(7)

    def test_seed_estimate_replaced_by_first_measurement(self):
        rc = RateController(RateControlConfig(target_bpe=1.0,
                                              ladder=(2, 4, 8)))
        rc.seed_estimate(4, 0.5)
        assert rc.estimate_bpe(4) == 0.5
        rc.on_tensor(4, coded_bytes=25000, n_elems=100000)  # 2.0 bpe
        # the estimate is dropped outright, not EWMA-blended
        assert rc.estimate_bpe(4) == 2.0
        rc.on_tensor(4, coded_bytes=12500, n_elems=100000)  # 1.0 bpe
        assert rc.estimate_bpe(4) == pytest.approx(0.4 * 1.0 + 0.6 * 2.0)
        # seeding never overrides an existing measurement
        rc.seed_estimate(4, 9.9)
        assert rc.estimate_bpe(4) != 9.9

    def test_prime_controller_orders_mixed_ladder(self, features):
        from repro.transport.rate_control import Rung
        ladder = (2, 4, Rung(4, "channel"), 8)
        bank = CodecBank(CodecConfig(n_levels=4, clip_mode="minmax",
                                     constrain_cmin_zero=False,
                                     channel_axis=-1), features,
                         ladder=ladder)
        rc = RateController(RateControlConfig(target_bpe=1.0,
                                              ladder=ladder))
        bank.prime_controller(rc)
        # every rung carries an in-graph estimate before any coding,
        # and the per-channel rung estimates below per-tensor at equal N
        # on these channel-biased features
        est = {r: rc.estimate_bpe(r) for r in rc.ladder}
        assert all(v > 0 for v in est.values())
        assert est[Rung(4, "channel")] < est[Rung(4)]

    def test_tile_rate_bits_sums_to_estimate(self, features):
        import jax.numpy as jnp
        codec = _codec(features, "channel")
        tr = np.asarray(codec.tile_rate_bits(jnp.asarray(features)))
        assert tr.shape == (codec.plan.n_cgroups, codec.plan.n_sblocks)
        est = float(codec.estimate_rate(jnp.asarray(features)))
        assert tr.sum() / features.size == pytest.approx(est, rel=1e-4)


class TestAsyncTransport:
    def test_concurrent_sessions_bit_exact(self, features):
        codec = _codec(features, granularity="channel", n_levels=8)

        def tail(t):
            return [np.asarray(t, np.float32).sum(axis=-1)]

        async def run():
            async with CloudServer(tail_fn=tail, echo_features=True) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    tensors = [features, 0.5 * features, 2.0 * features]
                    return await asyncio.gather(
                        *[client.submit(t) for t in tensors]), srv

        results, srv = asyncio.run(run())
        assert srv.sessions_served == 3
        for t, res in zip([features, 0.5 * features, 2.0 * features],
                          results):
            ref = codec.decode(codec.encode(t), shape=t.shape)
            recon = np.asarray(res.arrays[0])
            assert recon.shape == t.shape
            np.testing.assert_array_equal(recon, ref)
            np.testing.assert_allclose(res.arrays[1], ref.sum(axis=-1),
                                       rtol=1e-5)
            assert res.bits_per_elem > 0
            assert res.feedback is not None
            assert res.feedback.recv_bytes_per_s > 0

    def test_rate_controlled_client(self, features):
        async def run():
            async with CloudServer(echo_features=True) as srv:
                bank = CodecBank(CodecConfig(n_levels=8, clip_mode="minmax",
                                             constrain_cmin_zero=False),
                                 features)
                rc = RateController(RateControlConfig(target_bpe=2.0))
                async with EdgeClient("127.0.0.1", srv.port,
                                      codec_bank=bank, rate_controller=rc,
                                      chunk_elems=2048) as client:
                    for _ in range(5):   # sequential: lets the bucket adapt
                        res = await client.submit(features)
                        c = bank.get(res.n_levels)
                        ref = c.decode(c.encode(features),
                                       shape=features.shape)
                        np.testing.assert_array_equal(
                            np.asarray(res.arrays[0]), ref)
                return rc

        rc = asyncio.run(run())
        assert len(rc.history) == 5
        assert abs(rc.measured_bpe - 2.0) <= 0.4


class TestModelSplitHelpers:
    def test_head_plus_tail_equals_forward(self):
        import dataclasses

        import jax
        import jax.numpy as jnp

        from repro.configs import ARCHS, reduced
        from repro.models import (forward, forward_from_boundary,
                                  forward_head, init_params)

        cfg = dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                                  vocab_size=64, d_model=32, d_ff=64,
                                  num_heads=2, num_kv_heads=2, head_dim=16)
        params = init_params(cfg, jax.random.PRNGKey(0))
        toks = (np.arange(24, dtype=np.int32).reshape(2, 12)) % 64
        ref, _ = forward(cfg, params, jnp.asarray(toks),
                         codec_fn=lambda x: (x, 0.0))
        head = forward_head(cfg, params, jnp.asarray(toks))
        tail = forward_from_boundary(cfg, params, head)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(tail),
                                   rtol=1e-5, atol=1e-5)
