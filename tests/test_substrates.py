"""Substrate tests: data pipeline, checkpoint/restore + failure injection,
trainer convergence, gradient compression, serving engine."""

import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compression import (GradCompressionConfig, compress_grads,
                               init_error_feedback)
from repro.configs import ARCHS, reduced
from repro.data import DataConfig, stream
from repro.models import init_params
from repro.train import Trainer, TrainerConfig, checkpoint as ckpt


@pytest.fixture()
def tiny_cfg():
    return dataclasses.replace(reduced(ARCHS["codeqwen1.5-7b"]),
                               vocab_size=128, d_model=32, d_ff=64,
                               num_heads=2, num_kv_heads=2, head_dim=16)


class TestData:
    def test_deterministic_replay(self):
        cfg = DataConfig(vocab_size=100, batch=4, seq_len=16)
        a = list(zip(range(5), stream(cfg)))
        b = list(zip(range(5), stream(cfg)))
        for (_, x), (_, y) in zip(a, b):
            np.testing.assert_array_equal(x["tokens"], y["tokens"])

    def test_resume_mid_stream(self):
        cfg = DataConfig(vocab_size=100, batch=2, seq_len=8)
        full = [b["tokens"] for _, b in zip(range(6), stream(cfg))]
        resumed = [b["tokens"] for _, b in zip(range(3), stream(cfg, 3))]
        for x, y in zip(full[3:], resumed):
            np.testing.assert_array_equal(x, y)

    def test_tokens_in_range(self):
        cfg = DataConfig(vocab_size=37, batch=2, seq_len=64)
        b = next(stream(cfg))
        assert b["tokens"].min() >= 0 and b["tokens"].max() < 37


class TestCheckpoint:
    def test_roundtrip(self, tmp_path, tiny_cfg):
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        ckpt.save(str(tmp_path), 7, {"params": params})
        assert ckpt.latest_step(str(tmp_path)) == 7
        restored = ckpt.restore(str(tmp_path), 7, {"params": params})
        for a, b in zip(jax.tree.leaves(params),
                        jax.tree.leaves(restored["params"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_gc_keeps_latest(self, tmp_path, tiny_cfg):
        params = {"w": jnp.ones((4,))}
        for s in (1, 2, 3, 4, 5):
            ckpt.save(str(tmp_path), s, params, keep=2)
        steps = sorted(os.listdir(tmp_path))
        assert steps == ["step_00000004", "step_00000005"]

    def test_atomic_no_tmp_left(self, tmp_path):
        ckpt.save(str(tmp_path), 1, {"w": jnp.ones((4,))})
        assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


class TestTrainer:
    def _mk(self, tiny_cfg, tmp_path, **kw):
        tcfg = TrainerConfig(steps=8, ckpt_every=4, ckpt_dir=str(tmp_path),
                             warmup_steps=2, **kw)
        dcfg = DataConfig(vocab_size=tiny_cfg.vocab_size, batch=2, seq_len=16)
        return Trainer(tiny_cfg, tcfg, dcfg)

    def test_loss_decreases(self, tiny_cfg, tmp_path):
        tr = self._mk(tiny_cfg, tmp_path)
        tr.run(resume=False)
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()

    def test_failure_injection_and_bitexact_resume(self, tiny_cfg, tmp_path):
        full = self._mk(tiny_cfg, tmp_path)
        state_full = full.run(resume=False)
        shutil.rmtree(tmp_path)
        crash = self._mk(tiny_cfg, tmp_path)
        crash.fail_at_step = 5  # after the step-4 checkpoint
        with pytest.raises(RuntimeError, match="injected failure"):
            crash.run(resume=False)
        assert ckpt.latest_step(str(tmp_path)) == 4
        resumed = self._mk(tiny_cfg, tmp_path)
        state_res = resumed.run(resume=True)  # restarts from step 4
        for a, b in zip(jax.tree.leaves(state_full["params"]),
                        jax.tree.leaves(state_res["params"])):
            np.testing.assert_allclose(np.asarray(a, np.float64),
                                       np.asarray(b, np.float64),
                                       rtol=1e-6, atol=1e-6)

    def test_grad_compression_training_still_converges(self, tiny_cfg, tmp_path):
        tr = self._mk(tiny_cfg, tmp_path,
                      grad_compression=GradCompressionConfig(n_levels=16))
        tr.run(resume=False)
        losses = [m["loss"] for m in tr.metrics_log]
        assert losses[-1] < losses[0]


class TestGradCompression:
    def test_error_feedback_preserves_mean_update(self):
        """EF: sum of compressed grads ~= sum of raw grads over time."""
        cfg = GradCompressionConfig(n_levels=4)
        rng = np.random.default_rng(0)
        g_raw = [{"w": jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
                 for _ in range(30)]
        ef = init_error_feedback(g_raw[0])
        total_c = jnp.zeros((64,))
        for g in g_raw:
            cg, ef, _ = compress_grads(cfg, g, ef)
            total_c = total_c + cg["w"]
        total_raw = sum(g["w"] for g in g_raw)
        resid = np.abs(np.asarray(total_c - total_raw)).max()
        per_step_q = float(np.asarray(ef["w"]).std()) + 1e-9
        # residual stays bounded by one step's quantization error, not O(T)
        assert resid < 10 * per_step_q

    def test_disabled_passthrough(self):
        cfg = GradCompressionConfig(enabled=False)
        g = {"w": jnp.arange(8.0)}
        ef = init_error_feedback(g)
        cg, _, _ = compress_grads(cfg, g, ef)
        np.testing.assert_array_equal(np.asarray(cg["w"]), np.asarray(g["w"]))

    def test_bf16_residual_accounts_for_cast(self):
        """EF invariant under low-precision grads: cg + new_e == gf.

        The compressed grad is cast to g.dtype before the reduction, so
        under bf16 the residual must be measured against the *cast*
        value -- otherwise the per-step cast rounding (up to ~2^-8
        relative) silently leaks out of the feedback loop.
        """
        cfg = GradCompressionConfig(n_levels=4)
        rng = np.random.default_rng(7)
        g = {"w": jnp.asarray(rng.normal(size=(256,)), jnp.bfloat16)}
        ef = init_error_feedback(g)
        # a couple of steps so the residual buffer is non-trivial
        for _ in range(3):
            gf = np.asarray(g["w"], np.float32) + np.asarray(ef["w"])
            cg, ef, _ = compress_grads(cfg, g, ef)
            assert cg["w"].dtype == jnp.bfloat16
            recon = (np.asarray(cg["w"], np.float32)
                     + np.asarray(ef["w"], np.float32))
            np.testing.assert_allclose(recon, gf, rtol=0, atol=1e-6)


class TestServing:
    def test_engine_generates(self, tiny_cfg):
        from repro.serving import Request, ServeEngine
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=2, max_seq=64)
        reqs = [Request(prompt=np.arange(5, dtype=np.int32) + i,
                        max_new_tokens=4) for i in range(3)]
        out = eng.generate(reqs)
        assert all(r.done and len(r.out_tokens) == 4 for r in out)
        assert all(0 <= t < tiny_cfg.vocab_size
                   for r in out for t in r.out_tokens)

    def test_engine_with_codec_logs_rate(self, tiny_cfg):
        from repro.core import CodecConfig, calibrate
        from repro.serving import Request, ServeEngine
        codec = calibrate(CodecConfig(n_levels=4, clip_mode="manual",
                                      manual_cmin=-6.0, manual_cmax=6.0))

        def codec_fn(x):
            return codec.apply(x), codec.estimate_rate(x)

        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=2, max_seq=64,
                          codec_fn=codec_fn)
        reqs = [Request(prompt=np.arange(4, dtype=np.int32), max_new_tokens=3)]
        eng.generate(reqs)
        assert len(eng.rate_log) > 0
        # entropy-coded TU bits/elem for N=4 is bounded by the max TU length
        assert all(0 <= r <= 3.0 for r in eng.rate_log)

    def test_slot_refill_staggered_lengths(self, tiny_cfg):
        """Short requests free their slot mid-epoch and queued requests
        are admitted without waiting for the longest request."""
        from repro.serving import Request, ServeEngine
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=2, max_seq=64)
        rng = np.random.default_rng(0)
        reqs = [Request(prompt=rng.integers(0, 128, 5).astype(np.int32),
                        max_new_tokens=m) for m in (2, 9, 3, 4, 1)]
        eng.generate(reqs)
        for r in reqs:
            assert r.done and len(r.out_tokens) == r.max_new_tokens
            assert r.latency_s is not None and r.latency_s >= 0
        assert len(eng.latency_log) == len(reqs)
        assert all(d["latency_s"] >= 0 for d in eng.latency_log)

    def test_refilled_request_keeps_first_token(self, tiny_cfg):
        """Regression: the refill path must record the prefill argmax as
        the request's first generated token, not silently consume it."""
        import jax.numpy as jnp

        from repro.models import init_cache, prefill
        from repro.serving import Request, ServeEngine
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=1, max_seq=64)
        rng = np.random.default_rng(3)
        a = Request(prompt=rng.integers(0, 128, 5).astype(np.int32),
                    max_new_tokens=2)
        b = Request(prompt=rng.integers(0, 128, 5).astype(np.int32),
                    max_new_tokens=3)
        eng.generate([a, b])
        # b was refilled at pos 6 (a's 5-token prompt + 1 decode step);
        # reproduce its batch-1 left-padded prefill independently
        toks = np.zeros((1, 6), np.int32)
        toks[0, 1:] = b.prompt
        cache = init_cache(tiny_cfg, batch=1, max_seq=64)
        logits, _ = prefill(tiny_cfg, params, jnp.asarray(toks), cache)
        assert b.out_tokens[0] == int(jnp.argmax(logits[0]))
        assert len(b.out_tokens) == 3

    def test_slot_refill_zero_token_requests(self, tiny_cfg):
        from repro.serving import Request, ServeEngine
        params = init_params(tiny_cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(tiny_cfg, params, slots=2, max_seq=64)
        reqs = [Request(prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=0),
                Request(prompt=np.arange(4, dtype=np.int32),
                        max_new_tokens=2)]
        eng.generate(reqs)
        assert reqs[0].done and reqs[0].out_tokens == []
        assert reqs[1].done and len(reqs[1].out_tokens) == 2
