"""Cross-session batching tick tests: byte-identical wire streams from
stacked encode ticks, one batched entropy drain across sessions, tick
triggers/latency bounds, failure isolation, and the shared worker-level
codec bank."""

import asyncio
import pathlib
import sys
import time

import numpy as np
import pytest

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))

from repro.core import CodecConfig, calibrate
from repro.core.codec import ChunkStreamDecoder, HeaderCache, flush_decoders
from repro.serving import DecodeBatcher, TickConfig, encode_tick
from repro.serving import batcher as batcher_mod
from repro.transport import (DEFAULT_CHUNK_ELEMS, CloudServer, EdgeClient,
                             bank_cache_stats, clear_bank_cache,
                             encode_frame, shared_bank, tensor_to_frames)
from repro.transport.framing import FT_HEADER

from golden_cases import (CASES, _conv_input, _flat_input,  # noqa: E402
                          _v2_uniform_codec, _v3_tile_codec,
                          _v4_tile2d_codec)


def _ref_payloads(codec, x, cfg: TickConfig):
    return list(codec.encode_stream(x, chunk_elems=cfg.chunk_elems,
                                    coder_mode=cfg.coder_mode))


def _channel_codec(x, n_levels=4):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="channel", channel_axis=-1,
                                 channel_group_size=2),
                     samples=x)


def test_default_chunk_elems_matches_transport():
    # batcher duplicates the constant to keep serving free of the wire
    # layer; they must never drift apart
    assert batcher_mod.DEFAULT_CHUNK_ELEMS == DEFAULT_CHUNK_ELEMS


class TestEncodeTick:
    @pytest.mark.parametrize("n_sessions", [1, 2, 5])
    def test_per_tensor_matches_encode_stream(self, n_sessions):
        codec = _v2_uniform_codec(n_levels=8)
        cfg = TickConfig(chunk_elems=700, coder_mode="rans")
        xs = [_flat_input(n=3000, seed=100 + i) * 0.9
              for i in range(n_sessions)]
        payloads, stats = encode_tick([(codec, x) for x in xs], cfg)
        assert payloads == [_ref_payloads(codec, x, cfg) for x in xs]
        assert stats.entropy_calls == 1
        assert stats.fused_launches == 1   # flat concat: one launch, any K

    def test_per_tensor_mixed_shapes_one_launch(self):
        codec = _v2_uniform_codec()
        cfg = TickConfig(chunk_elems=1 << 12, coder_mode="rans")
        xs = [_flat_input(n=n) for n in (500, 3000, 1700)]
        payloads, stats = encode_tick([(codec, x) for x in xs], cfg)
        assert payloads == [_ref_payloads(codec, x, cfg) for x in xs]
        # per-tensor codecs concatenate flat: shapes mix in ONE launch
        assert stats.fused_launches == 1
        assert stats.stacked_sessions == 3

    def test_channel_granularity_stacks(self):
        x0 = _flat_input(n=1024).reshape(128, 8)
        codec = _channel_codec(x0)
        cfg = TickConfig(chunk_elems=300, coder_mode="rans")
        xs = [x0, 0.5 * x0, x0[::-1].copy()]
        payloads, stats = encode_tick([(codec, x) for x in xs], cfg)
        assert payloads == [_ref_payloads(codec, x, cfg) for x in xs]
        assert stats.fused_launches == 1
        assert stats.stacked_sessions == 3

    def test_tile1d_stackable_vs_ragged(self):
        # stackable: M = 2*32 divides the 32-element blocks
        x = _conv_input(shape=(1, 4, 8, 8))
        codec = _v3_tile_codec(x)
        cfg = TickConfig(chunk_elems=1 << 10, coder_mode="rans")
        payloads, stats = encode_tick([(codec, x), (codec, 2.0 * x)], cfg)
        assert payloads == [_ref_payloads(codec, t, cfg)
                            for t in (x, 2.0 * x)]
        assert stats.fused_launches == 1 and stats.stacked_sessions == 2
        # ragged (golden geometry, M=99 % 32 != 0): per-session launches,
        # but STILL one entropy call for the tick
        xr = _conv_input()
        codec_r = _v3_tile_codec(xr)
        payloads, stats = encode_tick([(codec_r, xr), (codec_r, 0.5 * xr)],
                                      cfg)
        assert payloads == [_ref_payloads(codec_r, t, cfg)
                            for t in (xr, 0.5 * xr)]
        assert stats.fused_launches == 2 and stats.stacked_sessions == 0
        assert stats.entropy_calls == 1

    @pytest.mark.parametrize("use_ecsq", [False, True])
    def test_tile2d_stackable(self, use_ecsq):
        # H = 8 divides bh = 4 -> stacked (K*H, W) grid
        x = _conv_input(shape=(1, 4, 8, 9))
        codec = _v4_tile2d_codec(x, use_ecsq=use_ecsq)
        cfg = TickConfig(chunk_elems=1 << 10, coder_mode="rans")
        xs = [x, 0.25 * x, 4.0 * x]
        payloads, stats = encode_tick([(codec, t) for t in xs], cfg)
        assert payloads == [_ref_payloads(codec, t, cfg) for t in xs]
        assert stats.fused_launches == 1 and stats.stacked_sessions == 3

    def test_golden_cases_byte_identical(self):
        # every re-encodable conformance case, two sessions each: the
        # batched path must write the exact v2/v3/v4 bytes of the
        # per-session encoder (ragged tile cases cover the fallback)
        for case in CASES:
            if case.decode_only or case.coder_mode != "rans":
                continue
            x = case.make_input()
            codec = case.make_codec(x)
            chunk = case.chunk_elems or DEFAULT_CHUNK_ELEMS
            cfg = TickConfig(chunk_elems=chunk, coder_mode="rans")
            payloads, stats = encode_tick([(codec, x), (codec, 0.5 * x)],
                                          cfg)
            ref = [_ref_payloads(codec, t, cfg) for t in (x, 0.5 * x)]
            assert payloads == ref, case.name
            assert stats.entropy_calls == 1, case.name

    def test_mixed_rungs_and_shapes_one_tick(self):
        flat = _flat_input(n=2048)
        conv = _conv_input(shape=(1, 4, 8, 9))
        items = [
            (_v2_uniform_codec(4), flat),
            (_v2_uniform_codec(8), 0.5 * flat),
            (_channel_codec(flat.reshape(256, 8)), flat.reshape(256, 8)),
            (_v4_tile2d_codec(conv), conv),
        ]
        cfg = TickConfig(chunk_elems=600, coder_mode="rans")
        payloads, stats = encode_tick(items, cfg)
        for (codec, x), got in zip(items, payloads):
            assert got == _ref_payloads(codec, x, cfg)
        assert stats.entropy_calls == 1     # mixed n_levels share the call
        assert stats.groups == 4

    def test_max_batch_splits_launches(self):
        codec = _channel_codec(_flat_input(n=1024).reshape(128, 8))
        cfg = TickConfig(chunk_elems=1 << 10, coder_mode="rans",
                         max_batch=2)
        xs = [_flat_input(n=1024, seed=i).reshape(128, 8)
              for i in range(5)]
        payloads, stats = encode_tick([(codec, x) for x in xs], cfg)
        assert payloads == [_ref_payloads(codec, x, cfg) for x in xs]
        # ceil(5/2) = 3 launches: two stacked pairs + one singleton
        assert stats.fused_launches == 3
        assert stats.stacked_sessions == 4
        assert stats.entropy_calls == 1


class TestDecodeBatcher:
    def _streams(self, specs, chunk_elems=500):
        """[(codec, x)] -> (decoders fed out-of-order, refs)."""
        decs, refs = [], []
        for codec, x in specs:
            payloads = list(codec.encode_stream(x, chunk_elems=chunk_elems,
                                                coder_mode="rans"))
            dec = ChunkStreamDecoder(payloads[0], chunk_batch=0)
            for p in reversed(payloads[1:]):    # out-of-order arrival
                dec.add_chunk(p)
            decs.append(dec)
            refs.append(codec.decode(codec.encode(x, coder_mode="rans"),
                                     shape=x.shape))
        return decs, refs

    def test_cross_session_flush_bit_exact(self):
        flat = _flat_input(n=2600)
        conv = _conv_input(shape=(1, 4, 8, 9))
        specs = [(_v2_uniform_codec(4), flat),
                 (_v2_uniform_codec(8), 0.7 * flat),
                 (_channel_codec(flat[:2048].reshape(256, 8)),
                  flat[:2048].reshape(256, 8)),
                 (_v4_tile2d_codec(conv, use_ecsq=True), conv)]
        decs, refs = self._streams(specs)
        batcher = DecodeBatcher()
        for d in decs:
            batcher.note(d)
        assert batcher.pending_sessions == len(decs)
        failures = batcher.drain()
        assert failures == []
        assert batcher.counters["entropy_calls"] == 1
        assert batcher.counters["sessions"] == len(decs)
        for d, (codec, x), ref in zip(decs, specs, refs):
            np.testing.assert_array_equal(d.finish(x.shape), ref)

    def test_corrupt_session_isolated(self):
        flat = _flat_input(n=2600)
        specs = [(_v2_uniform_codec(4), flat),
                 (_v2_uniform_codec(8), 0.7 * flat)]
        decs, refs = self._streams(specs)
        # a third session whose chunk blob is truncated garbage
        codec = _v2_uniform_codec(4)
        payloads = list(codec.encode_stream(flat, chunk_elems=500,
                                            coder_mode="rans"))
        bad = ChunkStreamDecoder(payloads[0], chunk_batch=0)
        bad.add_chunk(payloads[1][:5])
        n_chunks, n_elems, failures = flush_decoders(decs + [bad])
        assert [d for d, _ in failures] == [bad]
        for d, (codec, x), ref in zip(decs, specs, refs):
            np.testing.assert_array_equal(d.finish(x.shape), ref)

    def test_discard_leaves_others_intact(self):
        flat = _flat_input(n=2600)
        specs = [(_v2_uniform_codec(4), flat),
                 (_v2_uniform_codec(8), 0.7 * flat)]
        decs, refs = self._streams(specs)
        batcher = DecodeBatcher()
        for d in decs:
            batcher.note(d)
        batcher.discard(decs[0])
        assert batcher.pending_sessions == 1
        assert batcher.drain() == []
        np.testing.assert_array_equal(decs[1].finish(specs[1][1].shape),
                                      refs[1])


@pytest.fixture(scope="module")
def features():
    rng = np.random.default_rng(7)
    mu = np.linspace(0.0, 6.0, 16).astype(np.float32)
    return (mu[None, :] + rng.exponential(1.0, (512, 16))).astype(np.float32)


def _live_codec(features, n_levels=8):
    return calibrate(CodecConfig(n_levels=n_levels, clip_mode="minmax",
                                 constrain_cmin_zero=False,
                                 granularity="channel", channel_axis=-1,
                                 channel_group_size=4), samples=features)


class TestServerTick:
    def test_concurrent_sessions_tick_counters(self, features):
        codec = _live_codec(features)

        async def run():
            async with CloudServer(echo_features=True) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    tensors = [features, 0.5 * features, 2.0 * features]
                    res = await asyncio.gather(
                        *[client.submit(t) for t in tensors])
                    return res, srv.counters

        results, counters = asyncio.run(run())
        for t, res in zip([features, 0.5 * features, 2.0 * features],
                          results):
            ref = codec.decode(codec.encode(t), shape=t.shape)
            np.testing.assert_array_equal(np.asarray(res.arrays[0]), ref)
        assert counters["sessions_served"] == 3
        assert counters["ticks"] >= 1
        assert counters["entropy_calls"] >= 1
        assert counters["queue_depth"] == 0
        assert counters["bpe_avg"] > 0
        # same codec + shape -> same header bytes: parsed once, shared
        assert counters["header_cache"]["hits"] >= 2
        assert counters["header_cache"]["misses"] >= 1

    def test_max_chunks_trigger_beats_long_window(self, features):
        # max_wait_s is effectively infinite; completion must come from
        # the max_chunks drain trigger + ready-with-nothing-pending rule
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=60.0, max_chunks=1)

        async def run():
            async with CloudServer(echo_features=True, tick=tick) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    return await client.submit(features)

        t0 = time.perf_counter()
        res = asyncio.run(run())
        assert time.perf_counter() - t0 < 30.0
        ref = codec.decode(codec.encode(features), shape=features.shape)
        np.testing.assert_array_equal(np.asarray(res.arrays[0]), ref)

    def test_tick_window_latency_bound(self, features):
        # a lone session's END must not wait out more than ~max_wait_s
        # plus processing time; generous margin for CI schedulers
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.01, max_chunks=1 << 30)

        async def run():
            async with CloudServer(echo_features=True, tick=tick) as srv:
                async with EdgeClient("127.0.0.1", srv.port,
                                      codec=codec) as client:
                    t0 = time.perf_counter()
                    await client.submit(features)
                    return time.perf_counter() - t0

        assert asyncio.run(run()) < 10.0

    def test_disconnect_mid_tick_leaves_others_intact(self, features):
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.05, max_chunks=1 << 30)

        async def run():
            async with CloudServer(echo_features=True, tick=tick) as srv:
                # connection A: half a tensor stream, then vanish
                frames = list(tensor_to_frames(codec, features, session=0,
                                               chunk_elems=600))
                reader_a, writer_a = await asyncio.open_connection(
                    "127.0.0.1", srv.port)
                for fb in frames[:max(2, len(frames) // 2)]:
                    writer_a.write(fb)
                await writer_a.drain()
                await asyncio.sleep(0.01)   # let the server buffer them
                writer_a.close()
                await writer_a.wait_closed()
                # connection B: a full submit, concurrently mid-tick
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    res = await client.submit(0.5 * features)
                await asyncio.sleep(0.2)    # tick drains, A forgotten
                return res, srv.counters

        res, counters = asyncio.run(run())
        ref = codec.decode(codec.encode(0.5 * features),
                           shape=features.shape)
        np.testing.assert_array_equal(np.asarray(res.arrays[0]), ref)
        assert counters["sessions_served"] == 1
        assert counters["queue_depth"] == 0     # A's decoder was purged
        assert counters["decode_errors"] == 0

    def test_legacy_path_unchanged(self, features):
        codec = _live_codec(features)

        async def run():
            async with CloudServer(echo_features=True, tick=None) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600) as client:
                    res = await client.submit(features)
                    return res, srv.counters

        res, counters = asyncio.run(run())
        ref = codec.decode(codec.encode(features), shape=features.shape)
        np.testing.assert_array_equal(np.asarray(res.arrays[0]), ref)
        # legacy counters stay minimal: no tick metrics
        assert counters["sessions_served"] == 1
        assert set(counters) == {"sessions_served", "open_connections"}


class TestClientTick:
    def test_coalesced_submits_bit_exact(self, features):
        codec = _live_codec(features)
        tick = TickConfig(max_wait_s=0.01, max_batch=8)

        async def run():
            async with CloudServer(echo_features=True) as srv:
                async with EdgeClient("127.0.0.1", srv.port, codec=codec,
                                      chunk_elems=600,
                                      tick=tick) as client:
                    tensors = [features, 0.5 * features, 2.0 * features]
                    res = await asyncio.gather(
                        *[client.submit(t) for t in tensors])
                    return res, dict(client.encode_counters)

        results, counters = asyncio.run(run())
        for t, res in zip([features, 0.5 * features, 2.0 * features],
                          results):
            ref = codec.decode(codec.encode(t), shape=t.shape)
            np.testing.assert_array_equal(np.asarray(res.arrays[0]), ref)
            assert res.coded_bytes > 0
        assert counters["sessions"] == 3
        assert counters["ticks"] >= 1
        assert counters["entropy_calls"] == counters["ticks"]


class TestSharedBank:
    def test_hit_miss_and_identity(self, features):
        clear_bank_cache()
        cfg = CodecConfig(n_levels=8, clip_mode="minmax",
                          constrain_cmin_zero=False)
        try:
            b1 = shared_bank(cfg, features.reshape(-1))
            b2 = shared_bank(cfg, features.reshape(-1))
            assert b1 is b2
            assert bank_cache_stats() == {"hits": 1, "misses": 1,
                                          "entries": 1}
            # different samples -> different bank
            b3 = shared_bank(cfg, 2.0 * features.reshape(-1))
            assert b3 is not b1
            assert bank_cache_stats()["entries"] == 2
        finally:
            clear_bank_cache()


class TestHeaderCache:
    def test_parse_once_per_distinct_header(self, features):
        codec = _live_codec(features)
        payloads = list(codec.encode_stream(features, chunk_elems=600))
        hdr = payloads[0]
        cache = HeaderCache(maxsize=4)
        # deferred decoders wired to one cache share the parsed header
        dec1 = ChunkStreamDecoder(hdr, chunk_batch=0, header_cache=cache)
        dec2 = ChunkStreamDecoder(hdr, chunk_batch=0, header_cache=cache)
        assert dec1.header is dec2.header
        assert cache.stats == {"hits": 1, "misses": 1, "entries": 1}
        # a different rung -> different header bytes -> fresh parse
        other = list(_live_codec(features, n_levels=4)
                     .encode_stream(features, chunk_elems=600))
        dec3 = ChunkStreamDecoder(other[0], chunk_batch=0,
                                  header_cache=cache)
        assert dec3.header is not dec1.header
        assert cache.stats == {"hits": 1, "misses": 2, "entries": 2}
